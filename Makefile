PYTHON ?= python
export PYTHONPATH := src:$(PYTHONPATH)
# where bench smokes drop their machine-readable BENCH_*.json artifacts
BENCH_JSON_DIR ?= out
export BENCH_JSON_DIR

.PHONY: test test-fast bench-smoke bench-smoke-async bench-smoke-links \
	bench-smoke-kernels bench-smoke-scale dryrun-smoke lint lint-deep \
	lint-deep-full

# tier-1 verify: the full test suite
test:
	$(PYTHON) -m pytest -x -q

# skip the long end-to-end training tests (the CI fast PR gate)
test-fast:
	$(PYTHON) -m pytest -x -q -m "not slow"

# kernel microbenchmarks + the cheapest experiment benches; every bench
# also lands as $(BENCH_JSON_DIR)/BENCH_<name>.json (the CI artifact)
bench-smoke:
	$(PYTHON) -m benchmarks.run --only kernels,fig4 --json $(BENCH_JSON_DIR)

# kernel-dispatch smoke + gate: re-measure the kernels bench, then
# assert the dispatched path never loses to the jnp oracle (ratio
# <= 1 + noise band) and that the headline ops (neighbor_mix,
# group_norm) beat the old interpret path by the required speedup
bench-smoke-kernels:
	$(PYTHON) -m benchmarks.run --only kernels --json $(BENCH_JSON_DIR)
	$(PYTHON) -m benchmarks.report --gate $(BENCH_JSON_DIR)/BENCH_kernels.json

# asynchronous-gossip backend smoke: sync D-PSGD vs AD-PSGD on the
# geo-wan fabric; asserts the async ledger strictly beats sync wall-clock
bench-smoke-async:
	$(PYTHON) -m benchmarks.fig_topology --smoke-async

# stochastic-link smoke: transient Markov stragglers on an all-LAN
# fabric; asserts async AD-PSGD strictly beats sync D-PSGD wall-clock
# at accuracy within noise (the occasional-straggler headline claim)
bench-smoke-links:
	$(PYTHON) -m benchmarks.fig_topology --smoke-links

# fabric scale smoke + gate: price 50 gossip rounds on the 10k-node
# hier-cliques fabric (sampled links, 10% participation, ledger-only)
# and assert the array-native ledger stays inside its host-time budget;
# drops $(BENCH_JSON_DIR)/BENCH_scale.json for the cross-commit gate
bench-smoke-scale:
	$(PYTHON) -m benchmarks.fig_topology --smoke-scale
	$(PYTHON) -m benchmarks.report --gate $(BENCH_JSON_DIR)/BENCH_scale.json

# launch-path gossip smoke: lower + compile the pod-gossip train step on
# a tiny CPU mesh; fails if the cross-pod exchange stops lowering to
# pod-axis collective-permutes (ring + tv-dcliques fabrics).
# --strict-audit: ANY graph-audit finding aborts, not just gossip ones.
dryrun-smoke:
	$(PYTHON) -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k \
	  --reduced --mesh 2,2,2 --strategy dpsgd --topology ring --strict-audit
	$(PYTHON) -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k \
	  --reduced --mesh 2,2,2 --strategy adpsgd --topology tv-dcliques \
	  --strict-audit

# repo static analysis (hard CI gate): AST invariant lints, kernel
# registry parity, the jaxpr dataflow sweep over every strategy x
# topology combo (trace-only, cheap), and the HLO graph audit of the
# compiled pod-gossip smoke combo.  Findings land in
# $(BENCH_JSON_DIR)/AUDIT.json (uploaded with the bench artifacts);
# suppress per-line with `# repro-allow: <rule>` or grandfather via
# `python -m repro.analysis --update-baseline`.
lint-deep:
	$(PYTHON) -m repro.analysis --fail-on-stale \
	  --json $(BENCH_JSON_DIR)/AUDIT.json

# the full matrix: additionally compile + HLO-audit EVERY combo (22
# graphs, minutes not seconds) and emit the complete coverage matrix —
# the CI full job's gate
lint-deep-full:
	$(PYTHON) -m repro.analysis --all-combos --fail-on-stale \
	  --json $(BENCH_JSON_DIR)/AUDIT.json

# ruff (pinned in requirements.txt); containers without it fall back to
# the old pyflakes-level compileall check instead of failing the target
lint:
	@if $(PYTHON) -m ruff --version >/dev/null 2>&1; then \
	  $(PYTHON) -m ruff check src benchmarks examples tests; \
	else \
	  echo "ruff not installed; falling back to compileall"; \
	  $(PYTHON) -m compileall -q src benchmarks examples tests; \
	fi
