PYTHON ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test test-fast bench-smoke bench-smoke-async dryrun-smoke lint

# tier-1 verify: the full test suite
test:
	$(PYTHON) -m pytest -x -q

# skip the long end-to-end training tests
test-fast:
	$(PYTHON) -m pytest -x -q -m "not slow"

# kernel microbenchmarks + the cheapest experiment benches
bench-smoke:
	$(PYTHON) -m benchmarks.run --only kernels,fig4

# asynchronous-gossip backend smoke: sync D-PSGD vs AD-PSGD on the
# geo-wan fabric; asserts the async ledger strictly beats sync wall-clock
bench-smoke-async:
	$(PYTHON) -m benchmarks.fig_topology --smoke-async

# launch-path gossip smoke: lower + compile the pod-gossip train step on
# a tiny CPU mesh; fails if the cross-pod exchange stops lowering to
# pod-axis collective-permutes (ring + tv-dcliques fabrics)
dryrun-smoke:
	$(PYTHON) -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k \
	  --reduced --mesh 2,2,2 --strategy dpsgd --topology ring
	$(PYTHON) -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k \
	  --reduced --mesh 2,2,2 --strategy adpsgd --topology tv-dcliques

# pyflakes-level check: every module compiles
lint:
	$(PYTHON) -m compileall -q src benchmarks examples tests
