"""Shared experiment setup for the paper-figure benchmarks.

The synthetic-CIFAR stand-in is tuned so BSP/IID reaches ~1.0 accuracy
(matching the paper's methodology: validate the IID baseline first, then
attribute any drop to the decentralized algorithm / data skew)."""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

from repro.core.partition import partition_label_skew
from repro.data.synthetic import synth_images

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "results")

# data difficulty: class_sep/noise chosen so BN pathology and algorithm
# accuracy gaps are visible at CPU scale (see EXPERIMENTS.md §Setup)
DATA = dict(noise=0.8, class_sep=0.35)
TRAIN = dict(batch=20, lr=0.02, eval_every=200)
# norm-free nets destabilize at 0.02 under label skew (logit collapse);
# the paper likewise tunes lr per model (App. C: AlexNet 10x lower)
MODEL_LR = {"lenet": 0.005, "alexnet-s": 0.005}
K = 5


def train_args(model: str):
    args = dict(TRAIN)
    args["lr"] = MODEL_LR.get(model, args["lr"])
    return args


def make_data(n_train: int = 4000, n_val: int = 1000):
    ds = synth_images(n_train, seed=0, **DATA)
    val = synth_images(n_val, seed=99, **DATA)
    return ds, val


def make_parts(ds, skew: float, n_nodes: int = K, seed: int = 1):
    idx = partition_label_skew(ds.y, n_nodes, skew, seed=seed)
    return [(ds.x[i], ds.y[i]) for i in idx]


def save_rows(name: str, rows: List[Dict]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    return path


def git_commit() -> str:
    """Best-effort commit id for bench provenance: CI env var first,
    then git; empty string when neither is available."""
    sha = os.environ.get("GITHUB_SHA", "")
    if sha:
        return sha
    import subprocess
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(__file__), timeout=10,
        ).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        return ""


def save_bench_json(name: str, rows: List[Dict], *, derived: str = "",
                    us_per_call: float = 0.0,
                    out_dir: str = None) -> str:
    """Machine-readable per-bench artifact (``BENCH_<name>.json``): the
    perf-trajectory record CI uploads per commit.  Writes to ``out_dir``
    or ``$BENCH_JSON_DIR``; silently a no-op when neither is set, so
    local bench runs don't litter the tree."""
    out_dir = out_dir or os.environ.get("BENCH_JSON_DIR")
    if not out_dir:
        return ""
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(dict(name=name, commit=git_commit(),
                       timestamp=time.time(), us_per_call=us_per_call,
                       derived=derived, rows=rows), f, indent=1)
    return path


def load_rows(name: str):
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return None


def timed(fn, *args, n_warmup: int = 2, n_iter: int = 10, **kw) -> float:
    """us per call."""
    for _ in range(n_warmup):
        fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(n_iter):
        fn(*args, **kw)
    return (time.perf_counter() - t0) / n_iter * 1e6
