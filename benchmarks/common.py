"""Shared experiment setup for the paper-figure benchmarks.

The synthetic-CIFAR stand-in is tuned so BSP/IID reaches ~1.0 accuracy
(matching the paper's methodology: validate the IID baseline first, then
attribute any drop to the decentralized algorithm / data skew)."""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core.partition import partition_label_skew
from repro.data.synthetic import synth_images

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "results")

# data difficulty: class_sep/noise chosen so BN pathology and algorithm
# accuracy gaps are visible at CPU scale (see EXPERIMENTS.md §Setup)
DATA = dict(noise=0.8, class_sep=0.35)
TRAIN = dict(batch=20, lr=0.02, eval_every=200)
# norm-free nets destabilize at 0.02 under label skew (logit collapse);
# the paper likewise tunes lr per model (App. C: AlexNet 10x lower)
MODEL_LR = {"lenet": 0.005, "alexnet-s": 0.005}
K = 5


def train_args(model: str):
    args = dict(TRAIN)
    args["lr"] = MODEL_LR.get(model, args["lr"])
    return args


def make_data(n_train: int = 4000, n_val: int = 1000):
    ds = synth_images(n_train, seed=0, **DATA)
    val = synth_images(n_val, seed=99, **DATA)
    return ds, val


def make_parts(ds, skew: float, n_nodes: int = K, seed: int = 1):
    idx = partition_label_skew(ds.y, n_nodes, skew, seed=seed)
    return [(ds.x[i], ds.y[i]) for i in idx]


def save_rows(name: str, rows: List[Dict]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    return path


def load_rows(name: str):
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return None


def timed(fn, *args, n_warmup: int = 2, n_iter: int = 10, **kw) -> float:
    """us per call."""
    for _ in range(n_warmup):
        fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(n_iter):
        fn(*args, **kw)
    return (time.perf_counter() - t0) / n_iter * 1e6
