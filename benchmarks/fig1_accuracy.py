"""Figure 1 analogue: Top-1 validation accuracy for image classification,
4 CNNs x 4 algorithms x {IID, non-IID}, K=5 partitions.

Paper claim reproduced: the three communication-reducing algorithms retain
BSP accuracy in the IID setting but lose significant accuracy under 100%
label skew; BSP itself loses accuracy for the BatchNorm model."""
from __future__ import annotations

from repro.configs.base import CommConfig
from repro.configs.cnn_zoo import CNN_ZOO
from repro.core.trainer import train_decentralized

from benchmarks.common import make_data, make_parts, save_rows, train_args

MODELS = ("lenet", "bn-lenet", "alexnet-s", "resnet-s")
ALGOS = ("bsp", "gaia", "fedavg", "dgc")
# paper §4.1 hyper-parameters: T0=10%, Iter_local=20, E_warm~ (we use the
# final 99.9% sparsity with a short warmup scaled to our step budget)
COMM = CommConfig(gaia_t0=0.10, iter_local=20, dgc_sparsity=0.999,
                  dgc_warmup_epochs=1)


def run(quick: bool = False):
    steps = 200 if quick else 350
    ds, val = make_data(2000 if quick else 4000)
    rows = []
    for model in (MODELS[:2] if quick else MODELS):
        for algo in ALGOS:
            for skew in (0.0, 1.0):
                parts = make_parts(ds, skew)
                r = train_decentralized(
                    CNN_ZOO[model], algo, parts, (val.x, val.y), comm=COMM,
                    steps=steps, **train_args(model))
                rows.append(dict(model=model, algo=algo, skew=skew,
                                 val_acc=r.val_acc,
                                 comm_savings=r.comm_savings))
                print(f"[fig1] {model} {algo} skew={skew}: "
                      f"acc={r.val_acc:.3f} savings={r.comm_savings:.1f}x",
                      flush=True)
    save_rows("fig1", rows)
    return rows


if __name__ == "__main__":
    run()
