"""Figure 2/20 analogue: real-world geo-skew (Flickr-Mammal stand-in).

Classes have home regions (Table 1's 32-92%% share pattern); node k holds
region k's images.  Claim reproduced: the real-world skew costs accuracy vs
the artificial IID split, but less than 100% label skew (most labels exist
in all regions); subcontinent-level partitioning (K=13) hurts more."""
from __future__ import annotations

import numpy as np

from repro.configs.base import CommConfig
from repro.configs.cnn_zoo import CNN_ZOO
from repro.core.partition import partition_by_region, partition_label_skew
from repro.core.trainer import train_decentralized
from repro.data.synthetic import synth_geo_images

from benchmarks.common import TRAIN, save_rows

COMM = CommConfig(gaia_t0=0.10, iter_local=20)


def run(quick: bool = False):
    steps = 200 if quick else 350
    n = 3000 if quick else 6000
    rows = []
    for n_regions, tag in (((5, "continent"),) if quick
                           else ((5, "continent"), (13, "subcontinent"))):
        ds, region = synth_geo_images(n, n_regions=n_regions, n_classes=15,
                                      home_share=0.7, seed=0)
        val_mask = np.arange(n) % 20 == 0            # 5% validation
        tr_mask = ~val_mask
        val = (ds.x[val_mask], ds.y[val_mask])
        for algo in ("bsp", "gaia", "fedavg"):
            for setting in ("noniid", "iid"):
                if setting == "noniid":
                    idx = partition_by_region(region, n_regions)
                    idx = [i[tr_mask[i]] for i in idx]
                else:
                    idx = partition_label_skew(ds.y[tr_mask], n_regions, 0.0,
                                               seed=2)
                    base = np.where(tr_mask)[0]
                    idx = [base[i] for i in idx]
                parts = [(ds.x[i], ds.y[i]) for i in idx]
                r = train_decentralized(
                    CNN_ZOO["gn-lenet"], algo, parts, val, comm=COMM,
                    steps=steps, **TRAIN)
                rows.append(dict(level=tag, algo=algo, setting=setting,
                                 val_acc=r.val_acc,
                                 comm_savings=r.comm_savings))
                print(f"[fig2] {tag} {algo} {setting}: acc={r.val_acc:.3f}",
                      flush=True)
    save_rows("fig2", rows)
    return rows


if __name__ == "__main__":
    run()
