"""Figure 4 analogue: minibatch-mean divergence of the first BN layer of
BN-LeNet between partitions, IID vs non-IID.

Paper claim reproduced: mu_B divergence is several-fold larger in the
non-IID setting — the mechanism behind BN's failure under BSP."""
from __future__ import annotations

import jax
import numpy as np

from repro.configs.cnn_zoo import CNN_ZOO
from repro.core.divergence import bn_divergence
from repro.data.pipeline import DecentralizedLoader
from repro.models.cnn import init_cnn

from benchmarks.common import make_data, make_parts, save_rows


def run(quick: bool = False):
    ds, _ = make_data()
    cfg = CNN_ZOO["bn-lenet"]
    params, _ = init_cnn(jax.random.PRNGKey(0), cfg)
    rows = []
    n_batches = 20 if quick else 100   # paper averages over 100 minibatches
    for skew, name in ((0.0, "iid"), (1.0, "noniid")):
        parts = make_parts(ds, skew, n_nodes=2)      # paper uses two P_k
        loader = DecentralizedLoader(parts, batch=20, seed=0)
        mu_acc = None
        for _ in range(n_batches):
            xs, _ = loader.next_stacked()
            mu_d, var_d = bn_divergence(params, cfg, list(xs), layer=0)
            mu_acc = mu_d if mu_acc is None else mu_acc + mu_d
        mu_avg = mu_acc / n_batches
        for ch, v in enumerate(mu_avg):
            rows.append(dict(setting=name, channel=ch,
                             mu_divergence=float(v)))
        print(f"[fig4] {name}: mean mu_B divergence "
              f"{float(np.mean(mu_avg)):.3f} "
              f"(range {mu_avg.min():.3f}-{mu_avg.max():.3f})", flush=True)
    save_rows("fig4", rows)
    return rows


if __name__ == "__main__":
    run()
