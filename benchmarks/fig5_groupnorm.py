"""Figure 5 + Table 9 analogue: BatchNorm vs GroupNorm vs BatchReNorm for
(BN/GN/BRN)-LeNet across all four algorithms, non-IID setting.

Paper claims reproduced: GroupNorm recovers BSP's non-IID loss and helps
every decentralized algorithm; BatchReNorm sits in between."""
from __future__ import annotations

from repro.configs.base import CommConfig
from repro.configs.cnn_zoo import CNN_ZOO
from repro.core.trainer import train_decentralized

from benchmarks.common import make_data, make_parts, save_rows, train_args

COMM = CommConfig(gaia_t0=0.10, iter_local=20, dgc_sparsity=0.999,
                  dgc_warmup_epochs=1)


def run(quick: bool = False):
    steps = 200 if quick else 350
    ds, val = make_data(2000 if quick else 4000)
    models = ("bn-lenet", "gn-lenet") if quick else \
        ("bn-lenet", "gn-lenet", "brn-lenet")
    algos = ("bsp", "gaia") if quick else ("bsp", "gaia", "fedavg", "dgc")
    rows = []
    for model in models:
        for algo in algos:
            for skew in (0.0, 1.0):
                parts = make_parts(ds, skew)
                r = train_decentralized(
                    CNN_ZOO[model], algo, parts, (val.x, val.y), comm=COMM,
                    steps=steps, **train_args(model))
                rows.append(dict(model=model, algo=algo, skew=skew,
                                 val_acc=r.val_acc))
                print(f"[fig5] {model} {algo} skew={skew}: "
                      f"acc={r.val_acc:.3f}", flush=True)
    save_rows("fig5", rows)
    return rows


if __name__ == "__main__":
    run()
