"""Figure 6 analogue: GN-LeNet accuracy vs degree of skew (20-100%) for the
three decentralized algorithms.

Paper claims reproduced: partial skew already costs accuracy, and the loss
grows monotonically (noisily) with the skew fraction."""
from __future__ import annotations

from repro.configs.base import CommConfig
from repro.configs.cnn_zoo import CNN_ZOO
from repro.core.trainer import train_decentralized

from benchmarks.common import TRAIN, make_data, make_parts, save_rows

COMM = CommConfig(gaia_t0=0.10, iter_local=20, dgc_sparsity=0.999,
                  dgc_warmup_epochs=1)


def run(quick: bool = False):
    steps = 200 if quick else 350
    ds, val = make_data(2000 if quick else 4000)
    skews = (0.0, 0.4, 0.8, 1.0) if quick else (0.0, 0.2, 0.4, 0.6, 0.8, 1.0)
    rows = []
    for algo in ("gaia", "fedavg", "dgc"):
        for skew in skews:
            parts = make_parts(ds, skew)
            r = train_decentralized(
                CNN_ZOO["gn-lenet"], algo, parts, (val.x, val.y), comm=COMM,
                steps=steps, **TRAIN)
            rows.append(dict(algo=algo, skew=skew, val_acc=r.val_acc))
            print(f"[fig6] {algo} skew={skew}: acc={r.val_acc:.3f}",
                  flush=True)
    save_rows("fig6", rows)
    return rows


if __name__ == "__main__":
    run()
