"""Figure 8 analogue: SkewScout communication savings over BSP, vs the
unrealistic Oracle, across degrees of skew, training GN-LeNet with Gaia.

Paper claims reproduced: SkewScout saves large factors over BSP at equal
accuracy (more under mild skew), and stays within ~1.1-1.5x of Oracle's
communication."""
from __future__ import annotations

from repro.configs.base import CommConfig
from repro.configs.cnn_zoo import CNN_ZOO
from repro.core.skewscout import THETA_LADDERS
from repro.core.trainer import train_decentralized

from benchmarks.common import TRAIN, make_data, make_parts, save_rows


def run(quick: bool = False):
    steps = 300 if quick else 400
    ds, val = make_data(2000 if quick else 4000)
    skews = (0.2, 1.0) if quick else (0.2, 0.6, 1.0)
    cfg = CNN_ZOO["gn-lenet"]
    rows = []
    for skew in skews:
        parts = make_parts(ds, skew)
        # BSP reference accuracy + cost
        bsp = train_decentralized(cfg, "bsp", parts, (val.x, val.y),
                                  steps=steps, **TRAIN)
        target = bsp.val_acc - 0.02            # "same accuracy as BSP" band

        # SkewScout (one pass, adaptive theta; travel period scaled to our
        # shorter step budget — paper uses 500 minibatches)
        comm = CommConfig(skewscout=True, travel_every=max(25, steps // 12),
                          sigma_al=0.05, lambda_al=50.0, lambda_c=1.0,
                          tuner="hill")
        ss = train_decentralized(cfg, "gaia", parts, (val.x, val.y),
                                 comm=comm, steps=steps,
                                 theta_start_index=3, **TRAIN)

        # Oracle: run every theta, pick cheapest one reaching target
        oracle_savings, oracle_theta = 1.0, None
        ladder = THETA_LADDERS["gaia"][::2]
        for t0 in ladder:
            r = train_decentralized(
                cfg, "gaia", parts, (val.x, val.y),
                comm=CommConfig(gaia_t0=t0), steps=steps, **TRAIN)
            if r.val_acc >= target and r.comm_savings > oracle_savings:
                oracle_savings, oracle_theta = r.comm_savings, t0
        rows.append(dict(skew=skew, bsp_acc=bsp.val_acc,
                         skewscout_acc=ss.val_acc,
                         skewscout_savings=ss.comm_savings,
                         skewscout_met_target=bool(ss.val_acc >= target),
                         oracle_savings=oracle_savings,
                         oracle_theta=oracle_theta,
                         thetas=[h.theta for h in ss.skewscout_history],
                         accuracy_losses=[round(h.accuracy_loss, 3)
                                          for h in ss.skewscout_history]))
        print(f"[fig8] skew={skew}: bsp={bsp.val_acc:.3f} "
              f"skewscout={ss.val_acc:.3f} ({ss.comm_savings:.1f}x) "
              f"oracle={oracle_savings:.1f}x (T0={oracle_theta})", flush=True)
    save_rows("fig8", rows)
    return rows


if __name__ == "__main__":
    run()
