"""Topology x skew sweep for gossip (D-PSGD) training, plus a schedule
column at fixed full skew.

The scenario-diversity unlock on top of the paper: the same algorithm on
the same partitions, varying only *who talks to whom*.  Under label skew,
sparse graphs (ring) pay in accuracy for their bandwidth savings, label-
aware D-Cliques recover most of the gap at a fraction of the edges, and
the geo-WAN hierarchy shows the LAN/WAN traffic split the flat
``comm_floats`` scalar could never express.  Link costs use the geo-wan
profile so WAN bytes and the simulated step time diverge across graphs.

The schedule column then varies *when* the edges exist: constant
D-Cliques vs the one-peer-per-round time-varying variant vs EquiTopo
random matchings, reporting WAN floats x final accuracy at full skew —
the paper-level claim that a time-varying fabric keeps the mixing rate
while shedding most per-round (and especially WAN) traffic.

The sync-vs-async column fixes the fabric (geo-wan, full label skew)
and varies *who waits*: synchronous D-PSGD (every round ends at the
slowest link) vs AD-PSGD with bounded-staleness mixing priced by the
async ledger's per-edge clocks — accuracy within noise at a fraction of
the simulated wall-clock, plus the per-node idle time the straggler was
costing everyone.

The straggler-rate column (``run_straggler`` / ``--smoke-links``) drops
the persistent WAN gap entirely: an all-LAN fabric under the stochastic
link model, sweeping the Markov transient-slowdown rate.  Sync pays
every round's straggler (sum of per-round maxes); async only pays it on
the link it hit (max of per-edge sums) — AD-PSGD's actual headline
claim, unmeasurable under class-constant link pricing.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs.base import CommConfig, FabricConfig, LinkConfig
from repro.configs.cnn_zoo import CNN_ZOO
from repro.core.partition import partition_label_skew
from repro.core.trainer import train_decentralized
from repro.data.synthetic import synth_images

from benchmarks.common import save_bench_json, save_rows

K = 10
N_CLASSES = 5          # < K so D-Cliques can span the label space
# harder than the fig1/fig6 setting (lower separation, higher noise,
# larger lr): sparse-graph consensus lag must actually cost accuracy
# under skew, or every topology trivially matches BSP
DATA = dict(noise=1.2, class_sep=0.22, n_classes=N_CLASSES)
LR = 0.05
TOPOLOGIES = ("ring", "full", "dcliques", "geo-wan")
# schedule column: same greedy cliques, different *per-round* edges.
# 3 classes over 9 nodes => 3 cliques, so constant D-Cliques keeps 3 WAN
# edges live every round while the time-varying variant rotates one; a
# 2-clique split would hide the WAN win (both fabrics would have 1 WAN
# edge).  One-peer-per-round mixes less per step, so the column runs at
# a gentler lr than the dense-graph sweep.
SCHED_K, SCHED_CLASSES, SCHED_LR = 9, 3, 0.02
SCHED_DATA = dict(noise=0.8, class_sep=0.35, n_classes=SCHED_CLASSES)
SCHEDULES = ("dcliques", "tv-dcliques", "random-matching")
# sync-vs-async column: same geo-wan fabric + full skew, the only
# difference is whether rounds stop-and-wait for the slowest link
ASYNC_MODES = (("sync", "dpsgd", False), ("async", "adpsgd", True))
# straggler column: all-LAN fabric (no persistent WAN gap), transient
# Markov slowdowns only — the occasional-straggler regime
STRAGGLER_RATES = (0.0, 0.05, 0.15)
STRAGGLER_SLOWDOWN = 25.0


def _exclusive_parts(ds, n_nodes=K, n_classes=N_CLASSES):
    """Full label skew with K > n_classes: node k sees only class
    k % C; each class is sharded over the K/C nodes that hold it."""
    per = n_nodes // n_classes
    parts = []
    for k in range(n_nodes):
        cls_idx = np.where(ds.y == k % n_classes)[0]
        idx = cls_idx[k // n_classes::per]
        parts.append((ds.x[idx], ds.y[idx]))
    return parts


def run(quick: bool = False):
    steps = 100 if quick else 300
    ds = synth_images(2000 if quick else 4000, seed=0, **DATA)
    val = synth_images(600 if quick else 1000, seed=99, **DATA)
    rows = []
    for skew in (0.0, 1.0):
        if skew == 1.0:
            parts = _exclusive_parts(ds)
        else:
            idx = partition_label_skew(ds.y, K, skew, seed=1)
            parts = [(ds.x[i], ds.y[i]) for i in idx]
        for topo in TOPOLOGIES:
            comm = CommConfig(strategy="dpsgd",
                              fabric=FabricConfig(topology=topo,
                                                  profile="geo-wan"))
            r = train_decentralized(
                CNN_ZOO["gn-lenet"], "dpsgd", parts, (val.x, val.y),
                comm=comm, steps=steps, batch=20, lr=LR,
                eval_every=steps)
            rows.append(dict(
                schedule="constant", topology=topo, skew=skew,
                val_acc=r.val_acc,
                wan_mfloats=r.comm_wan_floats / 1e6,
                lan_mfloats=r.comm_lan_floats / 1e6,
                sim_time_s=r.sim_time_s,
                spectral_gap=r.extras["spectral_gap"]))
            print(f"[fig_topology] {topo:8s} skew={skew}: "
                  f"acc={r.val_acc:.3f} wan={r.comm_wan_floats/1e6:.1f}M "
                  f"lan={r.comm_lan_floats/1e6:.1f}M "
                  f"t_sim={r.sim_time_s:.1f}s "
                  f"gap={r.extras['spectral_gap']:.3f}", flush=True)

    # schedule column: fixed full skew, constant vs time-varying fabrics;
    # WAN floats x accuracy is the trade the schedules exist to win
    sds = synth_images(1800 if quick else 3600, seed=0, **SCHED_DATA)
    sval = synth_images(600 if quick else 1000, seed=99, **SCHED_DATA)
    parts = _exclusive_parts(sds, SCHED_K, SCHED_CLASSES)
    for name in SCHEDULES:
        comm = CommConfig(strategy="dpsgd",
                          fabric=FabricConfig(topology=name,
                                              profile="geo-wan",
                                              rewire_floats=64.0))
        r = train_decentralized(
            CNN_ZOO["gn-lenet"], "dpsgd", parts, (sval.x, sval.y),
            comm=comm, steps=steps, batch=20, lr=SCHED_LR,
            eval_every=steps)
        led = r.extras["ledger"]
        rows.append(dict(
            schedule=name, topology=r.topology, skew=1.0,
            val_acc=r.val_acc,
            wan_mfloats=r.comm_wan_floats / 1e6,
            lan_mfloats=r.comm_lan_floats / 1e6,
            wan_mfloats_per_round=r.comm_wan_floats / 1e6 / steps,
            rewire_mfloats=led["rewire_floats"] / 1e6,
            sim_time_s=r.sim_time_s,
            schedule_period=r.extras["schedule_period"],
            spectral_gap=r.extras["spectral_gap"]))
        print(f"[fig_topology] sched {name:16s}: acc={r.val_acc:.3f} "
              f"wan/round={r.comm_wan_floats/1e6/steps:.2f}M "
              f"rewire={led['rewire_floats']/1e6:.2f}M "
              f"period={r.extras['schedule_period']} "
              f"gap={r.extras['spectral_gap']:.3f}", flush=True)

    rows.extend(run_async(parts=_exclusive_parts(ds), ds_val=val,
                          steps=steps))
    rows.extend(run_straggler(parts=_exclusive_parts(ds), ds_val=val,
                              steps=steps))
    save_rows("fig_topology", rows)
    return rows


def run_async(parts=None, ds_val=None, steps: int = 100):
    """Sync-vs-async column (also the ``--smoke-async`` CI entry): the
    same geo-wan fabric, full label skew — D-PSGD priced synchronously
    vs AD-PSGD on the async ledger.  The claim: accuracy within noise,
    simulated wall-clock strictly lower, and the idle time the straggler
    link was costing every LAN node goes to ~zero."""
    if parts is None:
        ds = synth_images(1200, seed=0, **DATA)
        ds_val = synth_images(400, seed=99, **DATA)
        parts = _exclusive_parts(ds)
    rows = []
    for mode, algo, async_gossip in ASYNC_MODES:
        comm = CommConfig(strategy=algo,
                          fabric=FabricConfig(topology="geo-wan",
                                              profile="geo-wan"),
                          async_gossip=async_gossip, max_staleness=2)
        r = train_decentralized(
            CNN_ZOO["gn-lenet"], algo, parts, (ds_val.x, ds_val.y),
            comm=comm, steps=steps, batch=20, lr=LR, eval_every=steps)
        led = r.extras["ledger"]
        rows.append(dict(
            schedule="constant", mode=mode, topology="geo-wan", skew=1.0,
            val_acc=r.val_acc,
            wan_mfloats=r.comm_wan_floats / 1e6,
            lan_mfloats=r.comm_lan_floats / 1e6,
            sim_time_s=r.sim_time_s,
            sim_time_per_step_ms=r.sim_time_s / steps * 1e3,
            clock_skew_s=led["clock_skew_s"],
            idle_s_mean=led["idle_s_mean"]))
        print(f"[fig_topology] {mode:5s} ({algo:6s}): "
              f"acc={r.val_acc:.3f} t_sim={r.sim_time_s:.2f}s "
              f"({r.sim_time_s/steps*1e3:.1f}ms/step) "
              f"idle={led['idle_s_mean']:.2f}s "
              f"skew={led['clock_skew_s']:.2f}s", flush=True)
    return rows


def run_straggler(parts=None, ds_val=None, steps: int = 100,
                  rates=STRAGGLER_RATES):
    """Straggler-rate sweep (also the ``--smoke-links`` CI entry): an
    otherwise-LAN fabric (ring, datacenter profile — every link LAN),
    the stochastic link model's transient Markov slowdowns the only
    heterogeneity.  Sync D-PSGD stop-and-waits on whichever link is
    currently slow; AD-PSGD's per-edge clocks absorb the burst — the
    wall-clock gap *grows with the straggler rate* while accuracy stays
    within noise, and at rate 0 the two ledgers price identical rounds
    (modulo staleness amortization of the ~zero LAN latency)."""
    if parts is None:
        ds = synth_images(1200, seed=0, **DATA)
        ds_val = synth_images(400, seed=99, **DATA)
        parts = _exclusive_parts(ds)
    rows = []
    for rate in rates:
        for mode, algo, async_gossip in ASYNC_MODES:
            comm = CommConfig(
                strategy=algo,
                fabric=FabricConfig(
                    topology="ring", profile="datacenter",
                    link=LinkConfig(model="sampled", straggler_rate=rate,
                                    straggler_slowdown=STRAGGLER_SLOWDOWN)),
                async_gossip=async_gossip, max_staleness=2)
            r = train_decentralized(
                CNN_ZOO["gn-lenet"], algo, parts, (ds_val.x, ds_val.y),
                comm=comm, steps=steps, batch=20, lr=LR,
                eval_every=steps)
            lm = r.extras["link_model"]
            rows.append(dict(
                schedule="constant", mode=mode, topology="ring",
                link_model="sampled", straggler_rate=rate, skew=1.0,
                val_acc=r.val_acc,
                sim_time_s=r.sim_time_s,
                sim_time_per_step_ms=r.sim_time_s / steps * 1e3,
                slow_fraction=lm["slow_fraction"],
                clock_skew_s=r.extras["ledger"]["clock_skew_s"]))
            print(f"[fig_topology] straggler={rate:.2f} {mode:5s} "
                  f"({algo:6s}): acc={r.val_acc:.3f} "
                  f"t_sim={r.sim_time_s:.3f}s "
                  f"slow_frac={lm['slow_fraction']:.3f}", flush=True)
    return rows


def smoke_async():
    """Tiny end-to-end async exercise for CI: must finish in seconds and
    still show the async ledger strictly beating sync wall-clock."""
    rows = run_async(steps=12)
    sync = next(r for r in rows if r["mode"] == "sync")
    asy = next(r for r in rows if r["mode"] == "async")
    assert asy["sim_time_s"] < sync["sim_time_s"], \
        (asy["sim_time_s"], sync["sim_time_s"])
    save_rows("fig_topology_async_smoke", rows)
    save_bench_json("fig_topology_async_smoke", rows,
                    derived=f"async={asy['sim_time_s']:.3f}s "
                            f"sync={sync['sim_time_s']:.3f}s")
    return rows


def smoke_links():
    """Stochastic-link CI smoke: transient stragglers on an all-LAN
    fabric — async AD-PSGD must strictly beat sync D-PSGD's simulated
    wall-clock at accuracy within noise."""
    rows = run_straggler(steps=12, rates=(0.15,))
    sync = next(r for r in rows if r["mode"] == "sync")
    asy = next(r for r in rows if r["mode"] == "async")
    assert asy["sim_time_s"] < sync["sim_time_s"], \
        (asy["sim_time_s"], sync["sim_time_s"])
    assert asy["val_acc"] > sync["val_acc"] - 0.15, \
        (asy["val_acc"], sync["val_acc"])
    assert sync["slow_fraction"] > 0, "straggler chain never fired"
    save_rows("fig_topology_links_smoke", rows)
    save_bench_json("fig_topology_links_smoke", rows,
                    derived=f"async={asy['sim_time_s']:.3f}s "
                            f"sync={sync['sim_time_s']:.3f}s "
                            f"slow_frac={sync['slow_fraction']:.3f}")
    return rows


def smoke_scale(rounds: int = 50, budget_s: float = 10.0):
    """Array-native fabric scale smoke (the ``--smoke-scale`` CI entry):
    price ``rounds`` gossip rounds on a 10k-node hier-cliques fabric —
    stochastic sampled links, 10% partial participation, async ledger,
    no training — and assert the whole thing fits in ``budget_s`` host
    seconds.  A 1k-node config rides along so the JSON shows per-round
    cost growing with *active edges*, not node count squared (the
    O(active edges) contract of the array ledger)."""
    from repro.topology import (LINK_PROFILES, CommLedger, Participation,
                                hierarchical_cliques, make_link_model)
    model_floats = 1e6
    rows = []
    for n_nodes, clique in ((1000, 10), (10000, 25)):
        topo = hierarchical_cliques(n_nodes, clique)
        fabric = FabricConfig(
            topology="hier-cliques", profile="geo-wan",
            link=LinkConfig(model="sampled", jitter=0.1,
                            straggler_rate=0.05),
            participation=0.1)
        profile = LINK_PROFILES[fabric.profile]
        links = make_link_model(fabric.link, profile, seed=0)
        part = Participation(n_nodes, fabric.participation, seed=0)
        led = CommLedger(topo, profile, config=fabric, async_mode=True,
                         link_model=links, participation=part)
        t0 = time.perf_counter()
        for t in range(rounds):
            led.record_gossip(model_floats, t=t)
        wall = time.perf_counter() - t0
        v = led.view()
        active = int(np.count_nonzero(v.edge_traffic))
        rows.append(dict(nodes=n_nodes, edges=len(topo.edges),
                         active_edges=active, rounds=rounds,
                         per_round_ms=wall / rounds * 1e3, wall_s=wall,
                         wan_mfloats=v.wan_floats / 1e6,
                         sim_time_s=v.sim_time_s))
        print(f"[fig_topology] scale K={n_nodes}: {len(topo.edges)} "
              f"edges, {active} active, {wall/rounds*1e3:.2f}ms/round "
              f"({wall:.2f}s total)", flush=True)
    big = rows[-1]
    assert big["wall_s"] < budget_s, \
        (f"10k-node ledger took {big['wall_s']:.2f}s for {rounds} "
         f"rounds (budget {budget_s}s)")
    # 10% participation must actually mask traffic: with both endpoints
    # sampled independently, most edges never fire in 50 rounds
    assert big["active_edges"] < big["edges"], rows
    save_rows("fig_topology_scale_smoke", rows)
    save_bench_json("scale", rows,
                    derived=f"10k={big['per_round_ms']:.2f}ms/round "
                            f"wall={big['wall_s']:.2f}s "
                            f"active={big['active_edges']}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke-async", action="store_true",
                    help="tiny sync-vs-async CI smoke (seconds, asserts "
                         "async < sync simulated wall-clock)")
    ap.add_argument("--smoke-links", action="store_true",
                    help="stochastic-link CI smoke (transient stragglers "
                         "on an all-LAN fabric, asserts async < sync)")
    ap.add_argument("--smoke-scale", action="store_true",
                    help="array-ledger scale smoke (10k-node hier-cliques "
                         "fabric, 50 priced rounds under 10s host time)")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.smoke_async:
        smoke_async()
    elif args.smoke_links:
        smoke_links()
    elif args.smoke_scale:
        smoke_scale()
    else:
        run(quick=args.quick)
