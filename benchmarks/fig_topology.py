"""Topology x skew sweep for gossip (D-PSGD) training.

The scenario-diversity unlock on top of the paper: the same algorithm on
the same partitions, varying only *who talks to whom*.  Under label skew,
sparse graphs (ring) pay in accuracy for their bandwidth savings, label-
aware D-Cliques recover most of the gap at a fraction of the edges, and
the geo-WAN hierarchy shows the LAN/WAN traffic split the flat
``comm_floats`` scalar could never express.  Link costs use the geo-wan
profile so WAN bytes and the simulated step time diverge across graphs.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import CommConfig
from repro.configs.cnn_zoo import CNN_ZOO
from repro.core.partition import partition_label_skew
from repro.core.trainer import train_decentralized
from repro.data.synthetic import synth_images

from benchmarks.common import save_rows

K = 10
N_CLASSES = 5          # < K so D-Cliques can span the label space
# harder than the fig1/fig6 setting (lower separation, higher noise,
# larger lr): sparse-graph consensus lag must actually cost accuracy
# under skew, or every topology trivially matches BSP
DATA = dict(noise=1.2, class_sep=0.22, n_classes=N_CLASSES)
LR = 0.05
TOPOLOGIES = ("ring", "full", "dcliques", "geo-wan")


def _exclusive_parts(ds):
    """Full label skew with K > n_classes: node k sees only class
    k % C; each class is sharded over the K/C nodes that hold it."""
    per = K // N_CLASSES
    parts = []
    for k in range(K):
        cls_idx = np.where(ds.y == k % N_CLASSES)[0]
        idx = cls_idx[k // N_CLASSES::per]
        parts.append((ds.x[idx], ds.y[idx]))
    return parts


def run(quick: bool = False):
    steps = 100 if quick else 300
    ds = synth_images(2000 if quick else 4000, seed=0, **DATA)
    val = synth_images(600 if quick else 1000, seed=99, **DATA)
    rows = []
    for skew in (0.0, 1.0):
        if skew == 1.0:
            parts = _exclusive_parts(ds)
        else:
            idx = partition_label_skew(ds.y, K, skew, seed=1)
            parts = [(ds.x[i], ds.y[i]) for i in idx]
        for topo in TOPOLOGIES:
            comm = CommConfig(strategy="dpsgd", topology=topo,
                              link_profile="geo-wan")
            r = train_decentralized(
                CNN_ZOO["gn-lenet"], "dpsgd", parts, (val.x, val.y),
                comm=comm, steps=steps, batch=20, lr=LR,
                eval_every=steps)
            rows.append(dict(
                topology=topo, skew=skew, val_acc=r.val_acc,
                wan_mfloats=r.comm_wan_floats / 1e6,
                lan_mfloats=r.comm_lan_floats / 1e6,
                sim_time_s=r.sim_time_s,
                spectral_gap=r.extras["spectral_gap"]))
            print(f"[fig_topology] {topo:8s} skew={skew}: "
                  f"acc={r.val_acc:.3f} wan={r.comm_wan_floats/1e6:.1f}M "
                  f"lan={r.comm_lan_floats/1e6:.1f}M "
                  f"t_sim={r.sim_time_s:.1f}s "
                  f"gap={r.extras['spectral_gap']:.3f}", flush=True)
    save_rows("fig_topology", rows)
    return rows


if __name__ == "__main__":
    run()
