"""Kernel microbenchmarks: three rows per op.

* ``kernel/<op>``  — the production path: whatever the backend-aware
  dispatcher (``kernels/dispatch.py``) picks for this op/shape/backend.
* ``oracle/<op>``  — the jnp twin from ``kernels/ref.py``, timed
  directly (the dispatch candidate the kernel row must never lose to).
* ``interp/<op>``  — the pre-dispatch path: Pallas forced through
  ``interpret=True`` with the old hardcoded blocks.  Kept as the
  baseline the overhaul is measured against (``report.py --gate``
  asserts kernel/oracle <= 1+band and the headline interp speedups).

The interp rows are expensive by construction (interpret mode loses by
5-170x at these sizes), so they use fewer timing iterations.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.models.attention import chunked_attention

from benchmarks.common import save_bench_json, timed

KEY = jax.random.PRNGKey(0)


def _bench(rows, base, dispatched, oracle, interp):
    rows.append((f"kernel/{base}", timed(dispatched)))
    rows.append((f"oracle/{base}", timed(oracle)))
    rows.append((f"interp/{base}", timed(interp, n_warmup=1, n_iter=3)))


def run(quick: bool = False):
    rows = []
    B, H, T, D = 1, 4, 256, 64
    q = jax.random.normal(KEY, (B, H, T, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, T, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, T, D))

    fa = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v))
    fr = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    fi = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, block_q=64,
                                                     block_k=64,
                                                     interpret=True))
    _bench(rows, "flash_attention",
           lambda: jax.block_until_ready(fa(q, k, v)),
           lambda: jax.block_until_ready(fr(q, k, v)),
           lambda: jax.block_until_ready(fi(q, k, v)))
    qb = q.transpose(0, 2, 1, 3)
    ca = jax.jit(lambda q, k, v: chunked_attention(q, k, v, chunk=64))
    rows.append(("prod/chunked_attention_jnp",
                 timed(lambda: jax.block_until_ready(
                     ca(qb, k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3))))))

    n = 1 << 20
    vv = jax.random.normal(KEY, (n,))
    ww = jax.random.normal(jax.random.PRNGKey(3), (n,)) * 0.3
    gs = jax.jit(lambda v, w: ops.gaia_select(v, w, 0.5))
    gr = jax.jit(lambda v, w: ref.gaia_select_ref(v, w, 0.5))
    gi = jax.jit(lambda v, w: ops.gaia_select(v, w, 0.5, block_rows=64,
                                              interpret=True))
    _bench(rows, "gaia_select_1M",
           lambda: jax.block_until_ready(gs(vv, ww)),
           lambda: jax.block_until_ready(gr(vv, ww)),
           lambda: jax.block_until_ready(gi(vv, ww)))

    dg = jax.jit(lambda v: ops.dgc_sparsify(v, jnp.float32(0.999)))
    dr = jax.jit(lambda v: ref.dgc_sparsify_ref(v, jnp.float32(0.999)))
    di = jax.jit(lambda v: ops.dgc_sparsify(v, jnp.float32(0.999),
                                            block_rows=64, interpret=True))
    _bench(rows, "dgc_sparsify_1M",
           lambda: jax.block_until_ready(dg(vv)),
           lambda: jax.block_until_ready(dr(vv)),
           lambda: jax.block_until_ready(di(vv)))
    dq = jax.jit(lambda v: ref.dgc_threshold_ref(v, 0.999))
    rows.append(("oracle/dgc_quantile_1M",
                 timed(lambda: jax.block_until_ready(dq(vv)))))

    seed = jnp.int32(7)
    rk = jax.jit(lambda v: ops.rand_k_sparsify(v, jnp.float32(0.001), seed))
    rr = jax.jit(lambda v: ref.rand_k_select_ref(v, jnp.float32(0.001),
                                                 seed))
    ri = jax.jit(lambda v: ops.rand_k_sparsify(v, jnp.float32(0.001), seed,
                                               block_rows=64,
                                               interpret=True))
    _bench(rows, "rand_k_1M",
           lambda: jax.block_until_ready(rk(vv)),
           lambda: jax.block_until_ready(rr(vv)),
           lambda: jax.block_until_ready(ri(vv)))

    from repro.topology import ring
    topo = ring(8)
    nbr_idx, nbr_w, self_w = (jnp.asarray(a) for a in
                              topo.neighbor_arrays())
    xs = jax.random.normal(KEY, (8, 1 << 17))        # 8 nodes x 128k params
    nm = jax.jit(lambda x: ops.neighbor_mix(x, nbr_idx, nbr_w, self_w))
    nr = jax.jit(lambda x: ref.neighbor_mix_padded_ref(x, nbr_idx, nbr_w,
                                                       self_w))
    ni = jax.jit(lambda x: ops.neighbor_mix(x, nbr_idx, nbr_w, self_w,
                                            block_rows=64, interpret=True))
    _bench(rows, "neighbor_mix_ring8_128k",
           lambda: jax.block_until_ready(nm(xs)),
           lambda: jax.block_until_ready(nr(xs)),
           lambda: jax.block_until_ready(ni(xs)))
    W = jnp.asarray(topo.mixing, jnp.float32)
    nd = jax.jit(lambda x: ref.neighbor_mix_ref(x, W))
    rows.append(("oracle/neighbor_mix_dense",
                 timed(lambda: jax.block_until_ready(nd(xs)))))

    # per-node CIFAR batch at a late ResNet stage: many samples, small
    # feature maps — the GroupNorm shape gossip training actually runs
    x = jax.random.normal(KEY, (128, 8, 8, 64))
    sc, bi = jnp.ones(64), jnp.zeros(64)
    gn = jax.jit(lambda x: ops.group_norm(x, sc, bi, group_size=2))
    gnr = jax.jit(lambda x: ref.group_norm_ref(x, sc, bi, group_size=2))
    gni = jax.jit(lambda x: ops.group_norm(x, sc, bi, group_size=2,
                                           interpret=True))
    _bench(rows, "group_norm",
           lambda: jax.block_until_ready(gn(x)),
           lambda: jax.block_until_ready(gnr(x)),
           lambda: jax.block_until_ready(gni(x)))
    return [dict(name=n, us_per_call=u) for n, u in rows]


if __name__ == "__main__":
    out = run()
    for r in out:
        print(f"{r['name']},{r['us_per_call']:.1f},")
    # standalone runs land the same artifact the run.py --json path
    # emits (respects $BENCH_JSON_DIR; no-op when unset)
    if os.environ.get("BENCH_JSON_DIR"):
        print("wrote", save_bench_json("kernels", out))
