"""Kernel microbenchmarks: us_per_call for each Pallas kernel (interpret
mode on CPU — structural check; real perf is the TPU target) and the jnp
twin used by the production path."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.models.attention import chunked_attention

from benchmarks.common import timed

KEY = jax.random.PRNGKey(0)


def run(quick: bool = False):
    rows = []
    B, H, T, D = 1, 4, 256, 64
    q = jax.random.normal(KEY, (B, H, T, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, T, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, T, D))

    fa = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, block_q=64,
                                                     block_k=64))
    rows.append(("kernel/flash_attention_interp",
                 timed(lambda: jax.block_until_ready(fa(q, k, v)))))
    fr = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    rows.append(("oracle/attention_materialized",
                 timed(lambda: jax.block_until_ready(fr(q, k, v)))))
    qb = q.transpose(0, 2, 1, 3)
    ca = jax.jit(lambda q, k, v: chunked_attention(q, k, v, chunk=64))
    rows.append(("prod/chunked_attention_jnp",
                 timed(lambda: jax.block_until_ready(
                     ca(qb, k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3))))))

    n = 1 << 20
    vv = jax.random.normal(KEY, (n,))
    ww = jax.random.normal(jax.random.PRNGKey(3), (n,)) * 0.3
    gs = jax.jit(lambda v, w: ops.gaia_select(v, w, 0.5))
    rows.append(("kernel/gaia_select_1M",
                 timed(lambda: jax.block_until_ready(gs(vv, ww)))))
    gr = jax.jit(lambda v, w: ref.gaia_select_ref(v, w, 0.5))
    rows.append(("oracle/gaia_select_1M",
                 timed(lambda: jax.block_until_ready(gr(vv, ww)))))

    dg = jax.jit(lambda v: ops.dgc_sparsify(v, jnp.float32(0.999)))
    rows.append(("kernel/dgc_sparsify_1M",
                 timed(lambda: jax.block_until_ready(dg(vv)))))
    dq = jax.jit(lambda v: ref.dgc_threshold_ref(v, 0.999))
    rows.append(("oracle/dgc_quantile_1M",
                 timed(lambda: jax.block_until_ready(dq(vv)))))

    from repro.topology import ring
    topo = ring(8)
    nbr_idx, nbr_w, self_w = (jnp.asarray(a) for a in
                              topo.neighbor_arrays())
    xs = jax.random.normal(KEY, (8, 1 << 17))        # 8 nodes x 128k params
    nm = jax.jit(lambda x: ops.neighbor_mix(x, nbr_idx, nbr_w, self_w))
    rows.append(("kernel/neighbor_mix_ring8_128k",
                 timed(lambda: jax.block_until_ready(nm(xs)))))
    W = jnp.asarray(topo.mixing, jnp.float32)
    nr = jax.jit(lambda x: ref.neighbor_mix_ref(x, W))
    rows.append(("oracle/neighbor_mix_dense",
                 timed(lambda: jax.block_until_ready(nr(xs)))))

    x = jax.random.normal(KEY, (16, 16, 16, 64))
    sc, bi = jnp.ones(64), jnp.zeros(64)
    gn = jax.jit(lambda x: ops.group_norm(x, sc, bi, group_size=2))
    rows.append(("kernel/group_norm",
                 timed(lambda: jax.block_until_ready(gn(x)))))
    gnr = jax.jit(lambda x: ref.group_norm_ref(x, sc, bi, group_size=2))
    rows.append(("oracle/group_norm",
                 timed(lambda: jax.block_until_ready(gnr(x)))))
    return [dict(name=n, us_per_call=u) for n, u in rows]


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},")
