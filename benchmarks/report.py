"""Assemble EXPERIMENTS.md from the experiment artifacts:

- experiments/dryrun/combos/*.json   -> §Dry-run + §Roofline tables
- experiments/results/*.json         -> paper-figure reproductions
- experiments/perf/perf_log.jsonl    -> §Perf iteration log

  PYTHONPATH=src python -m benchmarks.report

Bench-trajectory modes (the per-commit ``BENCH_*.json`` artifacts CI
uploads as ``bench-json-<sha>``):

  # cross-commit trend table over any set of downloaded artifacts
  python -m benchmarks.report --trend 'artifacts/*/BENCH_*.json'

  # enforcement: fail when the kernels bench regresses
  python -m benchmarks.report --gate out/BENCH_kernels.json \
      [--baseline prev/BENCH_kernels.json] [--noise-band 0.5] \
      [--min-speedup 8]

The gate holds the kernel-overhaul line: every ``kernel/<op>`` row
(the dispatched production path) must be <= its ``oracle/<op>`` jnp
twin times (1 + noise band); the headline ops (``neighbor_mix``,
``group_norm``) must additionally beat their ``interp/<op>`` old-path
rows by ``--min-speedup``; and with ``--baseline`` no kernel row may
regress beyond the noise band against the prior commit's artifact.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")
COMBOS = os.path.join(ROOT, "experiments", "dryrun", "combos2")   # metric v2
COMBOS_V1 = os.path.join(ROOT, "experiments", "dryrun", "combos")  # multi-pod
RESULTS = os.path.join(ROOT, "experiments", "results")
PERF = os.path.join(ROOT, "experiments", "perf", "perf_log.jsonl")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _load_combos(kind: str, base=None):
    out = {}
    for f in glob.glob(os.path.join(base or COMBOS, f"*__{kind}.json")):
        r = json.load(open(f))
        out[(r["arch"], r["shape"])] = r
    return out


def _rows(name):
    p = os.path.join(RESULTS, f"{name}.json")
    return json.load(open(p)) if os.path.exists(p) else None


def dryrun_section(single, multi):
    lines = ["## §Dry-run", ""]
    n_ok_s = sum(1 for r in single.values() if r.get("ok"))
    n_ok_m = sum(1 for r in multi.values() if r.get("ok"))
    lines.append(
        f"Every (architecture × input shape) pair lowers **and compiles** on "
        f"both production meshes: **{n_ok_s}/40** on the single-pod 16×16 "
        f"(256 chips) mesh and **{n_ok_m}/40** on the multi-pod 2×16×16 "
        f"(512 chips) mesh — the multi-pod pass proves the `pod` "
        f"(decentralized-site) axis shards, with the Gaia exchange as the "
        f"training comm strategy.  Failures: "
        f"{[k for k, r in {**single, **multi}.items() if not r.get('ok')] or 'none'}.")
    lines.append("")
    lines.append("Per-device memory (multi-pod mesh, training state incl. "
                 "fp32 velocity + Gaia residuals; bytes from "
                 "`compiled.memory_analysis()`):")
    lines.append("")
    lines.append("| arch | args MB/dev | temp MB/dev |")
    lines.append("|---|---|---|")
    for arch in sorted({a for a, _ in multi}):
        r = multi.get((arch, "train_4k"))
        if not r or not r.get("ok"):
            continue
        mem = r["memory"]
        lines.append(f"| {arch} | {mem.get('argument_size_in_bytes', 0)/1e6:.0f} "
                     f"| {mem.get('temp_size_in_bytes', 0)/1e6:.0f} |")
    lines.append("")
    # collective schedule summary
    lines.append("Collective schedule (multi-pod train_4k, GB/device/step by "
                 "kind, from the partitioned HLO):")
    lines.append("")
    lines.append("| arch | all-gather | all-reduce | reduce-scatter | "
                 "all-to-all | collective-permute |")
    lines.append("|---|---|---|---|---|---|")
    for arch in sorted({a for a, _ in multi}):
        r = multi.get((arch, "train_4k"))
        if not r or not r.get("ok"):
            continue
        cb = r["roofline"]["coll_breakdown_gb"]
        lines.append("| " + arch + " | " + " | ".join(
            f"{cb.get(k, 0):.2f}" for k in
            ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")) + " |")
    lines.append("")
    return "\n".join(lines)


def roofline_section(single):
    lines = ["## §Roofline", ""]
    lines.append(
        "Per (arch × shape) on the single-pod 16×16 mesh.  Terms in ms per "
        "step per device: compute = HLO_FLOPs/(197 TFLOP/s), memory = "
        "HLO_bytes/(819 GB/s), collective = collective_bytes/(50 GB/s link). "
        "FLOPs/bytes from trip-count-aware analysis of the SPMD-partitioned "
        "HLO (`repro.launch.hlo_analysis`; XLA's `cost_analysis()` counts "
        "scan bodies once and is unusable for scan-over-layers programs). "
        "`useful` = MODEL_FLOPS (6·N_active·D train / 2·N_active·D serve) ÷ "
        "HLO FLOPs.")
    lines.append("")
    lines.append("| arch | shape | t_comp | t_mem | t_coll | bottleneck | "
                 "useful |")
    lines.append("|---|---|---|---|---|---|---|")
    for arch in sorted({a for a, _ in single}):
        for shape in SHAPE_ORDER:
            r = single.get((arch, shape))
            if not r:
                continue
            if not r.get("ok"):
                lines.append(f"| {arch} | {shape} | FAILED | | | | |")
                continue
            ro = r["roofline"]
            lines.append(
                f"| {arch} | {shape} | {ro['t_compute_ms']:.1f} | "
                f"{ro['t_memory_ms']:.1f} | {ro['t_collective_ms']:.1f} | "
                f"{ro['bottleneck']} | {ro['useful_ratio']:.2f} |")
    lines.append("")
    return "\n".join(lines)


def figure_sections():
    parts = []
    fig1 = _rows("fig1")
    if fig1:
        parts.append("### Fig. 1 — algorithms × models, IID vs non-IID "
                     "(synthetic-CIFAR, K=5)\n")
        parts.append("| model | algo | IID acc | non-IID acc | Δ | "
                     "comm savings |")
        parts.append("|---|---|---|---|---|---|")
        by = {}
        for r in fig1:
            by.setdefault((r["model"], r["algo"]), {})[r["skew"]] = r
        for (mdl, algo), d in sorted(by.items()):
            if 0.0 in d and 1.0 in d:
                parts.append(
                    f"| {mdl} | {algo} | {d[0.0]['val_acc']:.3f} | "
                    f"{d[1.0]['val_acc']:.3f} | "
                    f"{d[1.0]['val_acc']-d[0.0]['val_acc']:+.3f} | "
                    f"{d[1.0]['comm_savings']:.1f}× |")
        parts.append("")
    fig2 = _rows("fig2")
    if fig2:
        parts.append("### Fig. 2/20 — real-world geo skew (Flickr-Mammal "
                     "analogue)\n")
        parts.append("| level | algo | IID acc | geo-non-IID acc |")
        parts.append("|---|---|---|---|")
        by = {}
        for r in fig2:
            by.setdefault((r["level"], r["algo"]), {})[r["setting"]] = r
        for (lvl, algo), d in sorted(by.items()):
            if "iid" in d and "noniid" in d:
                parts.append(f"| {lvl} | {algo} | {d['iid']['val_acc']:.3f} "
                             f"| {d['noniid']['val_acc']:.3f} |")
        parts.append("")
    fig4 = _rows("fig4")
    if fig4:
        import numpy as np
        by = {}
        for r in fig4:
            by.setdefault(r["setting"], []).append(r["mu_divergence"])
        parts.append("### Fig. 4 — BatchNorm minibatch-mean divergence\n")
        parts.append("| setting | mean μ_B divergence | max channel |")
        parts.append("|---|---|---|")
        for k, v in by.items():
            parts.append(f"| {k} | {np.mean(v):.3f} | {np.max(v):.3f} |")
        parts.append("")
    fig5 = _rows("fig5")
    if fig5:
        parts.append("### Fig. 5 / Table 9 — GroupNorm & BatchReNorm vs "
                     "BatchNorm (non-IID)\n")
        parts.append("| model | algo | IID acc | non-IID acc |")
        parts.append("|---|---|---|---|")
        by = {}
        for r in fig5:
            by.setdefault((r["model"], r["algo"]), {})[r["skew"]] = r
        for (mdl, algo), d in sorted(by.items()):
            if 0.0 in d and 1.0 in d:
                parts.append(f"| {mdl} | {algo} | {d[0.0]['val_acc']:.3f} | "
                             f"{d[1.0]['val_acc']:.3f} |")
        parts.append("")
    fig6 = _rows("fig6")
    if fig6:
        parts.append("### Fig. 6 — degree of skew (GN-LeNet)\n")
        skews = sorted({r["skew"] for r in fig6})
        parts.append("| algo | " + " | ".join(f"{int(s*100)}%" for s in skews)
                     + " |")
        parts.append("|---|" + "---|" * len(skews))
        by = {}
        for r in fig6:
            by.setdefault(r["algo"], {})[r["skew"]] = r["val_acc"]
        for algo, d in sorted(by.items()):
            parts.append(f"| {algo} | " + " | ".join(
                f"{d.get(s, float('nan')):.3f}" for s in skews) + " |")
        parts.append("")
    fig8 = _rows("fig8")
    if fig8:
        parts.append("### Fig. 8 — SkewScout vs BSP vs Oracle "
                     "(Gaia, GN-LeNet)\n")
        parts.append("| skew | BSP acc | SkewScout acc | SkewScout savings | "
                     "Oracle savings | θ path |")
        parts.append("|---|---|---|---|---|---|")
        for r in fig8:
            parts.append(
                f"| {int(r['skew']*100)}% | {r['bsp_acc']:.3f} | "
                f"{r['skewscout_acc']:.3f} | {r['skewscout_savings']:.1f}× | "
                f"{r['oracle_savings']:.1f}× | "
                f"{'→'.join(str(t) for t in r['thetas'][:6])} |")
        parts.append("")
    tab = _rows("tab678")
    if tab:
        parts.append("### Tables 6-8 — θ sensitivity\n")
        parts.append("| algo | θ | IID acc | non-IID acc | savings |")
        parts.append("|---|---|---|---|---|")
        by = {}
        for r in tab:
            by.setdefault((r["algo"], r["theta"]), {})[r["skew"]] = r
        for (algo, th), d in sorted(by.items(), key=lambda kv: str(kv[0])):
            if 0.0 in d and 1.0 in d:
                parts.append(f"| {algo} | {th} | {d[0.0]['val_acc']:.3f} | "
                             f"{d[1.0]['val_acc']:.3f} | "
                             f"{d[1.0]['comm_savings']:.1f}× |")
        parts.append("")
    return "\n".join(parts)


PERF_SUMMARY = """Three pairs were hillclimbed (worst roofline fraction /
most collective-bound / most technique-representative at scale).  The
paper-faithful implementation is the baseline; beyond-paper optimizations
are recorded separately (both measured under the final v2 metric):

| pair | dominant term | baseline | optimized | gain |
|---|---|---|---|---|
| qwen3-0.6b × decode_32k | memory | 607.1 ms | **35.5 ms** | **17.1×** |
| gemma2-9b × train_4k | memory | 19 829 ms | **16 435 ms** (chunk 2048) | **1.21×** |
| deepseek-v2-lite-16b × train_4k | collective | 32 155 ms | **4 865 ms** (shard_map+all_to_all EP, `REPRO_MOE_EP=1`) | **6.6×** (+2.6× memory; bottleneck flips to memory) |
| deepseek-v2-236b × train_4k (transfer) | collective | 173 654 ms | **25 948 ms** (same EP path) | **6.7×** (+2.0× memory) |

The deepseek-lite path took three attempts: two GSPMD-level hypotheses were
refuted (iterations 3), then the structural `shard_map`+`all_to_all`
expert-parallel rewrite (iteration 7) delivered 6.6× — bit-exact against
the dense formulation (tests/test_moe_ep.py).
"""


def perf_section():
    lines = ["## §Perf — hillclimbing log", "", PERF_SUMMARY, ""]
    if not os.path.exists(PERF):
        return "\n".join(lines + ["(no iterations logged)"])
    for raw in open(PERF):
        raw = raw.strip()
        if not raw:
            continue
        it = json.loads(raw)
        lines.append(f"### Iteration {it['iter']} — {it['pair']} "
                     f"(dominant: {it['dominant']})")
        lines.append("")
        lines.append(f"- **Hypothesis:** {it['hypothesis']}")
        lines.append(f"- **Change:** {it['change']}")
        lines.append(f"- **Before:** {it['before_ms']} ms")
        lines.append(f"- **After:** {it['after_ms']} ms")
        lines.append(f"- **Verdict:** {it['verdict']}")
        lines.append("")
    return "\n".join(lines)


HEADER = """# EXPERIMENTS

Reproduction of *The Non-IID Data Quagmire of Decentralized Machine
Learning* (Hsieh et al., ICML 2020) — experiment report.

## Setup

- CPU-only container; TPU v5e is the *target* (197 TFLOP/s bf16, 819 GB/s
  HBM, ~50 GB/s ICI per link).  Training experiments run the vmap
  simulation backend; distribution claims are established by
  `.lower().compile()` dry-runs against 512 fake host devices.
- Datasets are deterministic synthetic stand-ins (real CIFAR/ImageNet/
  Flickr unavailable offline): `synth_images` (class prototypes with
  per-class channel statistics + noise; BSP/IID reaches ~1.00 accuracy so
  any drop is attributable to the algorithm/skew — the paper's own
  methodology), `synth_geo_images` (Flickr-Mammal geography analogue).
  Claims are validated **directionally**, not as absolute accuracies.
- Paper hyper-parameters carried over: K=5 partitions, batch 20/node,
  momentum 0.9, Gaia T₀=10 %, FedAvg Iter_local=20, DGC warm-up to 99.9 %
  sparsity, SkewScout σ_AL=5 %, λ_AL=50, λ_C=1, hill-climbing tuner.

## Paper-claim scoreboard

| paper claim | status |
|---|---|
| decentralized algorithms lose accuracy under label skew at θ that is IID-safe (Fig 1) | reproduced — FedAvg 1.000→0.579, Gaia diverges at shared θ under skew (preliminary 300-step matrix; full table below when present) |
| the loss appears on real-world geo skew, milder than 100 % skew (Fig 2) | consistent in the limit: with Table-1-style home-share 0.7 (all labels present in every region, as in real Flickr-Mammal) the CNN-scale task converges to identical accuracy IID vs geo-non-IID — i.e. the geo-skew penalty is far milder than exclusive label skew, matching the paper's explanation; the partitioner's concentration properties are verified in tests |
| μ_B divergence is the BN failure mechanism (Fig 4) | reproduced — non-IID 16.97 vs IID 2.61 (6.5×) |
| BN loses accuracy even under BSP; GroupNorm recovers it (Fig 5) | reproduced — BSP non-IID: BN-LeNet 0.708 / GN-LeNet 1.000; ResNet-s BN 0.926 / GN 1.000 |
| difficulty grows with skew fraction (Fig 6) | reproduced in tests (test_system) + preliminary sweeps |
| SkewScout: BSP-level accuracy at large comm savings (Fig 8) | reproduced — 9.9×/16× savings at BSP accuracy (table below); controller tightens θ under skew, relaxes when IID (tested) |
| conservative θ still loses accuracy non-IID (Tables 6-8) | reproduced in θ-sensitivity tests (test_algorithms/test_system) |

"""


def main():
    single = _load_combos("single")
    multi = _load_combos("multi", base=COMBOS_V1)
    doc = [HEADER]
    doc.append(figure_sections())
    doc.append(dryrun_section(single, multi))
    doc.append(roofline_section(single))
    doc.append(perf_section())
    out = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(out, "w") as f:
        f.write("\n".join(doc))
    print(f"wrote {out}")


# ---------------------------------------------------- bench trajectory

# the gate's headline ops: the dispatched path must beat the old
# interpret path by --min-speedup on these (ISSUE 7 acceptance)
HEADLINE_SPEEDUP_OPS = ("neighbor_mix_ring8_128k", "group_norm")


def _load_bench(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    return {r["name"]: float(r["us_per_call"]) for r in data.get("rows", [])
            if "us_per_call" in r}


def _bench_files(spec: str):
    """Expand a --trend/--gate spec: a file, a directory (its
    BENCH_*.json members), or a glob."""
    if os.path.isdir(spec):
        return sorted(glob.glob(os.path.join(spec, "BENCH_*.json")))
    hits = sorted(glob.glob(spec))
    return hits


def trend(spec: str) -> int:
    """Cross-commit trend table: one section per bench name, one row per
    (commit, timestamp), columns = that bench's row names (kernels) or
    wall time + headline (experiment benches)."""
    files = _bench_files(spec)
    if not files:
        print(f"no BENCH_*.json matched {spec!r}", file=sys.stderr)
        return 1
    by_bench = {}
    for p in files:
        try:
            with open(p) as f:
                data = json.load(f)
        except (OSError, ValueError):
            continue
        by_bench.setdefault(data.get("name", "?"), []).append(data)
    for name, records in sorted(by_bench.items()):
        records.sort(key=lambda d: d.get("timestamp", 0.0))
        print(f"## bench trend: {name}\n")
        if name == "kernels":
            cols = sorted({r["name"] for d in records
                           for r in d.get("rows", [])
                           if r["name"].startswith("kernel/")})
            print("| commit | " + " | ".join(
                c.split("/", 1)[1] + " us" for c in cols) + " |")
            print("|---|" + "---|" * len(cols))
            for d in records:
                rows = {r["name"]: r.get("us_per_call") for r in d["rows"]}
                print("| " + (d.get("commit", "")[:8] or "?") + " | " +
                      " | ".join(f"{rows[c]:.0f}" if c in rows else ""
                                 for c in cols) + " |")
        else:
            print("| commit | wall ms | headline |")
            print("|---|---|---|")
            for d in records:
                print(f"| {(d.get('commit', '')[:8] or '?')} | "
                      f"{d.get('us_per_call', 0.0) / 1e3:.0f} | "
                      f"{d.get('derived', '')} |")
        print()
    return 0


def _load_scale_rows(path: str) -> dict:
    """BENCH_scale.json rows keyed by node count."""
    with open(path) as f:
        data = json.load(f)
    return {int(r["nodes"]): r for r in data.get("rows", [])
            if "nodes" in r}


def _gate_scale(files, baseline, noise_band, budget_s):
    """Ledger-overhead rules for the fabric scale smoke
    (``BENCH_scale.json`` from ``fig_topology --smoke-scale``): the 10k
    hier-cliques pricing run must stay under its host-time budget, and
    with a baseline artifact no node-count row's per-round host ms may
    regress beyond the noise band."""
    scale = [p for p in files if p.endswith("BENCH_scale.json")]
    if not scale:
        return [], False
    failures = []
    rows = _load_scale_rows(scale[0])
    big = max(rows)
    wall = float(rows[big]["wall_s"])
    if wall > budget_s:
        failures.append(
            f"scale/{big}: {wall:.2f}s host time for "
            f"{rows[big]['rounds']} rounds, budget {budget_s}s")
    else:
        print(f"gate: scale {big}-node pricing {wall:.2f}s "
              f"(< {budget_s}s budget, "
              f"{rows[big]['per_round_ms']:.1f} ms/round)")
    if baseline:
        prev_files = [p for p in _bench_files(baseline)
                      if p.endswith("BENCH_scale.json")]
        if prev_files:
            prev = _load_scale_rows(prev_files[0])
            for nodes, r in sorted(rows.items()):
                if nodes not in prev:
                    continue
                ms, was = float(r["per_round_ms"]), \
                    float(prev[nodes]["per_round_ms"])
                if ms > was * (1.0 + noise_band):
                    failures.append(
                        f"scale/{nodes}: {ms:.1f} ms/round regressed "
                        f"beyond {was:.1f} x (1 + {noise_band}) "
                        f"vs baseline")
        else:
            print(f"gate: baseline {baseline!r} has no "
                  f"BENCH_scale.json; skipping scale regression check")
    return failures, True


def gate(path: str, baseline: str = None, noise_band: float = 0.5,
         min_speedup: float = 8.0, scale_budget_s: float = 10.0) -> int:
    """Fail (exit 1) when the kernels bench regresses — see module
    docstring for the three rules — or when the fabric scale smoke
    (``BENCH_scale.json``, if present alongside) blows its ledger-only
    host-time budget or regresses per-round vs the baseline."""
    files = _bench_files(path)
    scale_failures, scale_checked = _gate_scale(
        files, baseline, noise_band, scale_budget_s)
    kern = [p for p in files if p.endswith("BENCH_kernels.json")]
    if not kern:
        if scale_checked:
            if scale_failures:
                print("\n".join("GATE FAIL: " + f
                                for f in scale_failures), file=sys.stderr)
                return 1
            print("gate: OK (scale rows only)")
            return 0
        print(f"gate: no BENCH_kernels.json under {path!r}",
              file=sys.stderr)
        return 1
    rows = _load_bench(kern[0])
    failures = list(scale_failures)
    checked = 0
    for name, us in sorted(rows.items()):
        if not name.startswith("kernel/"):
            continue
        base = name.split("/", 1)[1]
        oracle = rows.get(f"oracle/{base}")
        if oracle is not None:
            checked += 1
            if us > oracle * (1.0 + noise_band):
                failures.append(
                    f"{name}: dispatched {us:.0f}us > oracle "
                    f"{oracle:.0f}us x (1 + {noise_band})")
        interp = rows.get(f"interp/{base}")
        if interp is not None and base in HEADLINE_SPEEDUP_OPS:
            speedup = interp / max(us, 1e-9)
            if speedup < min_speedup:
                failures.append(
                    f"{name}: only {speedup:.1f}x over the old interpret "
                    f"path ({interp:.0f}us), need >= {min_speedup}x")
            else:
                print(f"gate: {base} {speedup:.1f}x over old interpret "
                      f"path (>= {min_speedup}x required)")
    if baseline:
        prev_files = [p for p in _bench_files(baseline)
                      if p.endswith("BENCH_kernels.json")]
        if prev_files:
            prev = _load_bench(prev_files[0])
            for name, us in sorted(rows.items()):
                if not name.startswith("kernel/") or name not in prev:
                    continue
                if us > prev[name] * (1.0 + noise_band):
                    failures.append(
                        f"{name}: {us:.0f}us regressed beyond "
                        f"{prev[name]:.0f}us x (1 + {noise_band}) "
                        f"vs baseline")
        else:
            print(f"gate: baseline {baseline!r} has no "
                  f"BENCH_kernels.json; skipping cross-commit check")
    if failures:
        print("\n".join("GATE FAIL: " + f for f in failures),
              file=sys.stderr)
        return 1
    print(f"gate: OK ({checked} kernel rows <= oracle x "
          f"(1 + {noise_band}))")
    return 0


def cli(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trend", metavar="GLOB_OR_DIR",
                    help="print a cross-commit trend table over "
                         "BENCH_*.json artifacts")
    ap.add_argument("--gate", metavar="FILE_OR_DIR",
                    help="enforce the kernel-dispatch perf contract on a "
                         "BENCH_kernels.json; exit 1 on regression")
    ap.add_argument("--baseline", metavar="FILE_OR_DIR", default=None,
                    help="prior commit's artifact for the cross-commit "
                         "regression check (with --gate)")
    ap.add_argument("--noise-band", type=float, default=0.5,
                    help="allowed fractional slack on every ratio check "
                         "(default 0.5: CI runner timing is noisy)")
    ap.add_argument("--min-speedup", type=float, default=8.0,
                    help="required kernel-vs-old-interpret speedup on the "
                         "headline ops (default 8)")
    ap.add_argument("--scale-budget-s", type=float, default=10.0,
                    help="host-time budget for the largest fabric scale "
                         "smoke row in BENCH_scale.json (default 10)")
    args = ap.parse_args(argv)
    if args.trend:
        return trend(args.trend)
    if args.gate:
        return gate(args.gate, baseline=args.baseline,
                    noise_band=args.noise_band,
                    min_speedup=args.min_speedup,
                    scale_budget_s=args.scale_budget_s)
    main()
    return 0


if __name__ == "__main__":
    sys.exit(cli())
