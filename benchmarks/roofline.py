"""Roofline table from the dry-run artifacts (deliverable g).

Reads experiments/dryrun/combos/*.json (written by repro.launch.dryrun)
and prints the per-(arch x shape x mesh) three-term roofline with the
dominant bottleneck and the useful-compute ratio."""
from __future__ import annotations

import glob
import json
import os

COMBO_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                         "dryrun", "combos")


def load_reports(combo_dir: str = COMBO_DIR):
    reports = []
    for f in sorted(glob.glob(os.path.join(combo_dir, "*.json"))):
        with open(f) as fh:
            reports.append(json.load(fh))
    return reports


def run(quick: bool = False):
    reports = load_reports()
    rows = []
    for r in reports:
        if not r.get("ok"):
            rows.append(dict(arch=r["arch"], shape=r["shape"], ok=False,
                             error=r.get("error", "?")))
            continue
        ro = r["roofline"]
        rows.append(dict(
            arch=r["arch"], shape=r["shape"], mesh=r["mesh"], ok=True,
            t_compute_ms=ro["t_compute_ms"], t_memory_ms=ro["t_memory_ms"],
            t_collective_ms=ro["t_collective_ms"],
            bottleneck=ro["bottleneck"], useful_ratio=ro["useful_ratio"],
            coll_gb=ro["coll_gbytes_per_dev"]))
    return rows


def print_table(rows):
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':9s} {'t_comp':>9s} "
           f"{'t_mem':>9s} {'t_coll':>9s} {'bound':>10s} {'useful':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if not r.get("ok"):
            print(f"{r['arch']:24s} {r['shape']:12s} FAILED: "
                  f"{r['error'][:60]}")
            continue
        print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:9s} "
              f"{r['t_compute_ms']:8.1f}m {r['t_memory_ms']:8.1f}m "
              f"{r['t_collective_ms']:8.1f}m {r['bottleneck']:>10s} "
              f"{r['useful_ratio']:7.2f}")


if __name__ == "__main__":
    print_table(run())
