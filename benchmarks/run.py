"""Benchmark entry point — one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # quick pass (default)
  PYTHONPATH=src python -m benchmarks.run --full     # full paper-scale runs
  PYTHONPATH=src python -m benchmarks.run --only fig1,fig8
  PYTHONPATH=src python -m benchmarks.run --json out # + BENCH_*.json per
                                                     # bench (CI artifact)

Prints ``name,us_per_call,derived`` CSV.  For kernel benches us_per_call is
the measured call time; for experiment benches us_per_call is the total
wall time of the run and ``derived`` carries the headline metric
(accuracy / savings / divergence), full rows land in experiments/results/.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (fig1_accuracy, fig2_flickr, fig4_bn_divergence,
                        fig5_groupnorm, fig6_skew_degree, fig8_skewscout,
                        fig_topology, kernels_bench, roofline,
                        tab678_hparams)

BENCHES = {  # priority order: cheap + headline results first
    "kernels": (kernels_bench, "pallas kernels vs oracles"),
    "fig4": (fig4_bn_divergence, "BN minibatch-mean divergence"),
    "fig8": (fig8_skewscout, "SkewScout vs BSP vs Oracle"),
    "fig1": (fig1_accuracy, "4 CNN x 4 algo x IID/non-IID accuracy"),
    "fig5": (fig5_groupnorm, "GroupNorm vs BatchNorm rescue"),
    "fig6": (fig6_skew_degree, "degree-of-skew sweep"),
    "fig2": (fig2_flickr, "geo-skew (Flickr-Mammal analogue)"),
    "fig_topology": (fig_topology, "D-PSGD topology x skew sweep"),
    "tab678": (tab678_hparams, "theta sensitivity"),
    "roofline": (roofline, "dry-run roofline table"),
}


def _headline(name, rows):
    if not rows:
        return ""
    if name == "kernels":
        return ""
    if name == "fig4":
        import numpy as np
        by = {}
        for r in rows:
            by.setdefault(r["setting"], []).append(r["mu_divergence"])
        return ";".join(f"{k}:mean_div={np.mean(v):.3f}"
                        for k, v in by.items())
    if name == "fig8":
        return ";".join(
            f"skew{r['skew']}:ss={r['skewscout_savings']:.1f}x,"
            f"oracle={r['oracle_savings']:.1f}x" for r in rows)
    if name == "roofline":
        ok = [r for r in rows if r.get("ok")]
        fail = len(rows) - len(ok)
        from collections import Counter
        c = Counter(r["bottleneck"] for r in ok)
        return f"ok={len(ok)};fail={fail};" + \
            ";".join(f"{k}={v}" for k, v in sorted(c.items()))
    if "val_acc" in rows[0]:
        worst = min(rows, key=lambda r: r["val_acc"])
        keys = [k for k in ("model", "algo", "skew", "setting", "theta")
                if k in worst]
        tag = "/".join(str(worst[k]) for k in keys)
        return f"n={len(rows)};worst_acc={worst['val_acc']:.3f}@{tag}"
    return f"n={len(rows)}"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="DIR",
                    help="emit a machine-readable BENCH_<name>.json per "
                         "bench into DIR (the CI perf-trajectory "
                         "artifact: commit, timestamp, wall time, "
                         "headline, full rows)")
    ap.add_argument("--use-cache", action="store_true",
                    help="reuse experiments/results/*.json if present")
    ap.add_argument("--cache-only", action="store_true",
                    help="with --use-cache: skip experiment benches whose "
                         "results are missing instead of re-running")
    args = ap.parse_args(argv)
    names = list(BENCHES) if not args.only else args.only.split(",")

    print("name,us_per_call,derived")
    for name in names:
        mod, _desc = BENCHES[name]
        t0 = time.perf_counter()
        if args.use_cache and name not in ("kernels", "roofline"):
            from benchmarks.common import load_rows
            rows = load_rows(name)
            if rows is None:
                if args.cache_only:
                    print(f"{name},0,SKIPPED(no cached result)")
                    continue
                rows = mod.run(quick=not args.full)
        else:
            rows = mod.run(quick=not args.full)
        dt_us = (time.perf_counter() - t0) * 1e6
        if name == "kernels":
            for r in rows:
                print(f"{r['name']},{r['us_per_call']:.1f},")
        else:
            print(f"{name},{dt_us:.0f},{_headline(name, rows)}")
        if args.json:
            from benchmarks.common import save_bench_json
            save_bench_json(name, rows, derived=_headline(name, rows),
                            us_per_call=dt_us, out_dir=args.json)
        sys.stdout.flush()
    return 0


if __name__ == "__main__":
    sys.exit(main())
