"""Tables 6-8 analogue: hyper-parameter sensitivity of the non-IID problem.

Paper claim reproduced: even conservative theta (high communication) loses
accuracy in the non-IID setting while matching BSP in the IID setting;
relaxed theta degrades further."""
from __future__ import annotations

from repro.configs.base import CommConfig
from repro.configs.cnn_zoo import CNN_ZOO
from repro.core.trainer import train_decentralized

from benchmarks.common import TRAIN, make_data, make_parts, save_rows

SWEEPS = {
    "gaia": ("gaia_t0", (0.02, 0.10, 0.30)),
    "fedavg": ("iter_local", (5, 20, 100)),
    "dgc": ("dgc_sparsity", (0.9375, 0.996, 0.999)),
}


def run(quick: bool = False):
    steps = 200 if quick else 350
    ds, val = make_data(2000 if quick else 4000)
    rows = []
    for algo, (field, values) in SWEEPS.items():
        for v in (values[:2] if quick else values):
            for skew in (0.0, 1.0):
                comm = CommConfig(**{field: v}, dgc_warmup_epochs=10**6)
                parts = make_parts(ds, skew)
                r = train_decentralized(
                    CNN_ZOO["gn-lenet"], algo, parts, (val.x, val.y),
                    comm=comm, steps=steps, **TRAIN)
                rows.append(dict(algo=algo, theta=v, skew=skew,
                                 val_acc=r.val_acc,
                                 comm_savings=r.comm_savings))
                print(f"[tab678] {algo} {field}={v} skew={skew}: "
                      f"acc={r.val_acc:.3f} savings={r.comm_savings:.1f}x",
                      flush=True)
    save_rows("tab678", rows)
    return rows


if __name__ == "__main__":
    run()
