"""Quickstart: the paper's core finding in ~60 seconds on CPU.

Trains GN-LeNet on synthetic-CIFAR with 5 decentralized nodes twice —
IID vs 100% skewed label partitions — under Gaia, and shows the accuracy
gap plus communication savings.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.configs.base import CommConfig
from repro.configs.cnn_zoo import CNN_ZOO
from repro.core import partition_label_skew, train_decentralized
from repro.data.synthetic import synth_images


def main():
    ds = synth_images(3000, seed=0, noise=0.8, class_sep=0.35)
    val = synth_images(800, seed=99, noise=0.8, class_sep=0.35)
    cfg = CNN_ZOO["gn-lenet"]
    comm = CommConfig(strategy="gaia", gaia_t0=0.10)

    print(f"model={cfg.name}  K=5 nodes  algo=gaia (T0={comm.gaia_t0})")
    for skew, tag in ((0.0, "IID"), (1.0, "Non-IID")):
        idx = partition_label_skew(ds.y, 5, skew, seed=1)
        parts = [(ds.x[i], ds.y[i]) for i in idx]
        r = train_decentralized(cfg, "gaia", parts, (val.x, val.y),
                                comm=comm, steps=300, batch=20, lr=0.02,
                                eval_every=100)
        print(f"  {tag:8s} val_acc={r.val_acc:.3f}  "
              f"comm_savings={r.comm_savings:.1f}x vs BSP")
    print("\nThe Non-IID drop at identical hyper-parameters is the paper's "
          "headline finding (Fig. 1).")


if __name__ == "__main__":
    main()
