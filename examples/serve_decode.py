"""Serving example: batched greedy decoding with a ring-buffer KV cache,
using the same serve_step the decode dry-runs lower.

Demonstrates all three cache families: GQA KV cache (qwen3), compressed
MLA cache (deepseek-lite), and constant-size SSM state (mamba2).

  PYTHONPATH=src python examples/serve_decode.py [--arch qwen3-0.6b]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.launch.steps import make_serve_step
from repro.models.model import init_cache, init_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    B = args.batch
    cache = init_cache(cfg, B, args.cache_len)
    serve = jax.jit(make_serve_step(cfg))

    key = jax.random.PRNGKey(7)
    prompt = jax.random.randint(key, (B, args.prompt_len), 0, cfg.vocab)
    extra = {}
    if cfg.encoder is not None:
        extra["frames"] = jax.random.normal(
            key, (B, cfg.encoder.n_frames, cfg.modality.feat_dim))

    # prefill by stepping through prompt tokens (serve_step is one-token)
    tok = prompt[:, 0]
    t0 = time.time()
    for t in range(args.prompt_len):
        batch = {"token": prompt[:, t], "t": jnp.full((B,), t, jnp.int32),
                 **extra}
        tok, cache = serve(params, cache, batch)
    generated = [tok]
    for t in range(args.prompt_len, args.prompt_len + args.gen_len - 1):
        batch = {"token": tok, "t": jnp.full((B,), t, jnp.int32), **extra}
        tok, cache = serve(params, cache, batch)
        generated.append(tok)
    gen = jnp.stack(generated, axis=1)
    dt = time.time() - t0
    n_tok = B * (args.prompt_len + args.gen_len - 1)
    print(f"arch={args.arch} (reduced)  batch={B}")
    print(f"generated {gen.shape[1]} tokens/request in {dt:.2f}s "
          f"({n_tok/dt:.0f} tok/s on CPU)")
    for b in range(min(B, 2)):
        print(f"  request {b}: {list(map(int, gen[b, :16]))} ...")
    cache_kinds = {"ssm": "constant SSM state", "hybrid": "RG-LRU + ring KV",
                   "moe": "compressed MLA c_kv"}
    print(f"cache family: "
          f"{cache_kinds.get(cfg.family, 'ring-buffer KV')}")


if __name__ == "__main__":
    main()
