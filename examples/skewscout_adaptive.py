"""SkewScout in action: the same training job under mild and heavy skew.

Watch the controller probe remote partitions (model traveling), measure
accuracy loss, and walk Gaia's significance threshold up (mild skew: save
communication) or down (heavy skew: protect accuracy) — Eq. 1 of §7.2.

  PYTHONPATH=src python examples/skewscout_adaptive.py
"""
import sys

sys.path.insert(0, "src")

from repro.configs.base import CommConfig
from repro.configs.cnn_zoo import CNN_ZOO
from repro.core import partition_label_skew, train_decentralized
from repro.data.synthetic import synth_images


def main():
    ds = synth_images(3000, seed=0, noise=0.8, class_sep=0.35)
    val = synth_images(800, seed=99, noise=0.8, class_sep=0.35)
    cfg = CNN_ZOO["gn-lenet"]

    for skew, tag in ((0.2, "mild skew (20%)"), (1.0, "full label skew")):
        idx = partition_label_skew(ds.y, 5, skew, seed=1)
        parts = [(ds.x[i], ds.y[i]) for i in idx]
        comm = CommConfig(skewscout=True, travel_every=40, sigma_al=0.05,
                          lambda_al=50.0, lambda_c=1.0, tuner="hill")
        r = train_decentralized(cfg, "gaia", parts, (val.x, val.y),
                                comm=comm, steps=400, batch=20, lr=0.02,
                                eval_every=200, theta_start_index=3)
        print(f"\n=== {tag} ===")
        print(f"final val_acc={r.val_acc:.3f}  "
              f"comm_savings={r.comm_savings:.1f}x vs BSP")
        print("travel log (step: theta -> new_theta, measured AL):")
        for h in r.skewscout_history:
            print(f"  step {h.step:4d}: T0={h.theta:<5} "
                  f"AL={h.accuracy_loss:.3f} C/CM={h.comm_ratio:.4f} "
                  f"J={h.objective:.3f} -> T0={h.new_theta}")


if __name__ == "__main__":
    main()
