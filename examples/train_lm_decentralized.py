"""End-to-end driver: decentralized training of a transformer LM with the
production step functions (the same code path the dry-run lowers for the
512-chip mesh), on CPU with a reduced model.

Two pods x (data, model) mesh on 8 fake host devices; the configured
strategy controls the cross-pod exchange — Gaia's masked psum, or the
D-PSGD/AD-PSGD gossip ring over a topology fabric (per-round neighbor
operands, so a rotating schedule reuses one compilation).  Trains a
~10M-param qwen3-family model on synthetic Markov token streams for a
few hundred steps and reports the loss curve and cross-pod communication.

  PYTHONPATH=src python examples/train_lm_decentralized.py \
      [--steps 200] [--strategy gaia|dpsgd|adpsgd] [--topology ring] \
      [--d-model 256] [--layers 4]
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CommConfig, FabricConfig
from repro.configs.registry import get_config
from repro.data.synthetic import synth_tokens
from repro.launch.sharding import batch_shardings, train_state_shardings
from repro.launch.steps import (GOSSIP_STRATEGIES, gossip_operands,
                                make_train_state, make_train_step)
from repro.models.model import init_model
from repro.models.shard_hints import activation_sharding
from repro.checkpointing import save
from repro.topology.graphs import build_demo_schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--strategy", default="gaia",
                    choices=["bsp", "gaia", "fedavg", "dgc",
                             "dpsgd", "adpsgd"])
    ap.add_argument("--topology", default="ring",
                    help="gossip fabric across the two pods")
    ap.add_argument("--staleness", type=int, default=1,
                    help="adpsgd staleness rung (<= max_staleness=2)")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch-per-pod", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    base = get_config("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(
        base, n_layers=args.layers, d_model=args.d_model,
        d_ff=args.d_model * 3, vocab=512,
        attention=dataclasses.replace(
            base.attention, n_heads=4, n_kv_heads=2,
            head_dim=args.d_model // 4))
    n_params = cfg.n_params()
    print(f"arch=qwen3-family reduced  params~{n_params/1e6:.1f}M  "
          f"strategy={args.strategy}")

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    comm = CommConfig(strategy=args.strategy,
                      fabric=FabricConfig(topology=args.topology),
                      gaia_t0=0.05, iter_local=10, dgc_sparsity=0.95)
    params = init_model(jax.random.PRNGKey(0), cfg)
    state = make_train_state(params, comm, 2)

    data = synth_tokens(512, args.seq + 1, vocab=cfg.vocab, seed=0)
    rng = np.random.default_rng(0)

    def next_batch():
        idx = rng.integers(0, data.tokens.shape[0],
                           size=(2, args.batch_per_pod))
        seqs = data.tokens[idx]
        return {"tokens": jnp.asarray(seqs[..., :-1]),
                "labels": jnp.asarray(seqs[..., 1:])}

    gossip = args.strategy in GOSSIP_STRATEGIES
    # label-aware fabrics get the synthetic full-skew histogram (the
    # Markov stream has no labels to derive one from)
    sched = build_demo_schedule(args.topology, 2) if gossip else None
    with mesh, activation_sharding(mesh):
        s_shard = train_state_shardings(jax.eval_shape(lambda: state), mesh)
        b_shard = batch_shardings(jax.eval_shape(next_batch), mesh,
                                  pod_stacked=True)
        in_sh = (s_shard, b_shard, None) + ((None,) if gossip else ())
        step_fn = jax.jit(
            make_train_step(cfg, comm, mesh=mesh, lr=args.lr, remat=False,
                            chunk=64),
            in_shardings=in_sh,
            # pin the state outputs to the canonical shardings so step t's
            # output is bit-compatible with step t+1's in_shardings (GSPMD
            # may otherwise pick a different layout for e.g. vel)
            out_shardings=(s_shard, None), donate_argnums=(0,))
        t0 = time.time()
        for t in range(args.steps):
            extra = ()
            if gossip:
                # per-round runtime operands: a rotating schedule (and a
                # staleness move) reuses the one compilation
                extra = (gossip_operands(
                    sched, t,
                    staleness=args.staleness
                    if args.strategy == "adpsgd" else None,
                    max_staleness=comm.max_staleness),)
            state, metrics = step_fn(state, next_batch(), jnp.int32(t),
                                     *extra)
            if t % 20 == 0 or t == args.steps - 1:
                print(f"step {t:4d}  loss={float(metrics['loss']):.4f}  "
                      f"({(time.time()-t0):.1f}s)", flush=True)
    final = float(metrics["loss"])
    print(f"done: loss {final:.4f} (random = ln(512) = 6.24)")
    if args.ckpt:
        save(args.ckpt, jax.device_get(state["params"]), step=args.steps)
        print(f"checkpoint written to {args.ckpt}")
    assert final < 5.5, "LM failed to learn Markov structure"


if __name__ == "__main__":
    main()
