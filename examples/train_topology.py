"""Geo-WAN scenario end-to-end: gossip (D-PSGD) training over a
hierarchical topology — datacenters of LAN-connected nodes joined by
scarce WAN links — with link-level cost accounting.

Compares three fabrics on the same skewed partitions:
  full     all-to-all gossip (BSP-quality, every pair is a link)
  ring     minimal bandwidth, slowest consensus
  geo-wan  LAN cliques + WAN gateway mesh (the paper's Gaia deployment)

and prints each run's accuracy next to its LAN/WAN traffic split and the
simulated wall-clock time under the geo-wan link profile (10 Gb/s LAN,
100 Mb/s + 50 ms WAN).

  PYTHONPATH=src python examples/train_topology.py [--steps 200] [--skew 1.0]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs.base import CommConfig, FabricConfig
from repro.configs.cnn_zoo import CNN_ZOO
from repro.core.partition import partition_label_skew
from repro.core.trainer import train_decentralized
from repro.data.synthetic import synth_images
from repro.topology import LINK_PROFILES, build_topology


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--skew", type=float, default=1.0)
    ap.add_argument("--nodes", type=int, default=6)
    args = ap.parse_args()

    ds = synth_images(2400, seed=0, noise=0.8, class_sep=0.35, n_classes=6)
    val = synth_images(600, seed=99, noise=0.8, class_sep=0.35, n_classes=6)
    idx = partition_label_skew(ds.y, args.nodes, args.skew, seed=1)
    parts = [(ds.x[i], ds.y[i]) for i in idx]

    print(f"K={args.nodes} nodes, skew={args.skew}, "
          f"link profile: {LINK_PROFILES['geo-wan']}")
    for name in ("full", "ring", "geo-wan"):
        topo = build_topology(name, args.nodes)
        print(f"\n== {name}: {len(topo.edges)} edges "
              f"({len(topo.wan_edge_indices())} WAN), "
              f"spectral gap {topo.spectral_gap():.3f}")
        comm = CommConfig(strategy="dpsgd",
                          fabric=FabricConfig(topology=name,
                                              profile="geo-wan"))
        r = train_decentralized(
            CNN_ZOO["gn-lenet"], "dpsgd", parts, (val.x, val.y),
            comm=comm, steps=args.steps, batch=20, lr=0.02,
            eval_every=max(args.steps // 2, 1))
        led = r.extras["ledger"]
        print(f"   val_acc={r.val_acc:.3f}")
        print(f"   traffic: LAN {led['lan_floats']/1e6:.1f}M floats, "
              f"WAN {led['wan_floats']/1e6:.1f}M floats")
        print(f"   simulated wall-clock: {led['sim_time_s']:.2f}s "
              f"({led['sim_time_s']/args.steps*1e3:.1f} ms/step)")


if __name__ == "__main__":
    main()
