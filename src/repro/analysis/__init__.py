"""`repro.analysis` — the static-analysis subsystem.

Four passes, one CLI (``python -m repro.analysis``), wired into
``make lint-deep`` and the CI fast gate:

* :mod:`repro.analysis.astlint` — AST invariant lints (RA1xx):
  unkeyed randomness, host syncs in jitted code, jit-in-loop
  recompilation, broad excepts.
* :mod:`repro.analysis.parity` — kernel registry parity (PA3xx): every
  public op in ``kernels/ops.py`` must have its ref oracle, dispatch
  entry, bench row, and a test.
* :mod:`repro.analysis.graph_audit` — compiled-graph audit (GA2xx)
  over the partitioned HLO: pod-axis discipline, wire-dtype widening,
  host callbacks, donation drift.  Built on the HLO parser
  (:mod:`repro.analysis.hlo`, moved here from
  ``repro.launch.hlo_analysis``).
* :mod:`repro.analysis.jaxpr_audit` — pre-lowering dataflow audit
  (JA4xx) over ``jax.make_jaxpr`` output: host callbacks, wire-dtype
  widening into collectives, off-pod-axis collectives, large closed
  constants, unkeyed RNG — caught at trace time, before XLA folds
  them.  Cheap enough to sweep every strategy x topology combo
  (``audit_combos``).

Findings are suppressible per line (``# repro-allow: <rule>``) and
grandfatherable via a baseline file (see :mod:`repro.analysis.base`).

This module imports no JAX — the AST and parity passes run anywhere;
only the CLI's graph-compile mode touches the launch stack.
"""
from repro.analysis.base import (Finding, apply_baseline, load_baseline,
                                 write_baseline)
from repro.analysis import astlint, graph_audit, jaxpr_audit, parity
from repro.analysis.astlint import lint_file, lint_paths
from repro.analysis.parity import check_parity
from repro.analysis.graph_audit import GraphAudit, audit_hlo
from repro.analysis.jaxpr_audit import (JaxprAudit, audit_combos,
                                        audit_jaxpr)

#: every rule id -> short name, across the four passes
ALL_RULES = {**astlint.RULES, **parity.RULES, **graph_audit.RULES,
             **jaxpr_audit.RULES}

__all__ = ["Finding", "apply_baseline", "load_baseline", "write_baseline",
           "lint_file", "lint_paths", "check_parity", "GraphAudit",
           "audit_hlo", "JaxprAudit", "audit_combos", "audit_jaxpr",
           "ALL_RULES"]
