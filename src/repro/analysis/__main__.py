"""CLI runner: ``python -m repro.analysis`` — the lint-deep gate.

Runs the AST lints and the registry-parity check, then (unless
``--skip-graph``) the two graph passes:

* the **jaxpr sweep** — trace + audit every strategy x topology combo
  plus the prefill/decode graphs (JA4xx, pre-lowering, no XLA: the
  whole matrix costs less than one compile);
* the **HLO audit** (GA2xx, post-XLA) — either over a saved HLO text
  (``--graph-hlo``), the single reduced pod-gossip combo the CI dryrun
  smoke compiles (default), or the entire audit matrix
  (``--all-combos``, the CI full job).

Emits ``out/AUDIT.json`` — findings, the rule registry, and a coverage
matrix (combo -> rules run -> findings) so CI can assert nothing in the
matrix is silently unaudited — and exits non-zero on any finding not
grandfathered by the baseline file.

  PYTHONPATH=src python -m repro.analysis                   # fast gate
  PYTHONPATH=src python -m repro.analysis --all-combos      # full matrix
  PYTHONPATH=src python -m repro.analysis --skip-graph      # AST+parity
  PYTHONPATH=src python -m repro.analysis --graph-hlo step.hlo \
      --devices-per-pod 2 --wire-dtype bf16
  PYTHONPATH=src python -m repro.analysis --update-baseline # grandfather

Baseline: ``.lint-deep-baseline.json`` at the repo root (JSON list of
finding fingerprints).  Baselined findings are reported but do not
fail the gate; ``--update-baseline`` rewrites the file from the
current findings (pruning stale entries); ``--fail-on-stale`` turns
stale entries — fingerprints matching no current finding — into a
failure so they cannot accumulate.  Per-line suppressions:
``# repro-allow: <rule>``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional, Tuple

from repro.analysis import (ALL_RULES, Finding, apply_baseline, astlint,
                            check_parity, graph_audit, jaxpr_audit,
                            load_baseline, write_baseline)

BASELINE_NAME = ".lint-deep-baseline.json"

#: the default (fast-gate) HLO compile target: the same reduced
#: pod-gossip combo the CI dryrun smoke exercises (2 pods x 2 data x
#: 2 model on forced host devices)
_GRAPH_SHAPE = "train_4k"
_GRAPH_STRATEGY = "dpsgd"
_GRAPH_TOPOLOGY = "ring"


def _repo_root() -> str:
    """<root>/src/repro/analysis/__main__.py -> <root>."""
    here = os.path.abspath(os.path.dirname(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


def _from_json(d: Dict) -> Finding:
    return Finding(rule=d["rule"], path=d["path"], line=d["line"],
                   message=d["message"], source=d["source"])


def _graph_pass_compile(combos, verbose: bool
                        ) -> List[Tuple[str, Optional[Dict],
                                        List[Finding], Optional[str]]]:
    """Lower + compile each combo and audit its HLO (via the audit
    ``dryrun_one`` runs on every graph).  Returns
    ``[(combo, audit_json, findings, error)]`` — a combo that fails to
    compile stays in the matrix as an errored row.  Imported late:
    ``repro.launch.dryrun`` must set XLA_FLAGS before anything touches
    jax."""
    from repro.launch import dryrun
    mesh = dryrun._parse_mesh(dryrun.SWEEP_MESH)
    rows = []
    for shape_name, strat, topo in combos:
        combo = f"{shape_name}/{strat or '-'}/{topo or '-'}"
        try:
            rep = dryrun.dryrun_one(
                dryrun.SWEEP_ARCH, shape_name, reduced=True, mesh=mesh,
                strategy=strat, topology=topo, verbose=verbose,
                audit_fail="none")
            aj = rep["audit"]
            rows.append((combo, aj,
                         [_from_json(d) for d in aj["findings"]], None))
        except Exception as e:  # repro-allow: RA104 — matrix driver: a
            #                     broken combo must stay a visible row,
            #                     not abort the remaining compiles
            rows.append((combo, None, [], f"{type(e).__name__}: {e}"))
            if verbose:
                print(f"[analysis] {combo}: compile FAILED "
                      f"({type(e).__name__}: {e})")
    return rows


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo static analysis: AST lints, registry parity, "
                    "jaxpr dataflow audit, HLO graph audit")
    ap.add_argument("--root", default=_repo_root(),
                    help="repo root (default: inferred from the package)")
    ap.add_argument("--skip-graph", action="store_true",
                    help="AST + parity only (no trace, no compile, "
                         "no jax)")
    ap.add_argument("--all-combos", action="store_true",
                    help="compile + HLO-audit EVERY combo in the audit "
                         "matrix instead of the single smoke combo "
                         "(the jaxpr sweep always covers the matrix)")
    ap.add_argument("--graph-hlo", default=None,
                    help="audit this saved HLO text instead of compiling")
    ap.add_argument("--devices-per-pod", type=int, default=None,
                    help="pod size for --graph-hlo pod-axis checks")
    ap.add_argument("--wire-dtype", default=None,
                    help="expected wire dtype for --graph-hlo (e.g. bf16;"
                         " default: inferred from entry parameters)")
    ap.add_argument("--expect-donation", action="store_true",
                    help="--graph-hlo: fail if no input_output_alias map")
    ap.add_argument("--const-threshold", type=int,
                    default=jaxpr_audit.CONST_THRESHOLD_BYTES,
                    help="JA404 closed-constant size threshold in bytes")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="write the machine-readable audit here "
                         "(default: <root>/out/AUDIT.json)")
    ap.add_argument("--baseline", default=None,
                    help=f"fingerprint baseline (default: "
                         f"<root>/{BASELINE_NAME})")
    ap.add_argument("--update-baseline", action="store_true",
                    help="grandfather the current findings (pruning "
                         "stale fingerprints) and exit 0")
    ap.add_argument("--fail-on-stale", action="store_true",
                    help="fail when the baseline carries fingerprints "
                         "matching no current finding (CI hygiene)")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)

    root = os.path.abspath(args.root)
    t0 = time.time()
    findings: List[Finding] = []

    findings += astlint.lint_paths(root)
    n_ast = len(findings)
    findings += check_parity(root)
    n_parity = len(findings) - n_ast

    # ---- jaxpr sweep: the whole matrix, every run (trace-only) ----
    jaxpr_rows = None
    if not args.skip_graph and not args.graph_hlo:
        jaxpr_rows = jaxpr_audit.audit_combos(
            const_threshold_bytes=args.const_threshold,
            verbose=not args.quiet)
        for _, ja in jaxpr_rows:
            findings += ja.findings
    n_jaxpr = len(findings) - n_ast - n_parity

    # ---- HLO audit: saved text, smoke combo, or the full matrix ----
    graph_summary = None
    graph_rows = None
    if args.graph_hlo:
        with open(args.graph_hlo, encoding="utf-8") as f:
            text = f.read()
        ga = graph_audit.audit_hlo(
            text, tag=f"hlo:{os.path.basename(args.graph_hlo)}",
            devices_per_pod=args.devices_per_pod,
            expected_wire_dtype=args.wire_dtype,
            expect_donation=args.expect_donation)
        findings += ga.findings
        graph_summary = ga.to_json()
    elif not args.skip_graph:
        from repro.launch.dryrun import iter_combos
        combos = (list(iter_combos()) if args.all_combos
                  else [(_GRAPH_SHAPE, _GRAPH_STRATEGY, _GRAPH_TOPOLOGY)])
        graph_rows = _graph_pass_compile(combos, verbose=not args.quiet)
        for _, _, fs, _ in graph_rows:
            findings += fs
        if len(graph_rows) == 1:
            graph_summary = graph_rows[0][1]
    n_graph = len(findings) - n_ast - n_parity - n_jaxpr

    baseline_path = args.baseline or os.path.join(root, BASELINE_NAME)
    if args.update_baseline:
        stale = apply_baseline(findings, load_baseline(baseline_path))
        write_baseline(baseline_path, findings)
        print(f"[analysis] baselined {len(findings)} finding(s) "
              f"({len(stale)} stale fingerprint(s) pruned) -> "
              f"{baseline_path}")
        return 0
    stale = apply_baseline(findings, load_baseline(baseline_path))
    failing = [f for f in findings if not f.baselined]
    compile_errors = [(c, err) for c, _, _, err in (graph_rows or [])
                      if err]

    # the coverage matrix: one row per combo, jaxpr + (when compiled)
    # HLO columns — built AFTER apply_baseline so the rows carry the
    # baselined flags CI consumes
    coverage = None
    if jaxpr_rows is not None:
        hlo_by_combo = {c: (aj, fs, err)
                        for c, aj, fs, err in (graph_rows or [])}
        coverage = []
        for combo, ja in jaxpr_rows:
            row = {"combo": combo,
                   "jaxpr": {"rules": sorted(jaxpr_audit.RULES),
                             **ja.to_json()},
                   "hlo": None}
            if combo in hlo_by_combo:
                aj, fs, err = hlo_by_combo[combo]
                row["hlo"] = {"rules": sorted(graph_audit.RULES),
                              "error": err, **(aj or {})}
                if aj is not None:
                    row["hlo"]["findings"] = [f.to_json() for f in fs]
            coverage.append(row)

    ok = (not failing and not compile_errors
          and not (stale and args.fail_on_stale))
    json_out = args.json_out or os.path.join(root, "out", "AUDIT.json")
    payload = {
        "ok": ok,
        "elapsed_s": round(time.time() - t0, 2),
        "counts": {"ast": n_ast, "parity": n_parity, "jaxpr": n_jaxpr,
                   "graph": n_graph,
                   "baselined": len(findings) - len(failing)},
        "stale_baseline": stale,
        "compile_errors": [f"{c}: {e}" for c, e in compile_errors],
        "rules": ALL_RULES,
        "findings": [f.to_json() for f in findings],
        "graph": graph_summary,
        "coverage": coverage,
    }
    d = os.path.dirname(json_out)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(json_out, "w", encoding="utf-8") as f:
        json.dump(payload, f, indent=1)

    for f in findings:
        print(f"[analysis] {f.format()}")
    for fp in stale:
        print(f"[analysis] stale baseline fingerprint: {fp!r} matches "
              "no current finding (prune with --update-baseline)")
    print(f"[analysis] ast={n_ast} parity={n_parity} jaxpr={n_jaxpr} "
          f"graph={n_graph} ({len(findings) - len(failing)} baselined, "
          f"{len(stale)} stale) in {payload['elapsed_s']}s -> {json_out}")
    if failing:
        print(f"[analysis] FAIL: {len(failing)} finding(s); suppress a "
              "line with `# repro-allow: <rule>` or grandfather with "
              "--update-baseline")
        return 1
    if compile_errors:
        print(f"[analysis] FAIL: {len(compile_errors)} combo(s) failed "
              "to compile — the matrix has unaudited rows")
        return 1
    if stale and args.fail_on_stale:
        print(f"[analysis] FAIL: {len(stale)} stale baseline "
              "fingerprint(s); prune with --update-baseline")
        return 1
    print("[analysis] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
