"""CLI runner: ``python -m repro.analysis`` — the lint-deep gate.

Runs the AST lints and the registry-parity check, then (unless
``--skip-graph``) the graph auditor: either over a saved HLO text
(``--graph-hlo``) or by lowering + compiling the reduced pod-gossip
train step on a tiny forced-host-device mesh, exactly like the CI
dryrun smoke.  Emits ``out/AUDIT.json`` and exits non-zero on any
finding not grandfathered by the baseline file.

  PYTHONPATH=src python -m repro.analysis                   # full gate
  PYTHONPATH=src python -m repro.analysis --skip-graph      # AST+parity
  PYTHONPATH=src python -m repro.analysis --graph-hlo step.hlo \
      --devices-per-pod 2 --wire-dtype bf16
  PYTHONPATH=src python -m repro.analysis --update-baseline # grandfather

Baseline: ``.lint-deep-baseline.json`` at the repo root (JSON list of
finding fingerprints).  Baselined findings are reported but do not
fail the gate; ``--update-baseline`` rewrites the file from the
current findings.  Per-line suppressions: ``# repro-allow: <rule>``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import List, Optional

from repro.analysis import (ALL_RULES, Finding, apply_baseline, astlint,
                            check_parity, graph_audit, load_baseline,
                            write_baseline)

BASELINE_NAME = ".lint-deep-baseline.json"

#: the graph pass's auto-compile target: the same reduced pod-gossip
#: combo the CI dryrun smoke exercises (2 pods x 2 data x 2 model on
#: forced host devices)
_GRAPH_ARCH = "qwen3-0.6b"
_GRAPH_SHAPE = "train_4k"
_GRAPH_STRATEGY = "dpsgd"
_GRAPH_TOPOLOGY = "ring"
_GRAPH_MESH = "2,2,2"


def _repo_root() -> str:
    """<root>/src/repro/analysis/__main__.py -> <root>."""
    here = os.path.abspath(os.path.dirname(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


def _graph_pass_compile(verbose: bool) -> graph_audit.GraphAudit:
    """Lower + compile the reduced gossip step and audit its HLO.
    Imported late: ``repro.launch.dryrun`` must set XLA_FLAGS before
    anything touches jax."""
    from repro.launch.dryrun import _parse_mesh, dryrun_one
    from repro.launch.mesh import devices_per_pod
    mesh = _parse_mesh(_GRAPH_MESH)
    rep = dryrun_one(_GRAPH_ARCH, _GRAPH_SHAPE, reduced=True, mesh=mesh,
                     strategy=_GRAPH_STRATEGY, topology=_GRAPH_TOPOLOGY,
                     return_hlo=True, verbose=verbose)
    tag = (f"dryrun:{_GRAPH_ARCH}/{_GRAPH_SHAPE}/{_GRAPH_STRATEGY}/"
           f"{_GRAPH_TOPOLOGY}@{_GRAPH_MESH}")
    return graph_audit.audit_hlo(
        rep["_hlo"], tag=tag, devices_per_pod=devices_per_pod(mesh),
        expect_donation=True)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo static analysis: AST lints, registry parity, "
                    "HLO graph audit")
    ap.add_argument("--root", default=_repo_root(),
                    help="repo root (default: inferred from the package)")
    ap.add_argument("--skip-graph", action="store_true",
                    help="AST + parity only (no compile, no jax)")
    ap.add_argument("--graph-hlo", default=None,
                    help="audit this saved HLO text instead of compiling")
    ap.add_argument("--devices-per-pod", type=int, default=None,
                    help="pod size for --graph-hlo pod-axis checks")
    ap.add_argument("--wire-dtype", default=None,
                    help="expected wire dtype for --graph-hlo (e.g. bf16;"
                         " default: inferred from entry parameters)")
    ap.add_argument("--expect-donation", action="store_true",
                    help="--graph-hlo: fail if no input_output_alias map")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="write the machine-readable audit here "
                         "(default: <root>/out/AUDIT.json)")
    ap.add_argument("--baseline", default=None,
                    help=f"fingerprint baseline (default: "
                         f"<root>/{BASELINE_NAME})")
    ap.add_argument("--update-baseline", action="store_true",
                    help="grandfather the current findings and exit 0")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)

    root = os.path.abspath(args.root)
    t0 = time.time()
    findings: List[Finding] = []

    findings += astlint.lint_paths(root)
    n_ast = len(findings)
    findings += check_parity(root)
    n_parity = len(findings) - n_ast

    graph_summary = None
    if args.graph_hlo:
        with open(args.graph_hlo, encoding="utf-8") as f:
            text = f.read()
        ga = graph_audit.audit_hlo(
            text, tag=f"hlo:{os.path.basename(args.graph_hlo)}",
            devices_per_pod=args.devices_per_pod,
            expected_wire_dtype=args.wire_dtype,
            expect_donation=args.expect_donation)
        findings += ga.findings
        graph_summary = ga.to_json()
    elif not args.skip_graph:
        ga = _graph_pass_compile(verbose=not args.quiet)
        findings += ga.findings
        graph_summary = ga.to_json()

    baseline_path = args.baseline or os.path.join(root, BASELINE_NAME)
    if args.update_baseline:
        write_baseline(baseline_path, findings)
        print(f"[analysis] baselined {len(findings)} finding(s) -> "
              f"{baseline_path}")
        return 0
    apply_baseline(findings, load_baseline(baseline_path))
    failing = [f for f in findings if not f.baselined]

    json_out = args.json_out or os.path.join(root, "out", "AUDIT.json")
    payload = {
        "ok": not failing,
        "elapsed_s": round(time.time() - t0, 2),
        "counts": {"ast": n_ast, "parity": n_parity,
                   "graph": len(findings) - n_ast - n_parity,
                   "baselined": len(findings) - len(failing)},
        "rules": ALL_RULES,
        "findings": [f.to_json() for f in findings],
        "graph": graph_summary,
    }
    d = os.path.dirname(json_out)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(json_out, "w", encoding="utf-8") as f:
        json.dump(payload, f, indent=1)

    for f in findings:
        print(f"[analysis] {f.format()}")
    graph_n = payload["counts"]["graph"]
    print(f"[analysis] ast={n_ast} parity={n_parity} graph={graph_n} "
          f"({len(findings) - len(failing)} baselined) in "
          f"{payload['elapsed_s']}s -> {json_out}")
    if failing:
        print(f"[analysis] FAIL: {len(failing)} finding(s); suppress a "
              "line with `# repro-allow: <rule>` or grandfather with "
              "--update-baseline")
        return 1
    print("[analysis] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
