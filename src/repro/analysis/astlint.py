"""AST invariant lints: the statically-detectable half of every
correctness incident this repo has shipped a fix for.

Rules (ids are stable; suppress per-line with ``# repro-allow: <id>``):

* **RA101 unkeyed-randomness** — ``np.random.<fn>()`` module-level draws
  (global mutable RNG state) and argless ``default_rng()``.  Every draw
  in this repo must be a pure function of an explicit seed — Li et
  al.'s non-IID silos study (PAPERS.md) shows unreproducible
  partition/seed handling invalidates whole experiment grids, and the
  seeded-replay tests (``tests/test_links.py``) only hold when nothing
  draws from ambient state.  Keyed constructions
  (``default_rng(seed)``, ``Generator(PCG64(seed))``) pass;
  ``kernels/rng.py`` (the counter-hash RNG all in-kernel draws key
  from) is allow-listed wholesale.
* **RA102 host-sync-in-jit** — ``.item()``, or ``float()``/``int()``/
  ``bool()``/``np.asarray()``/``np.array()`` applied directly to a
  function parameter, inside a jit-decorated function (or a lambda
  handed straight to ``jax.jit``).  On traced values these force a
  device->host sync per call (or a tracer leak); scalars that must be
  read back belong outside the jitted step.
* **RA103 jit-in-loop** — ``jax.jit(...)`` called (or a jit-decorated
  ``def``) inside a ``for``/``while`` body.  A fresh jit per iteration
  retraces and recompiles every round — the compile-once discipline the
  ``trace_count`` tests enforce dynamically, checked statically.
* **RA104 broad-except** — bare ``except:`` / ``except Exception`` /
  ``except BaseException``.  The launch-path drift incidents (PR 4) hid
  behind exactly this kind of swallow-everything handler; sites that
  genuinely mean "any failure = this path is unsupported" carry an
  inline ``# repro-allow: RA104`` with their justification.
"""
from __future__ import annotations

import ast
import fnmatch
import os
from typing import Dict, List, Optional, Sequence

from repro.analysis.base import (Finding, SourceFile, iter_py_files,
                                 load_source)

RULES: Dict[str, str] = {
    "RA100": "syntax-error",
    "RA101": "unkeyed-randomness",
    "RA102": "host-sync-in-jit",
    "RA103": "jit-in-loop",
    "RA104": "broad-except",
}

#: directories linted by default (repo-relative)
DEFAULT_SUBDIRS = ("src/repro", "benchmarks", "examples")

#: per-rule path allow-list (repo-relative glob): the whole file is
#: exempt from that rule.  kernels/rng.py IS the keyed RNG substrate —
#: its tests-of-randomness idioms are the one place raw draws belong.
RULE_ALLOW_PATHS: Dict[str, Sequence[str]] = {
    "RA101": ("src/repro/kernels/rng.py",),
}

#: np.random attributes that are keyed-RNG *constructors*, not draws
#: from the module-level global generator
_KEYED_CONSTRUCTORS = {"default_rng", "Generator", "SeedSequence",
                       "PCG64", "Philox", "SFC64", "MT19937",
                       "BitGenerator", "RandomState"}

_HOST_CASTS = {"float", "int", "bool"}
_NP_HOST_FNS = {"asarray", "array"}


def _is_np_random_attr(node: ast.AST) -> Optional[str]:
    """If ``node`` is ``np.random.<X>`` / ``numpy.random.<X>``, return X."""
    if not isinstance(node, ast.Attribute):
        return None
    v = node.value
    if (isinstance(v, ast.Attribute) and v.attr == "random"
            and isinstance(v.value, ast.Name)
            and v.value.id in ("np", "numpy")):
        return node.attr
    return None


def _mentions_jit(node: ast.AST) -> bool:
    """Does this (decorator) expression reference a ``jit`` name —
    ``jax.jit``, bare ``jit``, ``functools.partial(jax.jit, ...)``?"""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr == "jit":
            return True
        if isinstance(sub, ast.Name) and sub.id == "jit":
            return True
    return False


def _is_jit_call(node: ast.Call) -> bool:
    """Is this call ``jax.jit(...)`` / ``jit(...)`` (not a decorated-def
    helper like ``functools.partial``)?"""
    f = node.func
    if isinstance(f, ast.Attribute) and f.attr == "jit":
        return True
    if isinstance(f, ast.Name) and f.id == "jit":
        return True
    return False


def _root_name(node: ast.AST) -> Optional[str]:
    """The base Name of a Name/Attribute/Subscript chain, else None."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    if isinstance(node, ast.Name):
        return node.id
    return None


def _param_names(fn: ast.AST) -> List[str]:
    a = fn.args
    names = [p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


class _Linter(ast.NodeVisitor):
    def __init__(self, src: SourceFile):
        self.src = src
        self.findings: List[Finding] = []
        self._loop_depth = 0
        # stack of per-jit-context parameter-name sets; non-empty =>
        # currently inside traced code
        self._jit_params: List[set] = []

    def _emit(self, rule: str, node: ast.AST, message: str) -> None:
        f = self.src.finding(rule, getattr(node, "lineno", 0), message)
        if f is not None:
            self.findings.append(f)

    # ---- loops (RA103 context) ----
    def visit_For(self, node):
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    visit_AsyncFor = visit_For

    def visit_While(self, node):
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    # ---- functions (jit context + RA103 for decorated defs) ----
    def _visit_fn(self, node):
        jitted = any(_mentions_jit(d) for d in node.decorator_list)
        if jitted and self._loop_depth:
            self._emit("RA103", node,
                       f"jit-decorated `{node.name}` defined inside a "
                       "loop: retraces/recompiles every iteration "
                       "(compile once, pass runtime operands instead)")
        if jitted:
            self._jit_params.append(set(_param_names(node)))
        # a nested def body runs at its own call time, not in this loop
        saved, self._loop_depth = self._loop_depth, 0
        self.generic_visit(node)
        self._loop_depth = saved
        if jitted:
            self._jit_params.pop()

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    # ---- except handlers (RA104) ----
    def visit_ExceptHandler(self, node):
        broad = node.type is None
        types = []
        if isinstance(node.type, ast.Tuple):
            types = node.type.elts
        elif node.type is not None:
            types = [node.type]
        for t in types:
            name = t.attr if isinstance(t, ast.Attribute) else \
                t.id if isinstance(t, ast.Name) else ""
            if name in ("Exception", "BaseException"):
                broad = True
        if broad:
            what = "bare `except:`" if node.type is None else \
                "`except Exception`"
            self._emit("RA104", node,
                       f"{what} swallows every failure mode — catch "
                       "concrete exception types, or justify with "
                       "`# repro-allow: RA104`")
        self.generic_visit(node)

    # ---- calls (RA101, RA102, RA103) ----
    def visit_Call(self, node):
        # RA101: np.random.<draw>(...) and argless default_rng()
        attr = _is_np_random_attr(node.func)
        if attr is not None:
            if attr == "default_rng" and not node.args and not node.keywords:
                self._emit("RA101", node,
                           "argless `np.random.default_rng()` draws from "
                           "OS entropy — pass an explicit seed")
            elif attr == "seed":
                self._emit("RA101", node,
                           "`np.random.seed` mutates global RNG state — "
                           "use an explicitly keyed `default_rng(seed)`")
            elif attr not in _KEYED_CONSTRUCTORS:
                self._emit("RA101", node,
                           f"`np.random.{attr}` draws from the global "
                           "generator — use an explicitly keyed "
                           "`default_rng(seed)`")
        elif (isinstance(node.func, ast.Name)
              and node.func.id == "default_rng"
              and not node.args and not node.keywords):
            self._emit("RA101", node,
                       "argless `default_rng()` draws from OS entropy — "
                       "pass an explicit seed")

        # RA103: jax.jit(...) invoked inside a loop body
        if _is_jit_call(node) and self._loop_depth:
            self._emit("RA103", node,
                       "`jax.jit(...)` called inside a loop: a fresh "
                       "jit per iteration recompiles every round "
                       "(hoist it; make changing values runtime operands)")

        # RA102: host syncs in traced code
        if self._jit_params:
            params = self._jit_params[-1]
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "item"
                    and not node.args and not node.keywords):
                self._emit("RA102", node,
                           "`.item()` inside a jitted function forces a "
                           "device->host sync per call (or leaks a "
                           "tracer) — return the array instead")
            fname = node.func.id if isinstance(node.func, ast.Name) else None
            np_attr = None
            if (isinstance(node.func, ast.Attribute)
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id in ("np", "numpy")):
                np_attr = node.func.attr
            hazard = (fname in _HOST_CASTS and fname) or \
                (np_attr in _NP_HOST_FNS and f"np.{np_attr}")
            if hazard and node.args:
                root = _root_name(node.args[0])
                if root in params:
                    self._emit("RA102", node,
                               f"`{hazard}(...)` applied to traced "
                               f"operand `{root}` inside a jitted "
                               "function — host materialization of a "
                               "tracer; keep it a jnp value")

        # a lambda handed straight to jax.jit traces with the lambda's
        # own params — lint its body in jit context
        if _is_jit_call(node):
            for arg in node.args:
                if isinstance(arg, ast.Lambda):
                    self._jit_params.append(set(_param_names(arg)))
                    self.generic_visit(arg)
                    self._jit_params.pop()
                else:
                    self.visit(arg)
            for kw in node.keywords:
                self.visit(kw)
            return
        self.generic_visit(node)


def lint_source(src: SourceFile) -> List[Finding]:
    """All AST findings for one parsed file (path allow-lists applied)."""
    try:
        tree = ast.parse(src.text, filename=src.path)
    except SyntaxError as e:
        return [Finding(rule="RA100", path=src.rel,
                        line=e.lineno or 0,
                        message=f"syntax error: {e.msg}",
                        source="")]
    linter = _Linter(src)
    linter.visit(tree)
    out = []
    for f in linter.findings:
        allows = RULE_ALLOW_PATHS.get(f.rule, ())
        if any(fnmatch.fnmatch(src.rel, pat) for pat in allows):
            continue
        out.append(f)
    return out


def lint_paths(root: str, subdirs: Sequence[str] = DEFAULT_SUBDIRS
               ) -> List[Finding]:
    """Lint every .py file under ``root/<subdir>``."""
    findings: List[Finding] = []
    for path in iter_py_files(root, subdirs):
        findings.extend(lint_source(load_source(path, root)))
    return findings


def lint_file(path: str, root: Optional[str] = None) -> List[Finding]:
    """Lint a single file (tests plant violations through this)."""
    return lint_source(load_source(path, root or os.path.dirname(path)))
