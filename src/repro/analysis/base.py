"""Shared plumbing for the static-analysis passes: the Finding record,
inline suppression comments, and the grandfathered-findings baseline.

Suppression
-----------
A finding is suppressed when the flagged source line carries a marker
naming its rule (or ``RA*``-style family wildcard)::

    except Exception:          # repro-allow: RA104 — any failure = skip

Suppressions are per-line and per-rule by design: a file-wide opt-out
would let a second, unrelated violation ride in on an old comment.

Baseline
--------
``load_baseline``/``write_baseline`` read and write a JSON list of
finding fingerprints.  A fingerprint is ``rule|path|<stripped source
line>`` — line-number free, so grandfathered findings survive unrelated
edits above them but die the moment the flagged line itself changes.
"""
from __future__ import annotations

import json
import os
import re
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional

#: inline suppression marker: ``# repro-allow: RA104`` (comma-separated
#: rule ids; a bare family prefix like ``RA*`` allows the whole class)
_ALLOW_RE = re.compile(r"#\s*repro-allow:\s*([A-Z]{2}[\w*,\s]*)")


@dataclass
class Finding:
    """One rule violation at one site."""
    rule: str                   # e.g. "RA101"
    path: str                   # repo-relative posix path (or HLO tag)
    line: int                   # 1-based; 0 for whole-artifact findings
    message: str
    source: str = ""            # the stripped offending line (fingerprint)
    baselined: bool = False     # grandfathered via the baseline file

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}|{self.path}|{self.source}"

    def format(self) -> str:
        tag = " [baselined]" if self.baselined else ""
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: {self.rule} {self.message}{tag}"

    def to_json(self) -> Dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message, "source": self.source,
                "baselined": self.baselined}


def allowed_rules(line: str) -> List[str]:
    """Rule ids (or family wildcards) named by a suppression marker on
    ``line``; empty when the line has none."""
    m = _ALLOW_RE.search(line)
    if not m:
        return []
    return [r.strip() for r in m.group(1).split(",") if r.strip()]


def is_suppressed(rule: str, line: str) -> bool:
    for allowed in allowed_rules(line):
        if allowed == rule:
            return True
        if allowed.endswith("*") and rule.startswith(allowed[:-1]):
            return True
    return False


@dataclass
class SourceFile:
    """A parsed-for-linting source file: path + line cache, so every
    rule shares one read and suppression checks are O(1)."""
    path: str                   # absolute
    rel: str                    # repo-relative posix
    text: str
    lines: List[str] = field(default_factory=list)

    def __post_init__(self):
        if not self.lines:
            self.lines = self.text.splitlines()

    def line_at(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    def finding(self, rule: str, lineno: int, message: str
                ) -> Optional[Finding]:
        """Build a Finding unless the flagged line suppresses the rule."""
        src = self.line_at(lineno).strip()
        if is_suppressed(rule, src):
            return None
        return Finding(rule=rule, path=self.rel, line=lineno,
                       message=message, source=src)


def load_source(path: str, root: str) -> SourceFile:
    with open(path, encoding="utf-8") as f:
        text = f.read()
    rel = os.path.relpath(path, root).replace(os.sep, "/")
    return SourceFile(path=path, rel=rel, text=text)


def iter_py_files(root: str, subdirs: Iterable[str]) -> List[str]:
    """All .py files under ``root/<subdir>`` for each subdir, sorted."""
    out: List[str] = []
    for sub in subdirs:
        base = os.path.join(root, sub)
        if os.path.isfile(base) and base.endswith(".py"):
            out.append(base)
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            for fn in filenames:
                if fn.endswith(".py"):
                    out.append(os.path.join(dirpath, fn))
    return sorted(out)


# ------------------------------------------------------------- baseline

def load_baseline(path: Optional[str]) -> List[str]:
    if not path or not os.path.exists(path):
        return []
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    if not isinstance(data, list):
        raise ValueError(f"baseline {path}: expected a JSON list of "
                         "fingerprints")
    return [str(x) for x in data]

def write_baseline(path: str, findings: Iterable[Finding]) -> None:
    fps = sorted({f.fingerprint for f in findings})
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(fps, f, indent=1)
        f.write("\n")


def apply_baseline(findings: List[Finding], baseline: List[str]
                   ) -> List[str]:
    """Mark findings whose fingerprint is grandfathered (in place).

    Returns the **stale** fingerprints — baseline entries that matched
    no current finding.  Stale entries accumulate silently as flagged
    lines are fixed or rewritten; the CLI reports them, prunes them on
    ``--update-baseline``, and fails on them under ``--fail-on-stale``.
    """
    known = set(baseline)
    hit = set()
    for f in findings:
        f.baselined = f.fingerprint in known
        if f.baselined:
            hit.add(f.fingerprint)
    return sorted(known - hit)
