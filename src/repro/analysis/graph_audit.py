"""Graph auditor: invariant checks over the partitioned step HLO.

Extends the single pod-exchange check ``launch/dryrun.py`` has enforced
since PR 4 into a general audit of the compiled train-step graph.  The
incidents behind each rule are real: gossip once leaked off the pod
axis, and adpsgd's payload silently widened bf16 to f32 on the wire
until PR 4 pinned the leaf dtype.

Rules:

* **GA201 off-pod-axis** — a cross-pod collective-permute pair does not
  preserve the intra-pod device coordinate: gossip is leaking off the
  ``pod`` mesh axis.
* **GA202 wire-dtype-widening** — a cross-pod transfer ships a floating
  dtype wider than the model's leaf dtype (expected wire dtype inferred
  as the narrowest float among ENTRY parameters unless given): bf16
  payloads must not widen to f32 on the wire.
* **GA203 host-callback** — a host callback (``custom-call`` into a
  Python/host target, or infeed/outfeed) inside the step graph: a
  device->host round-trip per step that no profiler of device time will
  show.
* **GA204 donation-drift** — the entry's ``input_output_alias`` map is
  missing (donation silently lost) or an aliased output's type no
  longer matches its donated parameter (step ``t``'s output cannot feed
  step ``t+1`` without a realloc/reshard).
* **GA205 unclassified-collective** — a collective the pod classifier
  cannot attribute (send/recv, broadcast, unparseable groups):
  cross-pod byte totals would silently understate the exchange.

``audit_hlo`` returns findings plus a machine-readable summary — the
CLI (``python -m repro.analysis``) lands both in ``out/AUDIT.json``.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.analysis.base import Finding
from repro.analysis import hlo

RULES = {
    "GA201": "off-pod-axis",
    "GA202": "wire-dtype-widening",
    "GA203": "host-callback",
    "GA204": "donation-drift",
    "GA205": "unclassified-collective",
}

_FLOAT_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2,
                "f8e4m3fn": 1, "f8e5m2": 1}

_ALIAS_ENTRY_RE = re.compile(
    r"\{([\d,\s]*)\}:\s*\((\d+),\s*\{([\d,\s]*)\}")
_PARAM_NUM_RE = re.compile(r"parameter\((\d+)\)")
_CC_TARGET_RE = re.compile(r'custom_call_target="([^"]+)"')

#: custom-call targets that round-trip through the host per step
_HOST_TARGET_HINTS = ("callback", "host", "py_", "python")


def _first_dtype(type_str: str) -> Optional[str]:
    m = hlo._SHAPE_PIECE.search(type_str)
    return m.group(1) if m else None


def _strip_layout(type_str: str) -> str:
    """Drop layout annotations and inline ``/*index=N*/`` comments:
    ``/*index=5*/f32[1,2]{1,0}`` -> ``f32[1,2]``."""
    s = re.sub(r"/\*.*?\*/", "", type_str)
    return re.sub(r"\]\{[\d,]*\}", "]", s).strip()


def _split_tuple(type_str: str) -> List[str]:
    """Top-level elements of a tuple type string (non-tuples: [self])."""
    s = type_str.strip()
    if not s.startswith("("):
        return [s]
    s = s[1:-1] if s.endswith(")") else s[1:]
    out, depth, start = [], 0, 0
    for i, ch in enumerate(s):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == "," and depth == 0:
            out.append(s[start:i].strip())
            start = i + 1
    tail = s[start:].strip()
    if tail:
        out.append(tail)
    return out


def _navigate(type_str: str, index_path: List[int]) -> Optional[str]:
    """Element type at a nested tuple index path (``[]`` = whole)."""
    cur = type_str
    for i in index_path:
        elems = _split_tuple(cur)
        if i >= len(elems):
            return None
        cur = elems[i]
    return cur


def parse_alias_map(text: str) -> Optional[List[Tuple[List[int], int,
                                                      List[int]]]]:
    """The module's ``input_output_alias`` entries as
    (output index path, param number, param index path), or None when
    the module declares no aliasing at all."""
    # the alias map lives on the HloModule header line; the map nests
    # braces ({0}: (0, {}, may-alias)), so extract the balanced span
    hdr = next((ln for ln in text.splitlines()
                if "input_output_alias=" in ln), None)
    if hdr is None:
        return None
    start = hdr.find("input_output_alias=")
    open_i = hdr.find("{", start)
    if open_i < 0:
        return None
    depth = 0
    close_i = open_i
    for i in range(open_i, len(hdr)):
        depth += hdr[i] == "{"
        depth -= hdr[i] == "}"
        if depth == 0:
            close_i = i
            break
    body = hdr[open_i + 1:close_i]
    entries = []
    for out_idx, pnum, pidx in _ALIAS_ENTRY_RE.findall(body):
        entries.append((
            [int(x) for x in out_idx.replace(" ", "").split(",") if x],
            int(pnum),
            [int(x) for x in pidx.replace(" ", "").split(",") if x]))
    return entries


@dataclass
class GraphAudit:
    """Findings + the machine-readable summary for AUDIT.json."""
    tag: str
    combo: Optional[str] = None
    findings: List[Finding] = field(default_factory=list)
    pod_exchange: Optional[hlo.PodExchange] = None
    expected_wire_dtype: Optional[str] = None
    cross_pod_dtype_bytes: Dict[str, float] = field(default_factory=dict)
    host_callbacks: List[str] = field(default_factory=list)
    donated_pairs: int = 0
    n_params: int = 0

    @property
    def ok(self) -> bool:
        return not self.findings

    def to_json(self) -> Dict:
        pex = None
        if self.pod_exchange is not None:
            p = self.pod_exchange
            pex = {
                "devices_per_pod": p.devices_per_pod,
                "permute_cross_bytes": p.permute_cross_bytes,
                "permute_local_bytes": p.permute_local_bytes,
                "reduce_cross_bytes": p.reduce_cross_bytes,
                "reduce_local_bytes": p.reduce_local_bytes,
                "pod_axis_only": p.pod_axis_only,
                "unparsed": p.unparsed,
            }
        return {
            "tag": self.tag, "combo": self.combo, "ok": self.ok,
            "pod_exchange": pex,
            "expected_wire_dtype": self.expected_wire_dtype,
            "cross_pod_dtype_bytes": self.cross_pod_dtype_bytes,
            "host_callbacks": self.host_callbacks,
            "donated_pairs": self.donated_pairs,
            "n_params": self.n_params,
            "findings": [f.to_json() for f in self.findings],
        }


def _entry(comps: Dict[str, hlo.Computation]
           ) -> Optional[hlo.Computation]:
    return next((c for c in comps.values() if c.is_entry), None)


def infer_wire_dtype(comps: Dict[str, hlo.Computation]) -> Optional[str]:
    """Narrowest floating dtype among ENTRY parameters — the model's
    leaf dtype, i.e. the widest thing that should legitimately cross
    pods in a gossip exchange."""
    ent = _entry(comps)
    if ent is None:
        return None
    best: Optional[str] = None
    for ins in ent.instrs:
        if ins.op != "parameter":
            continue
        for m in hlo._SHAPE_PIECE.finditer(ins.type_str):
            dt = m.group(1)
            if dt in _FLOAT_BYTES and (
                    best is None
                    or _FLOAT_BYTES[dt] < _FLOAT_BYTES[best]):
                best = dt
    return best


def audit_hlo(text: str, *, tag: str = "<hlo>",
              combo: Optional[str] = None,
              devices_per_pod: Optional[int] = None,
              expected_wire_dtype: Optional[str] = None,
              check_wire_dtype: bool = True,
              check_pod_axis: bool = True,
              expect_donation: bool = False) -> GraphAudit:
    """Audit one partitioned HLO module.

    ``combo`` labels the sweep row (``shape/strategy/topology``) this
    module came from — the coverage matrix in AUDIT.json keys on it.
    ``devices_per_pod`` enables the pod-axis / cross-pod rules (GA201,
    GA202 restricted to cross-pod transfers, GA205); without it GA202
    considers every collective-permute a wire transfer.
    ``check_pod_axis=False`` disables GA201 while keeping the
    pod-exchange report and GA205: the coordinate-preservation
    invariant is a *gossip-exchange* contract — non-gossip strategies
    legitimately let GSPMD reshard with arbitrary cross-pod permutes.
    ``expect_donation`` turns a missing ``input_output_alias`` map into
    a GA204 finding (train steps donate their state; serve/prefill
    don't have to).
    """
    rep = GraphAudit(tag=tag, combo=combo)
    comps = hlo.parse_module(text)
    mult = hlo._multiplicities(comps)

    def emit(rule: str, message: str, source: str) -> None:
        rep.findings.append(Finding(rule=rule, path=tag, line=0,
                                    message=message, source=source))

    # ---- pod-axis classification (GA201 / GA205) ----
    if devices_per_pod is not None:
        pex = hlo.pod_exchange_report(text, devices_per_pod)
        rep.pod_exchange = pex
        if check_pod_axis and not pex.pod_axis_only:
            emit("GA201",
                 "cross-pod collective-permute pair does not preserve "
                 "the intra-pod device coordinate — gossip is leaking "
                 "off the pod axis", "pod_axis_only")
        if pex.unparsed:
            emit("GA205",
                 f"{pex.unparsed} collective(s) the pod classifier "
                 "cannot attribute (send/recv, broadcast, or "
                 "unparseable replica groups) — cross-pod bytes would "
                 "silently understate the exchange", "unparsed")

    # ---- wire dtype (GA202) ----
    expected = expected_wire_dtype or infer_wire_dtype(comps)
    rep.expected_wire_dtype = expected if check_wire_dtype else None
    if check_wire_dtype and expected in _FLOAT_BYTES:
        exp_b = _FLOAT_BYTES[expected]
        for comp in comps.values():
            m = mult.get(comp.name, 0.0)
            if m == 0.0:
                continue
            for ins in comp.instrs:
                base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
                if base != "collective-permute" or ins.op.endswith("-done"):
                    continue
                if devices_per_pod is not None:
                    pairs = hlo._parse_pairs(ins.rest)
                    cross = pairs and any(
                        a // devices_per_pod != t // devices_per_pod
                        for a, t in pairs)
                    if not cross:
                        continue
                dt = _first_dtype(ins.type_str)
                if dt is None:
                    continue
                b = m * hlo._shape_bytes(ins.type_str)
                rep.cross_pod_dtype_bytes[dt] = \
                    rep.cross_pod_dtype_bytes.get(dt, 0.0) + b
                if dt in _FLOAT_BYTES and _FLOAT_BYTES[dt] > exp_b:
                    emit("GA202",
                         f"cross-pod transfer `{ins.name}` ships {dt} "
                         f"but the leaf dtype is {expected} — the "
                         "payload widened on the wire "
                         f"({hlo._shape_bytes(ins.type_str)} bytes/step)",
                         ins.name)

    # ---- host callbacks (GA203) ----
    for comp in comps.values():
        if mult.get(comp.name, 0.0) == 0.0:
            continue
        for ins in comp.instrs:
            if ins.op in ("infeed", "outfeed"):
                rep.host_callbacks.append(ins.op)
                emit("GA203",
                     f"`{ins.op}` in the step graph: a device<->host "
                     "transfer every step", ins.name)
            elif ins.op == "custom-call":
                tm = _CC_TARGET_RE.search(ins.rest)
                target = tm.group(1) if tm else ""
                if any(h in target.lower() for h in _HOST_TARGET_HINTS):
                    rep.host_callbacks.append(target)
                    emit("GA203",
                         f"host callback `{target}` in the step graph "
                         "— a Python round-trip per step that device "
                         "profiles never show", ins.name)
            elif ins.op in ("send", "recv") and \
                    "is_host_transfer=true" in ins.rest:
                rep.host_callbacks.append(ins.op)
                emit("GA203",
                     f"host-transfer `{ins.op}` in the step graph",
                     ins.name)

    # ---- donation / resharding drift (GA204) ----
    ent = _entry(comps)
    if ent is not None:
        params = {}
        for ins in ent.instrs:
            if ins.op == "parameter":
                pm = _PARAM_NUM_RE.search(ins.rest)
                if pm:
                    params[int(pm.group(1))] = ins.type_str
        rep.n_params = len(params)
        root = next((i for i in ent.instrs if i.is_root),
                    ent.instrs[-1] if ent.instrs else None)
        alias = parse_alias_map(text)
        if alias is None:
            if expect_donation:
                emit("GA204",
                     "module declares no input_output_alias: the donated "
                     "state buffers were silently lost — every step "
                     "reallocates the whole train state", "no-alias-map")
        elif root is not None:
            rep.donated_pairs = len(alias)
            for out_path, pnum, p_path in alias:
                out_t = _navigate(root.type_str, out_path)
                par_t = params.get(pnum)
                if par_t is not None and p_path:
                    par_t = _navigate(par_t, p_path)
                if out_t is None or par_t is None:
                    continue
                if _strip_layout(out_t) != _strip_layout(par_t):
                    emit("GA204",
                         f"donated buffer drift: output {out_path or [0]}"
                         f" is `{_strip_layout(out_t)}` but aliased "
                         f"parameter {pnum} is `{_strip_layout(par_t)}` "
                         "— step t's output cannot feed step t+1 "
                         "without a realloc/reshard", f"alias:{pnum}")
    return rep
