"""Trip-count-aware HLO cost analysis (home of the repo's HLO parser).

Moved here from ``repro.launch.hlo_analysis`` so the static-analysis
subsystem (``repro.analysis.graph_audit``) and the launch tooling share
one parser; ``repro.launch.hlo_analysis`` remains as a re-export shim
for external callers.

``Compiled.cost_analysis()`` visits while-loop bodies ONCE, so any
scan-over-layers / scan-over-chunks program is undercounted by ~n_layers.
This module parses the optimized HLO text instead:

- builds a per-computation symbol table (instruction name -> shape),
- walks the call graph from ENTRY, multiplying while bodies by their
  ``known_trip_count`` backend config (nested loops compose),
- FLOPs: 2 * prod(output) * prod(lhs contracting dims) for every
  dot / dot-general (wherever it lives, incl. inside fusions),
- bytes: operands + outputs at fusion/instruction boundaries (fusion
  internals are one kernel => free),
- collective bytes: output sizes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute, per kind,
  trip-multiplied.

All numbers are per-device (the input text is the SPMD-partitioned module).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_PIECE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+)$")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*{\s*$")
_CALLED_RE = re.compile(r"(?:calls|body|to_apply|branch_computations)="
                        r"[{]?%?([\w\.\-]+(?:,\s*%[\w\.\-]+)*)[}]?")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_PIECE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[List[int]]:
    """All array shapes in a (possibly tuple) type string."""
    out = []
    for m in _SHAPE_PIECE.finditer(type_str):
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append(dims)
    return out


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str
    is_root: bool = False


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    is_entry: bool = False
    is_fusion: bool = False


_OP_TOKEN = re.compile(r"^([a-z][\w\-]*)\(")


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR.match(line.strip())
        if hdr and line.strip().endswith("{"):
            name = hdr.group(2)
            cur = Computation(name=name, is_entry=bool(hdr.group(1)),
                              is_fusion=name.startswith("fused_"))
            comps[name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        is_root = line.lstrip().startswith("ROOT")
        name, rhs = m.group(1), m.group(2)
        # rhs = "<type> <op>(...), ..."
        # type may be tuple: ( ... ) — find op token after the type
        rhs_strip = rhs
        if rhs_strip.startswith("("):
            depth = 0
            for i, ch in enumerate(rhs_strip):
                depth += ch == "("
                depth -= ch == ")"
                if depth == 0:
                    break
            type_str = rhs_strip[:i + 1]
            tail = rhs_strip[i + 1:].strip()
        else:
            sp = rhs_strip.find(" ")
            type_str = rhs_strip[:sp]
            tail = rhs_strip[sp + 1:].strip()
        om = _OP_TOKEN.match(tail)
        op = om.group(1) if om else tail.split("(")[0].strip()
        cur.instrs.append(Instr(name=name, type_str=type_str, op=op,
                                rest=tail, is_root=is_root))
    return comps


def _multiplicities(comps: Dict[str, Computation]) -> Dict[str, float]:
    """Execution count per computation, walking ENTRY -> callees."""
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    mult: Dict[str, float] = {}

    def visit(name: str, m: float):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        for ins in comps[name].instrs:
            cm = _CALLED_RE.search(ins.rest)
            if not cm:
                continue
            callees = [c.strip().lstrip("%")
                       for c in cm.group(1).split(",")]
            child_m = m
            if ins.op == "while":
                tm = _TRIP_RE.search(ins.rest)
                trip = float(tm.group(1)) if tm else 1.0
                child_m = m * trip
            for c in callees:
                visit(c, child_m)
    if entry:
        visit(entry, 1.0)
    return mult


_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "while", "conditional", "call", "after-all",
             "partition-id", "replica-id", "domain", "opt-barrier",
             "get-dimension-size", "iota"}


_PAIR_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)+)\}")
_PAIR_ITEM_RE = re.compile(r"\{(\d+),(\d+)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{((?:\{[\d,]*\},?)*)\}")
_GROUP_ITEM_RE = re.compile(r"\{([\d,]*)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")

_OPNAME_RE = re.compile(r'op_name="([^"]*)"')


def _opname_bucket(rest: str) -> str:
    """Coarse attribution bucket from HLO metadata op_name."""
    m = _OPNAME_RE.search(rest)
    if not m:
        return "(none)"
    name = m.group(1)
    # e.g. jit(train_step)/while/body/remat/.../dot_general -> keep the
    # most informative middle segments
    parts = [p for p in name.split("/") if p and not p.startswith("jit(")]
    return "/".join(parts[:4]) if parts else "(root)"


@dataclass
class HLOCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    coll_by_op: Dict[str, float] = field(default_factory=dict)
    bytes_by_op: Dict[str, float] = field(default_factory=dict)

    @property
    def coll_total(self) -> float:
        return sum(self.collective_bytes.values())

    def top_collectives(self, n: int = 12):
        return sorted(self.coll_by_op.items(), key=lambda kv: -kv[1])[:n]

    def top_bytes(self, n: int = 12):
        return sorted(self.bytes_by_op.items(), key=lambda kv: -kv[1])[:n]


def _dus_update_bytes(ins: Instr, comps: Dict[str, Computation],
                      symtab: Dict[str, str]) -> Optional[float]:
    """If ``ins`` is (or is a fusion rooted in) a dynamic-update-slice whose
    output aliases its buffer operand, return the modeled in-place traffic:
    2x update-slice bytes + non-buffer operand bytes.  Else None."""
    if ins.op == "dynamic-update-slice":
        paren = ins.rest.find("(")
        close = ins.rest.find(")", paren)
        ops = _OPERAND_RE.findall(ins.rest[paren + 1:close])
        if len(ops) >= 2 and ops[1] in symtab:
            return 2.0 * _shape_bytes(symtab[ops[1]])
        return None
    if ins.op != "fusion":
        return None
    cm = _CALLED_RE.search(ins.rest)
    if not cm:
        return None
    callee = comps.get(cm.group(1).strip().lstrip("%"))
    if callee is None or not callee.instrs:
        return None
    root = callee.instrs[-1]
    # XLA:CPU legalizes bf16 by wrapping compute in f32 converts; on the
    # TPU target the DUS is native — see through trailing convert/bitcast
    inner_tab0 = {i.name: i.type_str for i in callee.instrs}
    seen = 0
    while root.op in ("convert", "bitcast", "copy") and seen < 4:
        paren = root.rest.find("(")
        close = root.rest.find(")", paren)
        ops = _OPERAND_RE.findall(root.rest[paren + 1:close])
        nxt = next((i for i in callee.instrs if ops and i.name == ops[0]),
                   None)
        if nxt is None:
            break
        root = nxt
        seen += 1
    if root.op == "dynamic-slice" or (
            callee.instrs and any(i.op == "dynamic-slice"
                                  for i in callee.instrs)
            and all(i.op in _LEGAL_OPS | {"dynamic-slice"}
                    for i in callee.instrs)):
        # slice-read fusion: traffic = slice out + slice in, not the buffer
        return 2.0 * _shape_bytes(ins.type_str)
    if root.op != "dynamic-update-slice":
        return None
    # update operand of the root DUS, resolved in the fused computation
    inner_tab = {i.name: i.type_str for i in callee.instrs}
    paren = root.rest.find("(")
    close = root.rest.find(")", paren)
    ops = _OPERAND_RE.findall(root.rest[paren + 1:close])
    upd = 0.0
    if len(ops) >= 2 and ops[1] in inner_tab:
        upd = _shape_bytes(inner_tab[ops[1]])
    else:
        return None
    # non-buffer outer operands (buffer = operand with same type as output)
    paren = ins.rest.find("(")
    close = ins.rest.find(")", paren)
    outer_ops = _OPERAND_RE.findall(ins.rest[paren + 1:close])
    extra = 0.0
    buffer_skipped = False
    for o in outer_ops:
        t = symtab.get(o)
        if t is None:
            continue
        if not buffer_skipped and _shape_bytes(t) == _shape_bytes(
                ins.type_str):
            buffer_skipped = True        # the aliased buffer: free
            continue
        extra += _shape_bytes(t)
    return 2.0 * upd + extra


_LEGAL_OPS = {"parameter", "constant", "convert", "bitcast", "copy",
              "reshape", "transpose"}


def _is_legalization_fusion(ins: Instr, comps: Dict[str, Computation]
                            ) -> bool:
    if ins.op != "fusion":
        return False
    cm = _CALLED_RE.search(ins.rest)
    if not cm:
        return False
    callee = comps.get(cm.group(1).strip().lstrip("%"))
    if callee is None:
        return False
    return all(i.op in _LEGAL_OPS for i in callee.instrs)


def _is_legalization_convert(ins: Instr, symtab: Dict[str, str]) -> bool:
    """Standalone bf16<->f32 convert of a whole buffer: XLA:CPU keeps
    loop carries in f32; native bf16 on TPU."""
    if ins.op != "convert":
        return False
    t_out = ins.type_str
    paren = ins.rest.find("(")
    close = ins.rest.find(")", paren)
    ops = _OPERAND_RE.findall(ins.rest[paren + 1:close])
    if not ops or ops[0] not in symtab:
        return False
    t_in = symtab[ops[0]]
    kinds = {t_out.split("[")[0], t_in.split("[")[0]}
    return kinds == {"f32", "bf16"}


def _scatter_inplace_bytes(ins: Instr, comps: Dict[str, Computation],
                           symtab: Dict[str, str]) -> Optional[float]:
    """Scatter updates the buffer in place: traffic = indices + 2x updates,
    not the whole buffer.  Handles bare scatter and fusion-wrapped scatter
    (``wrapped_scatter``)."""
    root = ins
    if ins.op == "fusion":
        cm = _CALLED_RE.search(ins.rest)
        callee = comps.get(cm.group(1).strip().lstrip("%")) if cm else None
        if callee is None or not any(i.op == "scatter" for i in callee.instrs):
            return None
        if not all(i.op in _LEGAL_OPS | {"scatter"} for i in callee.instrs):
            return None
    elif ins.op != "scatter":
        return None
    # operands: (buffer, indices, updates) — buffer matches output size
    paren = ins.rest.find("(")
    close = ins.rest.find(")", paren)
    ops = _OPERAND_RE.findall(ins.rest[paren + 1:close])
    out_bytes = _shape_bytes(ins.type_str)
    total = 0.0
    buffer_skipped = False
    for o in ops:
        t = symtab.get(o)
        if t is None:
            continue
        bb = _shape_bytes(t)
        if not buffer_skipped and bb == out_bytes:
            buffer_skipped = True
            continue
        total += bb
    return 2.0 * total if buffer_skipped else None


def _parse_pairs(rest: str) -> Optional[List[Tuple[int, int]]]:
    """collective-permute source_target_pairs, or None when absent."""
    m = _PAIR_RE.search(rest)
    if not m:
        return None
    return [(int(a), int(b)) for a, b in _PAIR_ITEM_RE.findall(m.group(1))]


def _parse_replica_groups(rest: str) -> Optional[List[List[int]]]:
    """Device groups of a reduction collective.  Handles the literal
    ``{{0,1},{2,3}}`` form and the iota v2 form ``[g,s]<=[dims]T(perm)``
    (arange over prod(dims), reshaped to dims, transposed by perm,
    flattened, then split into g groups of s).  ``{{}}``/missing groups
    mean all devices; returns None only when the attribute is present
    but unparseable."""
    m = _GROUPS_RE.search(rest)
    if m:
        groups = [[int(x) for x in g.split(",") if x]
                  for g in _GROUP_ITEM_RE.findall(m.group(1))]
        return [g for g in groups if g]
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",") if d]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(p) for p in m.group(4).split(",") if p]
            ids = ids.transpose(perm)
        return ids.reshape(g, s).tolist()
    if "replica_groups=" in rest:
        return None
    return []           # no groups attribute: all devices


#: reduction-style collectives whose replica_groups decide pod crossing
_REDUCE_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter",
                       "all-to-all")


@dataclass
class PodExchange:
    """Where a multi-pod program's collective traffic actually flows.

    The gossip/exchange contract for the pod-stacked train step: the
    model exchange must be collective-permutes whose cross-pod pairs move
    along the ``pod`` axis *only* (source and target share their
    intra-pod coordinate), and cross-pod reduction traffic must stay
    small relative to the permute exchange (GSPMD reshard noise aside,
    gossip that leaks into reduction collectives is a regression — the
    dryrun gossip gate enforces the ratio).  Bytes are per-device,
    trip-multiplied, using the same conventions as :func:`analyze`.
    """
    devices_per_pod: int
    permute_cross_bytes: float = 0.0     # collective-permute across pods
    permute_local_bytes: float = 0.0     # collective-permute inside a pod
    reduce_cross_bytes: float = 0.0      # reductions whose groups span pods
    reduce_local_bytes: float = 0.0      # reductions inside a single pod
    pod_axis_only: bool = True           # every cross-pod permute pair
    #                                      preserves the intra-pod coord
    unparsed: int = 0                    # collectives we could not classify

    @property
    def cross_pod_bytes(self) -> float:
        return self.permute_cross_bytes + self.reduce_cross_bytes


def pod_exchange_report(text: str, devices_per_pod: int) -> PodExchange:
    """Classify every collective in the partitioned HLO by whether it
    crosses the pod boundary (device ids are pod-major: pod p owns ids
    ``[p*devices_per_pod, (p+1)*devices_per_pod)``)."""
    comps = parse_module(text)
    mult = _multiplicities(comps)
    rep = PodExchange(devices_per_pod=devices_per_pod)
    dpp = devices_per_pod
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        for ins in comp.instrs:
            if ins.op.endswith("-done"):
                continue                 # bytes counted at the -start
            base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            b = m * _shape_bytes(ins.type_str)
            if base == "collective-permute":
                pairs = _parse_pairs(ins.rest)
                if pairs is None:
                    rep.unparsed += 1
                    continue
                cross = [(a, t) for a, t in pairs if a // dpp != t // dpp]
                if cross:
                    rep.permute_cross_bytes += b
                    if any(a % dpp != t % dpp for a, t in cross):
                        rep.pod_axis_only = False
                else:
                    rep.permute_local_bytes += b
            elif base in _REDUCE_COLLECTIVES:
                groups = _parse_replica_groups(ins.rest)
                if groups is None:
                    rep.unparsed += 1
                    rep.reduce_cross_bytes += b   # conservative
                    continue
                if not groups:                    # all devices
                    rep.reduce_cross_bytes += b
                elif any(len({g // dpp for g in grp}) > 1
                         for grp in groups):
                    rep.reduce_cross_bytes += b
                else:
                    rep.reduce_local_bytes += b
            elif base in ("collective-broadcast", "send", "recv",
                          "ragged-all-to-all"):
                # a collective kind this report can't classify: surface
                # it instead of silently under-stating cross-pod traffic
                rep.unparsed += 1
    return rep


def analyze(text: str) -> HLOCost:
    comps = parse_module(text)
    mult = _multiplicities(comps)
    cost = HLOCost(collective_bytes={k: 0.0 for k in COLLECTIVES})

    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        symtab = {i.name: i.type_str for i in comp.instrs}
        for ins in comp.instrs:
            # ---- flops: dots (count even inside fusions) ----
            if ins.op == "dot":
                out_dims_list = _shape_dims(ins.type_str)
                out_elems = 1
                for d in (out_dims_list[0] if out_dims_list else []):
                    out_elems *= d
                cmatch = _CONTRACT_RE.search(ins.rest)
                k = 1
                if cmatch:
                    ops = _OPERAND_RE.findall(
                        ins.rest[ins.rest.find("(") + 1:ins.rest.find(")")])
                    if ops and ops[0] in symtab:
                        lhs_dims = _shape_dims(symtab[ops[0]])
                        if lhs_dims:
                            for ci in cmatch.group(1).split(","):
                                if ci:
                                    ci = int(ci)
                                    if ci < len(lhs_dims[0]):
                                        k *= lhs_dims[0][ci]
                cost.flops += m * 2.0 * out_elems * k
            if ins.op in ("convolution",):
                # rough: 2 * out_elems * kernel_elems (per out channel set)
                out_dims_list = _shape_dims(ins.type_str)
                out_elems = 1
                for d in (out_dims_list[0] if out_dims_list else []):
                    out_elems *= d
                cost.flops += m * 2.0 * out_elems  # lower bound
            # ---- collectives ----
            for kind in COLLECTIVES:
                if ins.op == kind or ins.op == kind + "-start":
                    b = m * _shape_bytes(ins.type_str)
                    cost.collective_bytes[kind] += b
                    bucket = f"{kind}:{_opname_bucket(ins.rest)}"
                    cost.coll_by_op[bucket] = (
                        cost.coll_by_op.get(bucket, 0.0) + b)
            # ---- bytes at kernel boundaries ----
            if comp.is_fusion:
                continue                      # internals are one kernel
            if ins.op in _FREE_OPS or ins.op.endswith("-done"):
                continue
            out_b = _shape_bytes(ins.type_str)
            in_b = 0
            paren = ins.rest.find("(")
            close = ins.rest.find(")", paren)
            operands = []
            if paren >= 0 and close > paren:
                operands = _OPERAND_RE.findall(ins.rest[paren + 1:close])
                for opnd in operands:
                    if opnd in symtab:
                        in_b += _shape_bytes(symtab[opnd])
            # in-place dynamic-update-slice (scan carries / ys-stacking):
            # XLA updates the buffer in place; real traffic is the slice,
            # not the whole buffer.  Model that instead of buffer*2.
            dus_update = _dus_update_bytes(ins, comps, symtab)
            scatter_b = _scatter_inplace_bytes(ins, comps, symtab)
            if dus_update is not None:
                b = m * dus_update
            elif scatter_b is not None:
                b = m * scatter_b
            elif _is_legalization_fusion(ins, comps) or \
                    _is_legalization_convert(ins, symtab):
                # pure convert/bitcast = XLA:CPU bf16 legalization;
                # free on the TPU target this analysis models
                b = 0.0
            else:
                b = m * (out_b + in_b)
            cost.bytes_accessed += b
            bucket = _opname_bucket(ins.rest)
            if bucket == "(none)":
                bucket = f"(none):{ins.op}"
            cost.bytes_by_op[bucket] = cost.bytes_by_op.get(bucket, 0.0) + b
    return cost
