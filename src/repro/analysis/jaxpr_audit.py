"""Jaxpr-level dataflow audit: the pre-lowering half of the graph gate.

The HLO auditor (:mod:`repro.analysis.graph_audit`) sees the step graph
*after* XLA has folded it — by which point constant-folding and fusion
can have erased exactly the hazards it was meant to catch (a host sync
folded into a fused loop, a widening convert absorbed into a collective
lowering).  This pass walks the **closed jaxpr** of every step builder
instead — ``jax.make_jaxpr`` output, recursing into ``pjit`` / ``scan``
/ ``while`` / ``cond`` / ``shard_map`` sub-jaxprs — so the whole
strategy x topology matrix can be audited without ever invoking XLA:
tracing is ~0.5 s per combo where compiling is ~10x that.

Rules (JA4xx; suppressible only via the fingerprint baseline — jaxprs
have no source lines to carry ``# repro-allow:`` markers):

* **JA400 step-trace-failure** — a combo in the audit matrix failed to
  trace at all.  Emitted by :func:`audit_combos` so a broken builder is
  a finding, never a silently-unaudited row in the coverage matrix.
* **JA401 host-callback-in-step** — a host callback (``pure_callback``,
  ``io_callback``, ``debug_callback`` — i.e. ``jax.debug.print`` —
  infeed/outfeed) or an IO effect reachable from a train/serve step:
  a device->host round-trip per step, caught before XLA can disguise
  it as a fused custom-call.
* **JA402 widen-into-collective** — a collective ships a floating dtype
  wider than the narrowest float leaf it dataflow-traces back to, with
  the widening ``convert_element_type`` named when found on the path:
  the adpsgd bf16->f32 wire bug (PR 4) caught *before* lowering.  The
  legitimate accumulate-in-f32-then-narrow pattern does not fire — the
  wire operand itself must be wide.
* **JA403 off-pod-axis-collective** — a collective whose ``axis_name``
  is not the pod axis: gossip exchange belongs on the scarce cross-pod
  links; every other mesh axis is GSPMD's to schedule.
* **JA404 large-closed-constant** — a constant above the size threshold
  closed over into the jaxpr (any scope).  Baked-in arrays silently
  bloat every executable and force a recompile whenever their value
  changes — they belong in the step's runtime operands.
* **JA405 rng-key-not-from-args** — an RNG primitive whose key does not
  dataflow-trace back to a step argument: the step resamples the same
  stream every call (or bakes entropy at trace time).  The trace-level
  twin of AST rule RA101's unkeyed-randomness check.

The audit itself imports no JAX — it duck-types jaxpr objects (``eqns``
/ ``invars`` / ``primitive``), so ``repro.analysis`` stays importable
without jax and tests can feed it hand-built traces.  Only
:func:`audit_combos` (the sweep driver) touches the launch stack.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.base import Finding

RULES = {
    "JA400": "step-trace-failure",
    "JA401": "host-callback-in-step",
    "JA402": "widen-into-collective",
    "JA403": "off-pod-axis-collective",
    "JA404": "large-closed-constant",
    "JA405": "rng-key-not-from-args",
}

#: primitives that round-trip through the host (device->host per step)
HOST_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "infeed", "outfeed", "outside_call", "host_callback_call",
})

#: cross-device communication primitives (named-axis collectives)
COLLECTIVE_PRIMS = frozenset({
    "ppermute", "pshuffle", "psum", "pmax", "pmin", "pmean",
    "all_gather", "all_to_all", "reduce_scatter", "psum_scatter",
    "pgather", "pbroadcast",
})

#: primitives that mint or consume PRNG state
RNG_PRIMS = frozenset({
    "random_seed", "random_bits", "random_wrap", "random_fold_in",
    "random_gamma", "threefry2x32", "rng_bit_generator", "rng_uniform",
})

#: default JA404 threshold: anything above 1 MiB baked into the graph
#: is a deliberate decision, not an incidental table
CONST_THRESHOLD_BYTES = 1 << 20

_FLOAT_BYTES = {"float64": 8, "float32": 4, "float16": 2, "bfloat16": 2,
                "float8_e4m3fn": 1, "float8_e5m2": 1}


def _float_bytes(dtype) -> Optional[int]:
    return _FLOAT_BYTES.get(getattr(dtype, "name", str(dtype)))


def _is_literal(v: Any) -> bool:
    # jax.core.Literal carries .val; Var / DropVar do not
    return hasattr(v, "val")


def _is_jaxpr(x: Any) -> bool:
    return hasattr(x, "eqns") and hasattr(x, "invars")


def _as_open(x: Any) -> Optional[Tuple[Any, List[Any]]]:
    """(open jaxpr, consts) for a Jaxpr or ClosedJaxpr, else None."""
    if _is_jaxpr(x):
        return x, []
    inner = getattr(x, "jaxpr", None)
    if inner is not None and _is_jaxpr(inner):
        return inner, list(getattr(x, "consts", []))
    return None


def _sub_jaxprs(eqn) -> List[Tuple[Any, List[Any]]]:
    """Every (open jaxpr, consts) hanging off this eqn's params."""
    out = []
    for v in eqn.params.values():
        for x in (v if isinstance(v, (tuple, list)) else (v,)):
            pair = _as_open(x)
            if pair is not None:
                out.append(pair)
    return out


@dataclass
class _EqnRec:
    """One equation, flattened out of its (possibly nested) scope."""
    eqn: Any
    scope: str                  # e.g. "pjit/scan" ("" = top level)

    @property
    def name(self) -> str:
        return self.eqn.primitive.name

    @property
    def site(self) -> str:
        return f"{self.name}@{self.scope}" if self.scope else self.name


class _Graph:
    """The whole-trace dataflow graph: eqns from every scope, forward
    var->var edges (cross-scope boundaries wired through), producers,
    and the consts closed over at each level."""

    def __init__(self):
        self.eqns: List[_EqnRec] = []
        self.fwd: Dict[int, Set[int]] = {}
        self.vars: Dict[int, Any] = {}          # id -> var (keepalive)
        self.producer: Dict[int, _EqnRec] = {}
        self.consts: List[Tuple[str, Any]] = []  # (scope, const value)
        self.arg_ids: List[int] = []             # top-level invars

    def _edge(self, src: Any, dst: Any) -> None:
        if _is_literal(src):
            return
        self.vars[id(src)] = src
        self.vars[id(dst)] = dst
        self.fwd.setdefault(id(src), set()).add(id(dst))

    def _link(self, outers: Sequence[Any], inners: Sequence[Any]) -> None:
        """Wire outer operands to inner invars (or inner outvars to
        outer results): positional when the arities match, else the
        conservative all-to-all."""
        if len(outers) == len(inners):
            pairs: Iterable = zip(outers, inners)
        else:
            pairs = ((o, i) for o in outers for i in inners)
        for o, i in pairs:
            self._edge(o, i)


def _build(closed_jaxpr) -> _Graph:
    g = _Graph()

    def rec(jaxpr, consts, scope):
        for cv, c in zip(getattr(jaxpr, "constvars", []), consts):
            g.vars[id(cv)] = cv
            g.consts.append((scope, c))
        for eqn in jaxpr.eqns:
            r = _EqnRec(eqn, scope)
            g.eqns.append(r)
            live_in = [v for v in eqn.invars if not _is_literal(v)]
            for o in eqn.outvars:
                g.vars[id(o)] = o
                g.producer[id(o)] = r
                for v in live_in:
                    g._edge(v, o)
            subs = _sub_jaxprs(eqn)
            if not subs:
                continue
            inner_scope = f"{scope}/{r.name}" if scope else r.name
            name = r.name
            if name == "cond":
                # invars = [branch index, *operands]; each branch takes
                # the operands and yields the eqn outputs
                for sub, sc in subs:
                    g._link(eqn.invars[1:], sub.invars)
                    g._link(sub.outvars, eqn.outvars)
                    rec(sub, sc, inner_scope)
            elif name == "while":
                cn = eqn.params.get("cond_nconsts", 0)
                bn = eqn.params.get("body_nconsts", 0)
                carry = list(eqn.invars[cn + bn:])
                cond_j, cond_c = _as_open(eqn.params["cond_jaxpr"])
                body_j, body_c = _as_open(eqn.params["body_jaxpr"])
                g._link(list(eqn.invars[:cn]) + carry, cond_j.invars)
                g._link(list(eqn.invars[cn:cn + bn]) + carry, body_j.invars)
                g._link(body_j.outvars, eqn.outvars)
                # loop feedback: iteration t's carry feeds iteration t+1
                g._link(body_j.outvars, body_j.invars[bn:])
                g._link(body_j.outvars, cond_j.invars[cn:])
                rec(cond_j, cond_c, inner_scope)
                rec(body_j, body_c, inner_scope)
            else:
                # pjit / closed_call / remat / custom_* / shard_map /
                # scan: operands map positionally onto the sub-jaxpr
                # (scan: consts+carry+xs line up 1:1 with the body's
                # consts+carry+x-slices); unknown arities degrade to
                # the conservative all-to-all link
                for sub, sc in subs:
                    g._link(eqn.invars, sub.invars)
                    g._link(sub.outvars, eqn.outvars)
                    if name == "scan":
                        ncon = eqn.params.get("num_consts", 0)
                        ncar = eqn.params.get("num_carry", 0)
                        g._link(sub.outvars[:ncar],
                                sub.invars[ncon:ncon + ncar])
                    rec(sub, sc, inner_scope)

    top, consts = _as_open(closed_jaxpr)
    g.arg_ids = [id(v) for v in top.invars]
    for v in top.invars:
        g.vars[id(v)] = v
    rec(top, consts, "")
    return g


def _closure(start: Iterable[int], adj: Dict[int, Set[int]]) -> Set[int]:
    seen = set(start)
    stack = list(seen)
    while stack:
        for nxt in adj.get(stack.pop(), ()):
            if nxt not in seen:
                seen.add(nxt)
                stack.append(nxt)
    return seen


def _reverse(adj: Dict[int, Set[int]]) -> Dict[int, Set[int]]:
    rev: Dict[int, Set[int]] = {}
    for src, dsts in adj.items():
        for d in dsts:
            rev.setdefault(d, set()).add(src)
    return rev


def _axis_names(eqn) -> List[str]:
    names = eqn.params.get("axis_name", eqn.params.get("axes", ()))
    if isinstance(names, str):
        names = (names,)
    return [n for n in names if isinstance(n, str)]


def _aval_str(aval) -> str:
    dt = getattr(getattr(aval, "dtype", None), "name", "?")
    return f"{dt}{list(getattr(aval, 'shape', ()))}"


# ---------------------------------------------------------------- audit

@dataclass
class JaxprAudit:
    """Findings + the machine-readable summary for one traced step."""
    tag: str
    findings: List[Finding] = field(default_factory=list)
    n_eqns: int = 0
    n_collectives: int = 0
    collective_axes: List[str] = field(default_factory=list)
    max_const_bytes: int = 0
    n_rng_prims: int = 0
    error: Optional[str] = None          # JA400: the trace never ran

    @property
    def ok(self) -> bool:
        return not self.findings

    def to_json(self) -> Dict:
        return {
            "tag": self.tag, "ok": self.ok,
            "n_eqns": self.n_eqns,
            "n_collectives": self.n_collectives,
            "collective_axes": self.collective_axes,
            "max_const_bytes": self.max_const_bytes,
            "n_rng_prims": self.n_rng_prims,
            "error": self.error,
            "findings": [f.to_json() for f in self.findings],
        }


def audit_jaxpr(closed_jaxpr, *, tag: str = "<jaxpr>",
                pod_axis: Optional[str] = "pod",
                const_threshold_bytes: int = CONST_THRESHOLD_BYTES
                ) -> JaxprAudit:
    """Audit one closed jaxpr (every nested scope included).

    ``pod_axis`` names the only axis collectives may use (JA403);
    pass ``None`` to skip the axis-discipline rule (e.g. a graph with
    no pod fabric at all)."""
    rep = JaxprAudit(tag=tag)
    g = _build(closed_jaxpr)
    rep.n_eqns = len(g.eqns)

    def emit(rule: str, message: str, source: str) -> None:
        rep.findings.append(Finding(rule=rule, path=tag, line=0,
                                    message=message, source=source))

    # ---- JA401: host callbacks / io effects ----
    for r in g.eqns:
        if r.name in HOST_PRIMS:
            emit("JA401",
                 f"host callback `{r.name}` reachable from the step "
                 f"(scope {r.scope or 'top'}): a device<->host "
                 "round-trip per call that XLA may fold out of sight "
                 "post-lowering", r.site)
    for eff in getattr(closed_jaxpr, "effects", ()) or ():
        en = type(eff).__name__.lower()
        if any(h in en for h in ("io", "callback", "debug")) and \
                not any(f.rule == "JA401" for f in rep.findings):
            emit("JA401",
                 f"step trace carries host-visible effect "
                 f"`{type(eff).__name__}` — something inside the step "
                 "talks to the host", f"effect:{type(eff).__name__}")

    # ---- collectives: JA403 axis discipline, JA402 wire widening ----
    collectives = [r for r in g.eqns if r.name in COLLECTIVE_PRIMS]
    rep.n_collectives = len(collectives)
    axes_seen: Set[str] = set()
    rev = _reverse(g.fwd) if collectives else {}
    arg_id_set = set(g.arg_ids)
    for r in collectives:
        names = _axis_names(r.eqn)
        axes_seen.update(names)
        if pod_axis is not None:
            off = [n for n in names if n != pod_axis]
            if off:
                emit("JA403",
                     f"collective `{r.name}` runs over axis "
                     f"{off if len(off) > 1 else off[0]!r}, not the "
                     f"{pod_axis!r} axis — manual exchange belongs on "
                     "the pod fabric; other axes are GSPMD's", r.site)
        # JA402: for each float operand, walk the dataflow backward to
        # the step-argument leaves it ships; wider-on-the-wire => the
        # payload widened somewhere on the path
        for v in r.eqn.invars:
            if _is_literal(v):
                continue
            wire_b = _float_bytes(getattr(v.aval, "dtype", None))
            if wire_b is None:
                continue
            back = _closure([id(v)], rev)
            leaf_bytes = [
                _float_bytes(g.vars[i].aval.dtype)
                for i in back & arg_id_set
                if _float_bytes(getattr(g.vars[i].aval, "dtype", None))
            ]
            if not leaf_bytes or wire_b <= min(leaf_bytes):
                continue
            widener = next(
                (g.producer[i] for i in back
                 if i in g.producer
                 and g.producer[i].name == "convert_element_type"
                 and _is_widening(g.producer[i].eqn)), None)
            via = (f" (widened by `convert_element_type` in scope "
                   f"{widener.scope or 'top'})" if widener else "")
            emit("JA402",
                 f"collective `{r.name}` ships "
                 f"{_aval_str(v.aval)} but the narrowest float leaf it "
                 f"traces back to is {min(leaf_bytes)} byte(s)/elt — "
                 f"the payload widened on the wire{via}", r.site)
    rep.collective_axes = sorted(axes_seen)

    # ---- JA404: large closed-over constants ----
    for scope, c in g.consts:
        nb = int(getattr(c, "nbytes", 0) or 0)
        rep.max_const_bytes = max(rep.max_const_bytes, nb)
        if nb > const_threshold_bytes:
            shape = list(getattr(c, "shape", ()))
            dt = getattr(getattr(c, "dtype", None), "name", "?")
            emit("JA404",
                 f"{nb} -byte constant ({dt}{shape}) closed over into "
                 f"the jaxpr (scope {scope or 'top'}): baked into every "
                 "executable and a recompile each time its value "
                 "changes — make it a step operand",
                 f"const:{dt}{shape}@{scope or 'top'}")

    # ---- JA405: RNG keys that never touch a step argument ----
    rng = [r for r in g.eqns if r.name in RNG_PRIMS]
    rep.n_rng_prims = len(rng)
    if rng:
        arg_taint = _closure(g.arg_ids, g.fwd)
        rng_taint = _closure(
            [id(o) for r in rng for o in r.eqn.outvars], g.fwd)
        for r in rng:
            live = [id(v) for v in r.eqn.invars if not _is_literal(v)]
            if any(i in arg_taint for i in live):
                continue            # keyed from a step argument: fine
            if any(i in rng_taint for i in live):
                continue            # downstream of the root we flag
            emit("JA405",
                 f"RNG primitive `{r.name}` (scope {r.scope or 'top'}) "
                 "draws from a key that never traces back to a step "
                 "argument — the same stream replays every call; "
                 "thread the key/seed through the step's operands "
                 "(trace-level twin of RA101)", r.site)
    return rep


def _is_widening(eqn) -> bool:
    """convert_element_type eqn that widens float -> wider float."""
    try:
        src = _float_bytes(eqn.invars[0].aval.dtype)
        dst = _float_bytes(eqn.outvars[0].aval.dtype)
    except (AttributeError, IndexError):
        return False
    return src is not None and dst is not None and dst > src


# ------------------------------------------------------------ the sweep

def audit_combos(*, arch: Optional[str] = None,
                 mesh_spec: Optional[str] = None, reduced: bool = True,
                 combos: Optional[Sequence[Tuple]] = None,
                 pod_axis: str = "pod",
                 const_threshold_bytes: int = CONST_THRESHOLD_BYTES,
                 verbose: bool = False) -> List[Tuple[str, JaxprAudit]]:
    """Trace + audit every step builder across the full strategy x
    topology matrix (plus the prefill/serve graphs).

    Returns ``[(combo, JaxprAudit)]`` — one row per combo, ALWAYS: a
    combo whose builder raises gets a JA400 finding instead of silently
    vanishing from the coverage matrix.  Imports the launch stack
    lazily (``repro.launch.dryrun`` first, so XLA_FLAGS is set before
    jax initializes its device count).
    """
    from repro.launch import dryrun  # noqa: F401 — XLA_FLAGS side effect
    arch = arch or dryrun.SWEEP_ARCH
    mesh_spec = mesh_spec or dryrun.SWEEP_MESH
    mesh = dryrun._parse_mesh(mesh_spec)
    out: List[Tuple[str, JaxprAudit]] = []
    for shape_name, strategy, topology in (combos if combos is not None
                                           else dryrun.iter_combos()):
        combo = f"{shape_name}/{strategy or '-'}/{topology or '-'}"
        tag = f"jaxpr:{arch}/{combo}@{mesh_spec}"
        try:
            cj = dryrun.trace_combo(arch, shape_name, strategy=strategy,
                                    topology=topology, mesh=mesh,
                                    reduced=reduced)
            rep = audit_jaxpr(cj, tag=tag, pod_axis=pod_axis,
                              const_threshold_bytes=const_threshold_bytes)
        except Exception as e:  # repro-allow: RA104 — matrix driver: a
            #                     broken builder must become a JA400 row,
            #                     not abort the remaining combos
            rep = JaxprAudit(tag=tag, error=f"{type(e).__name__}: {e}")
            rep.findings.append(Finding(
                rule="JA400", path=tag, line=0,
                message=f"step trace failed: {type(e).__name__}: {e} — "
                        "this combo is unaudited until the builder is "
                        "fixed", source=f"trace:{combo}"))
        if verbose:
            state = ("FAIL" if rep.error else
                     f"{len(rep.findings)} finding(s)" if rep.findings
                     else "ok")
            print(f"[jaxpr-audit] {combo}: {state} "
                  f"({rep.n_eqns} eqns, {rep.n_collectives} collectives)")
        out.append((combo, rep))
    return out
