"""Registry-parity check: a kernel op cannot ship half-wired.

Every public op in ``kernels/ops.py`` is a four-legged contract:

* **PA301** a jnp oracle in ``kernels/ref.py`` — the dispatch candidate
  the kernel must never lose to, and the equivalence baseline tests
  compare against;
* **PA302** a dispatch decision (``_decide("<op>", ...)``) — otherwise
  the op silently bypasses the measured backend routing;
* **PA303** a ``benchmarks/kernels_bench.py`` row — otherwise the perf
  gate (``report.py --gate``) cannot see it regress;
* **PA304** at least one test referencing it — otherwise nothing pins
  its numerics.

Plus one meta-rule over the analysis subsystem itself:

* **PA305** every rule id in ``repro.analysis.ALL_RULES`` must appear
  in ``tests/test_analysis.py`` — a rule with no planted-violation
  test can silently stop firing.

Detection is structural (AST over ops.py, resolving one level of
module-level helper indirection — ``_gaia_oracle = jax.jit(
_ref.gaia_select_ref)`` counts as an oracle reference), so the check
needs no imports and works on a planted tree in tests.
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set

from repro.analysis.base import Finding, iter_py_files

RULES = {
    "PA301": "missing-ref-oracle",
    "PA302": "missing-dispatch-entry",
    "PA303": "missing-bench-row",
    "PA304": "missing-test-reference",
    "PA305": "untested-analysis-rule",
}

ANALYSIS_TESTS = os.path.join("tests", "test_analysis.py")

OPS_PATH = os.path.join("src", "repro", "kernels", "ops.py")
REF_PATH = os.path.join("src", "repro", "kernels", "ref.py")
BENCH_PATH = os.path.join("benchmarks", "kernels_bench.py")
TESTS_DIR = "tests"

#: the module alias ops.py imports the oracles under
_REF_ALIASES = ("_ref", "ref")


@dataclass
class OpWiring:
    """What one public op in ops.py is statically wired to."""
    name: str
    lineno: int
    ref_fns: Set[str] = field(default_factory=set)   # _ref.<X> reached
    dispatch_keys: Set[str] = field(default_factory=set)  # _decide("<k>")


def _collect_refs(node: ast.AST, wiring: OpWiring,
                  helper_names: Set[str]) -> Set[str]:
    """Scan one function/assignment body: record ``_ref.X`` attribute
    loads and ``_decide("key", ...)`` literals into ``wiring``; return
    the module-level helper names it references (for the BFS)."""
    used: Set[str] = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and \
                isinstance(sub.value, ast.Name) and \
                sub.value.id in _REF_ALIASES:
            wiring.ref_fns.add(sub.attr)
        if isinstance(sub, ast.Call):
            f = sub.func
            if isinstance(f, ast.Name) and f.id == "_decide" and sub.args:
                a0 = sub.args[0]
                if isinstance(a0, ast.Constant) and isinstance(a0.value, str):
                    wiring.dispatch_keys.add(a0.value)
        if isinstance(sub, ast.Name) and sub.id in helper_names:
            used.add(sub.id)
    return used


def op_wirings(ops_source: str) -> List[OpWiring]:
    """Public ops of an ops.py source and their reachable wiring."""
    tree = ast.parse(ops_source)
    helpers: Dict[str, ast.AST] = {}
    publics: List[ast.AST] = []
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if node.name.startswith("_"):
                helpers[node.name] = node
            else:
                publics.append(node)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id.startswith("_"):
                    helpers[t.id] = node
    out = []
    for fn in publics:
        w = OpWiring(name=fn.name, lineno=fn.lineno)
        seen: Set[str] = set()
        frontier = [fn]
        while frontier:
            node = frontier.pop()
            for used in _collect_refs(node, w, set(helpers)):
                if used not in seen:
                    seen.add(used)
                    frontier.append(helpers[used])
        out.append(w)
    return out


def _read(path: str) -> Optional[str]:
    if not os.path.exists(path):
        return None
    with open(path, encoding="utf-8") as f:
        return f.read()


def check_parity(root: str, *,
                 ops_path: str = OPS_PATH, ref_path: str = REF_PATH,
                 bench_path: str = BENCH_PATH,
                 tests_dir: str = TESTS_DIR) -> List[Finding]:
    """Parity findings for the tree rooted at ``root`` (paths
    root-relative so tests can point this at a planted layout)."""
    findings: List[Finding] = []
    rel = ops_path.replace(os.sep, "/")
    ops_src = _read(os.path.join(root, ops_path))
    if ops_src is None:
        return [Finding(rule="PA301", path=rel, line=0,
                        message=f"ops module {ops_path} not found",
                        source=ops_path)]
    ref_src = _read(os.path.join(root, ref_path)) or ""
    ref_fns = {n.name for n in ast.parse(ref_src).body
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    bench_src = _read(os.path.join(root, bench_path)) or ""
    test_srcs = [_read(p) or "" for p in
                 iter_py_files(root, (tests_dir,))]

    for w in op_wirings(ops_src):
        resolved = w.ref_fns & ref_fns
        if not resolved:
            missing = ", ".join(sorted(w.ref_fns)) or "none referenced"
            findings.append(Finding(
                rule="PA301", path=rel, line=w.lineno, source=w.name,
                message=f"op `{w.name}` has no oracle in "
                        f"{ref_path} ({missing})"))
        if not w.dispatch_keys:
            findings.append(Finding(
                rule="PA302", path=rel, line=w.lineno, source=w.name,
                message=f"op `{w.name}` never consults the dispatcher "
                        "(`_decide(\"<op>\", ...)`) — it bypasses "
                        "backend-aware routing"))
        if not re.search(rf"\bops\.{w.name}\b", bench_src):
            findings.append(Finding(
                rule="PA303", path=rel, line=w.lineno, source=w.name,
                message=f"op `{w.name}` has no row in {bench_path} — "
                        "the perf gate cannot see it regress"))
        pat = re.compile(rf"\b{w.name}\b")
        if not any(pat.search(src) for src in test_srcs):
            findings.append(Finding(
                rule="PA304", path=rel, line=w.lineno, source=w.name,
                message=f"op `{w.name}` is referenced by no test under "
                        f"{tests_dir}/ — nothing pins its numerics"))
    findings += _check_rule_tests(root)
    return findings


def _check_rule_tests(root: str) -> List[Finding]:
    """PA305: every registered rule id needs a planted-violation test.

    Skipped when ``root`` has no ``tests/test_analysis.py`` — the
    planted trees the parity tests build intentionally have no analysis
    tests, and a partial checkout should not red-herring."""
    path = os.path.join(root, ANALYSIS_TESTS)
    test_src = _read(path)
    if test_src is None:
        return []
    # late import: repro.analysis imports this module at its own import
    from repro.analysis import ALL_RULES
    rel = ANALYSIS_TESTS.replace(os.sep, "/")
    out: List[Finding] = []
    for rule in sorted(ALL_RULES):
        if not re.search(rf"\b{rule}\b", test_src):
            out.append(Finding(
                rule="PA305", path=rel, line=0, source=rule,
                message=f"rule {rule} ({ALL_RULES[rule]}) appears "
                        f"nowhere in {rel} — a rule with no "
                        "planted-violation test can silently stop "
                        "firing"))
    return out
