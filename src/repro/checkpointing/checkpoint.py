"""Pytree checkpointing: npz payload + msgpack-encoded treedef.

No orbax offline; this is a minimal, dependency-light implementation with
the same save/restore contract (atomic rename, step-tagged directories).
"""
from __future__ import annotations

import os
import tempfile
from typing import Any, Optional

import jax
import msgpack
import numpy as np

Params = Any


def _flatten(tree: Params):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(path: str, tree: Params, step: Optional[int] = None) -> str:
    """Atomically writes ``<path>/ckpt_<step>`` (or <path> if step None)."""
    target = os.path.join(path, f"ckpt_{step}") if step is not None else path
    os.makedirs(os.path.dirname(target) or ".", exist_ok=True)
    leaves, treedef = _flatten(tree)

    _NATIVE = {"float64", "float32", "float16", "int64", "int32", "int16",
               "int8", "uint64", "uint32", "uint16", "uint8", "bool"}

    def to_np(l):
        a = np.asarray(l)
        if a.dtype.name not in _NATIVE:         # e.g. bfloat16, float8
            a = a.astype(np.float32)
        return a
    arrays = {f"leaf_{i}": to_np(l) for i, l in enumerate(leaves)}
    meta = msgpack.packb({
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "step": step,
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
    })
    tmpdir = tempfile.mkdtemp(dir=os.path.dirname(target) or ".")
    np.savez(os.path.join(tmpdir, "payload.npz"), **arrays)
    with open(os.path.join(tmpdir, "meta.msgpack"), "wb") as f:
        f.write(meta)
    if os.path.isdir(target):
        import shutil
        shutil.rmtree(target)
    os.replace(tmpdir, target)
    return target


def restore(path: str, like: Params, step: Optional[int] = None) -> Params:
    """Restores into the structure of ``like`` (shape/dtype validated)."""
    target = os.path.join(path, f"ckpt_{step}") if step is not None else path
    with np.load(os.path.join(target, "payload.npz")) as z:
        leaves = [z[f"leaf_{i}"] for i in range(len(z.files))]
    like_leaves, treedef = _flatten(like)
    assert len(leaves) == len(like_leaves), \
        f"checkpoint has {len(leaves)} leaves, expected {len(like_leaves)}"
    out = []
    for got, want in zip(leaves, like_leaves):
        assert got.shape == want.shape, (got.shape, want.shape)
        out.append(jax.numpy.asarray(got).astype(want.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_", 1)[1]) for d in os.listdir(path)
             if d.startswith("ckpt_")]
    return max(steps) if steps else None
