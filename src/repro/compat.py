"""Version shims for jax API churn, shared by every shard_map consumer.

Newer jax promotes shard_map to ``jax.shard_map`` and (separately)
renames the replication-check kwarg ``check_rep`` -> ``check_vma``;
probe each change independently since they landed in different releases.
"""
from __future__ import annotations

import inspect

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map

#: name of shard_map's replication-check kwarg on this jax version
SHARD_MAP_CHECK_KW = ("check_vma" if "check_vma"
                      in inspect.signature(shard_map).parameters
                      else "check_rep")
