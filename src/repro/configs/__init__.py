from repro.configs.base import (AttentionConfig, CommConfig, EncoderConfig,
                                FabricConfig, INPUT_SHAPES, InputShape,
                                LinkConfig, MoEConfig, ModalityStub,
                                ModelConfig, RGLRUConfig, SSMConfig,
                                TrainConfig)
from repro.configs.cnn_zoo import CNN_ZOO, CNNConfig
from repro.configs.registry import ARCH_IDS, all_configs, get_config

__all__ = [
    "AttentionConfig", "CommConfig", "EncoderConfig", "FabricConfig",
    "INPUT_SHAPES", "InputShape", "LinkConfig", "MoEConfig", "ModalityStub",
    "ModelConfig", "RGLRUConfig", "SSMConfig", "TrainConfig", "CNN_ZOO",
    "CNNConfig", "ARCH_IDS", "all_configs", "get_config",
]
