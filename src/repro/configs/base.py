"""Config dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``; the same
dataclass drives full-scale dry-runs (via ShapeDtypeStructs) and reduced
CPU smoke tests (via ``reduced()``).
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class AttentionConfig:
    kind: str = "gqa"                 # "gqa" | "mla" | "none"
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 64
    qk_norm: bool = False             # qwen3-style per-head RMSNorm on q/k
    attn_softcap: Optional[float] = None   # gemma2 logit softcap
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None   # window size for "local" layers
    # layer pattern, cycled over layers: entries "global" | "local"
    layer_pattern: Tuple[str, ...] = ("global",)
    # --- MLA (deepseek-v2 / minicpm3) ---
    q_lora_rank: Optional[int] = None
    kv_lora_rank: int = 512
    rope_head_dim: int = 64           # decoupled rope dims per head
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0                # routed experts (0 => dense FFN)
    n_shared: int = 0                 # always-on shared experts
    top_k: int = 2
    d_ff_expert: int = 0              # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    first_dense_layers: int = 1       # deepseek-v2: first layer(s) dense


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    n_heads: int = 24                 # SSD heads (d_inner / head_dim)
    head_dim: int = 64
    chunk: int = 256                  # SSD chunk length


@dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0                # 0 => d_model
    d_conv: int = 4
    block_pattern: Tuple[str, ...] = ("rglru", "rglru", "attn")  # 1:2 attn:rglru


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec archs (seamless).  Frontend is stubbed:
    input_specs() provides precomputed frame embeddings (B, T_src, d_model)."""
    n_layers: int = 24
    n_frames: int = 1500              # encoder memory length for serve shapes
    d_model: int = 1024


@dataclass(frozen=True)
class ModalityStub:
    """VLM / audio frontend stub: precomputed patch/frame embeddings."""
    kind: str = "none"                # "none" | "vision" | "audio"
    n_tokens: int = 0                 # tokens contributed per sample
    feat_dim: int = 0                 # embedding dim provided by the frontend


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                       # dense|moe|ssm|hybrid|vlm|audio|cnn
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    citation: str = ""
    norm: str = "rms"                 # rms | layer
    tie_embeddings: bool = True
    final_softcap: Optional[float] = None  # gemma2 final logit softcap
    attention: AttentionConfig = field(default_factory=AttentionConfig)
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encoder: Optional[EncoderConfig] = None
    modality: ModalityStub = field(default_factory=ModalityStub)
    dtype: str = "bfloat16"
    # long-context policy: "native" (ssm/hybrid), "window" (ring-buffer
    # sliding-window decode cache), "skip"
    long_context: str = "window"
    long_window: int = 4096

    # ---- derived ----
    def block_kind(self, layer: int) -> str:
        """Which mixer this layer uses: attn | rglru | ssm, and local/global."""
        if self.family == "ssm":
            return "ssm"
        if self.rglru is not None:
            pat = self.rglru.block_pattern
            return pat[layer % len(pat)]
        return "attn"

    def attn_window(self, layer: int) -> Optional[int]:
        pat = self.attention.layer_pattern
        if pat[layer % len(pat)] == "local":
            return self.attention.sliding_window
        return None

    def n_params(self) -> int:
        """Total parameter count (approximate, embeddings included)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        a = self.attention
        total = V * d  # embedding
        if not self.tie_embeddings:
            total += V * d
        enc_layers = self.encoder.n_layers if self.encoder else 0
        for layer in range(L):
            kind = self.block_kind(layer)
            if kind == "ssm":
                assert self.ssm is not None
                d_in = self.ssm.expand * d
                total += d * 2 * d_in + d_in * d          # in/out proj
                total += d_in * (2 * self.ssm.d_state)     # B,C proj (per head shared)
                total += self.ssm.n_heads * 2              # A, dt bias
                total += self.ssm.d_conv * d_in
            elif kind == "rglru":
                assert self.rglru is not None
                w = self.rglru.lru_width or d
                total += d * w * 2 + w * d + 3 * w + self.rglru.d_conv * w
            else:  # attention
                if a.kind == "mla":
                    qd = a.q_lora_rank or 0
                    h = a.n_heads
                    qhead = a.nope_head_dim + a.rope_head_dim
                    if qd:
                        total += d * qd + qd * h * qhead
                    else:
                        total += d * h * qhead
                    total += d * (a.kv_lora_rank + a.rope_head_dim)
                    total += a.kv_lora_rank * h * (a.nope_head_dim + a.v_head_dim)
                    total += h * a.v_head_dim * d
                else:
                    total += d * a.n_heads * a.head_dim
                    total += 2 * d * a.n_kv_heads * a.head_dim
                    total += a.n_heads * a.head_dim * d
            # FFN / MoE
            m = self.moe
            if m.n_experts and layer >= m.first_dense_layers:
                total += (m.n_experts + m.n_shared) * 3 * d * m.d_ff_expert
                total += d * m.n_experts  # router
            else:
                ff = self.d_ff if not m.n_experts else self.d_ff
                total += 3 * d * ff  # gated MLP
            total += 2 * d  # norms
        for _ in range(enc_layers):
            ed = self.encoder.d_model
            total += 4 * ed * ed + 3 * ed * self.d_ff + 2 * ed
            total += 2 * ed * ed  # cross-attn kv in decoder (amortized rough)
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        m = self.moe
        if not m.n_experts:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        moe_layers = L - m.first_dense_layers
        inactive = (m.n_experts - m.top_k) * 3 * d * m.d_ff_expert * moe_layers
        return self.n_params() - inactive

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: ≤2 layers, d_model ≤ 512, ≤4 experts."""
        d = min(self.d_model, 256)
        a = self.attention
        heads = min(a.n_heads, 4) if a.n_heads else 0
        kv = max(1, min(a.n_kv_heads, heads)) if heads else 0
        red_attn = dataclasses.replace(
            a, n_heads=heads, n_kv_heads=kv,
            head_dim=max(16, d // heads) if heads else 0,
            q_lora_rank=(64 if a.q_lora_rank else None),
            kv_lora_rank=min(a.kv_lora_rank, 64),
            rope_head_dim=16, nope_head_dim=32, v_head_dim=32,
            sliding_window=(64 if a.sliding_window else None),
        )
        red_moe = dataclasses.replace(
            self.moe,
            n_experts=min(self.moe.n_experts, 4),
            n_shared=min(self.moe.n_shared, 1),
            top_k=min(self.moe.top_k, 2),
            d_ff_expert=(64 if self.moe.d_ff_expert else 0),
            first_dense_layers=min(self.moe.first_dense_layers, 1),
        )
        red_ssm = dataclasses.replace(
            self.ssm, d_state=16, n_heads=8,
            head_dim=self.ssm.expand * d // 8, chunk=32,
        ) if self.ssm else None
        red_rglru = dataclasses.replace(
            self.rglru, lru_width=(d if self.rglru.lru_width else 0),
        ) if self.rglru else None
        red_enc = dataclasses.replace(
            self.encoder, n_layers=1, n_frames=16, d_model=d,
        ) if self.encoder else None
        red_mod = dataclasses.replace(
            self.modality, n_tokens=min(self.modality.n_tokens, 8) or 0,
            feat_dim=(d if self.modality.feat_dim else 0),
        )
        return dataclasses.replace(
            self, n_layers=2, d_model=d, d_ff=min(self.d_ff, 512),
            vocab=min(self.vocab, 512),
            attention=red_attn, moe=red_moe, ssm=red_ssm, rglru=red_rglru,
            encoder=red_enc, modality=red_mod, dtype="float32",
            long_window=64,
        )


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                         # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,   32, "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",  524_288,    1, "decode"),
}


@dataclass(frozen=True)
class LinkConfig:
    """Stochastic-link knobs (``repro.topology.links.LinkModel``).

    ``model="sampled"`` draws per-edge, per-activation latency/bandwidth
    instead of the class constants — seeded + replayable; with all rates
    at zero the sampled ledger reproduces the constant ledger exactly."""
    model: str = "constant"           # constant | sampled
    jitter: float = 0.0               # per-activation lognormal sigma
    hetero: float = 0.0               # persistent per-edge base spread
    straggler_rate: float = 0.0       # P(normal -> slow) per activation
    straggler_exit: float = 0.5       # P(slow -> normal) per activation
    straggler_slowdown: float = 10.0  # lat x / bw / while slow


@dataclass(frozen=True)
class FabricConfig:
    """The communication fabric (``repro.topology``): who talks to whom,
    when, at what link cost, and which nodes show up each round.

    Static graphs become constant schedules; tv-dcliques /
    random-matching are genuinely time-varying."""
    topology: str = "full"            # full | ring | torus | random |
    #                                   geo-wan | dcliques | hier-cliques |
    #                                   tv-dcliques | random-matching
    profile: str = "uniform"          # uniform | datacenter | geo-wan
    link: LinkConfig = field(default_factory=LinkConfig)
    # handshake amortization: a newly-activated link spreads its setup
    # latency over its first `amortize_window` gossip activations (1 =
    # pay up front); dropping a link forfeits the unpaid balance
    amortize_window: int = 1
    # online re-wiring: control-plane floats charged per newly-activated
    # link whenever the active edge set changes (schedule rotation or a
    # SkewScout topology-rung switch); 0 keeps re-wiring free (the
    # per-class handshake latency is still priced into simulated time)
    rewire_floats: float = 0.0
    # client sampling / partial participation: each round a seeded
    # Bernoulli mask keeps this fraction of nodes in the gossip exchange
    # (local updates continue; an edge is active iff both endpoints
    # participate).  1.0 = everyone, every round (the pre-sampling
    # behavior, bit-exact).
    participation: float = 1.0


def _flat_comm_field(name: str, replacement: str, getter):
    """Deprecated read-only property for a retired flat CommConfig field."""
    def get(self):
        warnings.warn(
            f"CommConfig.{name} is deprecated; read CommConfig.{replacement}",
            DeprecationWarning, stacklevel=2)
        return getter(self)
    get.__name__ = name
    get.__doc__ = f"Deprecated alias for ``CommConfig.{replacement}``."
    return property(get)


@dataclass(frozen=True)
class CommConfig:
    """The paper's technique as a first-class trainer feature.

    Every strategy exists on *both* backends — the CPU-scale simulation
    (``core.trainer``/``core.algorithms``) and the pod-scale SPMD launch
    path (``launch.steps``, where dpsgd/adpsgd gossip rides a
    shard_map + ppermute ring over the mesh ``pod`` axis) — and the two
    are held equivalent by ``tests/test_launch_gossip.py``.

    Fabric/link knobs live on the nested ``fabric: FabricConfig`` (and
    its ``link: LinkConfig``); the retired flat fields (``topology``,
    ``link_profile``, ``link_jitter``, ...) remain readable through
    deprecated back-compat properties below."""
    strategy: str = "bsp"             # bsp | gaia | fedavg | dgc | dpsgd |
    #                                   adpsgd
    # the communication fabric: topology, link profile, stochastic-link
    # model, handshake amortization, re-wiring cost, participation
    fabric: FabricConfig = field(default_factory=FabricConfig)
    # asynchronous gossip (AD-PSGD): the ledger prices rounds on
    # per-edge virtual clocks (links never wait for each other) instead
    # of the synchronous slowest-link rule
    async_gossip: bool = False
    # snapshot-buffer depth for adpsgd: neighbor reads may be up to this
    # many rounds stale (also the top of the SkewScout staleness ladder)
    max_staleness: int = 2
    # Gaia
    gaia_t0: float = 0.10
    # FedAvg
    iter_local: int = 20
    # DGC
    dgc_sparsity: float = 0.999       # final sparsity (top 0.1% exchanged)
    dgc_warmup_epochs: int = 4
    dgc_clip: float = 1.0
    dgc_compressor: str = "topk"      # topk | randk (seeded in-kernel mask)
    # SkewScout
    skewscout: bool = False
    travel_every: int = 500           # minibatches between model traveling
    sigma_al: float = 0.05
    lambda_al: float = 50.0
    lambda_c: float = 1.0
    tuner: str = "hill"               # hill | stochastic | anneal


# Back-compat read access for the retired flat fabric fields.  Each fires
# one DeprecationWarning per read and forwards to the nested config; the
# flat names are no longer accepted as constructor kwargs.
for _flat, _nested, _get in (
    ("topology", "fabric.topology", lambda c: c.fabric.topology),
    ("link_profile", "fabric.profile", lambda c: c.fabric.profile),
    ("link_model", "fabric.link.model", lambda c: c.fabric.link.model),
    ("link_jitter", "fabric.link.jitter", lambda c: c.fabric.link.jitter),
    ("link_hetero", "fabric.link.hetero", lambda c: c.fabric.link.hetero),
    ("straggler_rate", "fabric.link.straggler_rate",
     lambda c: c.fabric.link.straggler_rate),
    ("straggler_exit", "fabric.link.straggler_exit",
     lambda c: c.fabric.link.straggler_exit),
    ("straggler_slowdown", "fabric.link.straggler_slowdown",
     lambda c: c.fabric.link.straggler_slowdown),
    ("amortize_window", "fabric.amortize_window",
     lambda c: c.fabric.amortize_window),
    ("rewire_floats", "fabric.rewire_floats",
     lambda c: c.fabric.rewire_floats),
):
    setattr(CommConfig, _flat, _flat_comm_field(_flat, _nested, _get))
del _flat, _nested, _get


@dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig
    comm: CommConfig = field(default_factory=CommConfig)
    lr: float = 2e-3
    momentum: float = 0.9
    weight_decay: float = 5e-4
    batch_per_node: int = 20
    n_nodes: int = 5
    seed: int = 0
