"""CNN configs for the paper's own study (CIFAR-10-scale image models).

The paper evaluates AlexNet, GoogLeNet, LeNet, BN-LeNet, GN-LeNet, ResNet20.
We implement the LeNet family exactly as described (BN-LeNet = LeNet with
BatchNorm after each conv; GN-LeNet swaps GroupNorm in) plus a compact
AlexNet-style net and a ResNet-20-style net with BatchNorm — enough to
reproduce every paper phenomenon (BN divergence, GN rescue, algorithm loss)
on CPU with synthetic data.
"""
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class CNNConfig:
    name: str
    conv_channels: Tuple[int, ...]            # channels per conv block
    kernel_sizes: Tuple[int, ...]
    pool_after: Tuple[bool, ...]              # 2x2 maxpool after block?
    norm: Optional[str]                       # None | "batch" | "group" | "batchrenorm"
    group_size: int = 2                       # paper: G_size=2 works best
    fc_dims: Tuple[int, ...] = (256,)
    n_classes: int = 10
    image_size: int = 16                      # synthetic-CIFAR side
    in_channels: int = 3
    residual: bool = False                    # ResNet-style skip connections


def lenet(norm=None, name=None) -> CNNConfig:
    return CNNConfig(
        name=name or {"batch": "bn-lenet", "group": "gn-lenet",
                      "batchrenorm": "brn-lenet", None: "lenet"}[norm],
        conv_channels=(32, 32, 64),
        kernel_sizes=(5, 5, 5),
        pool_after=(True, True, True),
        norm=norm,
        fc_dims=(64,),
    )


def alexnet_s() -> CNNConfig:
    return CNNConfig(
        name="alexnet-s",
        conv_channels=(64, 128, 128),
        kernel_sizes=(3, 3, 3),
        pool_after=(True, True, True),
        norm=None,
        fc_dims=(256, 128),
    )


def resnet20_s(norm="batch") -> CNNConfig:
    return CNNConfig(
        name=f"resnet-s-{norm or 'nonorm'}",
        conv_channels=(16, 16, 32, 32, 64, 64),
        kernel_sizes=(3, 3, 3, 3, 3, 3),
        pool_after=(False, False, True, False, True, False),
        norm=norm,
        fc_dims=(),
        residual=True,
    )


CNN_ZOO = {
    "lenet": lenet(None),
    "bn-lenet": lenet("batch"),
    "gn-lenet": lenet("group"),
    "brn-lenet": lenet("batchrenorm"),
    "alexnet-s": alexnet_s(),
    "resnet-s": resnet20_s("batch"),
    "resnet-s-gn": resnet20_s("group"),
}
