"""deepseek-v2-236b [moe] — 60L d_model=5120 128H d_ff=1536 (per expert)
vocab=102400.  MLA kv_lora=512, MoE: 2 shared + 160 routed, top-6.
[arXiv:2405.04434]"""
from repro.configs.base import AttentionConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    d_ff=12_288,                 # dense FFN width for the first (dense) layer
    vocab=102_400,
    citation="arXiv:2405.04434",
    norm="rms",
    tie_embeddings=False,
    attention=AttentionConfig(
        kind="mla", n_heads=128, n_kv_heads=128, head_dim=128,
        q_lora_rank=1536, kv_lora_rank=512,
        rope_head_dim=64, nope_head_dim=128, v_head_dim=128,
        rope_theta=10_000.0,
    ),
    moe=MoEConfig(
        n_experts=160, n_shared=2, top_k=6, d_ff_expert=1536,
        capacity_factor=1.25, router_aux_weight=0.001, first_dense_layers=1,
    ),
)
