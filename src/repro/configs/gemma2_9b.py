"""gemma2-9b [dense] — 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000.  Local+global alternating attention, logit softcaps.
[arXiv:2408.00118]"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    d_ff=14_336,
    vocab=256_000,
    citation="arXiv:2408.00118",
    norm="rms",
    tie_embeddings=True,
    final_softcap=30.0,
    attention=AttentionConfig(
        kind="gqa", n_heads=16, n_kv_heads=8, head_dim=256,
        attn_softcap=50.0, sliding_window=4096,
        layer_pattern=("local", "global"), rope_theta=10_000.0,
    ),
)
