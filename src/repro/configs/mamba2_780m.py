"""mamba2-780m [ssm] — 48L d_model=1536 (attention-free) vocab=50280,
ssm_state=128.  SSD (state-space duality), chunked dual form.
[arXiv:2405.21060]"""
from repro.configs.base import AttentionConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    d_ff=0,
    vocab=50_280,
    citation="arXiv:2405.21060",
    norm="rms",
    tie_embeddings=True,
    long_context="native",
    attention=AttentionConfig(kind="none", n_heads=0, n_kv_heads=0, head_dim=0),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, n_heads=48, head_dim=64,
                  chunk=256),
)
