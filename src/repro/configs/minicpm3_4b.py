"""minicpm3-4b [dense] — 62L d_model=2560 40H d_ff=6400 vocab=73448.
MLA attention (q_lora=768, kv_lora=256).  [hf:openbmb/MiniCPM3-4B]"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    d_ff=6400,
    vocab=73_448,
    citation="hf:openbmb/MiniCPM3-4B",
    norm="rms",
    tie_embeddings=True,
    attention=AttentionConfig(
        kind="mla", n_heads=40, n_kv_heads=40, head_dim=64,
        q_lora_rank=768, kv_lora_rank=256,
        rope_head_dim=32, nope_head_dim=64, v_head_dim=64,
        rope_theta=10_000.0,
    ),
)
