"""phi-3-vision-4.2b [vlm] — 32L d_model=3072 32H (GQA kv=32) d_ff=8192
vocab=32064.  phi3-mini backbone + CLIP frontend (stubbed patch embeddings).
[hf:microsoft/Phi-3-vision-128k-instruct]"""
from repro.configs.base import AttentionConfig, ModalityStub, ModelConfig

CONFIG = ModelConfig(
    arch_id="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    d_ff=8192,
    vocab=32_064,
    citation="hf:microsoft/Phi-3-vision-128k-instruct",
    norm="rms",
    tie_embeddings=False,
    attention=AttentionConfig(
        kind="gqa", n_heads=32, n_kv_heads=32, head_dim=96,
        rope_theta=10_000.0,
    ),
    # CLIP ViT-L/14 @336px => 576 patch tokens, 1024-d features, projected
    # into the LM by a learned projector (part of our backbone).
    modality=ModalityStub(kind="vision", n_tokens=576, feat_dim=1024),
)
