"""qwen3-0.6b [dense] — 28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936.
qk_norm + GQA.  [hf:Qwen/Qwen3-8B]"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    d_ff=3072,
    vocab=151_936,
    citation="hf:Qwen/Qwen3-8B",
    norm="rms",
    tie_embeddings=True,
    attention=AttentionConfig(
        kind="gqa", n_heads=16, n_kv_heads=8, head_dim=128,
        qk_norm=True, rope_theta=1_000_000.0,
    ),
)
