"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000.  RG-LRU + local attention, 1 attn : 2 recurrent.
[arXiv:2402.19427]"""
from repro.configs.base import AttentionConfig, ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    d_ff=7680,
    vocab=256_000,
    citation="arXiv:2402.19427",
    norm="rms",
    tie_embeddings=True,
    long_context="native",
    attention=AttentionConfig(
        kind="gqa", n_heads=10, n_kv_heads=1, head_dim=256,
        sliding_window=2048, layer_pattern=("local",),
        rope_theta=10_000.0,
    ),
    rglru=RGLRUConfig(lru_width=2560, d_conv=4,
                      block_pattern=("rglru", "rglru", "attn")),
)
