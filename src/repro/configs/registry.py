"""Architecture registry: ``get_config("<arch-id>")`` and the full list.

The ten assigned architectures plus the paper's own CNN models (used for the
faithful non-IID study on image classification).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig

_MODULES = {
    "qwen3-0.6b":            "repro.configs.qwen3_0_6b",
    "phi-3-vision-4.2b":     "repro.configs.phi_3_vision_4_2b",
    "gemma2-9b":             "repro.configs.gemma2_9b",
    "recurrentgemma-2b":     "repro.configs.recurrentgemma_2b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
    "starcoder2-3b":         "repro.configs.starcoder2_3b",
    "deepseek-v2-236b":      "repro.configs.deepseek_v2_236b",
    "minicpm3-4b":           "repro.configs.minicpm3_4b",
    "mamba2-780m":           "repro.configs.mamba2_780m",
    "deepseek-v2-lite-16b":  "repro.configs.deepseek_v2_lite_16b",
}

ARCH_IDS: List[str] = list(_MODULES)

_cache: Dict[str, ModelConfig] = {}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _cache:
        if arch_id not in _MODULES:
            raise KeyError(
                f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
        _cache[arch_id] = importlib.import_module(_MODULES[arch_id]).CONFIG
    return _cache[arch_id]


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
