"""seamless-m4t-large-v2 [audio] — 24L d_model=1024 16H (GQA kv=16) d_ff=8192
vocab=256206.  Encoder-decoder, multimodal; mel/conv frontend stubbed —
input_specs() provides precomputed frame embeddings.  [arXiv:2308.11596]"""
from repro.configs.base import (AttentionConfig, EncoderConfig, ModalityStub,
                                ModelConfig)

CONFIG = ModelConfig(
    arch_id="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    d_model=1024,
    d_ff=8192,
    vocab=256_206,
    citation="arXiv:2308.11596",
    norm="layer",
    tie_embeddings=True,
    attention=AttentionConfig(
        kind="gqa", n_heads=16, n_kv_heads=16, head_dim=64,
        rope_theta=10_000.0,
    ),
    encoder=EncoderConfig(n_layers=24, n_frames=1500, d_model=1024),
    modality=ModalityStub(kind="audio", n_tokens=1500, feat_dim=1024),
)
