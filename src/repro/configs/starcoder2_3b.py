"""starcoder2-3b [dense] — 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152.  GQA + RoPE, native sliding-window 4096.  [arXiv:2402.19173]"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    d_ff=12_288,
    vocab=49_152,
    citation="arXiv:2402.19173",
    norm="layer",
    tie_embeddings=True,
    attention=AttentionConfig(
        kind="gqa", n_heads=24, n_kv_heads=2, head_dim=128,
        sliding_window=4096, layer_pattern=("local",),
        rope_theta=100_000.0,
    ),
)
