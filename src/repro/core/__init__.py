from repro.core.partition import (label_distribution, partition_80_20,
                                  partition_by_region, partition_label_skew,
                                  skew_index)
from repro.core.skewscout import SkewScout, THETA_LADDERS
from repro.core.trainer import (RunResult, make_algorithm, make_cnn_fns,
                                train_decentralized)

__all__ = ["label_distribution", "partition_80_20", "partition_by_region",
           "partition_label_skew", "skew_index", "SkewScout",
           "THETA_LADDERS", "RunResult", "make_algorithm", "make_cnn_fns",
           "train_decentralized"]
