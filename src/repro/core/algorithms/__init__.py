from repro.core.algorithms.adpsgd import ADPSGD
from repro.core.algorithms.base import ModelFns, tree_size
from repro.core.algorithms.bsp import BSP
from repro.core.algorithms.dgc import DGC, WARMUP_SPARSITIES, warmup_sparsity
from repro.core.algorithms.dpsgd import DPSGD
from repro.core.algorithms.fedavg import FedAvg
from repro.core.algorithms.gaia import Gaia

__all__ = ["ADPSGD", "ModelFns", "tree_size", "BSP", "DGC",
           "WARMUP_SPARSITIES", "warmup_sparsity", "DPSGD", "FedAvg",
           "Gaia"]
