"""AD-PSGD (Lian et al., NeurIPS 2018): asynchronous decentralized SGD.

Same local momentum-SGD + gossip-averaging loop as :class:`DPSGD`, but
nodes do not wait for each round's slowest link: each node mixes with
the *last delivered* version of its neighbors' parameters, which may be
up to ``max_staleness`` rounds old.  The simulation models this with a
**bounded-staleness snapshot buffer**: ``state["snaps"]`` holds the
flattened per-node parameter stack of the last ``max_staleness + 1``
rounds (slot 0 = this round's post-gradient params, slot ``s`` = the
stack from ``s`` rounds ago), and every neighbor read gathers from slot
``staleness`` instead of slot 0.  ``staleness = 0`` is bit-identical to
synchronous D-PSGD; the *bound* is structural — a read deeper than the
buffer cannot be expressed.

The mixing reuses the dispatched ``ops.neighbor_mix`` (src-gather
variant; Pallas on TPU, measured winner elsewhere): the buffer
is stacked into one ``((S + 1) * K, N)`` source matrix and the round's
padded neighbor indices are offset by ``staleness * K`` — staleness
values therefore ride inside the same *runtime* index operand as the
schedule's neighbor sets, so rotating schedules, SkewScout rung
switches, **and** staleness changes (``set_staleness``) all reuse one
compilation per run (``trace_count`` asserts this in tests).

Why it matters here: under a geo-WAN fabric the synchronous ledger
prices every round at the slowest link — one straggler gates all nodes.
With stale reads the slow link keeps ``staleness + 1`` deliveries in
flight and its latency amortizes away (see ``CommLedger`` async mode),
while accuracy stays within noise of the synchronous run — the
communication-structure-vs-skew trade the paper's SkewScout controller
climbs, now with staleness as a rung.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.algorithms.base import ModelFns
from repro.core.algorithms.dpsgd import DPSGD
from repro.kernels import ops
from repro.topology.graphs import Topology, TopologySchedule


class ADPSGD(DPSGD):
    name = "adpsgd"

    def __init__(self, fns: ModelFns, n_nodes: int, *,
                 topology: Union[Topology, TopologySchedule],
                 momentum: float = 0.9, weight_decay: float = 0.0,
                 use_kernel: bool = True,
                 pad_degree: Optional[int] = None,
                 max_staleness: int = 2,
                 staleness: Optional[int] = None,
                 participation=None):
        """``max_staleness`` sizes the snapshot buffer (the hard bound a
        controller may move within); ``staleness`` is the current rung,
        defaulting to the bound (fully asynchronous)."""
        assert max_staleness >= 0, max_staleness
        self.max_staleness = int(max_staleness)
        s = self.max_staleness if staleness is None else int(staleness)
        assert 0 <= s <= self.max_staleness, (s, self.max_staleness)
        self.staleness = s
        self._stale_cache: Dict = {}
        super().__init__(fns, n_nodes, topology=topology,
                         momentum=momentum, weight_decay=weight_decay,
                         use_kernel=use_kernel, pad_degree=pad_degree,
                         participation=participation)

    # ---- staleness plumbing ----
    def set_schedule(self, fabric) -> None:
        super().set_schedule(fabric)
        self._stale_cache = {}

    def set_staleness(self, staleness: int) -> None:
        """Move the staleness rung (SkewScout).  The buffer depth is
        fixed at ``max_staleness + 1``, so any rung within the bound
        changes only the *values* of the runtime index operand — never
        the operand shapes, hence never the compilation."""
        s = int(staleness)
        assert 0 <= s <= self.max_staleness, \
            (f"staleness {s} outside the bound [0, {self.max_staleness}] "
             "fixed by the snapshot buffer at construction")
        if s != self.staleness:
            self.staleness = s
            self._stale_cache = {}

    def _stale_operand(self, t: int) -> jnp.ndarray:
        """(K, D) int32 per-read staleness slots for round ``t``: the
        current rung on real neighbor slots, 0 on padding (padding
        weights are 0, so the slot is irrelevant — 0 keeps the gather
        index in range without widening the buffer)."""
        key = (id(self.schedule.at(t)), self.staleness)
        op = self._stale_cache.get(key)
        if op is None:
            _, w, _ = self.schedule.neighbor_arrays(
                t, pad_degree=self._pad_degree)
            op = jnp.asarray(np.where(w > 0, self.staleness, 0)
                             .astype(np.int32))
            self._stale_cache[key] = op
        return op

    def edge_staleness(self, t: int) -> np.ndarray:
        """Per-edge staleness bound for round ``t``'s active edges,
        aligned with ``schedule.at(t).edges`` — what the async ledger
        uses to amortize each link's latency."""
        return np.full(len(self.schedule.at(int(t)).edges),
                       self.staleness, np.int64)

    # ---- state ----
    def init(self, params, mstate) -> Dict:
        state = super().init(params, mstate)
        flat, _, _ = self._flatten(state["params"])
        state["snaps"] = jnp.broadcast_to(
            flat, (self.max_staleness + 1,) + flat.shape)
        return state

    def step(self, state, batch, lr, step_idx) -> Tuple[Dict, Dict]:
        """One local step + stale gossip round.  Neighbor indices,
        weights, and staleness slots are all runtime operands of the one
        jitted body."""
        nbr_idx, nbr_w, self_w = self.mix_operands(int(step_idx))
        stale = self._stale_operand(int(step_idx))
        return self._step_stale(state, batch, lr, step_idx,
                                nbr_idx, nbr_w, self_w, stale)

    @partial(jax.jit, static_argnums=0)
    def _step_stale(self, state, batch, lr, step_idx,
                    nbr_idx, nbr_w, self_w, stale) -> Tuple[Dict, Dict]:
        self.trace_count += 1          # Python side effect: trace-time only
        losses, new_ms, vel, params = self._local_update(state, batch, lr)
        flat, treedef, leaves = self._flatten(params)
        # push this round's post-gradient stack into slot 0; slot s now
        # holds the stack from s rounds ago (pre-mix, like slot 0)
        snaps = jnp.concatenate([flat[None], state["snaps"][:-1]], axis=0)
        src = snaps.reshape(-1, flat.shape[1])     # ((S+1)*K, N)
        gidx = stale * self.K + nbr_idx            # slot-offset gather
        if self.use_kernel:
            mixed = ops.neighbor_mix(flat, gidx, nbr_w, self_w, src=src)
        else:
            # dense oracle: scatter the runtime weights into (K, (S+1)K)
            W = jnp.zeros((self.K, src.shape[0]), jnp.float32).at[
                jnp.arange(self.K)[:, None], gidx].add(nbr_w)
            mixed = jnp.matmul(W, src) + self_w[:, None] * flat
        params = self._unflatten(mixed, treedef, leaves)

        metrics = self._gossip_metrics(losses, params, nbr_w)
        nbr_mask = (nbr_w > 0).astype(jnp.float32)
        reads = jnp.maximum(jnp.sum(nbr_mask), 1.0)
        metrics["mean_staleness"] = jnp.sum(stale * nbr_mask) / reads
        metrics["max_staleness_used"] = jnp.max(stale * nbr_mask
                                                .astype(jnp.int32))
        return ({"params": params, "mstate": new_ms, "vel": vel,
                 "snaps": snaps}, metrics)
