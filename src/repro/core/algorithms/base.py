"""Shared machinery for the decentralized learning algorithms.

Simulation backend: K nodes live on one host as a stacked leading axis
(``vmap`` over nodes).  This is bit-faithful to the paper's algorithms —
each node sees only its partition's minibatch; cross-node exchange is an
explicit reduction over the node axis.  The pod-scale distributed backend
(``repro.launch.steps``) applies the same update transforms across the
``pod`` mesh axis with collectives.

Every algorithm implements:
  init(params, mstate)                       -> AlgoState
  step(state, stacked_batch, lr, step_idx,
       **dynamic_hypers)                     -> (AlgoState, metrics)
  eval_params(state)                         -> (params, mstate) global model
  node_params(state, k)                      -> node k's model

``metrics["comm_floats"]`` counts the floats exchanged this step per node —
the paper's communication-savings currency (BSP = model size each step).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any
tmap = jax.tree_util.tree_map


@dataclass(frozen=True)
class ModelFns:
    """Model adapter: everything an algorithm needs to know about a model.

    loss_and_grad(params, mstate, batch) -> (loss, grads, new_mstate)
        where ``batch`` is one node's minibatch (e.g. {"x": ..., "y": ...}).
    """
    loss_and_grad: Callable


def tree_size(tree: Params) -> int:
    return sum(l.size for l in jax.tree_util.tree_leaves(tree))


def tree_nnz(tree: Params) -> jnp.ndarray:
    return sum(jnp.sum(l != 0).astype(jnp.float32)
               for l in jax.tree_util.tree_leaves(tree))


def tree_stack_n(tree: Params, k: int) -> Params:
    return tmap(lambda l: jnp.broadcast_to(l, (k,) + l.shape), tree)


def tree_index(tree: Params, i) -> Params:
    return tmap(lambda l: l[i], tree)


def tree_mean0(tree: Params) -> Params:
    return tmap(lambda l: jnp.mean(l, axis=0), tree)


def tree_sum0(tree: Params) -> Params:
    return tmap(lambda l: jnp.sum(l, axis=0), tree)


def pernode_grads(fns: ModelFns, params: Params, mstate: Params,
                  batch: Params, *, params_stacked: bool):
    """vmap the node dimension.  batch leaves have leading axis K."""
    in_axes = (0 if params_stacked else None, 0, 0)
    return jax.vmap(fns.loss_and_grad, in_axes=in_axes)(params, mstate, batch)
