"""BSP (Valiant 1990): full synchronization every step — the paper's model-
quality target.  All node gradients are averaged each minibatch; a single
global model exists at all times.  Per-node BatchNorm still normalizes with
*local* minibatch statistics — which is exactly why BSP alone cannot fix the
non-IID problem for BN models (paper §5)."""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.algorithms.base import (ModelFns, Params, pernode_grads,
                                        tree_mean0, tree_size, tmap)
from repro.optim.sgd import init_momentum


class BSP:
    name = "bsp"

    def __init__(self, fns: ModelFns, n_nodes: int, *, momentum: float = 0.9,
                 weight_decay: float = 0.0):
        self.fns, self.K = fns, n_nodes
        self.m, self.wd = momentum, weight_decay

    def init(self, params: Params, mstate: Params) -> Dict[str, Params]:
        return {
            "params": params,
            "mstate": tmap(lambda l: jnp.broadcast_to(l, (self.K,) + l.shape),
                           mstate),
            "vel": init_momentum(params),
        }

    @partial(jax.jit, static_argnums=0)
    def step(self, state, batch, lr, step_idx) -> Tuple[Dict, Dict]:
        losses, grads, new_ms = pernode_grads(
            self.fns, state["params"], state["mstate"], batch,
            params_stacked=False)
        g = tree_mean0(grads)

        def upd(w, gl, u):
            gl = gl + self.wd * w
            return self.m * u - lr * gl
        vel = tmap(upd, state["params"], g, state["vel"])
        params = tmap(lambda w, u: w + u, state["params"], vel)
        new_state = {"params": params, "mstate": new_ms, "vel": vel}
        metrics = {"loss": jnp.mean(losses),
                   "comm_floats": jnp.asarray(
                       float(tree_size(state["params"])), jnp.float32)}
        return new_state, metrics

    def eval_params(self, state):
        return state["params"], tree_mean0(state["mstate"])

    def node_params(self, state, k: int):
        return state["params"], tmap(lambda l: l[k], state["mstate"])
