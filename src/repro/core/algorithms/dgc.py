"""DeepGradientCompression (Lin et al., ICLR 2018) — Algorithm 3.

Per step each node: scales its gradient by -eta, clips (global norm),
applies momentum correction (u = m*u + g), accumulates v += u, and exchanges
only the top-s% magnitude entries of v per tensor.  Exchanged entries are
cleared from BOTH v and u (momentum factor masking).  A warm-up schedule
raises s over epochs: 75%, 93.75%, 98.4375%, 99.6%, 99.9%.

``sparsity`` is dynamic (traced), so both the warm-up schedule and SkewScout
retuning require no recompilation.

``compressor="randk"`` swaps the exact top-s% selection for seeded
rand-k (the classic baseline top-k is measured against): the keep mask
is a pure function of (seed, step, leaf, flat index) generated inside
the select kernel (``kernels/rng.py``) — no materialized random arrays —
and the same (seed, counter) stream masks ``v`` and ``u`` consistently.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.algorithms.base import (ModelFns, Params, pernode_grads,
                                        tree_mean0, tree_sum0, tmap)
from repro.kernels import ops
from repro.optim.sgd import global_norm

WARMUP_SPARSITIES = (0.75, 0.9375, 0.984375, 0.996, 0.999)


def warmup_sparsity(epoch: int, e_warm: int) -> float:
    """Paper §3: s follows the warm-up schedule, e_warm epochs per level."""
    idx = min(epoch // max(e_warm, 1), len(WARMUP_SPARSITIES) - 1)
    return WARMUP_SPARSITIES[idx]


class DGC:
    name = "dgc"

    def __init__(self, fns: ModelFns, n_nodes: int, *, momentum: float = 0.9,
                 weight_decay: float = 0.0, clip: float = 1.0,
                 sparsity: float = 0.999, compressor: str = "topk",
                 seed: int = 0):
        if compressor not in ("topk", "randk"):
            raise ValueError(f"compressor={compressor!r}; expected "
                             "'topk' or 'randk'")
        self.fns, self.K = fns, n_nodes
        self.m, self.wd = momentum, weight_decay
        self.clip = clip
        self.sparsity = sparsity
        self.compressor = compressor
        self.seed = seed

    def init(self, params: Params, mstate: Params) -> Dict[str, Params]:
        stack = lambda l: jnp.broadcast_to(l, (self.K,) + l.shape)
        zeros = lambda l: jnp.zeros((self.K,) + l.shape, l.dtype)
        return {
            "params": params,                 # ONE global model
            "mstate": tmap(stack, mstate),
            "vel": tmap(zeros, params),       # u (per node)
            "acc": tmap(zeros, params),       # v (per node)
        }

    @partial(jax.jit, static_argnums=0)
    def step(self, state, batch, lr, step_idx, sparsity=None
             ) -> Tuple[Dict, Dict]:
        s = self.sparsity if sparsity is None else sparsity
        losses, grads, new_ms = pernode_grads(
            self.fns, state["params"], state["mstate"], batch,
            params_stacked=False)

        # g = -eta * grad, with per-node gradient clipping
        def clip_node(g):
            n = global_norm(g)
            scale = jnp.minimum(1.0, self.clip / jnp.maximum(n, 1e-12))
            return tmap(lambda l: l * scale, g)
        grads = jax.vmap(clip_node)(grads)
        g = tmap(lambda gl, w: -lr * (gl + self.wd * w[None]),
                 grads, state["params"])

        vel = tmap(lambda u, gl: self.m * u + gl, state["vel"], g)
        acc = tmap(lambda v, u: v + u, state["acc"], vel)

        if self.compressor == "randk":
            # seeded rand-k: each (step, leaf) gets its own counter
            # stream, and replaying the stream on ``vel`` clears exactly
            # the exchanged coordinates (momentum factor masking without
            # a materialized mask).
            keep = 1.0 - s
            leaves_v, treedef = jax.tree_util.tree_flatten(acc)
            leaves_u = treedef.flatten_up_to(vel)
            sh, cl, counts = [], [], []
            for li, (v, u) in enumerate(zip(leaves_v, leaves_u)):
                leaf_seed = (jnp.asarray(step_idx, jnp.int32) * 1009
                             + self.seed * 131 + li)
                sv, cnt = ops.rand_k_sparsify(v, keep, leaf_seed)
                su, _ = ops.rand_k_sparsify(u, keep, leaf_seed)
                sh.append(sv)
                cl.append(su)
                counts.append(cnt)
            shared = jax.tree_util.tree_unflatten(treedef, sh)
            total = tree_sum0(shared)                    # sum over nodes
            params = tmap(lambda w, t: w + t, state["params"], total)
            acc = tmap(lambda v, sv: v - sv, acc, shared)
            vel = jax.tree_util.tree_unflatten(
                treedef, [u - su for u, su in zip(leaves_u, cl)])
            comm = sum(c.astype(jnp.float32) for c in counts) / self.K
        else:
            # per-tensor, per-node top-(1-s) magnitude threshold
            def threshold(v):
                flat = jnp.abs(v.reshape(v.shape[0], -1))
                return jnp.quantile(flat, s, axis=1)     # (K,)
            def select(v):
                t = threshold(v)
                return (jnp.abs(v) > t.reshape((-1,) + (1,) * (v.ndim - 1))
                        ).astype(v.dtype)
            mask = tmap(select, acc)
            shared = tmap(lambda v, m_: v * m_, acc, mask)
            total = tree_sum0(shared)                    # sum over nodes
            params = tmap(lambda w, t: w + t, state["params"], total)
            # momentum factor masking: clear exchanged entries from v AND u
            acc = tmap(lambda v, m_: v * (1 - m_), acc, mask)
            vel = tmap(lambda u, m_: u * (1 - m_), vel, mask)
            comm = sum(jnp.sum(m_)
                       for m_ in jax.tree_util.tree_leaves(mask)) / self.K
        metrics = {"loss": jnp.mean(losses), "comm_floats": comm,
                   "resid_delta": _mean_rel(acc, params)}
        return ({"params": params, "mstate": new_ms, "vel": vel, "acc": acc},
                metrics)

    def eval_params(self, state):
        return state["params"], tree_mean0(state["mstate"])

    def node_params(self, state, k: int):
        return state["params"], tmap(lambda l: l[k], state["mstate"])


def _mean_rel(acc, params):
    num = sum(jnp.sum(jnp.abs(a)) for a in jax.tree_util.tree_leaves(acc))
    den = sum(jnp.sum(jnp.abs(p)) * acc_l.shape[0]
              for p, acc_l in zip(jax.tree_util.tree_leaves(params),
                                  jax.tree_util.tree_leaves(acc)))
    return num / jnp.maximum(den, 1e-12)
