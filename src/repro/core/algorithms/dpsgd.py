"""D-PSGD (Lian et al., NeurIPS 2017): decentralized parallel SGD.

Each node holds a model replica, takes a local momentum-SGD step, then
*gossip-averages* with its graph neighbors: ``x_k <- sum_j W[k,j] x_j``
restricted to the round's edges, with W the symmetric doubly-stochastic
mixing matrix.  On the complete graph (W = 1/K) this is exact averaging
and the trajectory coincides with BSP; on sparse graphs (ring, torus,
expander, D-Cliques) each step only moves the model toward consensus at
the rate of the spectral gap, trading accuracy-under-skew for per-node
bandwidth of ``degree * |model|`` instead of a full all-reduce.

The fabric is a :class:`~repro.topology.graphs.TopologySchedule`: round
``t`` mixes with ``schedule.at(t)``'s neighbors.  The padded neighbor
indices/weights are *runtime operands* of the jitted step — padded to
the schedule-wide max degree so every round (and every rung of a
SkewScout topology ladder, via :meth:`DPSGD.set_schedule`) shares one
operand shape and the step compiles exactly once per run
(``trace_count`` asserts this in tests).

The mixing itself runs as one fused gather-scale-accumulate over the
flattened parameter stack via ``ops.neighbor_mix`` — the backend-aware
dispatcher (``kernels/dispatch.py``) routes it to the Pallas kernel on
TPU and to whichever of {Pallas, jnp padded-scatter oracle} measured
faster elsewhere.  ``use_kernel=False`` bypasses ops entirely for a
locally-built dense ``W @ X`` (debug path).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.algorithms.base import (ModelFns, Params, pernode_grads,
                                        tree_mean0, tree_size, tmap)
from repro.kernels import ops
from repro.topology.graphs import Topology, TopologySchedule, as_schedule


class DPSGD:
    name = "dpsgd"

    def __init__(self, fns: ModelFns, n_nodes: int, *,
                 topology: Union[Topology, TopologySchedule],
                 momentum: float = 0.9, weight_decay: float = 0.0,
                 use_kernel: bool = True,
                 pad_degree: Optional[int] = None,
                 participation=None):
        """``pad_degree`` widens the neighbor operand shape beyond this
        schedule's max degree — set it to the max over a SkewScout
        topology ladder so rung switches don't change operand shapes
        (and hence never retrace the step).

        ``participation``: optional
        :class:`~repro.topology.links.Participation` sampler.  Each
        round its seeded node mask zeroes the mixing weight of every
        edge with a sampled-out endpoint (slack returns to the self
        weight, so rows still sum to 1 and sampled-out nodes keep their
        own model).  Masking changes operand *values* only — shapes are
        untouched, so the step still compiles exactly once."""
        schedule = as_schedule(topology)
        assert schedule.n_nodes == n_nodes, (schedule.n_nodes, n_nodes)
        self.fns, self.K = fns, n_nodes
        self.m, self.wd = momentum, weight_decay
        self.use_kernel = use_kernel
        self.participation = participation
        # how many times the jitted step body was traced; 1 after any
        # number of rounds == "schedules don't retrigger compilation"
        self.trace_count = 0
        self._pad_degree = max(schedule.max_degree, 1)
        if pad_degree is not None:
            self._pad_degree = max(self._pad_degree, pad_degree)
        self._operand_cache: Dict[int, tuple] = {}
        self.set_schedule(schedule)

    # ---- schedule plumbing ----
    def set_schedule(self, fabric: Union[Topology, TopologySchedule]
                     ) -> None:
        """Swap the fabric mid-run (SkewScout topology rung switch).
        Keeps the operand padding monotone so the jitted step's operand
        shapes — and its compilation — survive the switch."""
        schedule = as_schedule(fabric)
        assert schedule.n_nodes == self.K, (schedule.n_nodes, self.K)
        # widening the pad after the step compiled would change the
        # operand shape and silently retrace — refuse instead (growing
        # the pad is only safe while nothing has been traced yet)
        assert schedule.max_degree <= self._pad_degree or \
            self.trace_count == 0, \
            (f"schedule {schedule.name!r} needs degree "
             f"{schedule.max_degree} > pad {self._pad_degree}; construct "
             f"DPSGD with pad_degree=max over the ladder")
        self._pad_degree = max(self._pad_degree, schedule.max_degree)
        self.schedule = schedule
        self._operand_cache.clear()

    @property
    def topology(self) -> Topology:
        """Round-0 graph — the full graph for constant schedules (kept
        for one-graph-per-run callers)."""
        return self.schedule.at(0)

    def mix_operands(self, t: int) -> Tuple[jnp.ndarray, jnp.ndarray,
                                            jnp.ndarray]:
        """Round ``t``'s (nbr_idx, nbr_w, self_w) device arrays, cached
        per unique graph of the period, all padded to one shape.  With a
        participation sampler, round ``t``'s node mask is applied to the
        cached host arrays (same shapes, masked values) before upload;
        a full-participation round returns the cached device operands
        untouched."""
        i = id(self.schedule.at(t))
        ent = self._operand_cache.get(i)
        if ent is None:
            idx, w, sw = self.schedule.neighbor_arrays(
                t, pad_degree=self._pad_degree)
            ent = ((idx, w),
                   (jnp.asarray(idx), jnp.asarray(w), jnp.asarray(sw)))
            self._operand_cache[i] = ent
        (idx_np, w_np), ops_t = ent
        if self.participation is None:
            return ops_t
        m = self.participation.mask(int(t))
        if m.all():
            return ops_t
        # w'_ij = w_ij * m_i * m_j (symmetric), slack to the diagonal:
        # rows still sum to 1 and sampled-out nodes mix with nobody
        w2 = np.where(m[idx_np] & m[:, None], w_np, 0.0) \
            .astype(np.float32)
        sw2 = (1.0 - w2.sum(axis=1)).astype(np.float32)
        return ops_t[0], jnp.asarray(w2), jnp.asarray(sw2)

    def init(self, params: Params, mstate: Params) -> Dict[str, Params]:
        stack = lambda l: jnp.broadcast_to(l, (self.K,) + l.shape)
        return {
            "params": tmap(stack, params),
            "mstate": tmap(stack, mstate),
            "vel": tmap(lambda l: jnp.zeros((self.K,) + l.shape, l.dtype),
                        params),
        }

    def _flatten(self, stacked: Params):
        """Per-node model stack -> one (K, N) float32 matrix (+ the
        structure needed to split back)."""
        leaves, treedef = jax.tree_util.tree_flatten(stacked)
        flat = jnp.concatenate(
            [l.reshape(self.K, -1).astype(jnp.float32) for l in leaves],
            axis=1)
        return flat, treedef, leaves

    def _unflatten(self, mixed: jnp.ndarray, treedef, leaves) -> Params:
        out, off = [], 0
        for l in leaves:
            n = l[0].size
            out.append(mixed[:, off:off + n].reshape(l.shape).astype(l.dtype))
            off += n
        return jax.tree_util.tree_unflatten(treedef, out)

    def _mix(self, stacked: Params, nbr_idx, nbr_w, self_w) -> Params:
        """Gossip-average every leaf: flatten the per-node model stack to
        one (K, N) matrix, mix once, split back."""
        flat, treedef, leaves = self._flatten(stacked)
        if self.use_kernel:
            mixed = ops.neighbor_mix(flat, nbr_idx, nbr_w, self_w)
        else:
            # dense oracle path: rebuild W from the same runtime operands
            # (padding rows carry weight 0, so they scatter nothing)
            K = self.K
            W = jnp.zeros((K, K), jnp.float32).at[
                jnp.arange(K)[:, None], nbr_idx].add(nbr_w)
            W = W + jnp.diag(self_w)
            mixed = jnp.matmul(W, flat)
        return self._unflatten(mixed, treedef, leaves)

    def step(self, state, batch, lr, step_idx) -> Tuple[Dict, Dict]:
        """One local step + gossip round.  ``step_idx`` selects the
        round's graph; the neighbor operands enter the jitted body as
        traced arguments, so a schedule rotating its edge set reuses one
        compilation."""
        nbr_idx, nbr_w, self_w = self.mix_operands(int(step_idx))
        return self._step(state, batch, lr, step_idx,
                          nbr_idx, nbr_w, self_w)

    def _local_update(self, state, batch, lr):
        """Per-node momentum-SGD step (pre-gossip), shared with ADPSGD."""
        losses, grads, new_ms = pernode_grads(
            self.fns, state["params"], state["mstate"], batch,
            params_stacked=True)
        vel = tmap(lambda w, g, u: self.m * u - lr * (g + self.wd * w),
                   state["params"], grads, state["vel"])
        params = tmap(lambda w, u: w + u, state["params"], vel)
        return losses, new_ms, vel, params

    def _gossip_metrics(self, losses, params, nbr_w) -> Dict:
        # per-node price: ship the model once to each active neighbor
        # this round (padding entries carry weight 0, so counting
        # positive weights recovers the round graph's mean degree)
        model_floats = float(tree_size(params)) / self.K
        mean_degree = jnp.sum(nbr_w > 0).astype(jnp.float32) / self.K
        comm = mean_degree * model_floats
        # consensus distance: mean |w_k - w_avg| / |w_avg|
        avg = tree_mean0(params)
        num = sum(jnp.sum(jnp.abs(s - a[None]))
                  for s, a in zip(jax.tree_util.tree_leaves(params),
                                  jax.tree_util.tree_leaves(avg)))
        den = sum(jnp.sum(jnp.abs(a)) * self.K
                  for a in jax.tree_util.tree_leaves(avg))
        return {"loss": jnp.mean(losses), "comm_floats": comm,
                "consensus_delta": num / jnp.maximum(den, 1e-12)}

    @partial(jax.jit, static_argnums=0)
    def _step(self, state, batch, lr, step_idx, nbr_idx, nbr_w, self_w
              ) -> Tuple[Dict, Dict]:
        self.trace_count += 1          # Python side effect: trace-time only
        losses, new_ms, vel, params = self._local_update(state, batch, lr)
        params = self._mix(params, nbr_idx, nbr_w, self_w)
        metrics = self._gossip_metrics(losses, params, nbr_w)
        return ({"params": params, "mstate": new_ms, "vel": vel}, metrics)

    def eval_params(self, state):
        return tree_mean0(state["params"]), tree_mean0(state["mstate"])

    def node_params(self, state, k: int):
        return (tmap(lambda l: l[k], state["params"]),
                tmap(lambda l: l[k], state["mstate"]))
