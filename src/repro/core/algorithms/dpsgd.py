"""D-PSGD (Lian et al., NeurIPS 2017): decentralized parallel SGD.

Each node holds a model replica, takes a local momentum-SGD step, then
*gossip-averages* with its graph neighbors: ``x_k <- sum_j W[k,j] x_j``
restricted to the topology's edges, with W the symmetric doubly-stochastic
mixing matrix.  On the complete graph (W = 1/K) this is exact averaging
and the trajectory coincides with BSP; on sparse graphs (ring, torus,
expander, D-Cliques) each step only moves the model toward consensus at
the rate of the spectral gap, trading accuracy-under-skew for per-node
bandwidth of ``degree * |model|`` instead of a full all-reduce.

The mixing step runs as one fused Pallas gather-scale-accumulate over the
flattened parameter stack (``kernels/neighbor_mix.py``) rather than K
dense matmuls.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.algorithms.base import (ModelFns, Params, pernode_grads,
                                        tree_mean0, tree_size, tmap)
from repro.kernels import ops
from repro.topology.graphs import Topology


class DPSGD:
    name = "dpsgd"

    def __init__(self, fns: ModelFns, n_nodes: int, *, topology: Topology,
                 momentum: float = 0.9, weight_decay: float = 0.0,
                 use_kernel: bool = True):
        assert topology.n_nodes == n_nodes, (topology.n_nodes, n_nodes)
        self.fns, self.K = fns, n_nodes
        self.m, self.wd = momentum, weight_decay
        self.topology = topology
        self.use_kernel = use_kernel
        nbr_idx, nbr_w, self_w = topology.neighbor_arrays()
        self._nbr_idx = jnp.asarray(nbr_idx)
        self._nbr_w = jnp.asarray(nbr_w)
        self._self_w = jnp.asarray(self_w)
        self._mixing = jnp.asarray(topology.mixing, jnp.float32)

    def init(self, params: Params, mstate: Params) -> Dict[str, Params]:
        stack = lambda l: jnp.broadcast_to(l, (self.K,) + l.shape)
        return {
            "params": tmap(stack, params),
            "mstate": tmap(stack, mstate),
            "vel": tmap(lambda l: jnp.zeros((self.K,) + l.shape, l.dtype),
                        params),
        }

    def _mix(self, stacked: Params) -> Params:
        """Gossip-average every leaf: flatten the per-node model stack to
        one (K, N) matrix, mix once, split back."""
        leaves, treedef = jax.tree_util.tree_flatten(stacked)
        flat = jnp.concatenate(
            [l.reshape(self.K, -1).astype(jnp.float32) for l in leaves],
            axis=1)
        if self.use_kernel:
            mixed = ops.neighbor_mix(flat, self._nbr_idx, self._nbr_w,
                                     self._self_w)
        else:
            mixed = jnp.matmul(self._mixing, flat)
        out, off = [], 0
        for l in leaves:
            n = l[0].size
            out.append(mixed[:, off:off + n].reshape(l.shape).astype(l.dtype))
            off += n
        return jax.tree_util.tree_unflatten(treedef, out)

    @partial(jax.jit, static_argnums=0)
    def step(self, state, batch, lr, step_idx) -> Tuple[Dict, Dict]:
        losses, grads, new_ms = pernode_grads(
            self.fns, state["params"], state["mstate"], batch,
            params_stacked=True)
        vel = tmap(lambda w, g, u: self.m * u - lr * (g + self.wd * w),
                   state["params"], grads, state["vel"])
        params = tmap(lambda w, u: w + u, state["params"], vel)
        params = self._mix(params)

        # per-node price: ship the model once to each neighbor
        model_floats = float(tree_size(params)) / self.K
        comm = jnp.asarray(self.topology.mean_degree * model_floats,
                           jnp.float32)
        # consensus distance: mean |w_k - w_avg| / |w_avg|
        avg = tree_mean0(params)
        num = sum(jnp.sum(jnp.abs(s - a[None]))
                  for s, a in zip(jax.tree_util.tree_leaves(params),
                                  jax.tree_util.tree_leaves(avg)))
        den = sum(jnp.sum(jnp.abs(a)) * self.K
                  for a in jax.tree_util.tree_leaves(avg))
        metrics = {"loss": jnp.mean(losses), "comm_floats": comm,
                   "consensus_delta": num / jnp.maximum(den, 1e-12)}
        return ({"params": params, "mstate": new_ms, "vel": vel}, metrics)

    def eval_params(self, state):
        return tree_mean0(state["params"]), tree_mean0(state["mstate"])

    def node_params(self, state, k: int):
        return (tmap(lambda l: l[k], state["params"]),
                tmap(lambda l: l[k], state["mstate"]))
