"""FederatedAveraging (McMahan et al., AISTATS 2017) — Algorithm 2.

Each node runs ``iter_local`` local momentum-SGD steps, then all node models
are averaged (all_reduce) into the next round's starting point.  Following
the paper's Appendix A, all K partitions participate every round
(deterministic variant).  ``iter_local`` is dynamic: the sync happens when
``step_idx % iter_local == 0``, so SkewScout can retune it live."""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.algorithms.base import (ModelFns, Params, pernode_grads,
                                        tree_mean0, tree_size, tmap)


class FedAvg:
    name = "fedavg"

    def __init__(self, fns: ModelFns, n_nodes: int, *, momentum: float = 0.9,
                 weight_decay: float = 0.0, iter_local: int = 20):
        self.fns, self.K = fns, n_nodes
        self.m, self.wd = momentum, weight_decay
        self.iter_local = iter_local

    def init(self, params: Params, mstate: Params) -> Dict[str, Params]:
        stack = lambda l: jnp.broadcast_to(l, (self.K,) + l.shape)
        return {
            "params": tmap(stack, params),
            "mstate": tmap(stack, mstate),
            "vel": tmap(lambda l: jnp.zeros((self.K,) + l.shape, l.dtype),
                        params),
        }

    @partial(jax.jit, static_argnums=0)
    def step(self, state, batch, lr, step_idx, iter_local=None
             ) -> Tuple[Dict, Dict]:
        il = jnp.asarray(self.iter_local if iter_local is None else iter_local,
                         jnp.int32)
        losses, grads, new_ms = pernode_grads(
            self.fns, state["params"], state["mstate"], batch,
            params_stacked=True)
        vel = tmap(lambda w, g, u: self.m * u - lr * (g + self.wd * w),
                   state["params"], grads, state["vel"])
        params = tmap(lambda w, u: w + u, state["params"], vel)

        do_sync = (step_idx % il) == (il - 1)

        # divergence probe: mean |w_k - w_avg| / |w_avg| at sync points
        avg = tree_mean0(params)
        delta = _mean_rel_dev(params, avg)

        def sync(p):
            a = tree_mean0(p)
            return tmap(lambda l, m_: jnp.broadcast_to(m_, l.shape), p, a)

        params = jax.lax.cond(do_sync, sync, lambda p: p, params)
        new_ms = jax.lax.cond(do_sync, sync, lambda s: s, new_ms)
        comm = jnp.where(do_sync,
                         float(tree_size(avg)), 0.0).astype(jnp.float32)
        metrics = {"loss": jnp.mean(losses), "comm_floats": comm,
                   "local_delta": delta, "synced": do_sync}
        return ({"params": params, "mstate": new_ms, "vel": vel}, metrics)

    def eval_params(self, state):
        return tree_mean0(state["params"]), tree_mean0(state["mstate"])

    def node_params(self, state, k: int):
        return (tmap(lambda l: l[k], state["params"]),
                tmap(lambda l: l[k], state["mstate"]))


def _mean_rel_dev(stacked, avg):
    num = sum(jnp.sum(jnp.abs(s - a[None]))
              for s, a in zip(jax.tree_util.tree_leaves(stacked),
                              jax.tree_util.tree_leaves(avg)))
    den = sum(jnp.sum(jnp.abs(a)) * s.shape[0]
              for s, a in zip(jax.tree_util.tree_leaves(stacked),
                              jax.tree_util.tree_leaves(avg)))
    return num / jnp.maximum(den, 1e-12)
