"""Gaia (Hsieh et al., NSDI 2017) — Algorithm 1.

Each node runs local momentum SGD, accumulates weight updates v, and shares
only *significant* updates: those with |v/w| > T.  Shared updates are applied
by every other node and cleared locally.  T decays with the learning rate
(update_threshold).  Under non-IID partitions the insignificant residuals
let each node's model specialize — the paper's §4.3 failure mode, which our
divergence probes expose.

``t0`` is a *dynamic* hyper-parameter (traced scalar) so SkewScout can retune
it without recompilation.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.algorithms.base import (ModelFns, Params, pernode_grads,
                                        tree_mean0, tmap)
from repro.kernels import ops


class Gaia:
    name = "gaia"

    def __init__(self, fns: ModelFns, n_nodes: int, *, momentum: float = 0.9,
                 weight_decay: float = 0.0, t0: float = 0.10,
                 lr0: float = None):
        self.fns, self.K = fns, n_nodes
        self.m, self.wd = momentum, weight_decay
        self.t0 = t0
        self.lr0 = lr0  # reference lr for threshold decay (None => constant T)

    def init(self, params: Params, mstate: Params) -> Dict[str, Params]:
        stack = lambda l: jnp.broadcast_to(l, (self.K,) + l.shape)
        return {
            "params": tmap(stack, params),     # per-node replicas
            "mstate": tmap(stack, mstate),
            "vel": tmap(lambda l: jnp.zeros((self.K,) + l.shape, l.dtype),
                        params),
            "acc": tmap(lambda l: jnp.zeros((self.K,) + l.shape, l.dtype),
                        params),               # accumulated updates v
        }

    @partial(jax.jit, static_argnums=0)
    def step(self, state, batch, lr, step_idx, t0=None) -> Tuple[Dict, Dict]:
        t0 = self.t0 if t0 is None else t0
        # threshold decays with the learning rate (Algorithm 1, line 16)
        thresh = t0 * (lr / self.lr0) if self.lr0 is not None else t0

        losses, grads, new_ms = pernode_grads(
            self.fns, state["params"], state["mstate"], batch,
            params_stacked=True)

        vel = tmap(lambda w, g, u: self.m * u - lr * (g + self.wd * w),
                   state["params"], grads, state["vel"])
        params = tmap(lambda w, u: w + u, state["params"], vel)
        acc = tmap(lambda v, u: v + u, state["acc"], vel)

        # significance filter: |v / w| > thresh — the fused select kernel
        # (or its dispatched jnp twin) returns (v * mask, count) per leaf,
        # so the mask itself never materializes: the shared part is
        # cleared exactly via acc - shared (shared = acc * mask).
        leaves_v, treedef = jax.tree_util.tree_flatten(acc)
        leaves_w = treedef.flatten_up_to(params)
        picked = [ops.gaia_select(v, w, thresh)
                  for v, w in zip(leaves_v, leaves_w)]
        shared = jax.tree_util.tree_unflatten(treedef,
                                              [p[0] for p in picked])
        total = tmap(lambda s: jnp.sum(s, axis=0, keepdims=True), shared)
        # apply everyone else's significant updates; clear own shared part
        params = tmap(lambda w, t, s: w + (t - s), params, total, shared)
        acc = tmap(lambda v, s: v - s, acc, shared)

        comm = sum(p[1].astype(jnp.float32) for p in picked) / self.K
        metrics = {"loss": jnp.mean(losses), "comm_floats": comm,
                   "resid_delta": _mean_rel(acc, params)}
        return ({"params": params, "mstate": new_ms, "vel": vel, "acc": acc},
                metrics)

    def eval_params(self, state):
        return tree_mean0(state["params"]), tree_mean0(state["mstate"])

    def node_params(self, state, k: int):
        return (tmap(lambda l: l[k], state["params"]),
                tmap(lambda l: l[k], state["mstate"]))


def _mean_rel(acc, params):
    num = sum(jnp.sum(jnp.abs(a)) for a in jax.tree_util.tree_leaves(acc))
    den = sum(jnp.sum(jnp.abs(p)) for p in jax.tree_util.tree_leaves(params))
    return num / jnp.maximum(den, 1e-12)
