"""Divergence probes behind the paper's diagnostic figures.

- Figure 4:  BatchNorm minibatch-mean divergence across partitions.
- Figure 22: DGC residual update delta ||v/w||.
- Figure 23: FedAvg local weight update delta at sync points.
- §4.3 / Fig 21: per-partition model specialization (accuracy on own vs
  other partitions' label subsets).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.cnn_zoo import CNNConfig
from repro.models.cnn import cnn_batch_stats


def bn_divergence(params, cfg: CNNConfig, node_batches: Sequence[np.ndarray],
                  layer: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Per-channel divergence of minibatch means/vars between partitions:
    ||mu_{B,P0} - mu_{B,P1}|| / ||avg(mu)||  (paper's Figure 4 metric,
    generalized to K nodes as max pairwise over the node axis)."""
    stats = [cnn_batch_stats(params, cfg, jnp.asarray(b), layer)
             for b in node_batches]
    mus = np.stack([np.asarray(m) for m, _ in stats])      # (K, C)
    vars_ = np.stack([np.asarray(v) for _, v in stats])
    K = mus.shape[0]
    def div(x):
        num = 0.0 * x[0]
        for i in range(K):
            for j in range(i + 1, K):
                num = np.maximum(num, np.abs(x[i] - x[j]))
        den = np.abs(x.mean(axis=0)) + 1e-8
        return num / den
    return div(mus), div(vars_)


def model_l2_distance(params_a, params_b) -> float:
    la = jax.tree_util.tree_leaves(params_a)
    lb = jax.tree_util.tree_leaves(params_b)
    num = sum(float(jnp.sum((a - b) ** 2)) for a, b in zip(la, lb))
    den = sum(float(jnp.sum(a ** 2)) for a in la)
    return (num / max(den, 1e-12)) ** 0.5


def per_class_accuracy(predict_fn, x: np.ndarray, y: np.ndarray,
                       n_classes: int) -> np.ndarray:
    """Accuracy per class — exposes Gaia's per-partition specialization
    (Fig 21): a node's model is accurate on its own classes only."""
    preds = np.asarray(predict_fn(jnp.asarray(x)))
    acc = np.zeros(n_classes)
    for c in range(n_classes):
        m = y == c
        acc[c] = (preds[m] == c).mean() if m.any() else np.nan
    return acc
