"""Label-skew partitioning (paper §3, "Non-IID Data Partitions").

``skew`` controls the fraction of the dataset partitioned *by label*; the
rest is spread uniformly at random.  skew=1.0 reproduces §4-5's exclusive
label partitioning (each label lives in exactly one partition, labels dealt
round-robin); skew=0.0 is the IID setting; intermediate values reproduce §6.

Also: ``partition_80_20`` (Appendix F's K=10 setting: 80% of one class +
20% of another per node) and ``partition_by_region`` (Flickr-Mammal's
real-world geo partitioning).
"""
from __future__ import annotations

from typing import List

import numpy as np


def partition_label_skew(y: np.ndarray, n_nodes: int, skew: float,
                         seed: int = 0) -> List[np.ndarray]:
    """Returns per-node index arrays.  ``skew`` in [0, 1]."""
    assert 0.0 <= skew <= 1.0, skew
    rng = np.random.default_rng(seed)
    n = len(y)
    n_classes = int(y.max()) + 1
    perm = rng.permutation(n)
    n_skewed = int(round(skew * n))
    skewed, iid = perm[:n_skewed], perm[n_skewed:]

    parts: List[List[int]] = [[] for _ in range(n_nodes)]
    # skewed portion: labels dealt to nodes round-robin (class c -> node
    # c % K), giving each node a disjoint label set when K divides classes
    node_of_class = np.array([c % n_nodes for c in range(n_classes)])
    for i in skewed:
        parts[node_of_class[y[i]]].append(i)
    # iid portion: uniform
    for j, i in enumerate(iid):
        parts[j % n_nodes].append(i)
    out = [np.asarray(sorted(p), dtype=np.int64) for p in parts]
    # guard: every node needs data
    for k, p in enumerate(out):
        assert len(p) > 0, f"node {k} received no data (K={n_nodes})"
    return out


def partition_80_20(y: np.ndarray, n_nodes: int, major: float = 0.8,
                    seed: int = 0) -> List[np.ndarray]:
    """Appendix F: each node has ``major`` of one class and the rest of
    another (requires n_classes == n_nodes)."""
    rng = np.random.default_rng(seed)
    n_classes = int(y.max()) + 1
    assert n_classes == n_nodes, (n_classes, n_nodes)
    by_class = [rng.permutation(np.where(y == c)[0]) for c in range(n_classes)]
    parts = [[] for _ in range(n_nodes)]
    for c in range(n_classes):
        idx = by_class[c]
        cut = int(round(major * len(idx)))
        parts[c].extend(idx[:cut])
        parts[(c + 1) % n_nodes].extend(idx[cut:])
    return [np.asarray(sorted(p), dtype=np.int64) for p in parts]


def partition_by_region(region: np.ndarray, n_nodes: int
                        ) -> List[np.ndarray]:
    """Real-world geo partitioning: node k = region k (Flickr-Mammal)."""
    return [np.where(region == k)[0].astype(np.int64)
            for k in range(n_nodes)]


def label_distribution(y: np.ndarray, parts: List[np.ndarray]
                       ) -> np.ndarray:
    """(K, n_classes) empirical label distribution per partition."""
    n_classes = int(y.max()) + 1
    dist = np.zeros((len(parts), n_classes))
    for k, p in enumerate(parts):
        cnt = np.bincount(y[p], minlength=n_classes)
        dist[k] = cnt / max(cnt.sum(), 1)
    return dist


def skew_index(y: np.ndarray, parts: List[np.ndarray]) -> float:
    """Mean total-variation distance between per-partition label
    distributions and the global one — a scalar 'degree of skew'."""
    dist = label_distribution(y, parts)
    glob = np.bincount(y, minlength=dist.shape[1]) / len(y)
    return float(np.mean(np.abs(dist - glob).sum(axis=1) / 2.0))
