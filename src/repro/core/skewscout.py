"""SkewScout (paper §7): communication-adaptive decentralized learning.

Periodically (every ``travel_every`` minibatches):
 1. *Model traveling*: node k's current model is evaluated on a subset of
    node j's training data (and vice versa).  Since node k's training
    accuracy on its own partition is known, the drop is the measured
    **accuracy loss** AL(θ) — a proxy for model divergence.
 2. *Communication control*: minimize Eq. 1,
        J(θ) = λ_AL · max(0, AL(θ) − σ_AL) + λ_C · C(θ)/CM,
    over the algorithm's θ ladder with a pluggable tuner (hill climbing by
    default), where C(θ) is the measured per-step communication since the
    last travel and CM is the full-model cost (BSP's per-step price).

When a :class:`~repro.topology.CommLedger` is attached, C(θ)/CM is priced
at the *link level*: floats are weighted by the inverse bandwidth of the
links they crossed, so under the geo-wan profile scarce WAN bytes dominate
the objective — the paper's Gaia setting, where only WAN traffic matters.
With the uniform profile this reduces exactly to the flat float ratio.

SkewScout is algorithm-agnostic: anything exposing a dynamic θ knob
(Gaia t0, FedAvg iter_local, DGC sparsity) plugs in via ``theta_ladder``.

Topology as a rung: for gossip (D-PSGD) the θ ladder is a list of
:class:`~repro.topology.graphs.TopologySchedule` rungs (densest first —
see ``topology_ladder``), so the controller trades *edges*, not just
floats, against accuracy loss.  Switching rungs re-wires links, and the
ledger books that re-wiring traffic into ``priced_cost`` — so C(θ)
charges a rung-flapping controller for link churn, and CM is pinned at
construction (one full-model exchange on the densest fabric) so the
ratio stays comparable across rungs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import CommConfig
from repro.core.tuners import make_tuner

# θ ladders, ordered most-communication-heavy -> most-relaxed (paper §4.4)
THETA_LADDERS = {
    "gaia": [0.01, 0.02, 0.05, 0.10, 0.20, 0.30, 0.40, 0.50],
    "fedavg": [1, 2, 5, 10, 20, 50, 100, 200],
    "dgc": [0.75, 0.9375, 0.984375, 0.996, 0.999],
}


@dataclass
class TravelReport:
    step: int
    theta: Any
    accuracy_loss: float
    comm_ratio: float          # C(θ)/CM since last travel (per step)
    objective: float
    new_theta: Any


class SkewScout:
    def __init__(self, comm: CommConfig, algo_name: str, model_floats: int,
                 eval_acc_fn: Callable, *, start_index: Optional[int] = None,
                 seed: int = 0, ledger=None, warmup_travels: int = 1,
                 ladder: Optional[List] = None,
                 cm_ref: Optional[float] = None):
        """eval_acc_fn(params, mstate, x, y) -> accuracy in [0,1].
        ``ledger``: optional CommLedger; when given, C(θ)/CM is computed
        from bandwidth-priced link traffic instead of raw floats.
        ``warmup_travels``: initial probes that measure but do not move θ —
        the first window's communication reflects the init transient
        (updates are large at t=0 whatever θ is), so attributing it to the
        current rung sends the hill climber the wrong way.
        ``ladder``: override THETA_LADDERS — for topology mode, a list of
        TopologySchedule rungs ordered densest first.
        ``cm_ref``: pin the CM denominator (seconds for one full-model
        exchange) instead of re-deriving it from the ledger's current
        fabric each probe — required when rung switches change the fabric
        mid-run, or C(θ)/CM would be renormalized under the controller."""
        if ladder is None:
            ladder = THETA_LADDERS[algo_name]
        kw = {} if comm.tuner == "hill" else {"seed": seed}
        self.tuner = make_tuner(comm.tuner, ladder, start_index=start_index,
                                **kw)
        self.comm = comm
        self.model_floats = float(model_floats)
        self.eval_acc = eval_acc_fn
        self.ledger = ledger
        self.warmup_travels = warmup_travels
        self._cm_ref = cm_ref
        self._cost_mark = ledger.priced_cost() if ledger else 0.0
        self._comm_since = 0.0
        self._steps_since = 0
        self.history: List[TravelReport] = []

    @property
    def theta(self):
        return self.tuner.theta

    def record_step(self, comm_floats: float) -> None:
        self._comm_since += float(comm_floats)
        self._steps_since += 1

    def maybe_travel(self, step: int, algo, state,
                     sample_subset: Callable) -> Optional[TravelReport]:
        """sample_subset(node) -> (x, y) training subset of that node."""
        if self._steps_since < self.comm.travel_every:
            return None
        K = algo.K
        # model traveling: each node's model scored at home vs. away
        losses = []
        for k in range(K):
            pk, sk = algo.node_params(state, k)
            x_home, y_home = sample_subset(k)
            acc_home = float(self.eval_acc(pk, sk, x_home, y_home))
            j = (k + 1) % K                      # ring travel (1 hop/probe)
            x_away, y_away = sample_subset(j)
            acc_away = float(self.eval_acc(pk, sk, x_away, y_away))
            losses.append(max(0.0, acc_home - acc_away))
        al = float(np.mean(losses))
        if self.ledger is not None:
            # link-priced window cost vs. one full-model exchange (CM)
            window = self.ledger.priced_cost() - self._cost_mark
            cm = (self._cm_ref if self._cm_ref is not None
                  else self.ledger.full_exchange_cost(self.model_floats))
            c_ratio = (window / max(self._steps_since, 1)) / cm
        else:
            c_ratio = (self._comm_since / max(self._steps_since, 1)
                       ) / self.model_floats
        obj = (self.comm.lambda_al * max(0.0, al - self.comm.sigma_al)
               + self.comm.lambda_c * c_ratio)
        old = self.tuner.theta
        if len(self.history) < self.warmup_travels:
            new = old                     # measure-only warm-up probe
        else:
            new = self.tuner.step(obj)
        rep = TravelReport(step, old, al, c_ratio, obj, new)
        self.history.append(rep)
        self._comm_since = 0.0
        self._steps_since = 0
        if self.ledger is not None:
            self._cost_mark = self.ledger.priced_cost()
        return rep

    def rebase_cost_mark(self) -> None:
        """Re-anchor the priced-cost window after the caller books
        traffic that should not count toward C(θ) — e.g. the model-travel
        probe itself (the float-based path likewise excludes it from
        ``_comm_since``)."""
        if self.ledger is not None:
            self._cost_mark = self.ledger.priced_cost()

    def travel_overhead_floats(self) -> float:
        """Cost of shipping one model per probe (counted against savings)."""
        return self.model_floats * len(self.history)
