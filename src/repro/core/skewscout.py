"""SkewScout (paper §7): communication-adaptive decentralized learning.

Periodically (every ``travel_every`` minibatches):
 1. *Model traveling*: node k's current model is evaluated on a subset of
    node j's training data (and vice versa).  Since node k's training
    accuracy on its own partition is known, the drop is the measured
    **accuracy loss** AL(θ) — a proxy for model divergence.
 2. *Communication control*: minimize Eq. 1,
        J(θ) = λ_AL · max(0, AL(θ) − σ_AL) + λ_C · C(θ)/CM,
    over the algorithm's θ ladder with a pluggable tuner (hill climbing by
    default), where C(θ) is the measured per-step communication since the
    last travel and CM is the full-model cost (BSP's per-step price).

Probes ride the fabric: each node's model travels along one of the
round's *active* edges (falling back to the union graph's neighbors when
a sparse round leaves the node isolated, and to the legacy ring only
when there is no fabric at all), so probes measure peers the node can
actually reach.  When a :class:`~repro.topology.CommLedger` is attached,
every probe's model shipment is **booked on the edge it traverses** —
probe traffic is priced into C(θ) like any other traffic, instead of
being tallied off-ledger.

C(θ)/CM pricing: with a synchronous ledger, floats are weighted by the
inverse bandwidth of the links they crossed, so under the geo-wan
profile scarce WAN bytes dominate the objective — the paper's Gaia
setting.  With an **async** ledger (AD-PSGD), C(θ) is the simulated
wall-clock the window actually cost (per-edge clocks, latency amortized
by staleness) over the wall-clock of one full-model exchange — so θ
rungs that change *when* links block (staleness) are priced, not just
rungs that change how many floats move.  With the uniform profile the
sync path reduces exactly to the flat float ratio.  Under a stochastic
link model (``CommLedger(link_model=...)``) the CM denominator comes
from the ledger's per-edge EWMA *measured* costs instead of profile
constants, re-priced at every probe on a pinned fabric (``cm_fabric``).

SkewScout is algorithm-agnostic: anything exposing a dynamic θ knob
(Gaia t0, FedAvg iter_local, DGC sparsity) plugs in via ``theta_ladder``.

Topology as a rung: for gossip (D-PSGD) the θ ladder is a list of
:class:`~repro.topology.graphs.TopologySchedule` rungs (densest first —
see ``topology_ladder``), so the controller trades *edges*, not just
floats, against accuracy loss.  Switching rungs re-wires links, and the
ledger books that re-wiring traffic into ``priced_cost`` — so C(θ)
charges a rung-flapping controller for link churn, and CM is pinned at
construction (one full-model exchange on the densest fabric) so the
ratio stays comparable across rungs.

Staleness as a rung: for asynchronous gossip (AD-PSGD) the θ ladder is
``[0, 1, ..., max_staleness]`` (most synchronous = most expensive
first), priced by the async ledger's wall-clock — the controller trades
*freshness* against accuracy loss on a fixed fabric.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, List, Optional, Tuple

import numpy as np

from repro.configs.base import CommConfig
from repro.core.tuners import make_tuner
from repro.topology.graphs import as_schedule

# θ ladders, ordered most-communication-heavy -> most-relaxed (paper §4.4)
THETA_LADDERS = {
    "gaia": [0.01, 0.02, 0.05, 0.10, 0.20, 0.30, 0.40, 0.50],
    "fedavg": [1, 2, 5, 10, 20, 50, 100, 200],
    "dgc": [0.75, 0.9375, 0.984375, 0.996, 0.999],
}


@dataclass
class TravelReport:
    step: int
    theta: Any
    accuracy_loss: float
    comm_ratio: float          # C(θ)/CM since last travel (per step)
    objective: float
    new_theta: Any
    # model-traveling traffic this probe event shipped (K models, one
    # per node) and the union-fabric edges it crossed
    probe_floats: float = 0.0
    probe_edges: Tuple = ()


class SkewScout:
    def __init__(self, comm: CommConfig, algo_name: str, model_floats: int,
                 eval_acc_fn: Callable, *, start_index: Optional[int] = None,
                 seed: int = 0, ledger=None, warmup_travels: int = 1,
                 ladder: Optional[List] = None,
                 cm_ref: Optional[float] = None, cm_fabric=None,
                 participation=None):
        """eval_acc_fn(params, mstate, x, y) -> accuracy in [0,1].
        ``ledger``: optional CommLedger; when given, C(θ)/CM is computed
        from bandwidth-priced link traffic (sync) or simulated
        wall-clock (async), and probe shipments are booked on the edges
        they traverse.
        ``warmup_travels``: initial probes that measure but do not move θ —
        the first window's communication reflects the init transient
        (updates are large at t=0 whatever θ is), so attributing it to the
        current rung sends the hill climber the wrong way.
        ``ladder``: override THETA_LADDERS — for topology mode, a list of
        TopologySchedule rungs ordered densest first; for staleness mode,
        ints ordered most-synchronous first.
        ``cm_ref``: pin the CM denominator (seconds for one full-model
        exchange) instead of re-deriving it from the ledger's current
        fabric each probe — required when rung switches change the fabric
        mid-run, or C(θ)/CM would be renormalized under the controller.
        ``cm_fabric``: like ``cm_ref`` but for a ledger with a stochastic
        link model, where profile constants are a fiction: the *fabric*
        is pinned and CM is re-priced at every probe from the ledger's
        per-edge EWMA measured costs
        (``measured_full_exchange_time/cost``), so the denominator
        tracks what the links actually cost while staying comparable
        across rung switches.  Amortized handshake installments land in
        whichever C(θ) window reuses the links, so a rung switch that
        persists sees its setup cost decay across windows while
        thrashing keeps re-paying it.
        ``participation``: optional
        :class:`~repro.topology.links.Participation` sampler — probes
        only travel between nodes participating in the probe round
        (sampled-out nodes neither ship their model nor host a
        probe), mirroring how the ledger and gossip mask traffic."""
        if ladder is None:
            ladder = THETA_LADDERS[algo_name]
        kw = {} if comm.tuner == "hill" else {"seed": seed}
        self.tuner = make_tuner(comm.tuner, ladder, start_index=start_index,
                                **kw)
        self.comm = comm
        self.model_floats = float(model_floats)
        self.eval_acc = eval_acc_fn
        self.ledger = ledger
        self.warmup_travels = warmup_travels
        self.participation = participation
        self._cm_ref = cm_ref
        # normalize to a schedule once: union() is cached per schedule
        # instance, so per-probe CM re-pricing reuses one union graph
        self._cm_fabric = None if cm_fabric is None \
            else as_schedule(cm_fabric)
        self._cost_mark = self._ledger_cost()
        self._comm_since = 0.0
        self._steps_since = 0
        self.history: List[TravelReport] = []

    @property
    def theta(self):
        return self.tuner.theta

    def _ledger_cost(self) -> float:
        """The running cost counter C(θ) windows are cut from — the
        currency (wall-clock / sampled / constant bandwidth-seconds) is
        the *ledger's* policy (``LedgerView.window_cost``), so the
        numerator always matches the CM denominator's units."""
        return self.ledger.view().window_cost \
            if self.ledger is not None else 0.0

    def _cm(self) -> float:
        # an explicit pinned constant always wins — cm_ref exists to
        # keep C(θ)/CM comparable across rung switches, and a caller
        # that passed one must not have it silently overridden; the
        # pricing policy (measured vs constant, time vs cost) otherwise
        # lives on the ledger, with cm_fabric pinning the exchange graph
        if self._cm_ref is not None:
            return self._cm_ref
        return self.ledger.view().cm_denominator(self.model_floats,
                                                 fabric=self._cm_fabric)

    def record_step(self, comm_floats: float) -> None:
        self._comm_since += float(comm_floats)
        self._steps_since += 1

    def _probe_route(self, algo, step: int) -> List[Tuple[int, int]]:
        """One probe target per node, along the round's active edges.
        Isolated nodes (sparse rounds) fall back to the union graph;
        algorithms with no fabric at all (Gaia/FedAvg/DGC without a
        ledger) keep the legacy ring.  Successive travels rotate through
        each node's neighbor list so repeated probes cover the fabric.
        With a participation sampler, sampled-out nodes neither probe
        nor host, and participating nodes only target participating
        neighbors (a node with none sits the probe round out)."""
        K = algo.K
        sched = getattr(algo, "schedule", None)
        graph = union = None
        if sched is not None:
            sched = as_schedule(sched)
            graph, union = sched.at(step), sched.union()
        elif self.ledger is not None:
            union = self.ledger.topology      # route on the priced fabric
        m = None if self.participation is None \
            else self.participation.mask(step)
        route = []
        for k in range(K):
            if m is not None and not m[k]:
                continue
            nbrs = graph.neighbors(k) if graph is not None else []
            if m is not None:
                nbrs = [j for j in nbrs if m[j]]
            if not nbrs and union is not None:
                nbrs = union.neighbors(k)
                if m is not None:
                    nbrs = [j for j in nbrs if m[j]]
            if nbrs:
                j = nbrs[len(self.history) % len(nbrs)]
            elif m is None:
                j = (k + 1) % K
            else:
                continue        # no participating peer this round
            route.append((k, j))
        return route

    def maybe_travel(self, step: int, algo, state,
                     sample_subset: Callable) -> Optional[TravelReport]:
        """sample_subset(node) -> (x, y) training subset of that node."""
        if self._steps_since < self.comm.travel_every:
            return None
        route = self._probe_route(algo, step)
        # model traveling: each node's model scored at home vs. away
        losses = []
        for k, j in route:
            pk, sk = algo.node_params(state, k)
            x_home, y_home = sample_subset(k)
            acc_home = float(self.eval_acc(pk, sk, x_home, y_home))
            x_away, y_away = sample_subset(j)
            acc_away = float(self.eval_acc(pk, sk, x_away, y_away))
            losses.append(max(0.0, acc_home - acc_away))
        al = float(np.mean(losses)) if losses else 0.0
        probe_edges = tuple((min(k, j), max(k, j)) for k, j in route
                            if k != j)
        probe_floats = self.model_floats * len(probe_edges)
        if self.ledger is not None:
            # book the probes' model shipments on the links they crossed
            # *before* closing the window: each window's C(θ) includes
            # the probe cost the controller itself incurred under that θ
            self.ledger.record_probe(probe_edges, self.model_floats)
            window = self._ledger_cost() - self._cost_mark
            c_ratio = (window / max(self._steps_since, 1)) / self._cm()
        else:
            c_ratio = (self._comm_since / max(self._steps_since, 1)
                       ) / self.model_floats
        obj = (self.comm.lambda_al * max(0.0, al - self.comm.sigma_al)
               + self.comm.lambda_c * c_ratio)
        old = self.tuner.theta
        if len(self.history) < self.warmup_travels:
            new = old                     # measure-only warm-up probe
        else:
            new = self.tuner.step(obj)
        rep = TravelReport(step, old, al, c_ratio, obj, new,
                           probe_floats=probe_floats,
                           probe_edges=probe_edges)
        self.history.append(rep)
        self._comm_since = 0.0
        self._steps_since = 0
        self._cost_mark = self._ledger_cost()
        return rep

    def travel_overhead_floats(self) -> float:
        """Model-traveling floats counted against the savings: probe
        shipments of every travel *after* the measure-only warm-ups
        (warm-up probes calibrate the controller; their traffic is still
        booked on the ledger, but is not overhead attributed to θ)."""
        return float(sum(rep.probe_floats
                         for rep in self.history[self.warmup_travels:]))
