"""Decentralized training driver (simulation backend, CPU-scale).

This is the harness behind every paper experiment: pick a CNN, a
partitioning, an algorithm + θ, (optionally) SkewScout — train, track
communication, and report validation accuracy of the global model.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CommConfig
from repro.configs.cnn_zoo import CNNConfig
from repro.core.algorithms.adpsgd import ADPSGD
from repro.core.algorithms.base import ModelFns, tree_size
from repro.core.algorithms.bsp import BSP
from repro.core.algorithms.dgc import DGC, warmup_sparsity
from repro.core.algorithms.dpsgd import DPSGD
from repro.core.algorithms.fedavg import FedAvg
from repro.core.algorithms.gaia import Gaia
from repro.core.skewscout import SkewScout
from repro.data.pipeline import DecentralizedLoader
from repro.models.cnn import cnn_apply, init_cnn
from repro.topology import (LABEL_AWARE_TOPOLOGIES, LINK_PROFILES,
                            CommLedger, Participation, Topology,
                            TopologySchedule, as_schedule, build_schedule,
                            make_link_model, topology_ladder)


# ---------------------------------------------------------------------------
# CNN adapter
# ---------------------------------------------------------------------------

def make_cnn_fns(cfg: CNNConfig) -> Tuple[ModelFns, Callable]:
    def loss_fn(params, mstate, batch):
        logits, new_ms = cnn_apply(params, mstate, cfg, batch["x"],
                                   train=True)
        labels = batch["y"]
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
        return nll, new_ms

    def loss_and_grad(params, mstate, batch):
        (loss, new_ms), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, mstate, batch)
        return loss, grads, new_ms

    @jax.jit
    def eval_acc(params, mstate, x, y):
        logits, _ = cnn_apply(params, mstate, cfg, x, train=False)
        return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))

    def eval_acc_np(params, mstate, x, y, batch: int = 512):
        accs, ns = [], []
        for i in range(0, len(x), batch):
            xb = jnp.asarray(x[i:i + batch])
            yb = jnp.asarray(y[i:i + batch])
            accs.append(float(eval_acc(params, mstate, xb, yb)))
            ns.append(len(xb))
        return float(np.average(accs, weights=ns))

    return ModelFns(loss_and_grad=loss_and_grad), eval_acc_np


#: gossip-averaging strategies that run over a TopologySchedule fabric
GOSSIP_ALGOS = ("dpsgd", "adpsgd")


def make_algorithm(name: str, fns: ModelFns, n_nodes: int,
                   comm: CommConfig, *, momentum: float = 0.9,
                   weight_decay: float = 5e-4, lr0: Optional[float] = None,
                   topology: Optional[Topology | TopologySchedule] = None,
                   seed: int = 0, pad_degree: Optional[int] = None,
                   staleness: Optional[int] = None,
                   participation: Optional[Participation] = None):
    if name == "bsp":
        return BSP(fns, n_nodes, momentum=momentum, weight_decay=weight_decay)
    if name == "gaia":
        return Gaia(fns, n_nodes, momentum=momentum,
                    weight_decay=weight_decay, t0=comm.gaia_t0, lr0=lr0)
    if name == "fedavg":
        return FedAvg(fns, n_nodes, momentum=momentum,
                      weight_decay=weight_decay, iter_local=comm.iter_local)
    if name == "dgc":
        return DGC(fns, n_nodes, momentum=momentum,
                   weight_decay=weight_decay, clip=comm.dgc_clip,
                   sparsity=comm.dgc_sparsity,
                   compressor=getattr(comm, "dgc_compressor", "topk"),
                   seed=seed)
    if name in GOSSIP_ALGOS:
        if topology is None:
            # standalone fallback; label-aware topologies need the label
            # histograms only train_decentralized can supply — refuse to
            # silently build a label-blind graph in their place
            if comm.fabric.topology in LABEL_AWARE_TOPOLOGIES:
                raise ValueError(
                    f"comm.fabric.topology={comm.fabric.topology!r} is "
                    "label-aware: it needs per-node label histograms to "
                    "assemble cliques. Build it with build_schedule(..., "
                    "label_hist=...) and pass topology= explicitly "
                    "(train_decentralized does this from the partitions)")
            topology = build_schedule(comm.fabric.topology, n_nodes,
                                      seed=seed)
        if name == "adpsgd":
            return ADPSGD(fns, n_nodes, topology=topology,
                          momentum=momentum, weight_decay=weight_decay,
                          pad_degree=pad_degree,
                          max_staleness=comm.max_staleness,
                          staleness=staleness,
                          participation=participation)
        return DPSGD(fns, n_nodes, topology=topology, momentum=momentum,
                     weight_decay=weight_decay, pad_degree=pad_degree,
                     participation=participation)
    raise ValueError(name)


@dataclass
class RunResult:
    name: str
    val_acc: float
    val_acc_curve: List[Tuple[int, float]]
    loss_curve: List[Tuple[int, float]]
    comm_total_floats: float
    bsp_equiv_floats: float
    comm_savings: float
    skewscout_history: List = field(default_factory=list)
    extras: Dict[str, Any] = field(default_factory=dict)
    # link-level accounting (repro.topology.CommLedger)
    topology: str = "full"
    comm_lan_floats: float = 0.0
    comm_wan_floats: float = 0.0
    sim_time_s: float = 0.0


def train_decentralized(cnn_cfg: CNNConfig, algo_name: str,
                        parts: Sequence[Tuple[np.ndarray, np.ndarray]],
                        val: Tuple[np.ndarray, np.ndarray], *,
                        comm: CommConfig = CommConfig(),
                        steps: int = 400, batch: int = 20,
                        lr_schedule: Callable = None, lr: float = 0.05,
                        momentum: float = 0.9, weight_decay: float = 5e-4,
                        eval_every: int = 100, seed: int = 0,
                        theta_start_index: Optional[int] = None
                        ) -> RunResult:
    if steps < 1:
        raise ValueError(f"steps must be >= 1, got {steps}")
    if eval_every < 1:
        raise ValueError(f"eval_every must be >= 1, got {eval_every} "
                         "(with steps < eval_every the final step still "
                         "evaluates, but eval_every itself must be valid)")
    K = len(parts)
    fns, eval_acc = make_cnn_fns(cnn_cfg)
    params, mstate = init_cnn(jax.random.PRNGKey(seed), cnn_cfg)

    # communication fabric: per-round graph schedule + link-level cost.
    # Label histograms feed the label-aware builders — needed for a
    # dcliques-family topology, and for the SkewScout topology ladder
    # (whatever fabric the run starts on, the controller must be able to
    # climb to the label-aware rung)
    label_hist = None
    if comm.fabric.topology in LABEL_AWARE_TOPOLOGIES or \
            (comm.skewscout and algo_name == "dpsgd"):
        n_classes = int(max(int(y.max()) for _, y in parts)) + 1
        label_hist = np.stack([np.bincount(np.asarray(y, np.int64),
                                           minlength=n_classes)
                               for _, y in parts])
    sched = build_schedule(comm.fabric.topology, K, label_hist=label_hist,
                           seed=seed)

    # topology as a SkewScout rung (dpsgd): the theta ladder is a list
    # of schedules ordered densest first; training starts on the rung
    # matching the configured topology when there is one, and the
    # neighbor operands are padded to the ladder-wide max degree so rung
    # switches never retrace the step
    ladder = None
    pad_degree = None
    staleness = None
    start_index = theta_start_index
    if comm.skewscout and algo_name == "dpsgd":
        ladder = topology_ladder(K, label_hist=label_hist, seed=seed)
        # the configured fabric is always a rung: replace the same-named
        # rung with the exact built schedule, or insert it, then re-sort
        # densest-first (hill climbing needs the ladder monotone in cost)
        names = [s.name for s in ladder]
        if sched.name in names:
            ladder[names.index(sched.name)] = sched
        else:
            ladder.append(sched)
        ladder.sort(key=TopologySchedule.mean_round_edges, reverse=True)
        if start_index is None:
            start_index = ladder.index(sched)
        elif not 0 <= start_index < len(ladder):
            raise ValueError(
                f"theta_start_index={start_index} out of range for the "
                f"{len(ladder)}-rung topology ladder "
                f"({[s.name for s in ladder]})")
        sched = ladder[start_index]
        pad_degree = max(s.max_degree for s in ladder)
    elif comm.skewscout and algo_name == "adpsgd":
        # staleness as a SkewScout rung (adpsgd): most synchronous rung
        # first (staleness 0 pays full per-round latency -> the costly
        # end of the ladder under the async time-priced C(theta)).
        # A sync ledger ignores staleness, so every rung would have the
        # same C(theta) and the controller would drift on noise —
        # refuse instead of silently mis-steering
        if not comm.async_gossip:
            raise ValueError(
                "skewscout over the adpsgd staleness ladder needs "
                "async_gossip=True: a synchronous ledger prices every "
                "staleness rung identically (C(theta) is float-based), "
                "so the controller's cost term would be degenerate")
        ladder = list(range(comm.max_staleness + 1))
        if start_index is None:
            start_index = len(ladder) - 1     # start fully asynchronous
        elif not 0 <= start_index < len(ladder):
            raise ValueError(
                f"theta_start_index={start_index} out of range for the "
                f"{len(ladder)}-rung staleness ladder ({ladder})")
        staleness = ladder[start_index]

    # stochastic links: one seeded LinkModel for the run.  Its draws are
    # keyed streams of (seed, edge, activation) — the link seed cannot
    # perturb the clique assignment or anything else the run seed feeds
    profile = LINK_PROFILES[comm.fabric.profile]
    links = make_link_model(comm.fabric.link, profile, seed=seed)
    # partial participation: one seeded per-round node sampler shared by
    # the ledger (masked pricing), the gossip mixing operands, and the
    # SkewScout probes — tag-disjoint from the link streams, so toggling
    # participation never perturbs a link draw
    part = (Participation(K, comm.fabric.participation, seed=seed)
            if comm.fabric.participation < 1.0 else None)
    ledger = CommLedger(sched, profile, config=comm.fabric,
                        async_mode=comm.async_gossip,
                        link_model=links,
                        participation=part)

    algo = make_algorithm(algo_name, fns, K, comm, momentum=momentum,
                          weight_decay=weight_decay, lr0=lr, topology=sched,
                          seed=seed, pad_degree=pad_degree,
                          staleness=staleness, participation=part)
    state = algo.init(params, mstate)
    loader = DecentralizedLoader(parts, batch, seed=seed)
    lr_fn = lr_schedule or (lambda s: lr)

    def _cm_pin(fabric) -> float:
        # CM pinned to one full-model exchange on the given fabric, in
        # the unit the scout prices C(theta) with: wall-clock for an
        # async ledger, bandwidth-seconds for a sync one
        led = CommLedger(fabric, profile).view()
        m = float(tree_size(params))
        return led.full_exchange_time(m) if comm.async_gossip \
            else led.full_exchange_cost(m)

    scout = None
    if comm.skewscout and algo_name == "dpsgd":
        # densest rung pins the denominator so C(theta)/CM stays
        # comparable as the controller changes fabrics.  Under a link
        # model the constants are a fiction: pin the *fabric* instead
        # and let the scout re-price CM from the ledger's per-edge EWMA
        # measured costs at every probe
        cm = (dict(cm_fabric=ladder[0]) if links is not None
              else dict(cm_ref=_cm_pin(ladder[0])))
        scout = SkewScout(comm, algo_name, tree_size(params), eval_acc,
                          start_index=start_index, seed=seed,
                          ledger=ledger, ladder=ladder,
                          participation=part, **cm)
    elif comm.skewscout and algo_name == "adpsgd":
        cm = (dict(cm_fabric=sched) if links is not None
              else dict(cm_ref=_cm_pin(sched)))
        scout = SkewScout(comm, algo_name, tree_size(params), eval_acc,
                          start_index=start_index, seed=seed,
                          ledger=ledger, ladder=ladder,
                          participation=part, **cm)
    elif comm.skewscout and algo_name != "bsp":
        scout = SkewScout(comm, algo_name, tree_size(params), eval_acc,
                          start_index=theta_start_index, seed=seed,
                          ledger=ledger, participation=part)

    loss_curve, acc_curve, gap_curve, stale_curve = [], [], [], []
    comm_total = 0.0
    steps_per_epoch = loader.steps_per_epoch

    for t in range(steps):
        xs, ys = loader.next_stacked()
        sbatch = {"x": jnp.asarray(xs), "y": jnp.asarray(ys)}
        lr_t = jnp.asarray(lr_fn(t), jnp.float32)
        kw: Dict[str, Any] = {}
        if algo_name == "gaia":
            kw["t0"] = jnp.asarray(scout.theta if scout else comm.gaia_t0,
                                   jnp.float32)
        elif algo_name == "fedavg":
            kw["iter_local"] = jnp.asarray(
                scout.theta if scout else comm.iter_local, jnp.int32)
        elif algo_name == "dgc":
            epoch = t // steps_per_epoch
            s = (scout.theta if scout
                 else warmup_sparsity(epoch, comm.dgc_warmup_epochs))
            kw["sparsity"] = jnp.asarray(s, jnp.float32)
        state, metrics = algo.step(state, sbatch, lr_t,
                                   jnp.asarray(t, jnp.int32), **kw)
        cf = float(metrics["comm_floats"])
        comm_total += cf
        if algo_name in GOSSIP_ALGOS:
            # round t's active edge set prices this gossip exchange; an
            # async algorithm also reports its per-edge staleness bound
            # so the ledger can amortize link latency accordingly
            stale = algo.edge_staleness(t) \
                if algo_name == "adpsgd" else None
            ledger.record_gossip(float(tree_size(params)), t=t,
                                 staleness=stale)
            gap_curve.append(
                (t, float(algo.schedule.round_spectral_gap(t))))
            if algo_name == "adpsgd":
                stale_curve.append((t, float(metrics["mean_staleness"])))
        elif cf > 0:
            ledger.record_exchange(cf)
        if scout:
            scout.record_step(cf)
            rep = scout.maybe_travel(
                t, algo, state,
                lambda node, _t=t: loader.sample_train_subset(
                    node, 256, seed=_t))
            if rep is not None:
                # model traveling overhead: the scout booked each
                # probe's shipment on the edge it crossed
                comm_total += rep.probe_floats
                if algo_name == "dpsgd" and rep.new_theta is not rep.theta:
                    # topology rung switch: re-wiring is charged by the
                    # ledger on the next gossip round, inside the new
                    # rung's C(θ) window
                    algo.set_schedule(rep.new_theta)
                    ledger.switch_schedule(rep.new_theta)
                elif algo_name == "adpsgd" and rep.new_theta != rep.theta:
                    # staleness rung switch: same fabric, new bound —
                    # runtime operand values only, no re-wiring
                    algo.set_staleness(rep.new_theta)
        if (t + 1) % eval_every == 0 or t == steps - 1:
            p, s = algo.eval_params(state)
            acc = eval_acc(p, s, val[0], val[1])
            acc_curve.append((t + 1, acc))
        loss_curve.append((t, float(metrics["loss"])))

    if not acc_curve:
        raise RuntimeError(
            f"no evaluation happened in {steps} steps (eval_every="
            f"{eval_every}); acc_curve is empty — check the schedule")
    bsp_equiv = float(tree_size(params)) * steps
    # the fabric the run *ended* on (rung switches may have moved it)
    final_sched = as_schedule(algo.schedule) \
        if algo_name in GOSSIP_ALGOS else sched
    ledger_view = ledger.view()
    return RunResult(
        name=f"{cnn_cfg.name}/{algo_name}",
        val_acc=acc_curve[-1][1],
        val_acc_curve=acc_curve,
        loss_curve=loss_curve,
        comm_total_floats=comm_total,
        bsp_equiv_floats=bsp_equiv,
        comm_savings=bsp_equiv / max(comm_total, 1.0),
        skewscout_history=list(scout.history) if scout else [],
        extras={"ledger": ledger.summary(),
                "spectral_gap": final_sched.spectral_gap(),
                "spectral_gap_curve": gap_curve,
                "schedule_period": final_sched.period,
                # per-node clock accounting (async: who ran ahead; sync:
                # who sat waiting on the slowest link)
                "node_clock_skew_s": ledger_view.clock_skew_s,
                "node_busy_s": [float(b) for b in ledger_view.node_busy_s],
                "node_idle_s": [float(i) for i in ledger_view.node_idle_s],
                # stochastic-link extras: straggler/jitter exposure of
                # the run (activations, slow fraction, knob values)
                **({"link_model": links.summary()}
                   if links is not None else {}),
                **({"staleness_curve": stale_curve,
                    "max_staleness": algo.max_staleness}
                   if algo_name == "adpsgd" else {}),
                **({"topology_ladder": [s.name for s in ladder]}
                   if ladder is not None and algo_name == "dpsgd" else {}),
                **({"staleness_ladder": list(ladder)}
                   if ladder is not None and algo_name == "adpsgd"
                   else {})},
        topology=final_sched.name,
        comm_lan_floats=ledger.lan_floats,
        comm_wan_floats=ledger.wan_floats,
        sim_time_s=ledger.sim_time_s,
    )
