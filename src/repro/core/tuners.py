"""Hyper-parameter search strategies for SkewScout's communication control
(§7.2: "hill climbing, stochastic hill climbing, and simulated annealing").

All tuners operate on a discrete ladder of θ values ordered from most
communication-heavy (index 0) to most relaxed (last).  They minimize the
memoized objective J(θ) from Eq. 1.
"""
from __future__ import annotations

import math
import random
from typing import Dict, List, Optional


class LadderTuner:
    def __init__(self, ladder: List, start_index: Optional[int] = None):
        self.ladder = list(ladder)
        self.i = len(ladder) // 2 if start_index is None else start_index
        self.memo: Dict[int, float] = {}

    @property
    def theta(self):
        return self.ladder[self.i]

    def observe(self, objective: float) -> None:
        self.memo[self.i] = objective

    def propose(self) -> int:
        raise NotImplementedError

    def step(self, objective: float):
        """Record J(θ_current) and move.  Returns the new θ."""
        self.observe(objective)
        self.i = self.propose()
        return self.theta


class HillClimb(LadderTuner):
    """Greedy neighbour descent with memoization (paper's best performer)."""

    def propose(self) -> int:
        best_i, best_j = self.i, self.memo.get(self.i, math.inf)
        for n in (self.i - 1, self.i + 1):
            if 0 <= n < len(self.ladder):
                jn = self.memo.get(n)
                if jn is None:
                    return n                      # explore unseen neighbour
                if jn < best_j:
                    best_i, best_j = n, jn
        return best_i


class StochasticHillClimb(LadderTuner):
    def __init__(self, ladder, start_index=None, seed: int = 0):
        super().__init__(ladder, start_index)
        self.rng = random.Random(seed)

    def propose(self) -> int:
        cands = [n for n in (self.i - 1, self.i, self.i + 1)
                 if 0 <= n < len(self.ladder)]
        weights = []
        for n in cands:
            j = self.memo.get(n)
            weights.append(1.0 if j is None else math.exp(-j))
        total = sum(weights)
        r = self.rng.random() * total
        for n, w in zip(cands, weights):
            r -= w
            if r <= 0:
                return n
        return cands[-1]


class SimulatedAnnealing(LadderTuner):
    def __init__(self, ladder, start_index=None, seed: int = 0,
                 temp0: float = 1.0, decay: float = 0.9):
        super().__init__(ladder, start_index)
        self.rng = random.Random(seed)
        self.temp = temp0
        self.decay = decay

    def propose(self) -> int:
        cands = [n for n in (self.i - 1, self.i + 1)
                 if 0 <= n < len(self.ladder)]
        n = self.rng.choice(cands)
        j_cur = self.memo.get(self.i, math.inf)
        j_new = self.memo.get(n)
        self.temp *= self.decay
        if j_new is None or j_new < j_cur:
            return n
        if self.rng.random() < math.exp(-(j_new - j_cur)
                                        / max(self.temp, 1e-6)):
            return n
        return self.i


def make_tuner(kind: str, ladder: List, **kw) -> LadderTuner:
    return {"hill": HillClimb, "stochastic": StochasticHillClimb,
            "anneal": SimulatedAnnealing}[kind](ladder, **kw)
