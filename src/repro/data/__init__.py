from repro.data.pipeline import DecentralizedLoader, PartitionLoader
from repro.data.synthetic import (ImageDataset, TokenDataset, synth_geo_images,
                                  synth_images, synth_tokens)

__all__ = ["DecentralizedLoader", "PartitionLoader", "ImageDataset",
           "TokenDataset", "synth_geo_images", "synth_images", "synth_tokens"]
