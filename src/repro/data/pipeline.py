"""Deterministic per-partition batch pipeline.

Each decentralized node k draws minibatches from its own partition P_k
(shuffled per-epoch with a node-specific seed).  ``stacked_batches`` yields
(K, B, ...) arrays — the layout the vmap'd simulation backend consumes.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np


class PartitionLoader:
    def __init__(self, x: np.ndarray, y: np.ndarray, batch: int, seed: int):
        assert len(x) == len(y) and len(x) >= batch, (len(x), batch)
        self.x, self.y, self.batch = x, y, batch
        self.rng = np.random.default_rng(seed)
        self._order = self.rng.permutation(len(x))
        self._ptr = 0

    def next(self) -> Tuple[np.ndarray, np.ndarray]:
        if self._ptr + self.batch > len(self.x):
            self._order = self.rng.permutation(len(self.x))
            self._ptr = 0
        idx = self._order[self._ptr:self._ptr + self.batch]
        self._ptr += self.batch
        return self.x[idx], self.y[idx]


class DecentralizedLoader:
    """K per-partition loaders with a single stacked-batch interface."""

    def __init__(self, parts: Sequence[Tuple[np.ndarray, np.ndarray]],
                 batch: int, seed: int = 0):
        self.loaders = [PartitionLoader(x, y, batch, seed + 17 * k)
                        for k, (x, y) in enumerate(parts)]
        self.n_nodes = len(parts)
        self.samples_per_epoch = min(len(x) for x, _ in parts)
        self.steps_per_epoch = max(1, self.samples_per_epoch // batch)

    def next_stacked(self) -> Tuple[np.ndarray, np.ndarray]:
        xs, ys = zip(*(ld.next() for ld in self.loaders))
        return np.stack(xs), np.stack(ys)

    def sample_train_subset(self, node: int, n: int, seed: int = 0
                            ) -> Tuple[np.ndarray, np.ndarray]:
        """Random subset of node's training data — used by SkewScout's
        model-traveling accuracy probe."""
        ld = self.loaders[node]
        rng = np.random.default_rng(seed)
        idx = rng.choice(len(ld.x), size=min(n, len(ld.x)), replace=False)
        return ld.x[idx], ld.y[idx]
