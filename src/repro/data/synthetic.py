"""Deterministic synthetic datasets.

Real CIFAR-10 / ImageNet / Flickr are unavailable offline, so we use
generative stand-ins with controllable label geometry:

- ``synth_images``: each class is a random smooth prototype; samples are the
  prototype under random shift + per-pixel noise + brightness jitter.  CNNs
  reach high accuracy on it centrally, so any accuracy drop under
  decentralized training is attributable to the algorithm (matching the
  paper's methodology of validating the IID baseline first).
- ``synth_geo_images``: the Flickr-Mammal analogue — classes have a
  *home region*; region r's empirical label distribution concentrates on its
  home classes (Table 1's 32-92% shares).
- ``synth_tokens``: order-2 Markov token streams for LM-scale examples.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass
class ImageDataset:
    x: np.ndarray          # (N, H, W, C) float32
    y: np.ndarray          # (N,) int32
    n_classes: int


def _prototypes(rng: np.random.Generator, n_classes: int, side: int,
                channels: int) -> np.ndarray:
    """Smooth class prototypes: low-frequency random fields."""
    coarse = rng.normal(size=(n_classes, 4, 4, channels))
    protos = np.empty((n_classes, side, side, channels), np.float32)
    for c in range(n_classes):
        for ch in range(channels):
            g = coarse[c, :, :, ch]
            # bilinear upsample 4x4 -> side x side
            xs = np.linspace(0, 3, side)
            xi = np.floor(xs).astype(int).clip(0, 2)
            xf = xs - xi
            rows = (g[xi] * (1 - xf)[:, None] + g[xi + 1] * xf[:, None])
            cols = (rows[:, xi] * (1 - xf)[None, :]
                    + rows[:, xi + 1] * xf[None, :])
            protos[c, :, :, ch] = cols
    return protos * 1.5


def synth_images(n_samples: int, *, n_classes: int = 10, side: int = 16,
                 channels: int = 3, noise: float = 0.35,
                 class_sep: float = 1.0,
                 seed: int = 0, class_seed: int = 1234) -> ImageDataset:
    """``class_seed`` fixes the class prototypes (the "world"); ``seed``
    drives sampling.  Train/val splits share class_seed, differ in seed.
    ``class_sep`` < 1 makes prototypes = shared_base + sep * class_delta,
    so the class-discriminative signal shrinks relative to feature scale —
    the regime where normalization mismatch (paper §5) moves decision
    boundaries."""
    rng = np.random.default_rng(seed)
    crng = np.random.default_rng(class_seed)
    protos = _prototypes(crng, n_classes, side, channels)
    if class_sep != 1.0:
        base = _prototypes(crng, 1, side, channels)[0]
        protos = base[None] + class_sep * protos
    # per-class channel-mean offsets: classes differ in global statistics
    # (as real object categories do), so a label-skewed partition shifts
    # each node's minibatch mean mu_B — the paper's §5.1 BN mechanism
    chan_offset = crng.normal(scale=0.6, size=(n_classes, 1, 1, channels))
    protos = protos + chan_offset
    y = rng.integers(0, n_classes, size=n_samples).astype(np.int32)
    x = protos[y].copy()
    # random circular shifts (translation invariance pressure)
    sh = rng.integers(-2, 3, size=(n_samples, 2))
    for i in range(n_samples):
        x[i] = np.roll(x[i], (sh[i, 0], sh[i, 1]), axis=(0, 1))
    x += rng.normal(scale=noise, size=x.shape).astype(np.float32)
    x *= rng.uniform(0.8, 1.2, size=(n_samples, 1, 1, 1)).astype(np.float32)
    return ImageDataset(x.astype(np.float32), y, n_classes)


def synth_geo_images(n_samples: int, *, n_regions: int = 5,
                     n_classes: int = 15, side: int = 16,
                     home_share: float = 0.7, seed: int = 0
                     ) -> Tuple[ImageDataset, np.ndarray]:
    """Flickr-Mammal analogue.  Returns (dataset, region (N,) int32).

    Each class has a home region; with prob ``home_share`` a sample of that
    class lands in its home region, else uniformly elsewhere — reproducing
    Table 1's skewed-but-overlapping real-world label distribution.
    """
    rng = np.random.default_rng(seed)
    ds = synth_images(n_samples, n_classes=n_classes, side=side, seed=seed)
    home = rng.integers(0, n_regions, size=n_classes)
    region = np.empty(n_samples, np.int32)
    for i, cls in enumerate(ds.y):
        if rng.random() < home_share:
            region[i] = home[cls]
        else:
            region[i] = rng.integers(0, n_regions)
    return ds, region


@dataclass
class TokenDataset:
    tokens: np.ndarray     # (N, T) int32
    vocab: int


def synth_tokens(n_seqs: int, seq_len: int, *, vocab: int = 512,
                 seed: int = 0) -> TokenDataset:
    """Order-2 Markov streams with a sparse transition structure, so a small
    LM gets visible loss reduction within a few hundred steps."""
    rng = np.random.default_rng(seed)
    branch = 8
    nxt = rng.integers(0, vocab, size=(vocab, branch))
    out = np.empty((n_seqs, seq_len), np.int32)
    state = rng.integers(0, vocab, size=n_seqs)
    for t in range(seq_len):
        pick = rng.integers(0, branch, size=n_seqs)
        state = nxt[state, pick]
        out[:, t] = state
    return TokenDataset(out, vocab)
