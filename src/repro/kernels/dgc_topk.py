"""Pallas TPU kernels for DeepGradientCompression's top-s% sparsification.

GPU DGC implementations use sort/radix-select (warp-shuffle heavy).  TPUs
have no warp shuffles and a full sort is O(n log n) HBM traffic, so we adapt
the *insight* (find a magnitude threshold keeping the top (1-s) fraction) to
a TPU-native two-pass scheme:

  pass 1 — ``abs_histogram``: blocked 256-bin histogram of |v| over
            [0, v_max] (one HBM read; per-block one-hot matmul-friendly
            accumulation in VMEM).
  pass 2 — the caller picks the threshold from the cumulative histogram
            (tiny, on host/XLA), then ``dgc_select`` masks v in one more
            fused pass (same structure as gaia_select, absolute threshold).

Histogram quantiles are approximate to one bin width; tests bound the
resulting sparsity error and the benchmark compares against the exact
jnp.quantile oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
N_BINS = 256


def _hist_kernel(v_ref, vmax_ref, hist_ref, *, n_bins: int):
    v = jnp.abs(v_ref[...].astype(jnp.float32))         # (rows, 128)
    vmax = jnp.maximum(vmax_ref[0], 1e-30)
    idx = jnp.clip((v / vmax * n_bins).astype(jnp.int32), 0, n_bins - 1)
    # one-hot accumulate: (rows*128, n_bins) -> (n_bins,)
    flat = idx.reshape(-1)
    oh = (flat[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (flat.shape[0], n_bins), 1)).astype(jnp.int32)
    hist_ref[0, :] = jnp.sum(oh, axis=0)


def abs_histogram(v: jnp.ndarray, v_max: jnp.ndarray, *,
                  n_bins: int = N_BINS, block_rows: int = 64,
                  interpret: bool = False) -> jnp.ndarray:
    """256-bin histogram of |v| over [0, v_max].  Padding contributes to
    bin 0; the caller corrects for it (count known statically)."""
    n = v.size
    rows = -(-n // LANES)
    rows_pad = -(-rows // block_rows) * block_rows
    flat = jnp.pad(v.reshape(-1), (0, rows_pad * LANES - n))
    v2 = flat.reshape(rows_pad, LANES)
    n_blocks = rows_pad // block_rows
    vmax_arr = jnp.asarray(v_max, jnp.float32).reshape(1)

    hist = pl.pallas_call(
        functools.partial(_hist_kernel, n_bins=n_bins),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, n_bins), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, n_bins), jnp.int32),
        interpret=interpret,
    )(v2, vmax_arr)
    total = jnp.sum(hist, axis=0)
    pad_count = rows_pad * LANES - n
    return total.at[0].add(-pad_count)


def _select_kernel(v_ref, t_ref, out_ref, cnt_ref):
    v = v_ref[...]
    t = t_ref[0]
    mask = jnp.abs(v.astype(jnp.float32)) > t
    out_ref[...] = jnp.where(mask, v, jnp.zeros_like(v))
    cnt_ref[0, 0] = jnp.sum(mask.astype(jnp.int32))


def dgc_select(v: jnp.ndarray, threshold: jnp.ndarray, *,
               block_rows: int = 64, interpret: bool = False):
    """Absolute-magnitude select: (v * (|v| > t), count)."""
    orig_shape = v.shape
    n = v.size
    rows = -(-n // LANES)
    rows_pad = -(-rows // block_rows) * block_rows
    flat = jnp.pad(v.reshape(-1), (0, rows_pad * LANES - n))
    v2 = flat.reshape(rows_pad, LANES)
    n_blocks = rows_pad // block_rows
    t_arr = jnp.asarray(threshold, jnp.float32).reshape(1)

    out, cnt = pl.pallas_call(
        _select_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(v2.shape, v.dtype),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.int32),
        ],
        interpret=interpret,
    )(v2, t_arr)
    return out.reshape(-1)[:n].reshape(orig_shape), jnp.sum(cnt)


def threshold_from_histogram(hist: jnp.ndarray, v_max: jnp.ndarray,
                             sparsity: jnp.ndarray) -> jnp.ndarray:
    """Pick the bin edge whose cumulative count first reaches ``sparsity``
    of the total — the DGC magnitude threshold."""
    n_bins = hist.shape[0]
    cum = jnp.cumsum(hist)
    total = cum[-1]
    target = sparsity * total.astype(jnp.float32)
    bin_idx = jnp.searchsorted(cum.astype(jnp.float32), target)
    bin_idx = jnp.clip(bin_idx, 0, n_bins - 1)
    return (bin_idx.astype(jnp.float32) + 1.0) / n_bins * v_max
