"""Pallas TPU kernels for DeepGradientCompression's top-s% sparsification.

GPU DGC implementations use sort/radix-select (warp-shuffle heavy).  TPUs
have no warp shuffles and a full sort is O(n log n) HBM traffic, so we adapt
the *insight* (find a magnitude threshold keeping the top (1-s) fraction) to
a TPU-native two-pass scheme:

  pass 1 — ``abs_histogram_fused``: one kernel launch, two sweeps over
            the blocked layout of |v|: sweep 0 folds the global max
            (the old separate host-side ``jnp.max(|v|)`` pre-pass) into
            SMEM scratch; sweep 1 bins every block against it (per-block
            one-hot matmul-friendly accumulation in VMEM).  The
            max-reduce is order-independent, so the threshold is
            bit-identical to the old two-launch scheme.
  pass 2 — the caller picks the threshold from the cumulative histogram
            (tiny, on host/XLA), then ``dgc_select`` masks v in one more
            fused pass (same structure as gaia_select, absolute threshold).

Histogram quantiles are approximate to one bin width; tests bound the
resulting sparsity error and the benchmark compares against the exact
jnp.quantile oracle.

``rand_k_select`` is the stochastic counterpart (rand-k compression,
the classic baseline top-k is measured against): the keep/drop mask is
generated *inside* the kernel from (seed, flat element index) counters
(``kernels/rng.py``) — no materialized random array crosses HBM, and
the mask is bit-exact against the host generator baseline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import rng

LANES = 128
N_BINS = 256


def _blocked(v: jnp.ndarray, block_rows: int):
    """Flatten + pad any-rank ``v`` into the kernels' (rows_pad, 128)
    lane layout.  Returns (v2, n, n_blocks)."""
    n = v.size
    rows = -(-n // LANES)
    rows_pad = -(-rows // block_rows) * block_rows
    flat = jnp.pad(v.reshape(-1), (0, rows_pad * LANES - n))
    return flat.reshape(rows_pad, LANES), n, rows_pad // block_rows


def _hist_kernel(v_ref, vmax_ref, hist_ref, *, n_bins: int):
    v = jnp.abs(v_ref[...].astype(jnp.float32))         # (rows, 128)
    vmax = jnp.maximum(vmax_ref[0], 1e-30)
    idx = jnp.clip((v / vmax * n_bins).astype(jnp.int32), 0, n_bins - 1)
    # one-hot accumulate: (rows*128, n_bins) -> (n_bins,)
    flat = idx.reshape(-1)
    oh = (flat[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (flat.shape[0], n_bins), 1)).astype(jnp.int32)
    hist_ref[0, :] = jnp.sum(oh, axis=0)


def abs_histogram(v: jnp.ndarray, v_max: jnp.ndarray, *,
                  n_bins: int = N_BINS, block_rows: int = 64,
                  interpret: bool = False) -> jnp.ndarray:
    """256-bin histogram of |v| over [0, v_max].  Padding contributes to
    bin 0; the caller corrects for it (count known statically)."""
    n = v.size
    rows = -(-n // LANES)
    rows_pad = -(-rows // block_rows) * block_rows
    flat = jnp.pad(v.reshape(-1), (0, rows_pad * LANES - n))
    v2 = flat.reshape(rows_pad, LANES)
    n_blocks = rows_pad // block_rows
    vmax_arr = jnp.asarray(v_max, jnp.float32).reshape(1)

    hist = pl.pallas_call(
        functools.partial(_hist_kernel, n_bins=n_bins),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, n_bins), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, n_bins), jnp.int32),
        interpret=interpret,
    )(v2, vmax_arr)
    total = jnp.sum(hist, axis=0)
    pad_count = rows_pad * LANES - n
    return total.at[0].add(-pad_count)


def _hist_fused_kernel(v_ref, hist_ref, vmax_ref, mx_scr, *, n_bins: int):
    """Two-sweep grid (sweep, block): sweep 0 reduces the global max of
    |v| into SMEM scratch; sweep 1 bins each block against it.  TPU
    grids run sequentially (and interpret mode mirrors that), so every
    max lands before the first bin is computed."""
    sweep = pl.program_id(0)
    blk = pl.program_id(1)
    v = jnp.abs(v_ref[...].astype(jnp.float32))         # (rows, 128)

    @pl.when((sweep == 0) & (blk == 0))
    def _init():
        mx_scr[0] = 0.0

    @pl.when(sweep == 0)
    def _max():
        mx_scr[0] = jnp.maximum(mx_scr[0], jnp.max(v))
        # the out block is also mapped at sweep 0: write something
        # defined (it is fully overwritten at sweep 1)
        hist_ref[0, :] = jnp.zeros_like(hist_ref[0, :])

    @pl.when(sweep == 1)
    def _bin():
        vmax = jnp.maximum(mx_scr[0], 1e-30)
        idx = jnp.clip((v / vmax * n_bins).astype(jnp.int32), 0, n_bins - 1)
        flat = idx.reshape(-1)
        oh = (flat[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (flat.shape[0], n_bins), 1)).astype(jnp.int32)
        hist_ref[0, :] = jnp.sum(oh, axis=0)
        vmax_ref[0, 0] = mx_scr[0]


def abs_histogram_fused(v: jnp.ndarray, *, n_bins: int = N_BINS,
                        block_rows: int = 64, interpret: bool = False):
    """(histogram of |v| over [0, max|v|], max|v|) in ONE kernel launch —
    the fold of the old host-side ``jnp.max(jnp.abs(v))`` pre-pass into
    the histogram sweep.  Bit-identical histogram/v_max to the separate
    ``jnp.max`` + :func:`abs_histogram` pair (max is order-exact)."""
    v2, n, n_blocks = _blocked(v, block_rows)
    hist, vmax = pl.pallas_call(
        functools.partial(_hist_fused_kernel, n_bins=n_bins),
        grid=(2, n_blocks),
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda s, i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, n_bins), lambda s, i: (i, 0)),
            pl.BlockSpec((1, 1), lambda s, i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, n_bins), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.float32)],
        interpret=interpret,
    )(v2)
    total = jnp.sum(hist, axis=0)
    pad_count = v2.size - n
    return total.at[0].add(-pad_count), vmax[0, 0]


def _randk_kernel(v_ref, seed_ref, p_ref, out_ref, cnt_ref, *, n: int):
    """Seeded rand-k mask generated in-kernel: uniform(seed, flat index)
    per element, keep where u < keep_prob — no materialized randoms."""
    blk = pl.program_id(0)
    v = v_ref[...]
    rows, lanes = v.shape
    base = blk * rows * lanes
    idx = base + (jax.lax.broadcasted_iota(jnp.int32, (rows, lanes), 0)
                  * lanes
                  + jax.lax.broadcasted_iota(jnp.int32, (rows, lanes), 1))
    u = rng.uniform01(seed_ref[0].astype(jnp.uint32), idx)
    keep = (u < p_ref[0]) & (idx < n)          # padding never selects
    out_ref[...] = jnp.where(keep, v, jnp.zeros_like(v))
    cnt_ref[0, 0] = jnp.sum(keep.astype(jnp.int32))


def rand_k_select(v: jnp.ndarray, keep_prob: jnp.ndarray,
                  seed: jnp.ndarray, *, block_rows: int = 64,
                  interpret: bool = False):
    """Seeded rand-k sparsification: (v * mask, count) with
    ``mask[i] = uniform01(seed, i) < keep_prob``.  ``seed`` and
    ``keep_prob`` are runtime operands (a per-step seed never
    retraces).  Bit-exact vs ``ref.rand_k_select_ref``."""
    orig_shape = v.shape
    v2, n, n_blocks = _blocked(v, block_rows)
    seed_arr = jnp.asarray(seed, jnp.int32).reshape(1)
    p_arr = jnp.asarray(keep_prob, jnp.float32).reshape(1)
    out, cnt = pl.pallas_call(
        functools.partial(_randk_kernel, n=n),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),       # seed scalar
            pl.BlockSpec(memory_space=pl.ANY),       # keep_prob scalar
        ],
        out_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(v2.shape, v.dtype),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.int32),
        ],
        interpret=interpret,
    )(v2, seed_arr, p_arr)
    return out.reshape(-1)[:n].reshape(orig_shape), jnp.sum(cnt)


def _select_kernel(v_ref, t_ref, out_ref, cnt_ref):
    v = v_ref[...]
    t = t_ref[0]
    mask = jnp.abs(v.astype(jnp.float32)) > t
    out_ref[...] = jnp.where(mask, v, jnp.zeros_like(v))
    cnt_ref[0, 0] = jnp.sum(mask.astype(jnp.int32))


def dgc_select(v: jnp.ndarray, threshold: jnp.ndarray, *,
               block_rows: int = 64, interpret: bool = False):
    """Absolute-magnitude select: (v * (|v| > t), count)."""
    orig_shape = v.shape
    v2, n, n_blocks = _blocked(v, block_rows)
    t_arr = jnp.asarray(threshold, jnp.float32).reshape(1)

    out, cnt = pl.pallas_call(
        _select_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(v2.shape, v.dtype),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.int32),
        ],
        interpret=interpret,
    )(v2, t_arr)
    return out.reshape(-1)[:n].reshape(orig_shape), jnp.sum(cnt)


def threshold_from_histogram(hist: jnp.ndarray, v_max: jnp.ndarray,
                             sparsity: jnp.ndarray) -> jnp.ndarray:
    """Pick the bin edge whose cumulative count first reaches ``sparsity``
    of the total — the DGC magnitude threshold."""
    n_bins = hist.shape[0]
    cum = jnp.cumsum(hist)
    total = cum[-1]
    target = sparsity * total.astype(jnp.float32)
    bin_idx = jnp.searchsorted(cum.astype(jnp.float32), target)
    bin_idx = jnp.clip(bin_idx, 0, n_bins - 1)
    return (bin_idx.astype(jnp.float32) + 1.0) / n_bins * v_max
