"""Backend-aware kernel dispatch: route every op to the path that
actually wins on the backend we run on.

The old policy was a blanket ``interpret = (backend != "tpu")`` switch:
correct everywhere, but off-TPU the Pallas interpreter re-dispatches
every kernel op per grid step and loses to the jnp oracles by 5–170x on
exactly the paper's hot paths (gossip mixing, Gaia/DGC sparsification,
GroupNorm).  This module replaces it with a per-(op, shape-bucket,
dtype, backend) *measured* decision:

* **TPU** — the compiled Mosaic path, block sizes from a shape
  heuristic.  No timing: compiled Pallas is the whole point there.
* **CPU / GPU** — a one-time timed trial races the candidate paths
  (Pallas — interpret on CPU, compiled Triton on GPU, over a small
  block-size sweep — against the jnp oracle from ``kernels/ref.py``)
  and the winner is cached, so every later call (and every later
  *process*, via the persisted cache file) dispatches with zero timing
  and zero recompiles.

Decisions are sticky: the cache is keyed by
``backend/op/bucket`` and persisted as JSON to
``out/kernel_dispatch_cache.json`` (override with
``REPRO_DISPATCH_CACHE=<path>``; set it empty to keep decisions
in-memory only).  ``KernelDispatch.trials`` counts timed trials the
same way ``DPSGD.trace_count`` counts traces — tests assert it stops
moving once the cache is warm.

Overrides (no timing, no cache write):

* ``REPRO_KERNEL_DISPATCH=auto|oracle|pallas|interpret|compiled`` —
  global forced path (``pallas`` = whichever Pallas mode the backend
  compiles).
* ``REPRO_KERNEL_DISPATCH_<OP>`` (e.g. ``..._GAIA_SELECT``) — per-op
  override, same values, wins over the global one.

Timed trials run in a worker thread: JAX's trace state is thread-local,
so a decision forced during the first trace of an outer jitted step
(e.g. ``DPSGD._step``) still executes its candidates eagerly on
concrete sample inputs instead of being swallowed by the trace.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Callable, Dict, Optional

import jax

_FORCE_VALUES = ("auto", "oracle", "pallas", "interpret", "compiled")
_CACHE_ENV = "REPRO_DISPATCH_CACHE"
_FORCE_ENV = "REPRO_KERNEL_DISPATCH"
_DEFAULT_CACHE = os.path.join("out", "kernel_dispatch_cache.json")

# a candidate whose first timed sample is already this many times the
# best-so-far is abandoned after that sample (interpret at 1M elements
# costs hundreds of ms per call; no need to average three of those)
_ABANDON_RATIO = 10.0
_N_TIMED = 2


def size_bucket(n: int) -> str:
    """Shape bucket: next power of two of the element count.  Decisions
    are per-bucket, so e.g. 1M and 1.3M share one trial."""
    n = max(int(n), 1)
    return f"p{(n - 1).bit_length()}"


class KernelDispatch:
    """Measured, cached, overridable per-op path picker (see module
    docstring).  One instance (``get_dispatcher()``) serves ops.py; tests
    build their own around temp cache files."""

    def __init__(self, cache_path: Optional[str] = None,
                 backend: Optional[str] = None):
        if cache_path is None:
            cache_path = os.environ.get(_CACHE_ENV, _DEFAULT_CACHE)
        self.cache_path = cache_path or None   # "" disables persistence
        self.backend = backend or jax.default_backend()
        self.trials = 0          # timed trials run (stickiness assertions)
        self._lock = threading.Lock()
        self.cache: Dict[str, Dict] = {}
        self._load()

    # ---- persistence ----
    def _load(self) -> None:
        if not self.cache_path or not os.path.exists(self.cache_path):
            return
        try:
            with open(self.cache_path) as f:
                data = json.load(f)
            if isinstance(data, dict):
                self.cache = data
        except (OSError, ValueError):
            self.cache = {}

    def _save(self) -> None:
        if not self.cache_path:
            return
        try:
            d = os.path.dirname(self.cache_path)
            if d:
                os.makedirs(d, exist_ok=True)
            tmp = self.cache_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(self.cache, f, indent=1, sort_keys=True)
            os.replace(tmp, self.cache_path)
        except OSError:
            pass                              # read-only tree: stay in-memory

    # ---- overrides ----
    def forced_path(self, op: str) -> Optional[str]:
        """The env-forced path for ``op``, or None for auto."""
        v = os.environ.get(f"{_FORCE_ENV}_{op.upper()}",
                           os.environ.get(_FORCE_ENV, "auto")).lower()
        if v not in _FORCE_VALUES:
            raise ValueError(
                f"{_FORCE_ENV}[_{op.upper()}]={v!r}; expected one of "
                f"{_FORCE_VALUES}")
        return None if v == "auto" else v

    @staticmethod
    def _match(force: str, labels) -> Optional[str]:
        """First candidate label matching a forced path.  Labels are
        ``oracle`` or ``<mode>:b<block>``; ``pallas`` matches any
        non-oracle mode."""
        for lab in labels:
            mode = lab.split(":", 1)[0]
            if mode == force or (force == "pallas" and mode != "oracle"):
                return lab
        return None

    # ---- the decision ----
    def decide(self, op: str, bucket: str,
               candidates: Dict[str, Callable[[], object]],
               default: str) -> str:
        """Pick a candidate label for ``(op, bucket)``.

        ``candidates`` maps label -> zero-arg callable running that path
        on concrete sample inputs (used only if a timed trial is
        needed).  ``default`` is the no-trial answer (TPU's compiled
        label; also the fallback when a forced path has no candidate).
        """
        force = self.forced_path(op)
        if force is not None:
            return self._match(force, candidates) or default
        if self.backend == "tpu":
            return default                     # fixed policy: Mosaic
        key = f"{self.backend}/{op}/{bucket}"
        ent = self.cache.get(key)
        if ent and ent.get("label") in candidates:
            return ent["label"]
        with self._lock:
            ent = self.cache.get(key)          # raced trial already done?
            if ent and ent.get("label") in candidates:
                return ent["label"]
            label, times = self._trial(candidates)
            self.cache[key] = {"label": label, "us": times}
            self._save()
            return label

    def _trial(self, candidates: Dict[str, Callable[[], object]]):
        """Race the candidates eagerly in a worker thread (escapes any
        ambient jit trace; see module docstring) and return
        (winning label, per-label us)."""
        self.trials += 1
        times: Dict[str, float] = {}

        def run() -> None:
            best = float("inf")
            for label, fn in candidates.items():
                try:
                    jax.block_until_ready(fn())        # compile + warm
                    samples = []
                    for _ in range(_N_TIMED):
                        t0 = time.perf_counter()
                        jax.block_until_ready(fn())
                        samples.append(time.perf_counter() - t0)
                        if samples[0] > _ABANDON_RATIO * best:
                            break                      # hopeless: one sample
                    t = min(samples)
                except Exception:  # repro-allow: RA104 — any failure at
                    t = float("inf")     # all means: path unsupported on
                #                          this backend; time it out of
                #                          contention, don't crash the op
                times[label] = t * 1e6
                best = min(best, t)

        th = threading.Thread(target=run, daemon=True)
        th.start()
        th.join()
        finite = {k: v for k, v in times.items() if v != float("inf")}
        if not finite:
            # nothing ran (e.g. no jit at all): fall back to the oracle
            return next(iter(candidates)), times
        return min(finite, key=finite.get), times


_dispatcher: Optional[KernelDispatch] = None
_dispatcher_lock = threading.Lock()


def get_dispatcher() -> KernelDispatch:
    """The process-wide dispatcher ops.py consults."""
    global _dispatcher
    with _dispatcher_lock:
        if _dispatcher is None:
            _dispatcher = KernelDispatch()
        return _dispatcher


def reset_dispatcher() -> None:
    """Drop the process-wide dispatcher (tests; env changes)."""
    global _dispatcher
    with _dispatcher_lock:
        _dispatcher = None
