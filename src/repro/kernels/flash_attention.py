"""Pallas TPU flash attention (blocked online softmax).

Grid: (batch*heads, Tq/block_q, Tk/block_k) — the k dimension is the
innermost ("arbitrary") grid axis, so the (m, l, acc) running statistics
live in VMEM scratch across k iterations.  Block shapes are MXU-aligned
(block_q × d and block_k × d tiles, multiples of (8, 128) for fp32).

Supports causal masking, sliding windows (gemma2/starcoder2 local layers)
and gemma2's logit softcap.  Validated in interpret mode against
``ref.flash_attention_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: Optional[int],
                 logit_softcap: Optional[float], block_q: int, block_k: int,
                 n_k: int, tq: int, tk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                    # (bq, d)
    k = k_ref[0].astype(jnp.float32)                    # (bk, d)
    v = v_ref[0].astype(jnp.float32)                    # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if logit_softcap is not None:
        s = logit_softcap * jnp.tanh(s / logit_softcap)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0) \
        + (tk - tq)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    valid = k_pos < tk
    if causal:
        valid &= k_pos <= q_pos
    if window is not None:
        valid &= k_pos > q_pos - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_blk = jnp.max(s, axis=1, keepdims=True)           # (bq, 1)
    m_new = jnp.maximum(m_prev, m_blk)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    logit_softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q/k/v: (B, H, T, D) — MHA layout (GQA callers pre-broadcast KV heads).
    """
    B, H, Tq, D = q.shape
    Tk = k.shape[2]
    scale = D ** -0.5 if scale is None else scale
    block_q = min(block_q, Tq)
    block_k = min(block_k, Tk)

    # pad sequence dims to block multiples
    def pad_to(x, blk, axis):
        t = x.shape[axis]
        rem = (-t) % blk
        if rem == 0:
            return x
        cfg = [(0, 0)] * x.ndim
        cfg[axis] = (0, rem)
        return jnp.pad(x, cfg)

    qp = pad_to(q, block_q, 2).reshape(B * H, -1, D)
    kp = pad_to(k, block_k, 2).reshape(B * H, -1, D)
    vp = pad_to(v, block_k, 2).reshape(B * H, -1, D)
    nq = qp.shape[1] // block_q
    nk = kp.shape[1] // block_k

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        logit_softcap=logit_softcap, block_q=block_q, block_k=block_k,
        n_k=nk, tq=Tq, tk=Tk)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out.reshape(B, H, -1, D)[:, :, :Tq]
