"""Pallas TPU kernel for Gaia's significance filter (Algorithm 1, line 8):
``selected = v * (|v| > T * |w|)`` plus a per-block count of selected
entries.

This is the per-step hot-spot of Gaia at scale: a full HBM sweep of every
accumulated-update tensor.  The kernel fuses compare + mask + popcount into
a single pass over (8, 128)-aligned VMEM tiles, emitting one int32 count
per block (summed cheaply by the caller) instead of an atomic counter — the
TPU-idiomatic replacement for a GPU atomics-based compaction.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
SUBLANES = 8


def _gaia_kernel(v_ref, w_ref, t_ref, out_ref, cnt_ref):
    v = v_ref[...]
    w = w_ref[...]
    t = t_ref[0]
    mask = jnp.abs(v.astype(jnp.float32)) > t * jnp.abs(w.astype(jnp.float32))
    out_ref[...] = jnp.where(mask, v, jnp.zeros_like(v))
    cnt_ref[0, 0] = jnp.sum(mask.astype(jnp.int32))


def gaia_select(v: jnp.ndarray, w: jnp.ndarray, threshold: jnp.ndarray, *,
                block_rows: int = 64, interpret: bool = False):
    """v, w: same shape (any rank).  threshold: scalar.
    Returns (selected (same shape), n_selected int32)."""
    assert v.shape == w.shape, (v.shape, w.shape)
    orig_shape = v.shape
    n = v.size
    # lay the tensor out as (rows, 128) lanes, padding the tail
    rows = -(-n // LANES)
    rows_pad = -(-rows // block_rows) * block_rows
    flat_v = jnp.pad(v.reshape(-1), (0, rows_pad * LANES - n))
    flat_w = jnp.pad(w.reshape(-1), (0, rows_pad * LANES - n),
                     constant_values=1.0)  # pad w!=0 so padded v=0 never selects
    v2 = flat_v.reshape(rows_pad, LANES)
    w2 = flat_w.reshape(rows_pad, LANES)
    n_blocks = rows_pad // block_rows
    t_arr = jnp.asarray(threshold, jnp.float32).reshape(1)

    out, cnt = pl.pallas_call(
        _gaia_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),   # scalar threshold
        ],
        out_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(v2.shape, v.dtype),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.int32),
        ],
        interpret=interpret,
    )(v2, w2, t_arr)
    selected = out.reshape(-1)[:n].reshape(orig_shape)
    return selected, jnp.sum(cnt)
