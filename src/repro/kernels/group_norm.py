"""Pallas TPU kernel for fused GroupNorm (the paper's §5.2 BatchNorm fix).

One grid step per sample: the (H*W, C) activation tile is normalized
per-group entirely in VMEM (mean/var/normalize/affine in one pass), so the
activation makes a single HBM round-trip instead of the 3+ passes of an
unfused mean/var/normalize chain.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gn_kernel(x_ref, scale_ref, bias_ref, o_ref, *, group_size: int,
               eps: float):
    x = x_ref[0].astype(jnp.float32)                  # (HW, C)
    hw, c = x.shape
    g = c // group_size
    xg = x.reshape(hw, g, group_size)
    mu = jnp.mean(xg, axis=(0, 2), keepdims=True)     # (1, g, 1)
    var = jnp.mean(jnp.square(xg - mu), axis=(0, 2), keepdims=True)
    y = (xg - mu) * jax.lax.rsqrt(var + eps)
    y = y.reshape(hw, c) * scale_ref[...] + bias_ref[...]
    o_ref[0] = y.astype(o_ref.dtype)


def group_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, *,
               group_size: int = 2, eps: float = 1e-5,
               interpret: bool = False) -> jnp.ndarray:
    """x: (B, H, W, C) NHWC."""
    B, H, W, C = x.shape
    x2 = x.reshape(B, H * W, C)
    out = pl.pallas_call(
        functools.partial(_gn_kernel, group_size=group_size, eps=eps),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, H * W, C), lambda b: (b, 0, 0)),
            pl.BlockSpec((C,), lambda b: (0,)),
            pl.BlockSpec((C,), lambda b: (0,)),
        ],
        out_specs=pl.BlockSpec((1, H * W, C), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, scale, bias)
    return out.reshape(B, H, W, C)
