"""Pallas TPU kernel for D-PSGD's sparse neighbor averaging:
``y[k] = W[k,k] * x[k] + sum_d w[k,d] * x[nbr[k,d]]``.

This is the per-step hot-spot of gossip training: the whole stacked model
(K, N) must be re-mixed every step.  A dense ``W @ X`` wastes K**2 * N
MACs when the graph is sparse (ring: degree 2 regardless of K); looping
per node launches K kernels and re-reads X from HBM each time.  This
kernel streams X through VMEM once per (8,128)-aligned column block and,
inside the block, performs the gather-scale-accumulate over the padded
neighbor lists — O(K * max_degree * block) work, one HBM sweep total.

Neighbor structure comes in kernel-friendly padded form (see
``Topology.neighbor_arrays``): ``nbr_idx`` (K, D) int32 padded with the
node's own index and ``nbr_w`` (K, D) float32 padded with zeros, so
padding rows contribute ``0 * x[k]`` and no branching is needed.

``nbr_idx``/``nbr_w`` are *runtime operands*, not trace-time constants:
only their (K, D) shape is baked into the compiled kernel (the k/d loops
unroll over it), while the index values are gathered with
``dynamic_index_in_dim`` at run time.  A :class:`TopologySchedule` that
changes the neighbor set every round therefore reuses one compilation,
provided every round pads to the schedule-wide max degree
(``TopologySchedule.neighbor_arrays`` does) — that compile-once contract
is what ``DPSGD.trace_count`` asserts in the tests.

Stale mixing (AD-PSGD): passing ``src`` with M >= K rows gathers the
neighbor terms from ``src`` instead of ``x`` (the self term stays on
``x``).  AD-PSGD stacks its bounded-staleness snapshot buffer into
``src = snaps.reshape((S + 1) * K, N)`` and offsets the neighbor indices
by ``staleness * K`` — the staleness values ride inside the same runtime
index operand, so a controller moving the staleness rung mid-run reuses
the one compilation too.

The pod-scale distributed backend applies the same self-weight +
padded-neighbor-gather arithmetic (both variants) as a shard_map +
ppermute ring over the mesh ``pod`` axis (``launch/steps._pod_mix_fn``);
tests/test_launch_gossip.py holds the two implementations equivalent.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _mix_kernel(nbr_ref, w_ref, sw_ref, x_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)            # (K, block_rows, 128)
    K, D = nbr_ref.shape
    for k in range(K):                            # K, D static: unrolled
        acc = sw_ref[k] * x[k]
        for d in range(D):
            xn = jax.lax.dynamic_index_in_dim(x, nbr_ref[k, d], axis=0,
                                              keepdims=False)
            acc = acc + w_ref[k, d] * xn
        out_ref[k] = acc.astype(out_ref.dtype)


def _mix_src_kernel(nbr_ref, w_ref, sw_ref, x_ref, src_ref, out_ref):
    """Stale-mixing variant: neighbor rows gathered from ``src`` (M rows,
    e.g. a stacked staleness-snapshot buffer), self term from ``x``."""
    x = x_ref[...].astype(jnp.float32)            # (K, block_rows, 128)
    src = src_ref[...].astype(jnp.float32)        # (M, block_rows, 128)
    K, D = nbr_ref.shape
    for k in range(K):
        acc = sw_ref[k] * x[k]
        for d in range(D):
            xn = jax.lax.dynamic_index_in_dim(src, nbr_ref[k, d], axis=0,
                                              keepdims=False)
            acc = acc + w_ref[k, d] * xn
        out_ref[k] = acc.astype(out_ref.dtype)


def _to_blocks(x: jnp.ndarray, rows_pad: int) -> jnp.ndarray:
    rows, n = x.shape
    xp = jnp.pad(x, ((0, 0), (0, rows_pad * LANES - n)))
    return xp.reshape(rows, rows_pad, LANES)


def neighbor_mix(x: jnp.ndarray, nbr_idx: jnp.ndarray, nbr_w: jnp.ndarray,
                 self_w: jnp.ndarray, *, src: jnp.ndarray = None,
                 block_rows: int = 64,
                 interpret: bool = False) -> jnp.ndarray:
    """x: (K, N) stacked per-node vectors.  nbr_idx/nbr_w: (K, D) padded
    neighbor lists; self_w: (K,) = diag(W).  Returns (K, N) mixed.

    ``src`` (optional, (M, N) with M >= K): gather neighbor terms from
    ``src`` rows instead of ``x`` — AD-PSGD's stale mixing, where
    ``src`` is the flattened (staleness+1, K, N) snapshot buffer and
    ``nbr_idx`` carries ``staleness * K + neighbor`` offsets."""
    K, N = x.shape
    assert nbr_idx.shape == nbr_w.shape and nbr_idx.shape[0] == K
    assert self_w.shape == (K,)
    rows = -(-N // LANES)
    rows_pad = -(-rows // block_rows) * block_rows
    x3 = _to_blocks(x, rows_pad)
    n_blocks = rows_pad // block_rows
    block3 = lambda rows: pl.BlockSpec((rows, block_rows, LANES),
                                       lambda i: (0, i, 0))
    scalars = [
        pl.BlockSpec(memory_space=pl.ANY),        # nbr_idx (scalars)
        pl.BlockSpec(memory_space=pl.ANY),        # nbr_w
        pl.BlockSpec(memory_space=pl.ANY),        # self_w
    ]
    operands = (jnp.asarray(nbr_idx, jnp.int32),
                jnp.asarray(nbr_w, jnp.float32),
                jnp.asarray(self_w, jnp.float32))

    if src is None:
        out = pl.pallas_call(
            _mix_kernel,
            grid=(n_blocks,),
            in_specs=scalars + [block3(K)],
            out_specs=block3(K),
            out_shape=jax.ShapeDtypeStruct(x3.shape, x.dtype),
            interpret=interpret,
        )(*operands, x3)
    else:
        M = src.shape[0]
        assert src.shape[1] == N, (src.shape, x.shape)
        assert M >= K, (M, K)
        src3 = _to_blocks(src, rows_pad)
        out = pl.pallas_call(
            _mix_src_kernel,
            grid=(n_blocks,),
            in_specs=scalars + [block3(K), block3(M)],
            out_specs=block3(K),
            out_shape=jax.ShapeDtypeStruct(x3.shape, x.dtype),
            interpret=interpret,
        )(*operands, x3, src3)
    return out.reshape(K, rows_pad * LANES)[:, :N]
