"""Backend-aware public wrappers around the Pallas kernels.

The old contract here was a blanket ``interpret = backend != "tpu"``
switch: correct everywhere, but interpret-mode Pallas re-dispatches per
grid step and loses to the jnp oracles by 5-170x off-TPU.  Every op now
routes through ``kernels/dispatch.py``:

1. explicit ``interpret=`` (and, where applicable, ``block_*=``)
   arguments force the Pallas path exactly as before — tests and the
   bench's "old path" rows use this, and it is the escape hatch;
2. otherwise the dispatcher picks a path label for
   (op, dtype, size-bucket, backend): ``"oracle"`` (the jnp twin from
   ``kernels/ref.py``) or ``"<mode>:b<block>"`` where ``<mode>`` is the
   Pallas mode that runs on this backend — ``interpret`` on CPU,
   ``compiled`` (Triton / Mosaic) on GPU / TPU.  TPU always takes the
   compiled label (no timing); CPU / GPU decisions come from a one-time
   timed trial, cached in ``out/kernel_dispatch_cache.json``;
3. block sizes are no longer hardcoded 64/128: the heuristic picks the
   largest aligned power-of-two the shape supports, and the trial sweeps
   a couple of candidates for the compiled path.

Trial candidates run on zero-filled sample inputs of the real shape —
every kernel here is data-independent — in a worker thread, so a
decision forced during the first trace of an outer jitted step still
times its candidates eagerly.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dgc_topk as _dgc
from repro.kernels import dispatch as _dispatch
from repro.kernels import flash_attention as _fa
from repro.kernels import gaia_select as _gaia
from repro.kernels import group_norm as _gn
from repro.kernels import neighbor_mix as _nm
from repro.kernels import ref as _ref

LANES = 128


def _default_interpret() -> bool:
    """The Pallas mode that runs on this backend (True = interpret).
    Used when a caller forces the Pallas path without saying how."""
    return jax.default_backend() != "tpu"


def _pallas_mode() -> str:
    return "compiled" if jax.default_backend() in ("tpu", "gpu", "cuda",
                                                   "rocm") else "interpret"


def _block_rows_for(n: int, cap: int) -> int:
    """Largest power-of-two block_rows <= min(rows(n), cap), >= 8."""
    rows = max(-(-n // LANES), 8)
    r = min(rows, cap)
    return 1 << (r.bit_length() - 1)


def _parse_label(label: str) -> Tuple[str, Optional[int]]:
    mode, _, b = label.partition(":b")
    return mode, (int(b) if b else None)


def _decide(op: str, n: int, dtype, candidates, default: str) -> str:
    bucket = f"{jnp.dtype(dtype).name}/{_dispatch.size_bucket(n)}"
    return _dispatch.get_dispatcher().decide(op, bucket, candidates, default)


@functools.lru_cache(maxsize=64)
def _sample_cached(shape, dtype_name, fill):
    return jax.block_until_ready(jnp.full(shape, fill, jnp.dtype(dtype_name)))


def _z(shape, dt, fill=0.0):
    """Device-resident trial input, memoized per (shape, dtype, fill) so
    dispatch trials time the kernel — not a fresh host->device transfer
    on every timed call (an 8 MB copy per call swamps a ~1 ms oracle and
    poisons the decision)."""
    shape = (shape,) if isinstance(shape, int) else tuple(shape)
    return _sample_cached(shape, jnp.dtype(dt).name, fill)


def _blocked_candidates(n: int, pallas_fn, oracle_fn):
    """Candidates for the flat (rows, 128)-blocked kernel family.

    ``pallas_fn(block_rows, interpret)`` runs the Pallas path on sample
    inputs; ``oracle_fn()`` runs the jnp twin.  Interpret mode gets one
    big-block candidate (per-grid-step overhead dominates, so fewer
    steps is strictly better); compiled mode gets a small sweep.
    Returns (candidates, default_label) — the default is the heuristic
    compiled/interpret block, used on TPU without timing.
    """
    mode = _pallas_mode()
    cands = {"oracle": oracle_fn}          # first: cheap best-so-far for
    if mode == "interpret":                # the trial's early abandon
        blocks = [_block_rows_for(n, 2048)]
    else:
        blocks = sorted({_block_rows_for(n, 64), _block_rows_for(n, 256)})
    for b in blocks:
        cands[f"{mode}:b{b}"] = functools.partial(pallas_fn, b,
                                                  mode == "interpret")
    return cands, f"{mode}:b{blocks[-1]}"


# ---------------------------------------------------------------- attention

@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "logit_softcap", "scale", "block_q", "block_k",
    "interpret"))
def _fa_pallas(q, k, v, *, causal, window, logit_softcap, scale,
               block_q, block_k, interpret):
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window, logit_softcap=logit_softcap,
        scale=scale, block_q=block_q, block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "logit_softcap", "scale"))
def _fa_oracle(q, k, v, *, causal, window, logit_softcap, scale):
    return _ref.flash_attention_ref(
        q, k, v, causal=causal, window=window, logit_softcap=logit_softcap,
        scale=scale)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    logit_softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    block_q: Optional[int] = None,
                    block_k: Optional[int] = None,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    static = dict(causal=causal, window=window, logit_softcap=logit_softcap,
                  scale=scale)
    if interpret is not None:
        return _fa_pallas(q, k, v, block_q=block_q or 128,
                          block_k=block_k or 128, interpret=interpret,
                          **static)
    Tq, Tk = q.shape[2], k.shape[2]
    mode = _pallas_mode()
    if block_q is not None or block_k is not None:
        sweeps = [(block_q or 128, block_k or 128)]
    elif mode == "compiled":
        sweeps = sorted({(min(64, Tq), min(64, Tk)),
                         (min(128, Tq), min(128, Tk))})
    else:
        sweeps = [(min(128, Tq), min(128, Tk))]
    shape, dt = q.shape, q.dtype
    kshape = k.shape

    def pallas_trial(bq, bk):
        return _fa_pallas(_z(shape, dt), _z(kshape, dt),
                          _z(kshape, dt), block_q=bq, block_k=bk,
                          interpret=mode == "interpret", **static)

    cands = {"oracle": lambda: _fa_oracle(
        _z(shape, dt), _z(kshape, dt), _z(kshape, dt), **static)}
    for bq, bk in sweeps:
        cands[f"{mode}:b{bq}x{bk}"] = functools.partial(pallas_trial, bq, bk)
    default = f"{mode}:b{sweeps[-1][0]}x{sweeps[-1][1]}"
    label = _decide("flash_attention", q.size + 2 * k.size, dt, cands,
                    default)
    if label == "oracle":
        return _fa_oracle(q, k, v, **static)
    bq, bk = (int(x) for x in label.split(":b")[1].split("x"))
    return _fa_pallas(q, k, v, block_q=bq, block_k=bk,
                      interpret=label.startswith("interpret"), **static)


# --------------------------------------------------------------------- gaia

@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _gaia_pallas(v, w, threshold, *, block_rows, interpret):
    return _gaia.gaia_select(v, w, threshold, block_rows=block_rows,
                             interpret=interpret)


_gaia_oracle = jax.jit(_ref.gaia_select_ref)


def gaia_select(v, w, threshold, *, block_rows: Optional[int] = None,
                interpret: Optional[bool] = None):
    """Gaia significance filter: (v * (|v| > T|w|), count)."""
    if interpret is not None or block_rows is not None:
        it = _default_interpret() if interpret is None else interpret
        return _gaia_pallas(v, w, threshold, block_rows=block_rows or 64,
                            interpret=it)
    shape, dt = v.shape, v.dtype
    cands, default = _blocked_candidates(
        v.size,
        lambda b, it: _gaia_pallas(_z(shape, dt), _z(shape, dt),
                                   0.5, block_rows=b, interpret=it),
        lambda: _gaia_oracle(_z(shape, dt), _z(shape, dt), 0.5))
    label = _decide("gaia_select", v.size, dt, cands, default)
    if label == "oracle":
        return _gaia_oracle(v, w, threshold)
    mode, b = _parse_label(label)
    return _gaia_pallas(v, w, threshold, block_rows=b,
                        interpret=mode == "interpret")


# ---------------------------------------------------------------------- dgc

@functools.partial(jax.jit, static_argnames=("n_bins", "block_rows",
                                             "interpret"))
def _dgc_pallas(v, sparsity, *, n_bins, block_rows, interpret):
    """Histogram -> threshold -> select, with the |v| max folded into the
    histogram kernel's first sweep (one pass over v, not two)."""
    hist, v_max = _dgc.abs_histogram_fused(v, n_bins=n_bins,
                                           block_rows=block_rows,
                                           interpret=interpret)
    t = _dgc.threshold_from_histogram(hist, v_max, sparsity)
    sel, cnt = _dgc.dgc_select(v, t, block_rows=block_rows,
                               interpret=interpret)
    return sel, cnt, t


@functools.partial(jax.jit, static_argnames=("n_bins",))
def _dgc_oracle(v, sparsity, *, n_bins):
    return _ref.dgc_sparsify_ref(v, sparsity, n_bins=n_bins)


def dgc_sparsify(v, sparsity, *, n_bins: int = 256,
                 block_rows: Optional[int] = None,
                 interpret: Optional[bool] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full DGC top-s%: histogram -> threshold -> select.
    Returns (selected, count, threshold).  Both paths quantize the
    threshold through the same n_bins histogram, so dispatch never moves
    the numbers (see ``ref.dgc_sparsify_ref``)."""
    if interpret is not None or block_rows is not None:
        it = _default_interpret() if interpret is None else interpret
        return _dgc_pallas(v, sparsity, n_bins=n_bins,
                           block_rows=block_rows or 64, interpret=it)
    shape, dt = v.shape, v.dtype
    cands, default = _blocked_candidates(
        v.size,
        lambda b, it: _dgc_pallas(_z(shape, dt), 0.99, n_bins=n_bins,
                                  block_rows=b, interpret=it),
        lambda: _dgc_oracle(_z(shape, dt), 0.99, n_bins=n_bins))
    label = _decide("dgc_sparsify", v.size, dt, cands, default)
    if label == "oracle":
        return _dgc_oracle(v, sparsity, n_bins=n_bins)
    mode, b = _parse_label(label)
    return _dgc_pallas(v, sparsity, n_bins=n_bins, block_rows=b,
                       interpret=mode == "interpret")


# ------------------------------------------------------------------- rand-k

@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _randk_pallas(v, keep_prob, seed, *, block_rows, interpret):
    return _dgc.rand_k_select(v, keep_prob, seed, block_rows=block_rows,
                              interpret=interpret)


_randk_oracle = jax.jit(_ref.rand_k_select_ref)


def rand_k_sparsify(v, keep_prob, seed, *,
                    block_rows: Optional[int] = None,
                    interpret: Optional[bool] = None):
    """Seeded rand-k sparsification: (v * mask, count) with
    ``mask[i] = uniform01(seed, i) < keep_prob``.  The mask is generated
    *in-kernel* from (seed, flat-index) counters (``kernels/rng.py``) —
    no materialized random array — and is bit-exact on every path, so
    dispatch can never change which coordinates ship."""
    if interpret is not None or block_rows is not None:
        it = _default_interpret() if interpret is None else interpret
        return _randk_pallas(v, keep_prob, seed, block_rows=block_rows or 64,
                             interpret=it)
    shape, dt = v.shape, v.dtype
    cands, default = _blocked_candidates(
        v.size,
        lambda b, it: _randk_pallas(_z(shape, dt), 0.01, 1,
                                    block_rows=b, interpret=it),
        lambda: _randk_oracle(_z(shape, dt), 0.01, 1))
    label = _decide("rand_k_sparsify", v.size, dt, cands, default)
    if label == "oracle":
        return _randk_oracle(v, keep_prob, seed)
    mode, b = _parse_label(label)
    return _randk_pallas(v, keep_prob, seed, block_rows=b,
                         interpret=mode == "interpret")


# ------------------------------------------------------------- neighbor mix

@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _nm_pallas(x, nbr_idx, nbr_w, self_w, *, block_rows, interpret):
    return _nm.neighbor_mix(x, nbr_idx, nbr_w, self_w,
                            block_rows=block_rows, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _nm_src_pallas(x, nbr_idx, nbr_w, self_w, src, *, block_rows,
                   interpret):
    return _nm.neighbor_mix(x, nbr_idx, nbr_w, self_w, src=src,
                            block_rows=block_rows, interpret=interpret)


_nm_oracle = jax.jit(lambda x, i, w, s: _ref.neighbor_mix_padded_ref(
    x, i, w, s))
_nm_src_oracle = jax.jit(lambda x, i, w, s, src: _ref.neighbor_mix_padded_ref(
    x, i, w, s, src))


def neighbor_mix(x, nbr_idx, nbr_w, self_w, *, src=None,
                 block_rows: Optional[int] = None,
                 interpret: Optional[bool] = None) -> jnp.ndarray:
    """Sparse gossip averaging y[k] = W[k,k]*x[k] + sum_j W[k,j]*x[j]
    over padded neighbor lists (see Topology.neighbor_arrays).  With
    ``src`` (M, N), neighbor rows are gathered from ``src`` instead of
    ``x`` — AD-PSGD's stale mixing over a flattened snapshot buffer."""
    if interpret is not None or block_rows is not None:
        it = _default_interpret() if interpret is None else interpret
        if src is None:
            return _nm_pallas(x, nbr_idx, nbr_w, self_w,
                              block_rows=block_rows or 64, interpret=it)
        return _nm_src_pallas(x, nbr_idx, nbr_w, self_w, src,
                              block_rows=block_rows or 64, interpret=it)
    K, N = x.shape
    D = nbr_idx.shape[1]
    dt = x.dtype
    zi = functools.partial(_z, (K, D))
    if src is None:
        cands, default = _blocked_candidates(
            N,
            lambda b, it: _nm_pallas(
                _z((K, N), dt), zi(np.int32), zi(np.float32),
                _z(K, np.float32), block_rows=b, interpret=it),
            lambda: _nm_oracle(_z((K, N), dt), zi(np.int32),
                               zi(np.float32), _z(K, np.float32)))
        label = _decide("neighbor_mix", x.size, dt, cands, default)
        if label == "oracle":
            return _nm_oracle(x, nbr_idx, nbr_w, self_w)
        mode, b = _parse_label(label)
        return _nm_pallas(x, nbr_idx, nbr_w, self_w, block_rows=b,
                          interpret=mode == "interpret")
    M = src.shape[0]
    cands, default = _blocked_candidates(
        N,
        lambda b, it: _nm_src_pallas(
            _z((K, N), dt), zi(np.int32), zi(np.float32),
            _z(K, np.float32), _z((M, N), src.dtype),
            block_rows=b, interpret=it),
        lambda: _nm_src_oracle(_z((K, N), dt), zi(np.int32),
                               zi(np.float32), _z(K, np.float32),
                               _z((M, N), src.dtype)))
    label = _decide("neighbor_mix_src", x.size + src.size, dt, cands,
                    default)
    if label == "oracle":
        return _nm_src_oracle(x, nbr_idx, nbr_w, self_w, src)
    mode, b = _parse_label(label)
    return _nm_src_pallas(x, nbr_idx, nbr_w, self_w, src, block_rows=b,
                          interpret=mode == "interpret")


# --------------------------------------------------------------- group norm

@functools.partial(jax.jit, static_argnames=("group_size", "eps",
                                             "interpret"))
def _gn_pallas(x, scale, bias, *, group_size, eps, interpret):
    return _gn.group_norm(x, scale, bias, group_size=group_size, eps=eps,
                          interpret=interpret)


@functools.partial(jax.jit, static_argnames=("group_size", "eps"))
def _gn_oracle(x, scale, bias, *, group_size, eps):
    return _ref.group_norm_ref(x, scale, bias, group_size=group_size,
                               eps=eps)


def group_norm(x, scale, bias, *, group_size: int = 2, eps: float = 1e-5,
               interpret: Optional[bool] = None) -> jnp.ndarray:
    if interpret is not None:
        return _gn_pallas(x, scale, bias, group_size=group_size, eps=eps,
                          interpret=interpret)
    mode = _pallas_mode()
    shape, dt = x.shape, x.dtype
    C = shape[-1]
    static = dict(group_size=group_size, eps=eps)
    cands = {
        "oracle": lambda: _gn_oracle(_z(shape, dt),
                                     _z(C, np.float32, 1.0),
                                     _z(C, np.float32), **static),
        mode: lambda: _gn_pallas(_z(shape, dt),
                                 _z(C, np.float32, 1.0),
                                 _z(C, np.float32),
                                 interpret=mode == "interpret", **static),
    }
    label = _decide("group_norm", x.size, dt, cands, mode)
    if label == "oracle":
        return _gn_oracle(x, scale, bias, **static)
    return _gn_pallas(x, scale, bias, interpret=label == "interpret",
                      **static)
