"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites work in this
CPU container; on TPU backends the compiled Mosaic path is used.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import dgc_topk as _dgc
from repro.kernels import flash_attention as _fa
from repro.kernels import gaia_select as _gaia
from repro.kernels import group_norm as _gn
from repro.kernels import neighbor_mix as _nm


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "logit_softcap", "scale", "block_q", "block_k",
    "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    logit_softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    interpret = _default_interpret() if interpret is None else interpret
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window, logit_softcap=logit_softcap,
        scale=scale, block_q=block_q, block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def gaia_select(v, w, threshold, *, block_rows: int = 64,
                interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _gaia.gaia_select(v, w, threshold, block_rows=block_rows,
                             interpret=interpret)


@functools.partial(jax.jit, static_argnames=("n_bins", "block_rows",
                                             "interpret"))
def dgc_sparsify(v, sparsity, *, n_bins: int = 256, block_rows: int = 64,
                 interpret: Optional[bool] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full DGC top-s%: histogram -> threshold -> select.
    Returns (selected, count, threshold)."""
    interpret = _default_interpret() if interpret is None else interpret
    v_max = jnp.max(jnp.abs(v)).astype(jnp.float32)
    hist = _dgc.abs_histogram(v, v_max, n_bins=n_bins,
                              block_rows=block_rows, interpret=interpret)
    t = _dgc.threshold_from_histogram(hist, v_max, sparsity)
    sel, cnt = _dgc.dgc_select(v, t, block_rows=block_rows,
                               interpret=interpret)
    return sel, cnt, t


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def neighbor_mix(x, nbr_idx, nbr_w, self_w, *, src=None,
                 block_rows: int = 64,
                 interpret: Optional[bool] = None) -> jnp.ndarray:
    """Sparse gossip averaging y[k] = W[k,k]*x[k] + sum_j W[k,j]*x[j]
    over padded neighbor lists (see Topology.neighbor_arrays).  With
    ``src`` (M, N), neighbor rows are gathered from ``src`` instead of
    ``x`` — AD-PSGD's stale mixing over a flattened snapshot buffer."""
    interpret = _default_interpret() if interpret is None else interpret
    return _nm.neighbor_mix(x, nbr_idx, nbr_w, self_w, src=src,
                            block_rows=block_rows, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("group_size", "eps",
                                             "interpret"))
def group_norm(x, scale, bias, *, group_size: int = 2, eps: float = 1e-5,
               interpret: Optional[bool] = None) -> jnp.ndarray:
    interpret = _default_interpret() if interpret is None else interpret
    return _gn.group_norm(x, scale, bias, group_size=group_size, eps=eps,
                          interpret=interpret)
