"""Pure-jnp oracles for every Pallas kernel.  Tests assert_allclose the
kernels (interpret=True on CPU) against these — and off-TPU the dispatch
layer (``kernels/dispatch.py``) routes production calls to whichever of
{Pallas, oracle} measured faster, so these are first-class execution
paths, not just test fixtures."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import rng


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True,
                        window: Optional[int] = None,
                        logit_softcap: Optional[float] = None,
                        scale: Optional[float] = None) -> jnp.ndarray:
    """q: (B, H, Tq, D); k/v: (B, H, Tk, D).  Materialized-softmax oracle."""
    B, H, Tq, D = q.shape
    Tk = k.shape[2]
    scale = D ** -0.5 if scale is None else scale
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if logit_softcap is not None:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    q_pos = jnp.arange(Tq) + (Tk - Tq)
    k_pos = jnp.arange(Tk)
    valid = jnp.ones((Tq, Tk), bool)
    if causal:
        valid &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        valid &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(valid[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def gaia_select_ref(v: jnp.ndarray, w: jnp.ndarray, threshold: float
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Significance filter |v| > T*|w|.  Returns (selected, n_selected)."""
    mask = jnp.abs(v) > threshold * jnp.abs(w)
    return v * mask.astype(v.dtype), jnp.sum(mask).astype(jnp.int32)


def dgc_threshold_ref(v: jnp.ndarray, sparsity: float) -> jnp.ndarray:
    """Exact top-(1-sparsity) magnitude threshold (quantile)."""
    return jnp.quantile(jnp.abs(v).reshape(-1).astype(jnp.float32), sparsity)


def dgc_select_ref(v: jnp.ndarray, threshold: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    mask = jnp.abs(v) > threshold
    return v * mask.astype(v.dtype), jnp.sum(mask).astype(jnp.int32)


def abs_histogram_ref(v: jnp.ndarray, n_bins: int, v_max: jnp.ndarray
                      ) -> jnp.ndarray:
    """Histogram of |v| over [0, v_max] with n_bins linear bins (clamped)."""
    a = jnp.abs(v.reshape(-1)).astype(jnp.float32)
    idx = jnp.clip((a / jnp.maximum(v_max, 1e-30) * n_bins).astype(jnp.int32),
                   0, n_bins - 1)
    return jnp.zeros((n_bins,), jnp.int32).at[idx].add(1)


def dgc_sparsify_ref(v: jnp.ndarray, sparsity: jnp.ndarray, *,
                     n_bins: int = 256
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Histogram-thresholded DGC oracle — the *same* quantization family
    as the kernel path (``dgc_topk``), so dispatch may route to either
    without moving the threshold: (selected, count, threshold).

    The bin is found by bisection on cumulative counts (log2(n_bins)
    compare-and-sum passes) instead of materializing the histogram —
    XLA's CPU scatter-add makes a full 1M-element histogram ~10x slower
    than 8 streaming passes.  The predicate ``cum[b] >= target`` (in
    float32, like ``threshold_from_histogram``'s searchsorted) is
    monotone in ``b``, so bisection lands on the identical bin and the
    threshold is bit-equal to the kernel path's."""
    a = jnp.abs(v.reshape(-1)).astype(jnp.float32)
    v_max = jnp.max(a)
    idx = jnp.clip((a / jnp.maximum(v_max, 1e-30) * n_bins
                    ).astype(jnp.int32), 0, n_bins - 1)
    target = sparsity * jnp.float32(a.size)
    lo = jnp.int32(0)
    hi = jnp.int32(n_bins - 1)
    for _ in range(max(n_bins.bit_length() - 1, 1)):
        mid = (lo + hi) // 2
        reached = jnp.sum(idx <= mid).astype(jnp.float32) >= target
        hi = jnp.where(reached, mid, hi)
        lo = jnp.where(reached, lo, mid + 1)
    t = (hi.astype(jnp.float32) + 1.0) / n_bins * v_max
    sel, cnt = dgc_select_ref(v, t)
    return sel, cnt, t


def rand_k_select_ref(v: jnp.ndarray, keep_prob: jnp.ndarray,
                      seed) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Materialized-generator baseline for the in-kernel seeded rand-k
    mask: uniforms at every flat index from the same (seed, counter)
    hash, so the mask is bit-identical to the kernel's."""
    n = v.size
    seed = jnp.asarray(seed, jnp.int32).astype(jnp.uint32)
    u = rng.uniform01(seed, jnp.arange(n, dtype=jnp.int32))
    mask = (u < keep_prob).reshape(v.shape)
    return v * mask.astype(v.dtype), jnp.sum(mask).astype(jnp.int32)


def neighbor_mix_padded_ref(x: jnp.ndarray, nbr_idx: jnp.ndarray,
                            nbr_w: jnp.ndarray, self_w: jnp.ndarray,
                            src: Optional[jnp.ndarray] = None
                            ) -> jnp.ndarray:
    """Dense oracle over the kernel's own padded-neighbor operands: the
    runtime (K, D) index/weight lists are scattered into a dense mixing
    matrix and applied as one matmul (padding rows carry weight 0, so
    they scatter nothing).  With ``src`` this is the stale-mixing
    gather, self term on ``x`` — same operands as the kernel."""
    K = x.shape[0]
    rows = src if src is not None else x
    W = jnp.zeros((K, rows.shape[0]), jnp.float32).at[
        jnp.arange(K)[:, None], nbr_idx].add(nbr_w)
    out = jnp.matmul(W, rows.astype(jnp.float32)) \
        + self_w[:, None] * x.astype(jnp.float32)
    return out.astype(x.dtype)


def neighbor_mix_ref(x: jnp.ndarray, mixing: jnp.ndarray) -> jnp.ndarray:
    """Dense gossip-averaging oracle: ``W @ X`` with the full (K, K)
    mixing matrix.  x: (K, N) stacked per-node vectors."""
    return jnp.matmul(mixing.astype(jnp.float32),
                      x.astype(jnp.float32)).astype(x.dtype)


def neighbor_mix_src_ref(x: jnp.ndarray, src: jnp.ndarray,
                         nbr_idx: jnp.ndarray, nbr_w: jnp.ndarray,
                         self_w: jnp.ndarray) -> jnp.ndarray:
    """Materialized-gather oracle for the stale-mixing variant: neighbor
    rows pulled from ``src`` (M, N), self term from ``x`` (K, N)."""
    gathered = src.astype(jnp.float32)[nbr_idx]        # (K, D, N)
    out = self_w[:, None] * x.astype(jnp.float32) \
        + jnp.sum(nbr_w[..., None] * gathered, axis=1)
    return out.astype(x.dtype)


def group_norm_ref(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, *,
                   group_size: int, eps: float = 1e-5) -> jnp.ndarray:
    """x: (B, H, W, C) NHWC; groups of ``group_size`` adjacent channels."""
    B, H, W, C = x.shape
    G = C // group_size
    xg = x.astype(jnp.float32).reshape(B, H * W, G, group_size)
    mu = jnp.mean(xg, axis=(1, 3), keepdims=True)
    var = jnp.var(xg, axis=(1, 3), keepdims=True)
    y = (xg - mu) * jax.lax.rsqrt(var + eps)
    y = y.reshape(B, H, W, C) * scale + bias
    return y.astype(x.dtype)
