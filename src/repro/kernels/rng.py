"""Counter-based seeded RNG shared by the Pallas kernels, their jnp
oracles, and the topology link model.

Materializing full-size random arrays on the host and shipping them into
a kernel doubles the HBM traffic of every stochastic masking pass and
makes the draw order part of the call site.  Instead every random number
here is a *pure function of (key, counter)* — a 32-bit avalanche hash
(Wellons' lowbias32) of a per-stream key and a per-element counter — so
a kernel can generate exactly the numbers it needs for its block from
``(seed, block-start + lane offsets)`` with no input operand, and any
host-side consumer (the generator "baseline", the link model) reproduces
the same stream element-by-element, in any order.

Guarantees:

* ``uniform_bits``/``uniform01`` are **bit-exact** across the numpy
  path, the jnp path, and the in-kernel path (integer ops only; the
  float conversion keeps 24 bits, exact in float32).  Mask/select
  decisions derived from them are therefore identical everywhere —
  the property the dispatch-equivalence tests assert.
* ``normal01`` (Box–Muller over two counter uniforms) is deterministic
  per library; across numpy/jnp it agrees to float ulps (transcendental
  libm vs XLA), which is why only *uniform-derived* decisions are used
  in kernels and the normal path is host-side (link jitter) only.

Not cryptographic — a statistical-quality hash for masks and link
draws, in the spirit of the in-kernel batched-RNG technique from
Leonana69/pie's ``rand_mv.py`` (Triton weights generated inside the
kernel, bit-exact vs a generator baseline).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

_M32 = 0xFFFFFFFF
_GOLD = 0x9E3779B9          # 2**32 / golden ratio: stream-key spreading
_INV24 = float(2.0 ** -24)  # 24-bit mantissa uniform step


def _xp(*arrays):
    """numpy or jnp, by argument type (tracers are jax.Array too)."""
    return jnp if any(isinstance(a, jax.Array) for a in arrays) else np


def _mix(x, u32):
    """lowbias32: full-avalanche 32-bit hash (x is a uint32 array)."""
    x = x ^ (x >> u32(16))
    x = x * u32(0x7FEB352D)
    x = x ^ (x >> u32(15))
    x = x * u32(0x846CA68B)
    x = x ^ (x >> u32(16))
    return x


def _mix_py(x: int) -> int:
    """Python-int twin of :func:`_mix` (host-side key folding)."""
    x &= _M32
    x ^= x >> 16
    x = (x * 0x7FEB352D) & _M32
    x ^= x >> 15
    x = (x * 0x846CA68B) & _M32
    x ^= x >> 16
    return x


def fold_key(*parts: int) -> int:
    """Fold any number of integer key components (seed, tag, edge ids,
    ...) into one uint32 stream key.  Order-sensitive, avalanche-mixed
    per component, so (seed, 0, 1) and (seed, 1, 0) are independent."""
    k = 0
    for p in parts:
        k = _mix_py((k * _GOLD + (int(p) & _M32)) & _M32)
    return k


def fold_keys(key: int, *parts) -> np.ndarray:
    """Vectorized continuation of :func:`fold_key`: fold integer *array*
    components into an existing scalar key, elementwise — bit-equal to
    calling ``fold_key(..., parts[0][k], parts[1][k], ...)`` per element
    (uint32 arithmetic wraps exactly like the ``& _M32`` masking).
    Host-side (numpy) only; used to key whole edge sets at once."""
    u32 = np.uint32
    k = None
    for p in parts:
        p = np.asarray(p).astype(u32)
        if k is None:
            # first array part: fold the scalar prefix in exact ints
            k = _mix(u32((int(key) * _GOLD) & _M32) + p, u32)
        else:
            k = _mix(k * u32(_GOLD) + p, u32)
    return k if k is not None else np.asarray(int(key), u32)


def uniform_bits(key, ctr):
    """uint32 hash of (key, counter) — the raw stream.  ``key`` scalar
    (or broadcastable array), ``ctr`` any integer array; numpy in/out
    for numpy inputs, jnp for jnp/tracer inputs (kernel-safe)."""
    xp = _xp(key, ctr)
    u32 = xp.uint32
    key = xp.asarray(key).astype(u32)
    ctr = xp.asarray(ctr).astype(u32)
    return _mix(ctr ^ (key * u32(_GOLD)), u32)


def uniform01(key, ctr):
    """float32 uniforms in [0, 1) from (key, counter) — bit-exact across
    numpy / jnp / in-kernel (top 24 bits of the hash, exact in f32)."""
    xp = _xp(key, ctr)
    bits = uniform_bits(key, ctr)
    return (bits >> xp.uint32(8)).astype(xp.float32) * xp.float32(_INV24)


def normal01(key, ctr, dtype=None):
    """Standard normals via Box–Muller over counters (2*ctr, 2*ctr+1).
    Deterministic per library; numpy path (float64 by default) is what
    the link model replays."""
    xp = _xp(key, ctr)
    ctr = xp.asarray(ctr)
    dtype = dtype or (np.float64 if xp is np else jnp.float32)
    u1 = uniform01(key, ctr * 2).astype(dtype)
    u2 = uniform01(key, ctr * 2 + 1).astype(dtype)
    # 1 - u1 in (0, 1]: log never sees 0
    r = xp.sqrt(-2.0 * xp.log1p(-u1))
    return r * xp.cos(dtype(2.0 * np.pi) * u2)
