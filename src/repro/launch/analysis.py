"""Roofline-term extraction from compiled dry-run artifacts.

  compute term    = HLO_FLOPs   / (chips * peak_FLOP/s)
  memory term     = HLO_bytes   / (chips * HBM_bw)
  collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are
parsed from the optimized HLO text (all-gather / all-reduce / reduce-scatter
/ all-to-all / collective-permute operand sizes).

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-reduce.5 = f32[2,1024,512]{2,1,0} all-reduce(...)
#        ROOT %x = (f32[8]{0}, f32[4]{0}) all-gather-start(...)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)(?:-start)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output bytes per collective kind over the optimized HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue                      # avoid double count of async pairs
        m = _OP_RE.search(line)
        if not m:
            continue
        out[m.group(2)] += _shape_bytes(m.group(1))
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    hlo_gflops: float                    # total, all chips
    hlo_gbytes: float
    coll_gbytes: float
    coll_breakdown: Dict[str, float]
    t_compute: float                     # seconds
    t_memory: float
    t_collective: float
    bottleneck: str
    model_gflops: float                  # 6*N*D (or 6*N_active*D)
    useful_ratio: float                  # model_flops / hlo_flops
    bytes_per_device: Optional[float] = None
    note: str = ""

    def table_row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.mesh} | "
                f"{self.t_compute*1e3:.2f} | {self.t_memory*1e3:.2f} | "
                f"{self.t_collective*1e3:.2f} | {self.bottleneck} | "
                f"{self.useful_ratio:.2f} |")


def derive_roofline(arch: str, shape: str, mesh_name: str, n_chips: int,
                    cost: Dict, hlo_text: str, model_flops: float,
                    bytes_per_device: Optional[float] = None,
                    note: str = "") -> Roofline:
    # trip-count-aware per-device analysis (XLA's cost_analysis visits
    # while bodies once — useless for scan-over-layers programs)
    from repro.analysis import hlo as hlo_analysis
    hc = hlo_analysis.analyze(hlo_text)
    flops = hc.flops
    byts = hc.bytes_accessed
    colls = hc.collective_bytes
    coll_total = float(hc.coll_total)
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_x = coll_total / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    per_dev_model_flops = model_flops / n_chips
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, n_chips=n_chips,
        hlo_gflops=flops / 1e9, hlo_gbytes=byts / 1e9,
        coll_gbytes=coll_total / 1e9,
        coll_breakdown={k: v / 1e9 for k, v in colls.items()},
        t_compute=t_c, t_memory=t_m, t_collective=t_x,
        bottleneck=bottleneck,
        model_gflops=per_dev_model_flops / 1e9,
        useful_ratio=(per_dev_model_flops / flops) if flops else 0.0,
        bytes_per_device=bytes_per_device,
        note=note)


def model_flops_estimate(cfg, shape, mode: str) -> float:
    """MODEL_FLOPS = 6*N*D for training, 2*N*D for inference
    (N = active params, D = tokens processed)."""
    n_active = cfg.n_active_params()
    if mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch                      # one token per request
    return 2.0 * n_active * tokens
