import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
combination on the production meshes, record memory / cost / collective
analysis for the roofline report.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
      --shape train_4k [--multi-pod] [--strategy gaia] [--out report.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import sys
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import CommConfig, INPUT_SHAPES
from repro.configs.registry import ARCH_IDS, get_config
from repro.launch import analysis
from repro.launch.mesh import make_production_mesh, n_pods as mesh_n_pods
from repro.launch.sharding import (batch_shardings, cache_shardings,
                                   param_shardings, replicated)
from repro.launch.specs import input_specs
from repro.launch.steps import (make_prefill_step, make_serve_step,
                                make_train_step, train_state_shape)
from repro.models.model import init_cache, init_model
from repro.models.shard_hints import activation_sharding

SDS = jax.ShapeDtypeStruct


def _with_shardings(shapes, shardings):
    return jax.tree_util.tree_map(
        lambda sh, ns: SDS(sh.shape, sh.dtype, sharding=ns),
        shapes, shardings)


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               strategy: str = "gaia", chunk: int = 512,
               remat: bool = True, verbose: bool = True,
               return_hlo: bool = False) -> Dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    pods = mesh_n_pods(mesh)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    comm = CommConfig(strategy=strategy)
    long_mode = shape_name == "long_500k"

    with mesh, activation_sharding(mesh):
        if shape.mode == "train":
            state_shape = train_state_shape(cfg, comm, pods)
            state_shardings = {
                k: param_shardings(v, mesh, stacked=True)
                for k, v in state_shape.items()}
            batch_shapes = input_specs(cfg, shape_name, n_pods=pods)
            b_shardings = batch_shardings(batch_shapes, mesh,
                                          pod_stacked=True)
            step = make_train_step(cfg, comm, remat=remat, chunk=chunk)
            jitted = jax.jit(
                step,
                in_shardings=(state_shardings, b_shardings, None),
                donate_argnums=(0,))
            args = (_with_shardings(state_shape, state_shardings),
                    _with_shardings(batch_shapes, b_shardings),
                    SDS((), jnp.int32))
        elif shape.mode == "prefill":
            p_shape = jax.eval_shape(
                lambda: init_model(jax.random.PRNGKey(0), cfg))
            p_shardings = param_shardings(p_shape, mesh)
            batch_shapes = input_specs(cfg, shape_name)
            b_shardings = batch_shardings(batch_shapes, mesh,
                                          pod_stacked=False)
            step = make_prefill_step(cfg, chunk=chunk)
            jitted = jax.jit(step, in_shardings=(p_shardings, b_shardings))
            args = (_with_shardings(p_shape, p_shardings),
                    _with_shardings(batch_shapes, b_shardings))
        else:  # decode
            p_shape = jax.eval_shape(
                lambda: init_model(jax.random.PRNGKey(0), cfg))
            p_shardings = param_shardings(p_shape, mesh)
            cache_shape = jax.eval_shape(
                lambda: init_cache(cfg, shape.global_batch, shape.seq_len,
                                   long_mode))
            c_shardings = cache_shardings(
                cache_shape, mesh, batch_sharded=shape.global_batch >= 8)
            batch_shapes = input_specs(cfg, shape_name)
            b_shardings = batch_shardings(batch_shapes, mesh,
                                          pod_stacked=False)
            step = make_serve_step(cfg)
            jitted = jax.jit(
                step, in_shardings=(p_shardings, c_shardings, b_shardings),
                donate_argnums=(1,))
            args = (_with_shardings(p_shape, p_shardings),
                    _with_shardings(cache_shape, c_shardings),
                    _with_shardings(batch_shapes, b_shardings))

        lowered = jitted.lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()

    n_chips = mesh.devices.size
    per_dev_bytes = None
    mem_summary = {}
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                mem_summary[attr] = int(v)
        per_dev_bytes = (mem_summary.get("argument_size_in_bytes", 0)
                         + mem_summary.get("temp_size_in_bytes", 0)
                         - mem_summary.get("alias_size_in_bytes", 0))
    mf = analysis.model_flops_estimate(cfg, shape, shape.mode)
    roof = analysis.derive_roofline(
        arch, shape_name, mesh_name, n_chips, cost or {}, hlo, mf,
        bytes_per_device=per_dev_bytes)
    report = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "mode": shape.mode, "strategy": strategy if shape.mode == "train"
        else None,
        "ok": True,
        "memory": mem_summary,
        "cost": {k: float(v) for k, v in (cost or {}).items()
                 if isinstance(v, (int, float))},
        "roofline": {
            "t_compute_ms": roof.t_compute * 1e3,
            "t_memory_ms": roof.t_memory * 1e3,
            "t_collective_ms": roof.t_collective * 1e3,
            "bottleneck": roof.bottleneck,
            "hlo_gflops_per_dev": roof.hlo_gflops,
            "hlo_gbytes_per_dev": roof.hlo_gbytes,
            "coll_gbytes_per_dev": roof.coll_gbytes,
            "coll_breakdown_gb": roof.coll_breakdown,
            "model_gflops_per_dev": roof.model_gflops,
            "useful_ratio": roof.useful_ratio,
        },
    }
    if return_hlo:
        report["_hlo"] = hlo
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} on {mesh_name}: OK  "
              f"bottleneck={roof.bottleneck} "
              f"t=(c {roof.t_compute*1e3:.2f} / m {roof.t_memory*1e3:.2f} / "
              f"x {roof.t_collective*1e3:.2f}) ms  "
              f"useful={roof.useful_ratio:.2f}")
        if mem_summary:
            print(f"         memory: {json.dumps(mem_summary)}")
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--strategy", default="gaia",
                    choices=["bsp", "gaia", "fedavg", "dgc"])
    ap.add_argument("--chunk", type=int, default=512)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--outdir", default=None,
                    help="per-combo JSON dir; existing results are skipped")
    ap.add_argument("--save-hlo", action="store_true",
                    help="gzip the partitioned HLO next to each JSON")
    args = ap.parse_args(argv)

    combos = []
    if args.all:
        for a in ARCH_IDS:
            for s in INPUT_SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    reports, failures = [], []
    for a, s in combos:
        tag = f"{a}__{s}__{'multi' if args.multi_pod else 'single'}"
        path = os.path.join(args.outdir, tag + ".json") if args.outdir else None
        if path and os.path.exists(path):
            with open(path) as f:
                rep = json.load(f)
            (reports if rep.get("ok") else failures).append(rep)
            print(f"[dryrun] {tag}: cached ({'ok' if rep.get('ok') else 'FAILED'})")
            continue
        try:
            rep = dryrun_one(
                a, s, multi_pod=args.multi_pod, strategy=args.strategy,
                chunk=args.chunk, remat=not args.no_remat,
                return_hlo=args.save_hlo)
            if args.save_hlo and "_hlo" in rep:
                import gzip
                if args.outdir:
                    os.makedirs(args.outdir, exist_ok=True)
                    with gzip.open(os.path.join(
                            args.outdir, tag + ".hlo.gz"), "wt") as f:
                        f.write(rep.pop("_hlo"))
                else:
                    rep.pop("_hlo")
            reports.append(rep)
        except Exception as e:  # noqa: BLE001 — report and continue
            traceback.print_exc()
            rep = {"arch": a, "shape": s, "ok": False,
                   "error": f"{type(e).__name__}: {e}"}
            failures.append(rep)
        if path:
            os.makedirs(args.outdir, exist_ok=True)
            with open(path, "w") as f:
                json.dump(rep, f, indent=1)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(reports + failures, f, indent=1)
    print(f"[dryrun] {len(reports)} ok, {len(failures)} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
