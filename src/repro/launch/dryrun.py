import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
combination on the production meshes, record memory / cost / collective
analysis for the roofline report.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
      --shape train_4k [--multi-pod] [--strategy gaia] [--out report.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import sys
import traceback
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analysis import graph_audit
from repro.analysis import hlo as hlo_analysis
from repro.configs.base import CommConfig, FabricConfig, INPUT_SHAPES
from repro.configs.registry import ARCH_IDS, get_config
from repro.launch import analysis
from repro.launch.mesh import (devices_per_pod, make_production_mesh,
                               n_pods as mesh_n_pods)
from repro.launch.sharding import (batch_shardings, cache_shardings,
                                   param_shardings,
                                   train_state_shardings)
from repro.launch.specs import input_specs
from repro.launch.steps import (GOSSIP_STRATEGIES, cache_shape,
                                gossip_operands, make_prefill_step,
                                make_serve_step, make_train_step,
                                param_shape, train_state_shape)
from repro.models.shard_hints import activation_sharding
from repro.topology.graphs import build_demo_schedule

SDS = jax.ShapeDtypeStruct

STRATEGIES = ("bsp", "gaia", "fedavg", "dgc") + GOSSIP_STRATEGIES

#: every fabric a gossip strategy can ride — the topology half of the
#: audit matrix (STRATEGIES x GOSSIP_TOPOLOGIES, non-gossip strategies
#: compile the same graph for every fabric so they sweep once)
GOSSIP_TOPOLOGIES = ("ring", "torus", "full", "random", "geo-wan",
                     "dcliques", "tv-dcliques", "random-matching")

#: the all-combos sweep target: the reduced smoke config on the tiny
#: forced-host-device multi-pod mesh CI compiles (2 pods x 2 data x
#: 2 model) — same combo family the dryrun smoke has gated since PR 4
SWEEP_ARCH = "qwen3-0.6b"
SWEEP_SHAPE = "train_4k"
SWEEP_MESH = "2,2,2"

#: which graph-audit findings abort a dryrun: "gossip" (default — hard
#: incidents on the gossip exchange path), "all" (--strict-audit: any
#: strategy, serve/prefill included), "none" (collect only; the
#: analysis CLI applies its own baseline semantics)
AUDIT_FAIL_MODES = ("gossip", "all", "none")


def iter_combos(include_serve: bool = True):
    """The audit matrix: ``(shape_name, strategy, topology)`` rows —
    every strategy x topology combo the launch path can compile, plus
    the prefill/serve graphs (strategy/topology ``None`` there)."""
    for s in STRATEGIES:
        for t in (GOSSIP_TOPOLOGIES if s in GOSSIP_STRATEGIES
                  else (None,)):
            yield (SWEEP_SHAPE, s, t)
    if include_serve:
        yield ("prefill_32k", None, None)
        yield ("decode_32k", None, None)


def _with_shardings(shapes, shardings):
    return jax.tree_util.tree_map(
        lambda sh, ns: SDS(sh.shape, sh.dtype, sharding=ns),
        shapes, shardings)


def _parse_mesh(spec: Optional[str]):
    if not spec:
        return None
    dims = tuple(int(d) for d in spec.split(","))
    if len(dims) not in (2, 3):
        raise ValueError(
            f"--mesh {spec!r}: expected 'pod,data,model' (3 dims) or "
            "'data,model' (2 dims)")
    axes = {3: ("pod", "data", "model"), 2: ("data", "model")}[len(dims)]
    return jax.make_mesh(dims, axes)


def build_step(arch: str, shape_name: str, *,
               strategy: Optional[str] = "gaia",
               topology: Optional[str] = "ring",
               staleness: Optional[int] = None, max_staleness: int = 2,
               chunk: int = 512, remat: bool = True,
               reduced: bool = False, mesh=None) -> Tuple:
    """Construct one combo's ``(step, args, jit_kwargs)`` — the single
    builder behind both graph passes: ``dryrun_one`` jits + lowers +
    compiles it (post-XLA HLO audit), the jaxpr sweep
    (:func:`trace_combo` / ``repro.analysis.jaxpr_audit``) runs
    ``jax.make_jaxpr`` on the raw step (pre-lowering audit).  Must be
    called inside ``with mesh, activation_sharding(mesh)``.

    ``strategy``/``topology`` may be ``None`` for serve-side shapes
    (prefill/decode), where no communication strategy applies."""
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    shape = INPUT_SHAPES[shape_name]
    pods = mesh_n_pods(mesh)
    comm = CommConfig(strategy=strategy or "bsp",
                      fabric=FabricConfig(topology=topology or "ring"),
                      max_staleness=max_staleness)
    long_mode = shape_name == "long_500k"

    if shape.mode == "train":
        state_shape = train_state_shape(cfg, comm, pods)
        state_shardings = train_state_shardings(state_shape, mesh)
        batch_shapes = input_specs(cfg, shape_name, n_pods=pods)
        b_shardings = batch_shardings(batch_shapes, mesh,
                                      pod_stacked=True)
        step = make_train_step(cfg, comm, mesh=mesh, remat=remat,
                               chunk=chunk)
        args = (_with_shardings(state_shape, state_shardings),
                _with_shardings(batch_shapes, b_shardings),
                SDS((), jnp.int32))
        in_sh: Tuple = (state_shardings, b_shardings, None)
        if strategy in GOSSIP_STRATEGIES:
            # round-0 operands of the real fabric (label-aware
            # builders get the synthetic full-skew histogram): the
            # values are runtime operands, so one compile serves the
            # whole schedule
            sched = build_demo_schedule(topology, pods)
            args += (gossip_operands(
                sched, 0,
                staleness=(max_staleness if staleness is None
                           else staleness)
                if strategy == "adpsgd" else None,
                max_staleness=max_staleness),)
            in_sh += (None,)
        return step, args, {"in_shardings": in_sh,
                            "donate_argnums": (0,)}
    if shape.mode == "prefill":
        p_shape = param_shape(cfg)
        p_shardings = param_shardings(p_shape, mesh)
        batch_shapes = input_specs(cfg, shape_name)
        b_shardings = batch_shardings(batch_shapes, mesh,
                                      pod_stacked=False)
        step = make_prefill_step(cfg, chunk=chunk)
        args = (_with_shardings(p_shape, p_shardings),
                _with_shardings(batch_shapes, b_shardings))
        return step, args, {"in_shardings": (p_shardings, b_shardings)}
    # decode
    p_shape = param_shape(cfg)
    p_shardings = param_shardings(p_shape, mesh)
    c_shape = cache_shape(cfg, shape.global_batch, shape.seq_len,
                          long_mode)
    c_shardings = cache_shardings(
        c_shape, mesh, batch_sharded=shape.global_batch >= 8)
    batch_shapes = input_specs(cfg, shape_name)
    b_shardings = batch_shardings(batch_shapes, mesh,
                                  pod_stacked=False)
    step = make_serve_step(cfg)
    args = (_with_shardings(p_shape, p_shardings),
            _with_shardings(c_shape, c_shardings),
            _with_shardings(batch_shapes, b_shardings))
    return step, args, {"in_shardings": (p_shardings, c_shardings,
                                         b_shardings),
                        "donate_argnums": (1,)}


def trace_combo(arch: str, shape_name: str, *,
                strategy: Optional[str] = None,
                topology: Optional[str] = None,
                staleness: Optional[int] = None, max_staleness: int = 2,
                chunk: int = 512, remat: bool = True,
                reduced: bool = True, mesh=None):
    """Closed jaxpr of one combo's step — the pre-lowering artifact the
    jaxpr audit walks.  Never invokes XLA: tracing the whole audit
    matrix costs less than compiling one combo."""
    mesh = mesh or make_production_mesh(multi_pod=True)
    with mesh, activation_sharding(mesh):
        step, args, _ = build_step(
            arch, shape_name, strategy=strategy, topology=topology,
            staleness=staleness, max_staleness=max_staleness,
            chunk=chunk, remat=remat, reduced=reduced, mesh=mesh)
        return jax.make_jaxpr(step)(*args)


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               strategy: Optional[str] = "gaia",
               topology: Optional[str] = "ring",
               staleness: Optional[int] = None, max_staleness: int = 2,
               chunk: int = 512, remat: bool = True, verbose: bool = True,
               reduced: bool = False, mesh=None,
               return_hlo: bool = False,
               audit_fail: str = "gossip") -> Dict:
    if audit_fail not in AUDIT_FAIL_MODES:
        raise ValueError(
            f"audit_fail {audit_fail!r}: expected one of "
            f"{AUDIT_FAIL_MODES}")
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    shape = INPUT_SHAPES[shape_name]
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    pods = mesh_n_pods(mesh)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)

    with mesh, activation_sharding(mesh):
        step, args, jit_kwargs = build_step(
            arch, shape_name, strategy=strategy, topology=topology,
            staleness=staleness, max_staleness=max_staleness,
            chunk=chunk, remat=remat, reduced=reduced, mesh=mesh)
        jitted = jax.jit(step, **jit_kwargs)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):   # older jaxlib: one per device
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()

    n_chips = mesh.devices.size
    per_dev_bytes = None
    mem_summary = {}
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                mem_summary[attr] = int(v)
        per_dev_bytes = (mem_summary.get("argument_size_in_bytes", 0)
                         + mem_summary.get("temp_size_in_bytes", 0)
                         - mem_summary.get("alias_size_in_bytes", 0))
    mf = analysis.model_flops_estimate(cfg, shape, shape.mode)
    roof = analysis.derive_roofline(
        arch, shape_name, mesh_name, n_chips, cost or {}, hlo, mf,
        bytes_per_device=per_dev_bytes)
    pod_exchange = None
    if shape.mode == "train" and pods > 1:
        # where the cross-pod traffic flows: gossip must be pure pod-axis
        # collective-permutes; bsp/gaia/dgc show up as cross-pod reduces
        pex = hlo_analysis.pod_exchange_report(hlo, devices_per_pod(mesh))
        pod_exchange = {
            "permute_cross_gbytes_per_dev": pex.permute_cross_bytes / 1e9,
            "permute_local_gbytes_per_dev": pex.permute_local_bytes / 1e9,
            "reduce_cross_gbytes_per_dev": pex.reduce_cross_bytes / 1e9,
            "reduce_local_gbytes_per_dev": pex.reduce_local_bytes / 1e9,
            "cross_pod_gbytes_per_dev": pex.cross_pod_bytes / 1e9,
            "pod_axis_only": pex.pod_axis_only,
            "unparsed_collectives": pex.unparsed,
        }
        if strategy in GOSSIP_STRATEGIES:
            pod_exchange["topology"] = topology
            if not pex.pod_axis_only:
                raise RuntimeError(
                    f"{strategy} exchange leaked off the pod axis: a "
                    "cross-pod collective-permute pair does not preserve "
                    "the intra-pod device coordinate")
            if pex.permute_cross_bytes <= 0:
                raise RuntimeError(
                    f"{strategy} lowered with no cross-pod "
                    "collective-permute: the gossip exchange vanished")
            # GSPMD reshard noise (e.g. replicated-table all-gathers —
            # the CI smoke carries ~0.6x permute bytes of it from the
            # reduced config's rope-table gather) may legitimately cross
            # pods, but the moment cross-pod reductions *rival* the
            # permute exchange, part of the gossip has fallen back to
            # reduction collectives; if this ever reds on a config tweak
            # rather than a real leak, compare reduce_cross against the
            # bsp baseline before loosening
            if pex.reduce_cross_bytes >= pex.permute_cross_bytes:
                raise RuntimeError(
                    f"{strategy}: cross-pod reduction bytes "
                    f"({pex.reduce_cross_bytes:.0f}) rival the permute "
                    f"exchange ({pex.permute_cross_bytes:.0f}) — the "
                    "gossip is leaking into reduction collectives")
            if pex.unparsed:
                raise RuntimeError(
                    f"{strategy}: {pex.unparsed} collective(s) the pod "
                    "report cannot classify (send/recv, broadcast, or "
                    "unparseable groups) — cross-pod byte totals would "
                    "silently understate the exchange")
    # the general graph audit (repro.analysis.graph_audit): wire
    # dtype, host callbacks, donation drift on top of the pod-axis
    # checks above — now on every mode, serve/prefill included.
    # Gossip strategies hard-fail on any finding (the bf16-widening
    # incident PR 4 fixed is exactly GA202); --strict-audit
    # (audit_fail="all") extends the hard fail to every graph.
    # pod-axis classification (GA201/GA205) and the wire-dtype rule
    # (GA202) only make sense where a gossip exchange could exist: the
    # multi-pod train graph.  Serve/prefill graphs reshard with
    # arbitrary GSPMD permutes, so there we audit host callbacks
    # (GA203) and donation drift (GA204) only.  GA201's
    # coordinate-preservation invariant is narrower still — it is a
    # contract on the *gossip* exchange; reduction-based strategies
    # (bsp/gaia/fedavg/dgc) let GSPMD reshard across pods however it
    # likes, so GA201 is scoped to GOSSIP_STRATEGIES.
    combo = f"{shape_name}/{strategy or '-'}/{topology or '-'}"
    train_graph = shape.mode == "train" and pods > 1
    ga = graph_audit.audit_hlo(
        hlo, tag=f"{arch}/{shape_name}/{strategy or shape.mode}",
        combo=combo,
        devices_per_pod=devices_per_pod(mesh) if train_graph else None,
        check_wire_dtype=train_graph,
        check_pod_axis=strategy in GOSSIP_STRATEGIES,
        expect_donation=shape.mode == "train")
    audit = ga.to_json()
    hard_fail = audit_fail == "all" or (
        audit_fail == "gossip" and shape.mode == "train"
        and strategy in GOSSIP_STRATEGIES)
    if hard_fail and ga.findings:
        raise RuntimeError(
            f"{strategy or shape.mode}: graph audit failed — "
            + "; ".join(f"{f.rule} {f.message}" for f in ga.findings))
    report = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "mode": shape.mode, "strategy": strategy if shape.mode == "train"
        else None,
        "ok": True,
        "pod_exchange": pod_exchange,
        "audit": audit,
        "memory": mem_summary,
        "cost": {k: float(v) for k, v in (cost or {}).items()
                 if isinstance(v, (int, float))},
        "roofline": {
            "t_compute_ms": roof.t_compute * 1e3,
            "t_memory_ms": roof.t_memory * 1e3,
            "t_collective_ms": roof.t_collective * 1e3,
            "bottleneck": roof.bottleneck,
            "hlo_gflops_per_dev": roof.hlo_gflops,
            "hlo_gbytes_per_dev": roof.hlo_gbytes,
            "coll_gbytes_per_dev": roof.coll_gbytes,
            "coll_breakdown_gb": roof.coll_breakdown,
            "model_gflops_per_dev": roof.model_gflops,
            "useful_ratio": roof.useful_ratio,
        },
    }
    if return_hlo:
        report["_hlo"] = hlo
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} on {mesh_name}: OK  "
              f"bottleneck={roof.bottleneck} "
              f"t=(c {roof.t_compute*1e3:.2f} / m {roof.t_memory*1e3:.2f} / "
              f"x {roof.t_collective*1e3:.2f}) ms  "
              f"useful={roof.useful_ratio:.2f}")
        if mem_summary:
            print(f"         memory: {json.dumps(mem_summary)}")
        if pod_exchange is not None:
            print(f"         cross-pod exchange: "
                  f"{pod_exchange['cross_pod_gbytes_per_dev']:.4f} GB/dev "
                  f"(permute {pod_exchange['permute_cross_gbytes_per_dev']:.4f}"
                  f" / reduce {pod_exchange['reduce_cross_gbytes_per_dev']:.4f}"
                  f", pod_axis_only={pod_exchange['pod_axis_only']})")
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--all-combos", action="store_true",
                    help="compile + graph-audit the whole audit matrix "
                         "(iter_combos): every strategy x topology "
                         "combo plus prefill/decode, reduced config on "
                         f"the {SWEEP_MESH} mesh")
    ap.add_argument("--strict-audit", action="store_true",
                    help="fail on ANY graph-audit finding, serve/"
                         "prefill graphs included (default: only "
                         "gossip strategies hard-fail)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--strategy", default="gaia", choices=list(STRATEGIES))
    ap.add_argument("--topology", default="ring",
                    help="gossip fabric over the pod set (dpsgd/adpsgd): "
                         "ring | torus | full | random | geo-wan | "
                         "dcliques | tv-dcliques | random-matching")
    ap.add_argument("--staleness", type=int, default=None,
                    help="adpsgd staleness rung (default: max-staleness)")
    ap.add_argument("--max-staleness", type=int, default=2,
                    help="adpsgd snapshot-buffer depth")
    ap.add_argument("--mesh", default=None,
                    help="override mesh shape, e.g. 2,2,2 (pod,data,model)"
                         " — CI smoke / debugging knob")
    ap.add_argument("--reduced", action="store_true",
                    help="lower the reduced() smoke config instead of the"
                         " full-size arch (CI smoke)")
    ap.add_argument("--chunk", type=int, default=512)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--outdir", default=None,
                    help="per-combo JSON dir; existing results are skipped")
    ap.add_argument("--save-hlo", action="store_true",
                    help="gzip the partitioned HLO next to each JSON")
    args = ap.parse_args(argv)
    try:
        mesh_override = _parse_mesh(args.mesh)
    except ValueError as e:
        ap.error(str(e))

    # combo rows: (arch, shape, strategy, topology)
    combos = []
    if args.all_combos:
        args.mesh = args.mesh or SWEEP_MESH
        mesh_override = mesh_override or _parse_mesh(args.mesh)
        args.reduced = True
        for sh, st, tp in iter_combos():
            combos.append((SWEEP_ARCH, sh, st, tp))
    elif args.all:
        for a in ARCH_IDS:
            for s in INPUT_SHAPES:
                combos.append((a, s, args.strategy, args.topology))
    else:
        assert args.arch and args.shape, \
            "--arch/--shape, --all, or --all-combos"
        combos = [(args.arch, args.shape, args.strategy, args.topology)]
    # no communication strategy applies to serve-side graphs
    combos = [(a, s, strat, topo) if INPUT_SHAPES[s].mode == "train"
              else (a, s, None, None) for a, s, strat, topo in combos]

    audit_fail = "all" if args.strict_audit else "gossip"

    def cfg_tag(strategy, topology):
        # the cache tag must carry every report-changing knob, or a
        # cached JSON from a different configuration is silently
        # returned as this run's result (and the gossip pod-axis
        # verification never runs)
        return "__".join(
            [strategy or "serve", "multi" if args.multi_pod else "single"]
            + ([f"mesh{args.mesh.replace(',', 'x')}"] if args.mesh else [])
            + (["reduced"] if args.reduced else [])
            + ([f"chunk{args.chunk}"] if args.chunk != 512 else [])
            + (["noremat"] if args.no_remat else [])
            + (["strict"] if args.strict_audit else [])
            + ([f"{topology}",
                f"s{args.staleness}of{args.max_staleness}"]
               if strategy in GOSSIP_STRATEGIES else []))

    reports, failures = [], []
    for a, s, strat, topo in combos:
        tag = f"{a}__{s}__{cfg_tag(strat, topo)}"
        path = os.path.join(args.outdir, tag + ".json") if args.outdir else None
        if path and os.path.exists(path):
            with open(path) as f:
                rep = json.load(f)
            (reports if rep.get("ok") else failures).append(rep)
            print(f"[dryrun] {tag}: cached ({'ok' if rep.get('ok') else 'FAILED'})")
            continue
        try:
            rep = dryrun_one(
                a, s, multi_pod=args.multi_pod, strategy=strat,
                topology=topo, staleness=args.staleness,
                max_staleness=args.max_staleness,
                reduced=args.reduced, mesh=mesh_override,
                chunk=args.chunk, remat=not args.no_remat,
                return_hlo=args.save_hlo, audit_fail=audit_fail)
            if args.save_hlo and "_hlo" in rep:
                import gzip
                if args.outdir:
                    os.makedirs(args.outdir, exist_ok=True)
                    with gzip.open(os.path.join(
                            args.outdir, tag + ".hlo.gz"), "wt") as f:
                        f.write(rep.pop("_hlo"))
                else:
                    rep.pop("_hlo")
            reports.append(rep)
        except Exception as e:  # repro-allow: RA104 — sweep driver:
            #                     record the failure row and keep going
            traceback.print_exc()
            rep = {"arch": a, "shape": s, "ok": False,
                   "error": f"{type(e).__name__}: {e}"}
            failures.append(rep)
        if path:
            os.makedirs(args.outdir, exist_ok=True)
            with open(path, "w") as f:
                json.dump(rep, f, indent=1)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(reports + failures, f, indent=1)
    print(f"[dryrun] {len(reports)} ok, {len(failures)} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
