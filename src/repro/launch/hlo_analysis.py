"""Compatibility shim: the HLO parser moved to ``repro.analysis.hlo``.

The trip-count-aware cost analysis and the pod-exchange classifier now
live in the static-analysis subsystem (``src/repro/analysis/``), where
``graph_audit`` extends them into the CI graph auditor.  Everything is
re-exported here so existing callers — launch tooling, tests, and any
external users of ``repro.launch.hlo_analysis`` — keep working
unchanged.
"""
from repro.analysis.hlo import (  # noqa: F401
    COLLECTIVES,
    Computation,
    HLOCost,
    Instr,
    PodExchange,
    analyze,
    parse_module,
    pod_exchange_report,
    # private helpers some tests/tools poke at directly
    _dus_update_bytes,
    _multiplicities,
    _parse_pairs,
    _parse_replica_groups,
    _shape_bytes,
    _shape_dims,
)
