"""Production mesh construction.

Single pod: (data=16, model=16) — 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips.  The ``pod`` axis is
the decentralized-learning *site* axis: the paper's algorithms (Gaia /
FedAvg / DGC, and the D-PSGD/AD-PSGD gossip ring) control traffic across
it, standard data+tensor parallelism runs inside each pod.

A FUNCTION (not module-level constant) so importing never touches jax
device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axes(mesh) -> tuple:
    return tuple(mesh.axis_names)


def n_pods(mesh) -> int:
    return mesh.shape["pod"] if "pod" in mesh.axis_names else 1


def devices_per_pod(mesh) -> int:
    """Chips inside one pod — the device-id stride of the ``pod`` axis
    (mesh axes are ordered pod-major), which is what the HLO pod-traffic
    check keys on."""
    return mesh.devices.size // n_pods(mesh)


def batch_axes(mesh) -> tuple:
    """Mesh axes the global batch shards over (within-pod data axis only —
    the pod axis is the explicit site dimension)."""
    return ("data",)
