import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Perf probe: compile one (arch x shape) combo and print the top
collective / HBM-byte buckets attributed by op_name — the 'profile' that
drives §Perf hypothesis generation (no real hardware; the lowered IR is
the evidence).

  PYTHONPATH=src python -m repro.launch.perf_probe --arch X --shape Y \
      [--multi-pod] [--strategy gaia] [--chunk 512] [--no-remat]
"""
import argparse
import sys

from repro.analysis import hlo as hlo_analysis


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--strategy", default="gaia")
    ap.add_argument("--chunk", type=int, default=512)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--top", type=int, default=14)
    args = ap.parse_args(argv)

    # late import so XLA_FLAGS is already set
    from repro.launch.dryrun import dryrun_one

    # reuse dryrun_one but capture the HLO for bucket analysis
    rep = dryrun_one(args.arch, args.shape, multi_pod=args.multi_pod,
                     strategy=args.strategy, chunk=args.chunk,
                     remat=not args.no_remat, verbose=True,
                     return_hlo=True)
    hc = hlo_analysis.analyze(rep["_hlo"])
    print("\n== top collective buckets (GB/device/step) ==")
    for name, b in hc.top_collectives(args.top):
        print(f"  {b/1e9:10.3f}  {name}")
    print("\n== top HBM-byte buckets (GB/device/step) ==")
    for name, b in hc.top_bytes(args.top):
        print(f"  {b/1e9:10.3f}  {name}")


if __name__ == "__main__":
    sys.exit(main())
