"""Parameter/activation sharding rules.

2D scheme inside each pod: FSDP-style sharding over ``data`` + tensor/expert
parallelism over ``model``:

- in-projections (d -> heads*hd / ff):     (data, model)   [out-dim TP]
- out-projections (heads*hd / ff -> d):    (model, data)   [in-dim TP]
- embedding (vocab, d):                    (model, data)   [vocab TP]
- MoE stacked experts (E, d, ff):          (model, data, None)  [expert par.]
- norms / biases / small vectors:          replicated
- the decentralized-site axis ``pod`` shards the *stacked replica* dimension
  that ``steps.make_train_state`` prepends.

Rules match on the flattened path string (e.g. "body/0/mixer/wq/w") plus
leaf rank, so they cover every arch family without per-model tables.
"""
from __future__ import annotations

import os
import re
from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Params = Any

# (regex, spec-builder(shape) -> PartitionSpec) — first match wins.
# Specs are written for the *unstacked* leaf; a leading axis entry is
# prepended for pod-stacked training state.
_IN_PROJ = r"(wq|wk|wv|wuq|wdq|wdkv|gate|up|in_proj|in_x|in_gate|wuq)"
_OUT_PROJ = r"(wo|down|out_proj|out)"


def _dims_ok(shape, spec) -> bool:
    return len(spec) <= len(shape)


def rule_spec(path: str, shape: Tuple[int, ...]) -> P:
    ndim = len(shape)
    if ndim <= 1 or min(shape) == 1:
        return P()                                   # scalars/vectors/norms
    # embedding / unembed
    if re.search(r"embed/table$|table$", path):
        return P("model", "data")
    if re.search(r"unembed/w$", path):
        return P("data", "model")
    # MoE stacked experts (E, d, ff) / (E, ff, d)
    if re.search(r"ffn/w_(gate|up|down)$", path) and ndim == 3:
        return P("model", "data", None)
    # MLA 3D up-projection (r, h, nope+v): shard heads
    if re.search(r"wukv$", path) and ndim == 3:
        return P(None, "model", None)
    # conv kernels (K, Ch): shard channels
    if re.search(r"conv_w$", path) and ndim == 2:
        return P(None, "model")
    # output projections: TP on the input dim
    if re.search(_OUT_PROJ + r"/w$", path) and ndim == 2:
        return P("model", "data")
    # input projections and everything else 2D: TP on the output dim
    if ndim == 2:
        return P("data", "model")
    return P()


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _clamp_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding on dims the mesh can't divide (tiny reduced configs)."""
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        size = np.prod([mesh.shape[a] for a in
                        (ax if isinstance(ax, tuple) else (ax,))])
        out.append(ax if dim % size == 0 and dim >= size else None)
    return P(*out)


def param_shardings(params_shape: Params, mesh: Mesh, *,
                    stacked: bool = False,
                    snap_stacked: bool = False) -> Params:
    """NamedSharding pytree for a params(-shaped) tree.  ``stacked``: the
    tree has a prepended replica dimension (pod-site stacking in training
    state) — sharded over ``pod`` when the mesh has that axis.
    ``snap_stacked``: the tree additionally carries a staleness-slot
    dimension *before* the pod axis (adpsgd's bounded-staleness snapshot
    buffer, leaves (max_staleness+1, n_pods, ...)) — never sharded."""
    stack_axis = "pod" if (stacked and "pod" in mesh.axis_names) else None
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    out = []
    for path, leaf in flat:
        ps = _path_str(path)
        shape = leaf.shape
        segs = ps.split("/")
        # scan-stacked layer cycles carry a leading cycle axis
        cycle_stacked = "body" in segs or "layers" in segs
        lead: Tuple = ()
        if snap_stacked:
            lead += (None,)
            shape = shape[1:]
        if stacked:
            lead += (stack_axis,)
            shape = shape[1:]
        if cycle_stacked:
            lead += (None,)
            shape = shape[1:]
        base = rule_spec(ps, shape)
        spec = P(*lead, *tuple(base))
        spec = _clamp_spec(spec, leaf.shape, mesh)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def train_state_shardings(state_shape: Params, mesh: Mesh) -> Params:
    """NamedShardings for the full launch train state (one call site for
    every backend consumer): every entry is pod-stacked; adpsgd's
    ``snaps`` carries an extra unsharded snapshot-slot axis in front."""
    return {k: param_shardings(v, mesh, stacked=True,
                               snap_stacked=(k == "snaps"))
            for k, v in state_shape.items()}


def cache_shardings(cache_shape: Params, mesh: Mesh, *,
                    batch_sharded: bool) -> Params:
    """Decode-cache shardings.  KV caches are (B, L, H, hd) (+ an optional
    leading stacked-cycle axis).  When the batch is big enough it shards
    over ``data``; for global_batch=1 (long_500k) the cache *length* dim
    shards over ``data`` instead (sequence sharding)."""
    def spec_for(path: str, shape) -> P:
        nd = len(shape)
        stacked = path.startswith("body")        # leading cycle axis
        off = 1 if stacked else 0
        dims = [None] * nd
        if "pos" in path:                        # (B, L) int positions
            if batch_sharded:
                dims[off] = "data"
            elif nd - off >= 2:
                dims[off + 1] = "data"
            return P(*dims)
        if nd - off >= 3:                        # kv / ckv / conv / ssd
            if batch_sharded:
                dims[off] = "data"
                # attention caches (k/v/ckv/krope): shard LENGTH over model
                # (flash-decode); ssm/conv states: shard channel/head dims
                if not os.environ.get("REPRO_BASELINE_DECODE") and any(
                        t in path for t in ("/k", "/v", "ckv", "krope")):
                    dims[off + 1] = "model"
                elif nd - off >= 4:
                    dims[off + 2] = "model"
            else:
                dims[off + 1] = "data"           # shard length/heads dim
                if nd - off >= 4:
                    dims[off + 2] = "model"
        elif nd - off == 2:                      # (B, w) rglru h state
            if batch_sharded:
                dims[off] = "data"
            else:
                dims[off + 1] = "model"
        return P(*dims)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    out = []
    for path, leaf in flat:
        ps = _path_str(path)
        spec = _clamp_spec(spec_for(ps, leaf.shape), leaf.shape, mesh)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_shardings(batch_shape: Params, mesh: Mesh, *,
                    pod_stacked: bool) -> Params:
    """Input batches: leading (pod?, batch) dims shard over (pod?, data)."""
    def spec_for(shape) -> P:
        nd = len(shape)
        dims = [None] * nd
        i = 0
        if pod_stacked:
            dims[0] = "pod" if "pod" in mesh.axis_names else None
            i = 1
        if nd > i and shape[i] > 1:
            dims[i] = "data"
        return P(*dims)
    return jax.tree_util.tree_map(
        lambda l: NamedSharding(mesh, _clamp_spec(spec_for(l.shape),
                                                  l.shape, mesh)),
        batch_shape)


def replicated(tree: Params, mesh: Mesh) -> Params:
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), tree)
