"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, no device allocation.  The dry-run lowers against these."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig

SDS = jax.ShapeDtypeStruct


def text_len(cfg: ModelConfig, seq_len: int) -> int:
    """VLM: vision patch tokens occupy part of the sequence budget."""
    if cfg.modality.kind == "vision":
        return seq_len - cfg.modality.n_tokens
    return seq_len


def train_batch_specs(cfg: ModelConfig, shape: InputShape, *,
                      n_pods: int = 1) -> Dict[str, SDS]:
    assert shape.global_batch % n_pods == 0, (shape.global_batch, n_pods)
    b = shape.global_batch // n_pods
    T = text_len(cfg, shape.seq_len)
    specs: Dict[str, SDS] = {
        "tokens": SDS((n_pods, b, T), jnp.int32),
        "labels": SDS((n_pods, b, T), jnp.int32),
    }
    if cfg.modality.kind == "vision":
        specs["patches"] = SDS(
            (n_pods, b, cfg.modality.n_tokens, cfg.modality.feat_dim),
            jnp.bfloat16)
    if cfg.encoder is not None:
        specs["frames"] = SDS(
            (n_pods, b, cfg.encoder.n_frames, cfg.modality.feat_dim),
            jnp.bfloat16)
    return specs


def prefill_batch_specs(cfg: ModelConfig, shape: InputShape
                        ) -> Dict[str, SDS]:
    b = shape.global_batch
    T = text_len(cfg, shape.seq_len)
    specs: Dict[str, SDS] = {"tokens": SDS((b, T), jnp.int32)}
    if cfg.modality.kind == "vision":
        specs["patches"] = SDS(
            (b, cfg.modality.n_tokens, cfg.modality.feat_dim), jnp.bfloat16)
    if cfg.encoder is not None:
        specs["frames"] = SDS(
            (b, cfg.encoder.n_frames, cfg.modality.feat_dim), jnp.bfloat16)
    return specs


def decode_batch_specs(cfg: ModelConfig, shape: InputShape
                       ) -> Dict[str, SDS]:
    b = shape.global_batch
    specs: Dict[str, SDS] = {
        "token": SDS((b,), jnp.int32),
        "t": SDS((b,), jnp.int32),
    }
    if cfg.encoder is not None:
        # encoder memory precomputed at prefill time
        specs["frames"] = SDS(
            (b, cfg.encoder.n_frames, cfg.modality.feat_dim), jnp.bfloat16)
    return specs


def input_specs(cfg: ModelConfig, shape_name: str, *, n_pods: int = 1
                ) -> Dict[str, SDS]:
    shape = INPUT_SHAPES[shape_name]
    if shape.mode == "train":
        return train_batch_specs(cfg, shape, n_pods=n_pods)
    if shape.mode == "prefill":
        return prefill_batch_specs(cfg, shape)
    return decode_batch_specs(cfg, shape)
