"""Distributed train/serve steps with the paper's communication strategies
as a first-class stage.

The decentralized-site axis is the mesh ``pod`` axis.  Training state holds
*pod-stacked* model replicas — leaf shapes (n_pods, ...) sharded
P('pod', ...) — so each pod trains its own replica on its own data shard
(vmap over the stacked axis keeps all intra-pod collectives pod-local), and
the cross-pod exchange is an explicit reduction over axis 0, which GSPMD
lowers to collectives on the scarce cross-pod links:

  bsp:    grads averaged across pods every step (the quality target)
  gaia:   |accumulated update / weight| > T  -> masked psum (Algorithm 1)
  fedavg: params averaged across pods every Iter_local steps (Algorithm 2)
  dgc:    top-s% magnitude of accumulated -lr*grad momentum, via a
          256-bin histogram threshold — the TPU-native replacement for
          sort-based selection (Algorithm 3)

This is the *same arithmetic* as repro.core.algorithms (tested equivalent),
re-expressed for the SPMD path.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import CommConfig, ModelConfig
from repro.models.model import decode_step, forward, loss_fn

Params = Any
tmap = jax.tree_util.tree_map


# ---------------------------------------------------------------------------
# Train state
# ---------------------------------------------------------------------------

def make_train_state(params: Params, comm: CommConfig, n_pods: int) -> Dict:
    """Stack replicas over the pod axis; fp32 master velocity."""
    stack = lambda l: jnp.broadcast_to(l, (n_pods,) + l.shape)
    state = {
        "params": tmap(stack, params),
        "vel": tmap(lambda l: jnp.zeros((n_pods,) + l.shape, jnp.float32),
                    params),
    }
    if comm.strategy in ("gaia", "dgc"):
        state["acc"] = tmap(
            lambda l: jnp.zeros((n_pods,) + l.shape, jnp.float32), params)
    return state


def train_state_shape(cfg: ModelConfig, comm: CommConfig, n_pods: int
                      ) -> Dict:
    from repro.models.model import init_model
    p_shape = jax.eval_shape(
        lambda: init_model(jax.random.PRNGKey(0), cfg))
    return jax.eval_shape(
        lambda p: make_train_state(p, comm, n_pods), p_shape)


# ---------------------------------------------------------------------------
# Histogram-quantile threshold (pure jnp twin of kernels/dgc_topk)
# ---------------------------------------------------------------------------

def hist_threshold(v: jnp.ndarray, sparsity: jnp.ndarray,
                   n_bins: int = 256) -> jnp.ndarray:
    a = jnp.abs(v.reshape(-1)).astype(jnp.float32)
    vmax = jnp.maximum(jnp.max(a), 1e-30)
    idx = jnp.clip((a / vmax * n_bins).astype(jnp.int32), 0, n_bins - 1)
    hist = jnp.zeros((n_bins,), jnp.int32).at[idx].add(1)
    cum = jnp.cumsum(hist).astype(jnp.float32)
    target = sparsity * a.shape[0]
    bin_idx = jnp.clip(jnp.searchsorted(cum, target), 0, n_bins - 1)
    return (bin_idx.astype(jnp.float32) + 1.0) / n_bins * vmax


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, comm: CommConfig, *,
                    lr: float = 1e-3, momentum: float = 0.9,
                    weight_decay: float = 0.0,
                    remat: bool = True, chunk: int = 512) -> Callable:
    """Returns train_step(state, batch, step_idx) -> (state, metrics).
    ``batch`` leaves are (n_pods, b, ...)."""

    def pod_loss(params, batch):
        loss, parts = loss_fn(params, cfg, batch, remat=remat, chunk=chunk)
        return loss

    grad_fn = jax.value_and_grad(pod_loss)

    def local_sgd(params, grads, vel):
        """Per-pod momentum step.  Returns (params, vel, update)."""
        def upd(w, g, u):
            g32 = g.astype(jnp.float32) + weight_decay * w.astype(jnp.float32)
            return momentum * u - lr * g32
        vel = tmap(upd, params, grads, vel)
        params = tmap(lambda w, u: (w.astype(jnp.float32) + u
                                    ).astype(w.dtype), params, vel)
        return params, vel

    def train_step(state, batch, step_idx):
        losses, grads = jax.vmap(grad_fn)(state["params"], batch)
        metrics = {"loss": jnp.mean(losses)}

        if comm.strategy == "bsp":
            g = tmap(lambda x: jnp.mean(x, axis=0, keepdims=True), grads)
            g = tmap(lambda x, p: jnp.broadcast_to(x, p.shape), g,
                     state["params"])
            params, vel = local_sgd(state["params"], g, state["vel"])
            return {"params": params, "vel": vel}, metrics

        if comm.strategy == "fedavg":
            params, vel = local_sgd(state["params"], grads, state["vel"])
            il = comm.iter_local
            do_sync = (step_idx % il) == (il - 1)

            def sync(p):
                return tmap(lambda l: jnp.broadcast_to(
                    jnp.mean(l, axis=0, keepdims=True), l.shape), p)
            params = jax.lax.cond(do_sync, sync, lambda p: p, params)
            return {"params": params, "vel": vel}, metrics

        if comm.strategy == "gaia":
            params, vel = local_sgd(state["params"], grads, state["vel"])
            acc = tmap(lambda v, u: v + u, state["acc"], vel)
            t0 = comm.gaia_t0

            def exchange(w, v):
                mask = (jnp.abs(v) > t0 * jnp.abs(w.astype(jnp.float32))
                        ).astype(v.dtype)
                sel = v * mask
                total = jnp.sum(sel, axis=0, keepdims=True)   # cross-pod
                w_new = (w.astype(jnp.float32) + (total - sel)
                         ).astype(w.dtype)
                return w_new, v * (1 - mask)
            pairs = tmap(exchange, params, acc)
            params = tmap(lambda pr: pr[0], pairs,
                          is_leaf=lambda x: isinstance(x, tuple))
            acc = tmap(lambda pr: pr[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
            return {"params": params, "vel": vel, "acc": acc}, metrics

        if comm.strategy == "dgc":
            # g = -lr * grad (clip folded into hist threshold scale)
            g = tmap(lambda x: -lr * x.astype(jnp.float32), grads)
            vel = tmap(lambda u, gl: momentum * u + gl, state["vel"], g)
            acc = tmap(lambda v, u: v + u, state["acc"], vel)
            s = comm.dgc_sparsity

            def exchange(w, v, u):
                t = jax.vmap(lambda vv: hist_threshold(vv, s))(v)  # per pod
                t = t.reshape((-1,) + (1,) * (v.ndim - 1))
                mask = (jnp.abs(v) > t).astype(v.dtype)
                sel = v * mask
                total = jnp.sum(sel, axis=0)                  # cross-pod
                w_new = (w.astype(jnp.float32) + total[None]
                         ).astype(w.dtype)
                return w_new, v * (1 - mask), u * (1 - mask)
            triples = tmap(exchange, state["params"], acc, vel)
            params = tmap(lambda tr: tr[0], triples,
                          is_leaf=lambda x: isinstance(x, tuple))
            acc = tmap(lambda tr: tr[1], triples,
                       is_leaf=lambda x: isinstance(x, tuple))
            vel = tmap(lambda tr: tr[2], triples,
                       is_leaf=lambda x: isinstance(x, tuple))
            return {"params": params, "vel": vel, "acc": acc}, metrics

        raise ValueError(comm.strategy)

    return train_step


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, *, chunk: int = 512) -> Callable:
    def prefill_step(params, batch):
        logits, _ = forward(params, cfg, batch, remat=False, chunk=chunk)
        return logits[:, -1]                       # next-token logits
    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    def serve_step(params, cache, batch):
        logits, new_cache = decode_step(params, cfg, batch, cache)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_cache
    return serve_step
