"""Distributed train/serve steps with the paper's communication strategies
as a first-class stage.

The decentralized-site axis is the mesh ``pod`` axis.  Training state holds
*pod-stacked* model replicas — leaf shapes (n_pods, ...) sharded
P('pod', ...) — so each pod trains its own replica on its own data shard
(vmap over the stacked axis keeps all intra-pod collectives pod-local), and
the cross-pod exchange is an explicit reduction over axis 0, which GSPMD
lowers to collectives on the scarce cross-pod links:

  bsp:    grads averaged across pods every step (the quality target)
  gaia:   |accumulated update / weight| > T  -> masked psum (Algorithm 1);
          T decays with the learning rate, T = t0 * lr/lr0 (lr0 defaults
          to the construction-time lr), exactly like
          core/algorithms/gaia.py
  fedavg: params averaged across pods every Iter_local steps (Algorithm 2)
  dgc:    per-pod global-norm clip, momentum correction, then top-s%
          magnitude of the accumulated -lr*grad momentum via a 256-bin
          histogram threshold — the TPU-native replacement for sort-based
          selection (Algorithm 3); ``sparsity`` is a runtime operand so
          the warm-up schedule never recompiles
  dpsgd:  gossip averaging over a TopologySchedule fabric: a ring of
          ``n_pods - 1`` static ppermute rotations over the ``pod`` axis
          (shard_map; every other mesh axis keeps its GSPMD sharding),
          with the round's padded neighbor idx/weights entering as
          *runtime* operands — the SPMD twin of the Pallas
          ``neighbor_mix`` self-weight + padded-neighbor-gather
          arithmetic, and the same compile-once contract that
          ``DPSGD.trace_count`` asserts in the simulation
  adpsgd: same ring, but neighbor reads gather from a pod-stacked
          bounded-staleness snapshot buffer in the train state
          (``state["snaps"]``, slot s = the stack from s rounds ago);
          per-read staleness slots ride in a fourth runtime operand, so
          schedule rotation AND staleness moves reuse one compilation.
          Staleness 0 is bit-identical to dpsgd.

This is the *same arithmetic* as repro.core.algorithms — asserted by
tests/test_launch_gossip.py, which steps both backends on identical
inputs and compares the updates strategy by strategy.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import SHARD_MAP_CHECK_KW as _CHECK_KW
from repro.compat import shard_map as _shard_map
from repro.configs.base import CommConfig, ModelConfig
from repro.models.model import (decode_step, forward, init_cache,
                                init_model, loss_fn)
from repro.topology.graphs import Topology, TopologySchedule, as_schedule

Params = Any
tmap = jax.tree_util.tree_map

#: strategies whose cross-pod exchange is gossip over a topology fabric —
#: their train_step takes the round's mix operands (see gossip_operands)
GOSSIP_STRATEGIES = ("dpsgd", "adpsgd")


# ---------------------------------------------------------------------------
# Train state
# ---------------------------------------------------------------------------

def make_train_state(params: Params, comm: CommConfig, n_pods: int) -> Dict:
    """Stack replicas over the pod axis; fp32 master velocity.

    adpsgd additionally carries the bounded-staleness snapshot buffer:
    per leaf ``(max_staleness + 1, n_pods, ...)`` in the leaf's own dtype
    (slot 0 always holds the current round's post-gradient stack, so a
    staleness-0 read is exactly the fresh dpsgd read)."""
    stack = lambda l: jnp.broadcast_to(l, (n_pods,) + l.shape)
    state = {
        "params": tmap(stack, params),
        "vel": tmap(lambda l: jnp.zeros((n_pods,) + l.shape, jnp.float32),
                    params),
    }
    if comm.strategy in ("gaia", "dgc"):
        state["acc"] = tmap(
            lambda l: jnp.zeros((n_pods,) + l.shape, jnp.float32), params)
    if comm.strategy == "adpsgd":
        state["snaps"] = tmap(
            lambda l: jnp.broadcast_to(l,
                                       (comm.max_staleness + 1,) + l.shape),
            state["params"])
    return state


def param_shape(cfg: ModelConfig):
    """Abstract parameter pytree (the serve/prefill state) — the one
    shape source the dryrun sweep and the jaxpr audit both trace from."""
    return jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))


def cache_shape(cfg: ModelConfig, global_batch: int, seq_len: int,
                long_mode: bool = False):
    """Abstract decode-cache pytree for :func:`make_serve_step`."""
    return jax.eval_shape(
        lambda: init_cache(cfg, global_batch, seq_len, long_mode))


def train_state_shape(cfg: ModelConfig, comm: CommConfig, n_pods: int
                      ) -> Dict:
    return jax.eval_shape(
        lambda p: make_train_state(p, comm, n_pods), param_shape(cfg))


# ---------------------------------------------------------------------------
# Gossip fabric plumbing
# ---------------------------------------------------------------------------

def gossip_operands(fabric: Union[Topology, TopologySchedule], t: int, *,
                    pad_degree: Optional[int] = None,
                    staleness: Optional[int] = None,
                    max_staleness: Optional[int] = None) -> Tuple:
    """Round ``t``'s runtime mix operands for the pod-gossip step.

    Returns ``(nbr_idx, nbr_w, self_w)`` — plus a ``(K, D)`` int32 per-read
    staleness-slot operand when ``staleness`` is given (adpsgd; 0 on
    padding entries, whose weight is 0 anyway) — padded to the
    schedule-wide max degree (or ``pad_degree``, e.g. the max over a
    controller ladder).  Every round of a rotating schedule and every
    staleness move therefore shares one operand *shape*: only the values
    change, and the jitted train step compiles exactly once — the same
    contract ``DPSGD.trace_count`` asserts for the simulation backend."""
    sched = as_schedule(fabric)
    idx, w, sw = sched.neighbor_arrays(int(t), pad_degree=pad_degree)
    ops = (jnp.asarray(idx, jnp.int32), jnp.asarray(w, jnp.float32),
           jnp.asarray(sw, jnp.float32))
    if staleness is None:
        return ops
    # a slot outside the snapshot buffer would be *silently dropped* by
    # the coefficient scatter (jax out-of-bounds updates drop), zeroing
    # the neighbor weights — so the bound is mandatory here, the one
    # place the slot values are constructed
    if max_staleness is None:
        raise ValueError(
            "staleness needs max_staleness (= comm.max_staleness, the "
            "snapshot-buffer depth) so out-of-buffer slots are refused "
            "instead of silently scattering to nowhere")
    if not 0 <= staleness <= max_staleness:
        raise ValueError(
            f"staleness {staleness} outside the snapshot buffer bound "
            f"[0, {max_staleness}] fixed at construction "
            "(comm.max_staleness)")
    stale = np.where(w > 0, int(staleness), 0).astype(np.int32)
    return ops + (jnp.asarray(stale),)


def _pod_mix_fn(strategy: str, mesh, n_pods: int, p_specs,
                snap_specs=None, n_slots: int = 1) -> Callable:
    """Build the shard_map'd gossip exchange over the mesh ``pod`` axis.

    Mirrors the Pallas ``neighbor_mix`` arithmetic (self-weight term +
    padded-neighbor gather, f32 accumulate, cast back to the leaf dtype)
    re-expressed for SPMD: the pod axis is manual and every other mesh
    axis keeps the train state's own sharding (``in_specs`` are the
    leaves' actual PartitionSpecs, so the exchange inserts no reshard),
    and the neighbor gather becomes ``n_pods - 1`` static ppermute
    shifts.  dpsgd rotates the params one hop at a time: at shift ``r``
    pod ``k`` holds pod ``(k - r) % n_pods``'s payload and scales it by
    a coefficient scattered at *runtime* from the padded ``(K, D)``
    neighbor operands.  adpsgd instead contracts at the *source*: each
    pod collapses its ``(S+1)``-slot snapshot stack down to one
    already-weighted model per destination (via a ``(K, K, S+1)``
    runtime coefficient scatter keyed by the per-read staleness operand)
    and ships it with a direct distance-``r`` permute — same cross-pod
    bytes as dpsgd, instead of ``(S+1)x`` for rotating the whole buffer.
    Either way a rotating schedule (or a staleness move) changes operand
    values only, never shapes, and the exchange lowers to
    collective-permutes on the pod axis alone
    (``hlo_analysis.pod_exchange_report`` verifies).
    """
    perm = [(j, (j + 1) % n_pods) for j in range(n_pods)]
    op_specs = (P("pod", None), P("pod", None), P("pod"))

    if strategy == "dpsgd":
        def body(p, nbr_idx, nbr_w, self_w):
            k = jax.lax.axis_index("pod")
            # this pod's mixing-matrix row, from its (1, D) operand slice
            wvec = jnp.zeros((n_pods,), jnp.float32
                             ).at[nbr_idx[0]].add(nbr_w[0])

            def mix_leaf(x):
                y = self_w[0] * x.astype(jnp.float32)
                xr = x
                for r in range(1, n_pods):
                    xr = jax.lax.ppermute(xr, "pod", perm)
                    y = y + wvec[(k - r) % n_pods] * xr.astype(jnp.float32)
                return y.astype(x.dtype)
            return tmap(mix_leaf, p)

        return _shard_map(body, mesh=mesh,
                          in_specs=(p_specs,) + op_specs,
                          out_specs=p_specs, **{_CHECK_KW: False})

    def body(p, snaps, nbr_idx, nbr_w, self_w, stale):
        k = jax.lax.axis_index("pod")
        # structural staleness bound, as in the simulation ("a read
        # deeper than the buffer cannot be expressed"): a slot past the
        # compiled buffer reads the *oldest* snapshot instead of
        # scattering out of bounds, where jax would silently drop the
        # neighbor weight (gossip_operands refuses declared-bound
        # violations; this guards a bound that lied)
        stale = jnp.clip(stale, 0, n_slots - 1)
        # full (K, K, S+1) coefficient tensor from the *replicated*
        # operands: a source must know each destination's weight and
        # staleness slot for reads of itself, so it can contract its own
        # snapshot stack down to ONE model before shipping — rotating
        # the whole (S+1)-slot buffer around the ring instead would ship
        # (S+1)x the cross-pod bytes actually consumed
        rows = jnp.arange(n_pods)[:, None]
        coeff = jnp.zeros((n_pods, n_pods, n_slots), jnp.float32
                          ).at[rows, nbr_idx, stale].add(nbr_w)

        def mix_leaf(x, sn):
            y = self_w[0] * x.astype(jnp.float32)
            sn32 = sn.astype(jnp.float32)        # (n_slots, 1, ...) local
            for r in range(1, n_pods):
                dest = (k + r) % n_pods
                # already weighted by the destination's coefficients for
                # reads of this pod, so the receiver only adds; shipped
                # in the leaf dtype so the wire bytes equal dpsgd's
                # (for bf16 models that rounds each weighted term, the
                # standard price of bf16 comms; exact for f32)
                payload = jnp.tensordot(coeff[dest, k], sn32, axes=1
                                        ).astype(x.dtype)
                y = y + jax.lax.ppermute(
                    payload, "pod",
                    [(j, (j + r) % n_pods) for j in range(n_pods)]
                ).astype(jnp.float32)
            return y.astype(x.dtype)
        return tmap(mix_leaf, p, snaps)

    return _shard_map(body, mesh=mesh,
                      in_specs=(p_specs, snap_specs,
                                P(None, None), P(None, None), P("pod"),
                                P(None, None)),
                      out_specs=p_specs, **{_CHECK_KW: False})


# ---------------------------------------------------------------------------
# Histogram-quantile threshold (pure jnp twin of kernels/dgc_topk)
# ---------------------------------------------------------------------------

def hist_threshold(v: jnp.ndarray, sparsity: jnp.ndarray,
                   n_bins: int = 256) -> jnp.ndarray:
    a = jnp.abs(v.reshape(-1)).astype(jnp.float32)
    vmax = jnp.maximum(jnp.max(a), 1e-30)
    idx = jnp.clip((a / vmax * n_bins).astype(jnp.int32), 0, n_bins - 1)
    hist = jnp.zeros((n_bins,), jnp.int32).at[idx].add(1)
    cum = jnp.cumsum(hist).astype(jnp.float32)
    target = sparsity * a.shape[0]
    bin_idx = jnp.clip(jnp.searchsorted(cum, target), 0, n_bins - 1)
    return (bin_idx.astype(jnp.float32) + 1.0) / n_bins * vmax


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, comm: CommConfig, *,
                    mesh=None, lr: float = 1e-3,
                    lr0: Optional[float] = None,
                    momentum: float = 0.9, weight_decay: float = 0.0,
                    remat: bool = True, chunk: int = 512) -> Callable:
    """Returns ``train_step(state, batch, step_idx, mix=None, lr=None,
    sparsity=None) -> (state, metrics)``.  ``batch`` leaves are
    (n_pods, b, ...).

    Runtime operands (all optional, so existing 3-argument call sites
    keep working):
      mix       gossip neighbor operands from :func:`gossip_operands` —
                required for dpsgd/adpsgd, which also require ``mesh``
                (a mesh with a ``pod`` axis) at construction
      lr        traced learning-rate override of the static ``lr`` —
                lets one compilation serve a schedule, and drives Gaia's
                threshold decay T = t0 * lr / lr0 (``lr0`` defaults to
                the static ``lr``, matching the core trainer's
                always-decaying wiring; at the static lr the threshold
                is exactly t0)
      sparsity  traced DGC sparsity (the warm-up schedule / a controller)
                overriding ``comm.dgc_sparsity``
    """

    def pod_loss(params, batch):
        loss, parts = loss_fn(params, cfg, batch, remat=remat, chunk=chunk)
        return loss

    grad_fn = jax.value_and_grad(pod_loss)

    lr_static = lr
    mix_fn = None
    model_floats = None
    if comm.strategy in GOSSIP_STRATEGIES:
        if mesh is None or "pod" not in mesh.axis_names:
            raise ValueError(
                f"strategy {comm.strategy!r} gossips over the mesh 'pod' "
                "axis: pass make_train_step(..., mesh=) with a pod axis "
                "(make_production_mesh(multi_pod=True))")
        # in_specs for the manual exchange come from the same sharding
        # rules the callers use for the state, so the shard_map boundary
        # introduces no reshard
        from repro.launch.sharding import train_state_shardings
        n_pods = mesh.shape["pod"]
        state_shape = train_state_shape(cfg, comm, n_pods)
        state_sh = train_state_shardings(state_shape, mesh)
        p_specs = tmap(lambda ns: ns.spec, state_sh["params"])
        snap_specs = (tmap(lambda ns: ns.spec, state_sh["snaps"])
                      if comm.strategy == "adpsgd" else None)
        mix_fn = _pod_mix_fn(comm.strategy, mesh, n_pods, p_specs,
                             snap_specs=snap_specs,
                             n_slots=comm.max_staleness + 1)
        model_floats = float(sum(
            l.size for l in
            jax.tree_util.tree_leaves(state_shape["params"]))) / n_pods

    def local_sgd(params, grads, vel, lr_t):
        """Per-pod momentum step.  Returns (params, vel)."""
        def upd(w, g, u):
            g32 = g.astype(jnp.float32) + weight_decay * w.astype(jnp.float32)
            return momentum * u - lr_t * g32
        vel = tmap(upd, params, grads, vel)
        params = tmap(lambda w, u: (w.astype(jnp.float32) + u
                                    ).astype(w.dtype), params, vel)
        return params, vel

    def train_step(state, batch, step_idx, mix=None, lr=None,
                   sparsity=None):
        lr_t = lr_static if lr is None else lr
        losses, grads = jax.vmap(grad_fn)(state["params"], batch)
        metrics = {"loss": jnp.mean(losses)}

        if comm.strategy in GOSSIP_STRATEGIES:
            if mix is None:
                raise ValueError(
                    f"{comm.strategy} needs the round's "
                    "gossip_operands(...) as the mix argument")
            want = 4 if comm.strategy == "adpsgd" else 3
            if len(mix) != want:
                raise ValueError(
                    f"{comm.strategy} takes {want} mix operands, got "
                    f"{len(mix)} — build them with gossip_operands("
                    + ("..., staleness=, max_staleness=) so the "
                       "per-read staleness slots are included"
                       if comm.strategy == "adpsgd" else
                       "...) without staleness (dpsgd reads are fresh)"))
            # a schedule over the wrong node count would silently
            # mis-split over the pod axis (and scatter out of bounds)
            if mix[0].shape[0] != n_pods:
                raise ValueError(
                    f"gossip operands are for {mix[0].shape[0]} nodes "
                    f"but the mesh has {n_pods} pods — build the "
                    "schedule over the pod count")
            params, vel = local_sgd(state["params"], grads, state["vel"],
                                    lr_t)
            nbr_w = mix[1]
            # per-pod *algorithmic* price: one model per active neighbor
            # (padding entries carry weight 0) — the same currency the
            # simulation ledger books, NOT the wire bytes: the static
            # ring ships n_pods-1 permutes per round regardless of the
            # round's degree, and dryrun's pod_exchange reports those
            # physical bytes from the HLO
            mean_degree = (jnp.sum(nbr_w > 0).astype(jnp.float32)
                           / nbr_w.shape[0])
            metrics["mean_degree"] = mean_degree
            metrics["comm_floats"] = mean_degree * model_floats
            if comm.strategy == "dpsgd":
                nbr_idx, nbr_w_, self_w = mix
                params = mix_fn(params, nbr_idx, nbr_w_, self_w)
                return {"params": params, "vel": vel}, metrics
            nbr_idx, nbr_w_, self_w, stale = mix
            # push this round's post-gradient stack into slot 0; slot s
            # now holds the stack from s rounds ago (pre-mix, like the
            # simulation's snapshot buffer)
            snaps = tmap(lambda s, x: jnp.concatenate(
                [x[None].astype(s.dtype), s[:-1]], axis=0),
                state["snaps"], params)
            params = mix_fn(params, snaps, nbr_idx, nbr_w_, self_w, stale)
            nbr_mask = (nbr_w_ > 0).astype(jnp.float32)
            reads = jnp.maximum(jnp.sum(nbr_mask), 1.0)
            metrics["mean_staleness"] = jnp.sum(stale * nbr_mask) / reads
            return {"params": params, "vel": vel, "snaps": snaps}, metrics

        if comm.strategy == "bsp":
            g = tmap(lambda x: jnp.mean(x, axis=0, keepdims=True), grads)
            g = tmap(lambda x, p: jnp.broadcast_to(x, p.shape), g,
                     state["params"])
            params, vel = local_sgd(state["params"], g, state["vel"], lr_t)
            return {"params": params, "vel": vel}, metrics

        if comm.strategy == "fedavg":
            params, vel = local_sgd(state["params"], grads, state["vel"],
                                    lr_t)
            il = comm.iter_local
            do_sync = (step_idx % il) == (il - 1)

            def sync(p):
                return tmap(lambda l: jnp.broadcast_to(
                    jnp.mean(l, axis=0, keepdims=True), l.shape), p)
            params = jax.lax.cond(do_sync, sync, lambda p: p, params)
            return {"params": params, "vel": vel}, metrics

        if comm.strategy == "gaia":
            params, vel = local_sgd(state["params"], grads, state["vel"],
                                    lr_t)
            acc = tmap(lambda v, u: v + u, state["acc"], vel)
            # threshold decays with the learning rate (Algorithm 1 line
            # 16), matching core/algorithms/gaia.py; the reference lr
            # defaults to the static lr, so a runtime lr schedule decays
            # T at every call site without opt-in
            thresh = comm.gaia_t0 * (
                lr_t / (lr_static if lr0 is None else lr0))

            def exchange(w, v):
                mask = (jnp.abs(v) > thresh * jnp.abs(w.astype(jnp.float32))
                        ).astype(v.dtype)
                sel = v * mask
                total = jnp.sum(sel, axis=0, keepdims=True)   # cross-pod
                w_new = (w.astype(jnp.float32) + (total - sel)
                         ).astype(w.dtype)
                return w_new, v * (1 - mask)
            pairs = tmap(exchange, params, acc)
            params = tmap(lambda pr: pr[0], pairs,
                          is_leaf=lambda x: isinstance(x, tuple))
            acc = tmap(lambda pr: pr[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
            return {"params": params, "vel": vel, "acc": acc}, metrics

        if comm.strategy == "dgc":
            # per-pod global-norm gradient clip (Algorithm 3 line 2)
            sq = sum(jnp.sum(l.astype(jnp.float32) ** 2,
                             axis=tuple(range(1, l.ndim)))
                     for l in jax.tree_util.tree_leaves(grads))
            scale = jnp.minimum(
                1.0, comm.dgc_clip / jnp.maximum(jnp.sqrt(sq), 1e-12))
            grads_c = tmap(lambda l: l * scale.reshape(
                (-1,) + (1,) * (l.ndim - 1)).astype(l.dtype), grads)
            # g = -lr * (clipped grad + wd * w); momentum correction
            g = tmap(lambda x, w: -lr_t * (x.astype(jnp.float32)
                                           + weight_decay
                                           * w.astype(jnp.float32)),
                     grads_c, state["params"])
            vel = tmap(lambda u, gl: momentum * u + gl, state["vel"], g)
            acc = tmap(lambda v, u: v + u, state["acc"], vel)
            # runtime sparsity operand: the warm-up schedule (and any
            # controller) retunes without recompiling, like the
            # simulation DGC
            s = comm.dgc_sparsity if sparsity is None else sparsity

            def exchange(w, v, u):
                t = jax.vmap(lambda vv: hist_threshold(vv, s))(v)  # per pod
                t = t.reshape((-1,) + (1,) * (v.ndim - 1))
                mask = (jnp.abs(v) > t).astype(v.dtype)
                sel = v * mask
                total = jnp.sum(sel, axis=0)                  # cross-pod
                w_new = (w.astype(jnp.float32) + total[None]
                         ).astype(w.dtype)
                return w_new, v * (1 - mask), u * (1 - mask)
            triples = tmap(exchange, state["params"], acc, vel)
            params = tmap(lambda tr: tr[0], triples,
                          is_leaf=lambda x: isinstance(x, tuple))
            acc = tmap(lambda tr: tr[1], triples,
                       is_leaf=lambda x: isinstance(x, tuple))
            vel = tmap(lambda tr: tr[2], triples,
                       is_leaf=lambda x: isinstance(x, tuple))
            return {"params": params, "vel": vel, "acc": acc}, metrics

        raise ValueError(comm.strategy)

    return train_step


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, *, chunk: int = 512) -> Callable:
    """Prefill step.  Audited alongside the train graphs (jaxpr + HLO
    passes): donation is optional for serve-side graphs, host callbacks
    and off-pod-axis collectives are not."""
    def prefill_step(params, batch):
        logits, _ = forward(params, cfg, batch, remat=False, chunk=chunk)
        return logits[:, -1]                       # next-token logits
    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    def serve_step(params, cache, batch):
        logits, new_cache = decode_step(params, cfg, batch, cache)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_cache
    return serve_step
