"""Attention: GQA (qk-norm / softcap / sliding-window) and MLA
(multi-head latent attention, deepseek-v2 / minicpm3) with three paths:

- train/prefill: ``chunked_attention`` — a lax.scan online-softmax over key
  blocks (flash-attention schedule in pure jnp, so it lowers on every
  backend; the Pallas kernel in ``repro.kernels.flash_attention`` is the TPU
  executable twin).
- decode: one query token against a fixed-capacity KV cache.  The cache may
  be a *ring buffer* of ``window`` slots (long-context mode) — the
  sub-quadratic variant sanctioned for full-attention archs on long_500k.
- MLA decode uses the *absorbed* form: scores are taken directly against the
  compressed c_kv cache (kv_lora_rank-wide), never re-expanding K.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.models.layers import (init_linear, init_rmsnorm, linear_apply,
                                 rmsnorm_apply, softcap)
from repro.models.rope import apply_rope
from repro.models.shard_hints import hint

Params = Dict[str, Any]

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Core: chunked online-softmax attention (pure jnp flash schedule)
# ---------------------------------------------------------------------------

def expand_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """GQA group expansion via a tiny head-map gather, keeping the head axis
    shardable over ``model`` (a reshape-based grouped layout silently
    replicates heads under GSPMD)."""
    Hkv = k.shape[2]
    if Hkv == n_heads:
        return k
    head_map = jnp.arange(n_heads) // (n_heads // Hkv)
    return hint(jnp.take(k, head_map, axis=2), "data", None, "model", None)


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool = True,
                      window: Optional[int] = None,
                      logit_softcap: Optional[float] = None,
                      scale: Optional[float] = None,
                      chunk: int = 512,
                      q_offset: int = 0) -> jnp.ndarray:
    """q: (B, Tq, Hq, D); k, v: (B, Tk, Hkv, Dk/Dv).  Hq % Hkv == 0.

    Online softmax over key chunks: O(Tq * chunk) live scores instead of
    O(Tq * Tk).  ``q_offset`` is the absolute position of q[0] relative to
    k[0] (prefill: Tk - Tq when a prefix cache exists).
    """
    B, Tq, Hq, D = q.shape
    Tk = k.shape[1]
    assert Hq % k.shape[2] == 0, (Hq, k.shape)
    k = expand_kv(k, Hq)
    v = expand_kv(v, Hq)
    Dk, Dv = k.shape[-1], v.shape[-1]
    scale = D ** -0.5 if scale is None else scale

    # pad Tk to a multiple of chunk
    n_chunks = -(-Tk // chunk)
    pad = n_chunks * chunk - Tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, Hq, Dk).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, Hq, Dv).transpose(1, 0, 2, 3, 4)

    qh = hint(q, "data", None, "model", None)
    q_pos = q_offset + jnp.arange(Tq)

    def body(carry, xs):
        m, l, acc = carry
        k_blk, v_blk, blk_idx = xs
        k_pos = blk_idx * chunk + jnp.arange(chunk)
        # bf16 operands + f32 accumulation (flash-attention numerics)
        s = jnp.einsum("bqhd,bkhd->bhqk", qh, k_blk,
                       preferred_element_type=jnp.float32) * scale
        s = softcap(s, logit_softcap)
        valid = (k_pos < Tk)[None, :]
        if causal:
            valid = valid & (k_pos[None, :] <= q_pos[:, None])
        if window is not None:
            valid = valid & (k_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(valid[None, None], s, NEG_INF)
        m_blk = jnp.max(s, axis=-1)                       # (B,H,Tq)
        m_new = jnp.maximum(m, m_blk)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(v_blk.dtype), v_blk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hq, Tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hq, Tq), jnp.float32)
    a0 = jnp.zeros((B, Hq, Tq, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 2, 1, 3)
    return out.astype(q.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, *,
                     q_pos: jnp.ndarray,
                     cache_positions: jnp.ndarray,
                     logit_softcap: Optional[float] = None,
                     scale: Optional[float] = None) -> jnp.ndarray:
    """One-token decode.  q: (B, 1, Hq, D); caches: (B, L, Hkv, D*).
    ``cache_positions``: (B, L) absolute position of each cache slot, -1 for
    empty (ring-buffer semantics fall out of position bookkeeping)."""
    B, _, Hq, D = q.shape
    scale = D ** -0.5 if scale is None else scale
    if os.environ.get("REPRO_BASELINE_DECODE"):
        # paper-faithful baseline path (pre-hillclimb): head-expand + f32
        k_cache = expand_kv(k_cache, Hq)
        v_cache = expand_kv(v_cache, Hq)
        Dv = v_cache.shape[-1]
        qh = q.reshape(B, Hq, D).astype(jnp.float32)
        s = jnp.einsum("bhd,blhd->bhl", qh,
                       k_cache.astype(jnp.float32)) * scale
        s = softcap(s, logit_softcap)
        valid = (cache_positions >= 0) & (cache_positions <= q_pos[:, None])
        s = jnp.where(valid[:, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhl,blhd->bhd", p, v_cache.astype(jnp.float32))
        return out.reshape(B, 1, Hq, Dv).astype(q.dtype)
    Hkv = k_cache.shape[2]
    Dv = v_cache.shape[-1]
    g = Hq // Hkv
    # grouped layout: no KV expansion (a head-expand gather forces GSPMD to
    # replicate the cache).  The cache LENGTH dim is sharded over 'model'
    # (flash-decode): per-shard partial scores, softmax combines are tiny.
    k_cache = hint(k_cache, "data", "model", None, None)
    v_cache = hint(v_cache, "data", "model", None, None)
    qh = q.reshape(B, Hkv, g, D)
    # bf16 operands + f32 accumulation: no full-cache convert materializes
    s = jnp.einsum("bhgd,blhd->bhgl", qh, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = softcap(s, logit_softcap)
    valid = (cache_positions >= 0) & (cache_positions <= q_pos[:, None])
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgl,blhd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------

def init_gqa(key, a: AttentionConfig, d_model: int, dtype) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {
        "wq": init_linear(k1, d_model, a.n_heads * a.head_dim, dtype),
        "wk": init_linear(k2, d_model, a.n_kv_heads * a.head_dim, dtype),
        "wv": init_linear(k3, d_model, a.n_kv_heads * a.head_dim, dtype),
        "wo": init_linear(k4, a.n_heads * a.head_dim, d_model, dtype),
    }
    if a.qk_norm:
        p["q_norm"] = init_rmsnorm(a.head_dim)
        p["k_norm"] = init_rmsnorm(a.head_dim)
    return p


def gqa_qkv(p: Params, a: AttentionConfig, x: jnp.ndarray,
            positions: jnp.ndarray):
    B, T, _ = x.shape
    q = linear_apply(p["wq"], x).reshape(B, T, a.n_heads, a.head_dim)
    k = linear_apply(p["wk"], x).reshape(B, T, a.n_kv_heads, a.head_dim)
    v = linear_apply(p["wv"], x).reshape(B, T, a.n_kv_heads, a.head_dim)
    if a.qk_norm:
        q = rmsnorm_apply(p["q_norm"], q)
        k = rmsnorm_apply(p["k_norm"], k)
    q = apply_rope(q, positions, a.rope_theta)
    k = apply_rope(k, positions, a.rope_theta)
    q = hint(q, "data", None, "model", None)
    k = hint(k, "data", None, "model", None)
    v = hint(v, "data", None, "model", None)
    return q, k, v


def gqa_apply(p: Params, a: AttentionConfig, x: jnp.ndarray, *,
              window: Optional[int], positions: jnp.ndarray,
              chunk: int = 512) -> jnp.ndarray:
    """Train/prefill path (full sequence)."""
    q, k, v = gqa_qkv(p, a, x, positions)
    out = chunked_attention(q, k, v, causal=True, window=window,
                            logit_softcap=a.attn_softcap, chunk=chunk)
    B, T = x.shape[:2]
    return linear_apply(p["wo"], out.reshape(B, T, -1))


def gqa_init_cache(a: AttentionConfig, batch: int, length: int,
                   dtype) -> Params:
    return {
        "k": jnp.zeros((batch, length, a.n_kv_heads, a.head_dim), dtype),
        "v": jnp.zeros((batch, length, a.n_kv_heads, a.head_dim), dtype),
        "pos": jnp.full((batch, length), -1, jnp.int32),
    }


def gqa_decode(p: Params, a: AttentionConfig, x: jnp.ndarray,
               cache: Params, t: jnp.ndarray) -> Tuple[jnp.ndarray, Params]:
    """x: (B, 1, d).  t: (B,) absolute position of this token.  The cache is
    a ring buffer of ``L`` slots; slot = t mod L (sliding window when
    L < full context)."""
    B = x.shape[0]
    L = cache["k"].shape[1]
    q, k, v = gqa_qkv(p, a, x, t[:, None])
    slot = (t % L).astype(jnp.int32)
    b_idx = jnp.arange(B)
    new_cache = {
        "k": cache["k"].at[b_idx, slot].set(k[:, 0]),
        "v": cache["v"].at[b_idx, slot].set(v[:, 0]),
        "pos": cache["pos"].at[b_idx, slot].set(t.astype(jnp.int32)),
    }
    out = decode_attention(q, new_cache["k"], new_cache["v"], q_pos=t,
                           cache_positions=new_cache["pos"],
                           logit_softcap=a.attn_softcap)
    return linear_apply(p["wo"], out.reshape(B, 1, -1)), new_cache


# ---------------------------------------------------------------------------
# MLA block (deepseek-v2 / minicpm3)
# ---------------------------------------------------------------------------

def init_mla(key, a: AttentionConfig, d_model: int, dtype) -> Params:
    ks = jax.random.split(key, 6)
    h = a.n_heads
    qhead = a.nope_head_dim + a.rope_head_dim
    p: Params = {}
    if a.q_lora_rank:
        p["wdq"] = init_linear(ks[0], d_model, a.q_lora_rank, dtype)
        p["q_norm"] = init_rmsnorm(a.q_lora_rank)
        p["wuq"] = init_linear(ks[1], a.q_lora_rank, h * qhead, dtype)
    else:
        p["wq"] = init_linear(ks[0], d_model, h * qhead, dtype)
    p["wdkv"] = init_linear(ks[2], d_model,
                            a.kv_lora_rank + a.rope_head_dim, dtype)
    p["kv_norm"] = init_rmsnorm(a.kv_lora_rank)
    # up-projection, kept 3D so decode can use the absorbed form
    wukv = jax.random.normal(
        ks[3], (a.kv_lora_rank, h, a.nope_head_dim + a.v_head_dim),
        jnp.float32) * (a.kv_lora_rank ** -0.5)
    p["wukv"] = wukv.astype(dtype)
    p["wo"] = init_linear(ks[4], h * a.v_head_dim, d_model, dtype)
    return p


def _mla_q(p: Params, a: AttentionConfig, x: jnp.ndarray,
           positions: jnp.ndarray):
    B, T, _ = x.shape
    h = a.n_heads
    if a.q_lora_rank:
        cq = rmsnorm_apply(p["q_norm"], linear_apply(p["wdq"], x))
        q = linear_apply(p["wuq"], cq)
    else:
        q = linear_apply(p["wq"], x)
    q = hint(q.reshape(B, T, h, a.nope_head_dim + a.rope_head_dim),
             "data", None, "model", None)
    q_nope = q[..., :a.nope_head_dim]
    q_rope = apply_rope(q[..., a.nope_head_dim:], positions, a.rope_theta)
    return q_nope, q_rope


def _mla_ckv(p: Params, a: AttentionConfig, x: jnp.ndarray,
             positions: jnp.ndarray):
    ckv_kr = linear_apply(p["wdkv"], x)
    c_kv = rmsnorm_apply(p["kv_norm"], ckv_kr[..., :a.kv_lora_rank])
    k_rope = ckv_kr[..., a.kv_lora_rank:][:, :, None, :]   # (B,T,1,rope_dim)
    k_rope = apply_rope(k_rope, positions, a.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_apply(p: Params, a: AttentionConfig, x: jnp.ndarray, *,
              positions: jnp.ndarray, window: Optional[int] = None,
              chunk: int = 512) -> jnp.ndarray:
    """Train/prefill: expand K/V from the latent and run chunked attention."""
    B, T, _ = x.shape
    h = a.n_heads
    q_nope, q_rope = _mla_q(p, a, x, positions)
    c_kv, k_rope = _mla_ckv(p, a, x, positions)
    kv = jnp.einsum("btr,rhd->bthd", c_kv, p["wukv"].astype(x.dtype))
    kv = hint(kv, "data", None, "model", None)
    k_nope = kv[..., :a.nope_head_dim]
    v = kv[..., a.nope_head_dim:]
    k_rope_b = jnp.broadcast_to(
        k_rope[:, :, None, :], (B, T, h, a.rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    scale = (a.nope_head_dim + a.rope_head_dim) ** -0.5
    out = chunked_attention(q, k, v, causal=True, window=window,
                            scale=scale, chunk=chunk)
    return linear_apply(p["wo"], out.reshape(B, T, -1))


def mla_init_cache(a: AttentionConfig, batch: int, length: int,
                   dtype) -> Params:
    return {
        "ckv": jnp.zeros((batch, length, a.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, length, a.rope_head_dim), dtype),
        "pos": jnp.full((batch, length), -1, jnp.int32),
    }


def mla_decode(p: Params, a: AttentionConfig, x: jnp.ndarray,
               cache: Params, t: jnp.ndarray) -> Tuple[jnp.ndarray, Params]:
    """Absorbed decode: scores against the compressed cache directly.
    Cache is a ring buffer (sliding window when L < context)."""
    B = x.shape[0]
    L = cache["ckv"].shape[1]
    h = a.n_heads
    q_nope, q_rope = _mla_q(p, a, x, t[:, None])           # (B,1,h,*)
    c_kv, k_rope = _mla_ckv(p, a, x, t[:, None])           # (B,1,r),(B,1,rd)
    slot = (t % L).astype(jnp.int32)
    b_idx = jnp.arange(B)
    new_cache = {
        "ckv": cache["ckv"].at[b_idx, slot].set(c_kv[:, 0]),
        "krope": cache["krope"].at[b_idx, slot].set(k_rope[:, 0]),
        "pos": cache["pos"].at[b_idx, slot].set(t.astype(jnp.int32)),
    }
    wukv = p["wukv"].astype(jnp.float32)
    w_uk = wukv[..., :a.nope_head_dim]                     # (r,h,nope)
    w_uv = wukv[..., a.nope_head_dim:]                     # (r,h,v)
    # absorb W_uk into q: (B,h,r)
    q_abs = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0].astype(jnp.float32), w_uk)
    ckv_f = new_cache["ckv"].astype(jnp.float32)           # (B,L,r)
    s_nope = jnp.einsum("bhr,blr->bhl", q_abs, ckv_f)
    s_rope = jnp.einsum("bhd,bld->bhl",
                        q_rope[:, 0].astype(jnp.float32),
                        new_cache["krope"].astype(jnp.float32))
    scale = (a.nope_head_dim + a.rope_head_dim) ** -0.5
    s = (s_nope + s_rope) * scale
    valid = (new_cache["pos"] >= 0) & (new_cache["pos"] <= t[:, None])
    s = jnp.where(valid[:, None], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    out_c = jnp.einsum("bhl,blr->bhr", pr, ckv_f)          # (B,h,r)
    out = jnp.einsum("bhr,rhv->bhv", out_c, w_uv)          # (B,h,v)
    out = out.reshape(B, 1, h * a.v_head_dim).astype(x.dtype)
    return linear_apply(p["wo"], out), new_cache
