"""CNN models for the paper's own study (image classification).

LeNet / BN-LeNet (LeNet + BatchNorm after each conv, as in the paper) /
GN-LeNet (GroupNorm swap, §5.2) / BRN-LeNet (Batch Renormalization,
Appendix I) / AlexNet-s / ResNet-s.  NHWC layout, functional params, with
explicit BatchNorm state so the non-IID minibatch-statistics pathology is
observable and measurable (``repro.core.divergence``).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.cnn_zoo import CNNConfig
from repro.models.layers import (batchnorm_apply, batchrenorm_apply,
                                 groupnorm_apply, init_batchnorm,
                                 init_groupnorm)

Params = Dict[str, Any]


def _conv_init(key, k: int, c_in: int, c_out: int) -> jnp.ndarray:
    fan_in = k * k * c_in
    return jax.random.normal(key, (k, k, c_in, c_out)) * (2.0 / fan_in) ** 0.5


def init_cnn(key, cfg: CNNConfig) -> Tuple[Params, Params]:
    """Returns (params, state).  state = BatchNorm running stats (may be {})."""
    n_blocks = len(cfg.conv_channels)
    keys = jax.random.split(key, n_blocks + len(cfg.fc_dims) + 1)
    params: Params = {"conv": [], "norm": [], "fc": []}
    state: Params = {"norm": []}
    c_in = cfg.in_channels
    side = cfg.image_size
    for i, (c, k) in enumerate(zip(cfg.conv_channels, cfg.kernel_sizes)):
        params["conv"].append({"w": _conv_init(keys[i], k, c_in, c),
                               "b": jnp.zeros((c,))})
        if cfg.norm in ("batch", "batchrenorm"):
            np_, ns = init_batchnorm(c)
            params["norm"].append(np_)
            state["norm"].append(ns)
        elif cfg.norm == "group":
            params["norm"].append(init_groupnorm(c, cfg.group_size))
            state["norm"].append({})
        else:
            params["norm"].append({})
            state["norm"].append({})
        if cfg.pool_after[i]:
            side //= 2
        c_in = c
    d = side * side * c_in
    for j, fd in enumerate(cfg.fc_dims):
        kf = keys[n_blocks + j]
        params["fc"].append({
            "w": jax.random.normal(kf, (d, fd)) * (2.0 / d) ** 0.5,
            "b": jnp.zeros((fd,))})
        d = fd
    kf = keys[-1]
    params["out"] = {"w": jax.random.normal(kf, (d, cfg.n_classes)) * d ** -0.5,
                     "b": jnp.zeros((cfg.n_classes,))}
    return params, state


def cnn_apply(params: Params, state: Params, cfg: CNNConfig,
              images: jnp.ndarray, *, train: bool
              ) -> Tuple[jnp.ndarray, Params]:
    """images: (B, H, W, C).  Returns (logits, new_state)."""
    x = images
    new_norm_states = []
    prev_block = None
    for i, (cp, np_) in enumerate(zip(params["conv"], params["norm"])):
        y = jax.lax.conv_general_dilated(
            x, cp["w"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + cp["b"]
        ns = state["norm"][i]
        if cfg.norm == "batch" and np_:
            y, ns = batchnorm_apply(np_, ns, y, train=train)
        elif cfg.norm == "batchrenorm" and np_:
            y, ns = batchrenorm_apply(np_, ns, y, train=train)
        elif cfg.norm == "group" and np_:
            y = groupnorm_apply(np_, y, group_size=cfg.group_size)
        new_norm_states.append(ns)
        y = jax.nn.relu(y)
        if cfg.residual and prev_block is not None \
                and prev_block.shape == y.shape:
            y = y + prev_block
        prev_block = y
        x = y
        if cfg.pool_after[i]:
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1),
                "VALID")
            prev_block = None
    x = x.reshape(x.shape[0], -1)
    for fp in params["fc"]:
        x = jax.nn.relu(x @ fp["w"] + fp["b"])
    logits = x @ params["out"]["w"] + params["out"]["b"]
    return logits, {"norm": new_norm_states}


def cnn_batch_stats(params: Params, cfg: CNNConfig, images: jnp.ndarray,
                    layer: int = 0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Minibatch (mu_B, sigma_B) per channel at conv ``layer`` — the probe
    behind the paper's Figure 4 divergence analysis."""
    x = images
    for i, cp in enumerate(params["conv"]):
        y = jax.lax.conv_general_dilated(
            x, cp["w"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + cp["b"]
        if i == layer:
            mu = jnp.mean(y, axis=(0, 1, 2))
            var = jnp.var(y, axis=(0, 1, 2))
            return mu, var
        # continue through the network as if normless
        x = jax.nn.relu(y)
        if cfg.pool_after[i]:
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1),
                "VALID")
    raise ValueError(f"layer {layer} out of range")
