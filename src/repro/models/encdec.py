"""Encoder stack for enc-dec archs (seamless-m4t backbone).

The modality frontend (mel-spectrogram + conv feature extractor) is stubbed
per the carve-out: ``input_specs()`` provides precomputed frame embeddings
(B, n_frames, feat_dim).  The encoder here is the transformer stack that
consumes them (bidirectional self-attention); the decoder is the shared
``transformer.py`` machinery with cross-attention enabled.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import (gated_mlp_apply, init_gated_mlp, init_linear,
                                 linear_apply, make_norm)
from repro.models.transformer import _tree_stack

Params = Dict[str, Any]


def _dt(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


def init_encoder_layer(key, cfg: ModelConfig) -> Params:
    ed = cfg.encoder.d_model
    a = cfg.attention
    dt = _dt(cfg)
    ks = jax.random.split(key, 5)
    norm_init, _ = make_norm(cfg.norm, ed)
    h, hd = a.n_heads, a.head_dim
    return {
        "norm1": norm_init(), "norm2": norm_init(),
        "wq": init_linear(ks[0], ed, h * hd, dt),
        "wk": init_linear(ks[1], ed, h * hd, dt),
        "wv": init_linear(ks[2], ed, h * hd, dt),
        "wo": init_linear(ks[3], h * hd, ed, dt),
        "ffn": init_gated_mlp(ks[4], ed, cfg.d_ff, dt),
    }


def init_encoder(key, cfg: ModelConfig) -> Params:
    e = cfg.encoder
    ks = jax.random.split(key, e.n_layers + 2)
    dt = _dt(cfg)
    norm_init, _ = make_norm(cfg.norm, e.d_model)
    layers = [init_encoder_layer(ks[i], cfg) for i in range(e.n_layers)]
    p: Params = {
        "in_proj": init_linear(ks[-2], cfg.modality.feat_dim, e.d_model, dt),
        "layers": _tree_stack(layers),
        "final_norm": norm_init(),
    }
    return p


def encoder_apply(p: Params, cfg: ModelConfig, frames: jnp.ndarray
                  ) -> jnp.ndarray:
    """frames: (B, S, feat_dim) -> memory (B, S, enc_d_model)."""
    ed = cfg.encoder.d_model
    a = cfg.attention
    h, hd = a.n_heads, a.head_dim
    _, norm_apply = make_norm(cfg.norm, ed)
    x = linear_apply(p["in_proj"], frames.astype(_dt(cfg)))
    B, S, _ = x.shape

    def layer(x, lp):
        hh = norm_apply(lp["norm1"], x)
        q = linear_apply(lp["wq"], hh).reshape(B, S, h, hd)
        k = linear_apply(lp["wk"], hh).reshape(B, S, h, hd)
        v = linear_apply(lp["wv"], hh).reshape(B, S, h, hd)
        y = attn.chunked_attention(q, k, v, causal=False,
                                   chunk=min(512, S))
        x = x + linear_apply(lp["wo"], y.reshape(B, S, -1))
        x = x + gated_mlp_apply(lp["ffn"], norm_apply(lp["norm2"], x))
        return x, None

    x, _ = jax.lax.scan(layer, x, p["layers"])
    return norm_apply(p["final_norm"], x)
