"""Core layers: normalizations (the paper's §5 study lives here), MLPs,
embeddings.  Pure-functional: ``init_*`` build param pytrees, ``*_apply``
are side-effect-free.

BatchNorm carries running statistics explicitly (returned as updated state),
which is what makes the paper's non-IID pathology reproducible: each
partition's minibatch statistics (mu_B, sigma_B) diverge while the merged
model's running estimates match none of them.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.shard_hints import hint

Params = Dict[str, Any]


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Normalization layers
# ---------------------------------------------------------------------------

def init_rmsnorm(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm_apply(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(x.dtype)


def init_layernorm(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm_apply(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def init_batchnorm(channels: int) -> Tuple[Params, Params]:
    """Returns (params, state).  State = running mean/var, updated in train."""
    params = {"scale": jnp.ones((channels,), jnp.float32),
              "bias": jnp.zeros((channels,), jnp.float32)}
    state = {"mean": jnp.zeros((channels,), jnp.float32),
             "var": jnp.ones((channels,), jnp.float32),
             "count": jnp.zeros((), jnp.float32)}
    return params, state


def batchnorm_apply(p: Params, state: Params, x: jnp.ndarray, *,
                    train: bool, momentum: float = 0.9,
                    eps: float = 1e-5) -> Tuple[jnp.ndarray, Params]:
    """x: (B, H, W, C) or (B, C).  NHWC layout.

    Training uses minibatch statistics (the source of the paper's non-IID
    pathology); eval uses the running estimates.
    """
    xf = x.astype(jnp.float32)
    axes = tuple(range(x.ndim - 1))
    if train:
        mu = jnp.mean(xf, axis=axes)
        var = jnp.var(xf, axis=axes)
        new_state = {
            "mean": momentum * state["mean"] + (1 - momentum) * mu,
            "var": momentum * state["var"] + (1 - momentum) * var,
            "count": state["count"] + 1.0,
        }
    else:
        mu, var = state["mean"], state["var"]
        new_state = state
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"] + p["bias"]
    return y.astype(x.dtype), new_state


def batchrenorm_apply(p: Params, state: Params, x: jnp.ndarray, *,
                      train: bool, momentum: float = 0.9, eps: float = 1e-5,
                      r_max: float = 3.0, d_max: float = 5.0
                      ) -> Tuple[jnp.ndarray, Params]:
    """Batch Renormalization (Ioffe 2017) — Appendix I alternative.

    Uses minibatch stats corrected toward the running estimates by
    (clipped) r, d so train/eval normalization match more closely.
    """
    xf = x.astype(jnp.float32)
    axes = tuple(range(x.ndim - 1))
    if not train:
        y = (xf - state["mean"]) * jax.lax.rsqrt(state["var"] + eps)
        return (y * p["scale"] + p["bias"]).astype(x.dtype), state
    mu_b = jnp.mean(xf, axis=axes)
    var_b = jnp.var(xf, axis=axes)
    sigma_b = jnp.sqrt(var_b + eps)
    sigma = jnp.sqrt(state["var"] + eps)
    r = jax.lax.stop_gradient(jnp.clip(sigma_b / sigma, 1 / r_max, r_max))
    d = jax.lax.stop_gradient(
        jnp.clip((mu_b - state["mean"]) / sigma, -d_max, d_max))
    y = (xf - mu_b) / sigma_b * r + d
    y = y * p["scale"] + p["bias"]
    new_state = {
        "mean": momentum * state["mean"] + (1 - momentum) * mu_b,
        "var": momentum * state["var"] + (1 - momentum) * var_b,
        "count": state["count"] + 1.0,
    }
    return y.astype(x.dtype), new_state


def init_groupnorm(channels: int, group_size: int = 2) -> Params:
    assert channels % group_size == 0, (channels, group_size)
    return {"scale": jnp.ones((channels,), jnp.float32),
            "bias": jnp.zeros((channels,), jnp.float32)}


def groupnorm_apply(p: Params, x: jnp.ndarray, *, group_size: int = 2,
                    eps: float = 1e-5) -> jnp.ndarray:
    """GroupNorm (Wu & He 2018) with groups of ``group_size`` adjacent
    channels — per-sample statistics, hence minibatch-independent (the
    paper's §5.2 fix).  x: (B, H, W, C) or (B, C)."""
    xf = x.astype(jnp.float32)
    orig_shape = xf.shape
    c = orig_shape[-1]
    n_groups = c // group_size
    xg = xf.reshape(orig_shape[0], -1, n_groups, group_size)
    mu = jnp.mean(xg, axis=(1, 3), keepdims=True)
    var = jnp.var(xg, axis=(1, 3), keepdims=True)
    y = (xg - mu) * jax.lax.rsqrt(var + eps)
    y = y.reshape(orig_shape)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


def make_norm(kind: str, dim: int):
    """Returns (init_fn() -> params, apply_fn(params, x) -> y) for the
    per-sample norms used by transformer blocks."""
    if kind == "rms":
        return (lambda: init_rmsnorm(dim)), rmsnorm_apply
    if kind == "layer":
        return (lambda: init_layernorm(dim)), layernorm_apply
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Linear / MLP / Embedding
# ---------------------------------------------------------------------------

def init_linear(key, d_in: int, d_out: int, dtype=jnp.bfloat16,
                bias: bool = False, scale: Optional[float] = None) -> Params:
    s = scale if scale is not None else d_in ** -0.5
    w = (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear_apply(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_gated_mlp(key, d_model: int, d_ff: int, dtype=jnp.bfloat16) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_linear(k1, d_model, d_ff, dtype),
        "up": init_linear(k2, d_model, d_ff, dtype),
        "down": init_linear(k3, d_ff, d_model, dtype),
    }


def gated_mlp_apply(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    g = jax.nn.silu(linear_apply(p["gate"], x))
    u = linear_apply(p["up"], x)
    if g.ndim == 3:
        g = hint(g, "data", None, "model")
    return linear_apply(p["down"], g * u)


def init_embedding(key, vocab: int, d_model: int, dtype=jnp.bfloat16) -> Params:
    # unit-variance activations after the sqrt(d_model) embed scaling
    e = (jax.random.normal(key, (vocab, d_model), jnp.float32)
         * d_model ** -0.5).astype(dtype)
    return {"table": e}


def embedding_apply(p: Params, tokens: jnp.ndarray,
                    compute_dtype=None) -> jnp.ndarray:
    t = p["table"]
    if compute_dtype is not None:
        t = t.astype(compute_dtype)
    return jnp.take(t, tokens, axis=0)


def unembed_apply(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["table"].astype(x.dtype).T


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
