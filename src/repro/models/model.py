"""Top-level model API: one entry point per (family-agnostic) operation.

``batch`` layout (all produced by ``repro.launch.specs.input_specs``):
- train/prefill: {"tokens": (B, T_text) int32, "labels": (B, T_text) int32,
  ["patches": (B, n_vis, feat)] , ["frames": (B, S, feat)]}
- decode: {"token": (B,) int32, "t": (B,) int32, ["frames": ...]} + cache
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer

Params = Dict[str, Any]


def init_model(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    p = transformer.init_lm(k1, cfg)
    if cfg.encoder is not None:
        p["encoder"] = init_encoder_params(k2, cfg)
    return p


def init_encoder_params(key, cfg: ModelConfig) -> Params:
    return encdec.init_encoder(key, cfg)


def _memory(p: Params, cfg: ModelConfig, batch: Dict[str, Any]
            ) -> Optional[jnp.ndarray]:
    if cfg.encoder is None:
        return None
    return encdec.encoder_apply(p["encoder"], cfg, batch["frames"])


def forward(p: Params, cfg: ModelConfig, batch: Dict[str, Any], *,
            remat: bool = True, chunk: int = 512
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward.  Returns (logits (B,T,V) fp32, moe_aux)."""
    return transformer.lm_apply(
        p, cfg, batch["tokens"],
        patches=batch.get("patches"),
        memory=_memory(p, cfg, batch),
        remat=remat, chunk=chunk)


def loss_fn(p: Params, cfg: ModelConfig, batch: Dict[str, Any], *,
            remat: bool = True, chunk: int = 512) -> Tuple[jnp.ndarray, Dict]:
    logits, aux = forward(p, cfg, batch, remat=remat, chunk=chunk)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    ce = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return ce + aux, {"ce": ce, "aux": aux}


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               long_mode: bool = False) -> Params:
    return transformer.lm_init_cache(cfg, batch, cache_len, long_mode)


def decode_step(p: Params, cfg: ModelConfig, batch: Dict[str, Any],
                cache: Params) -> Tuple[jnp.ndarray, Params]:
    """One serve step: next-token logits + updated cache."""
    return transformer.lm_decode(
        p, cfg, batch["token"], cache, batch["t"],
        memory=_memory(p, cfg, batch))
