"""Mixture-of-Experts FFN (deepseek-v2 style): shared experts + routed
top-k experts with capacity-based scatter dispatch.

Dispatch is GShard-style with a fixed per-expert capacity so every shape is
static (jit/pjit-friendly) and the expert einsum carries an explicit expert
axis — shardable over the ``model`` mesh axis (expert parallelism).  Tokens
over capacity are dropped (their residual path passes through untouched).
"""
from __future__ import annotations

import math
import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import gated_mlp_apply, init_gated_mlp, init_linear
from repro.models.shard_hints import current_mesh, hint

Params = Dict[str, Any]


def init_moe(key, m: MoEConfig, d_model: int, dtype) -> Params:
    k_r, k_e, k_s = jax.random.split(key, 3)
    E, ff = m.n_experts, m.d_ff_expert
    ke = jax.random.split(k_e, 3)
    s = d_model ** -0.5
    p: Params = {
        "router": init_linear(k_r, d_model, E, jnp.float32),
        # stacked expert weights: (E, d, ff) / (E, ff, d)
        "w_gate": (jax.random.normal(ke[0], (E, d_model, ff)) * s).astype(dtype),
        "w_up":   (jax.random.normal(ke[1], (E, d_model, ff)) * s).astype(dtype),
        "w_down": (jax.random.normal(ke[2], (E, ff, d_model))
                   * ff ** -0.5).astype(dtype),
    }
    if m.n_shared:
        p["shared"] = init_gated_mlp(k_s, d_model, m.n_shared * ff, dtype)
    return p


def moe_capacity(m: MoEConfig, n_tokens: int) -> int:
    cap = int(math.ceil(n_tokens * m.top_k / m.n_experts * m.capacity_factor))
    return max(8, -(-cap // 8) * 8)  # round up to 8 for TPU-friendly shapes


def moe_apply(p: Params, m: MoEConfig, x: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, T, d).  Returns (y, aux_loss)."""
    B, T, d = x.shape
    N = B * T
    E, k = m.n_experts, m.top_k
    if os.environ.get("REPRO_MOE_EP"):
        from repro.models import moe_ep
        mesh = current_mesh()
        if moe_ep.ep_applicable_seq(m, B, T, mesh):
            y, aux = moe_ep.moe_apply_ep(p, m, x, mesh)
            if "shared" in p:
                y = y + gated_mlp_apply(p["shared"], x.reshape(N, d)
                                        ).reshape(B, T, d)
            return y, aux
    C = moe_capacity(m, N)
    xf = x.reshape(N, d)

    logits = (xf.astype(jnp.float32) @ p["router"]["w"])        # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, expert_idx = jax.lax.top_k(probs, k)                # (N, k)
    gate_w = gate_w / jnp.maximum(
        jnp.sum(gate_w, axis=-1, keepdims=True), 1e-9)          # renormalize

    # ---- load-balance auxiliary loss (Switch/GShard form) ----
    me = jnp.mean(probs, axis=0)                                # (E,)
    onehot_top1 = jax.nn.one_hot(expert_idx[:, 0], E)
    ce = jnp.mean(onehot_top1, axis=0)
    aux = m.router_aux_weight * E * jnp.sum(me * ce)

    # ---- capacity dispatch ----
    flat_e = expert_idx.reshape(-1)                             # (N*k,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)         # (N*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot              # rank in expert
    pos = jnp.sum(pos_in_e * onehot, axis=-1)                   # (N*k,)
    keep = pos < C
    slot = jnp.where(keep, flat_e * C + pos, E * C)             # OOB => dropped

    tok = jnp.repeat(jnp.arange(N), k)                          # (N*k,)
    buf = jnp.zeros((E * C, d), x.dtype)
    buf = buf.at[slot].add(xf[tok], mode="drop")                # scatter
    buf = hint(buf.reshape(E, C, d), "model", None, None)

    # ---- expert computation (expert axis shardable over 'model') ----
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(x.dtype)))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x.dtype))
    out = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"].astype(x.dtype))
    out = hint(out, "model", None, None)
    out_flat = out.reshape(E * C, d)

    # ---- combine ----
    slot_safe = jnp.minimum(slot, E * C - 1)
    gathered = out_flat[slot_safe] * keep[:, None]              # (N*k, d)
    gathered = hint(gathered, "data", None)
    gathered = gathered * gate_w.reshape(-1)[:, None].astype(x.dtype)
    y = hint(jnp.zeros((N, d), x.dtype).at[tok].add(gathered), "data", None)

    if "shared" in p:
        y = y + gated_mlp_apply(p["shared"], xf)
    return y.reshape(B, T, d), aux
