"""Expert-parallel MoE via shard_map + all_to_all — the structural fix for
the collective-bound MoE training rows (§Perf iteration 3's refuted GSPMD
attempt, done properly).

Tokens are manual-sharded over (data, model); experts over model.  Each
device routes its local tokens to the expert-owner peers along the
``model`` axis with ``all_to_all`` (the canonical EP schedule), computes
its E/M experts, and returns results the same way.  Capacity is enforced
per (source device, destination peer) and per local expert — exactly what
real EP systems do.  Cross-device traffic per layer is
O(local_tokens × top_k × d) instead of the global (E·C, d) buffer
all-reduces GSPMD emits for the gather-based formulation.

Enabled with REPRO_MOE_EP=1 under an active mesh with data+model axes
(single-pod path; the pod axis stays on the GSPMD formulation).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import SHARD_MAP_CHECK_KW as _CHECK_KW
from repro.compat import shard_map as _shard_map
from repro.configs.base import MoEConfig

Params = Dict[str, Any]


def _round8(n: int) -> int:
    return max(8, -(-n // 8) * 8)


def ep_applicable(m: MoEConfig, n_tokens: int, mesh) -> bool:
    if mesh is None or "data" not in mesh.axis_names \
            or "model" not in mesh.axis_names:
        return False
    D = mesh.shape["data"]
    M = mesh.shape["model"]
    return (n_tokens % (D * M) == 0 and m.n_experts % M == 0
            and n_tokens // (D * M) > 0)


def ep_applicable_seq(m: MoEConfig, B: int, T: int, mesh) -> bool:
    if not ep_applicable(m, B * T, mesh):
        return False
    return T % mesh.shape["model"] == 0 and B % mesh.shape["data"] == 0


def moe_apply_ep(p: Params, m: MoEConfig, x: jnp.ndarray, mesh
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, T, d) global.  Returns (y, aux) like moe_apply."""
    B, T, d = x.shape
    N = B * T
    E, k = m.n_experts, m.top_k
    D = mesh.shape["data"]
    M = mesh.shape["model"]
    E_loc = E // M
    N_loc = N // (D * M)
    # capacity per (source device, destination peer)
    C_send = _round8(math.ceil(N_loc * k / M * m.capacity_factor))
    # capacity per local expert (receives from M peers)
    C_exp = _round8(math.ceil(M * C_send / E_loc * m.capacity_factor))

    def body(xb, rw, wg, wu, wd):
        # xb: (N_loc, d) local tokens
        logits = xb.astype(jnp.float32) @ rw                  # (N_loc, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_w, eidx = jax.lax.top_k(probs, k)                # (N_loc, k)
        gate_w = gate_w / jnp.maximum(
            jnp.sum(gate_w, axis=-1, keepdims=True), 1e-9)
        # load-balance aux (global mean via pmean)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(eidx[:, 0], E), axis=0)
        aux = m.router_aux_weight * E * jnp.sum(me * ce)
        aux = jax.lax.pmean(jax.lax.pmean(aux, "data"), "model")

        flat_e = eidx.reshape(-1)                             # (Nk,)
        dest = flat_e // E_loc                                # owner peer
        ohd = jax.nn.one_hot(dest, M, dtype=jnp.int32)
        pos = jnp.sum((jnp.cumsum(ohd, axis=0) - ohd) * ohd, axis=-1)
        keep = pos < C_send
        send_slot = jnp.where(keep, dest * C_send + pos, M * C_send)
        tok = jnp.repeat(jnp.arange(N_loc), k)

        send_x = jnp.zeros((M * C_send, d), xb.dtype
                           ).at[send_slot].set(xb[tok], mode="drop")
        send_el = jnp.full((M * C_send,), -1, jnp.int32
                           ).at[send_slot].set(
            (flat_e % E_loc).astype(jnp.int32), mode="drop")

        recv_x = jax.lax.all_to_all(send_x, "model", 0, 0, tiled=True)
        recv_el = jax.lax.all_to_all(send_el, "model", 0, 0, tiled=True)

        # group received tokens by local expert
        valid = recv_el >= 0
        el = jnp.clip(recv_el, 0, E_loc - 1)
        ohe = jax.nn.one_hot(el, E_loc, dtype=jnp.int32) * valid[:, None]
        pos_e = jnp.sum((jnp.cumsum(ohe, axis=0) - ohe) * ohe, axis=-1)
        keep2 = valid & (pos_e < C_exp)
        buf_slot = jnp.where(keep2, el * C_exp + pos_e, E_loc * C_exp)
        buf = jnp.zeros((E_loc * C_exp, d), xb.dtype
                        ).at[buf_slot].set(recv_x, mode="drop")
        buf = buf.reshape(E_loc, C_exp, d)

        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
        u = jnp.einsum("ecd,edf->ecf", buf, wu)
        out = jnp.einsum("ecf,efd->ecd", g * u, wd)
        out_flat = out.reshape(E_loc * C_exp, d)

        back = out_flat[jnp.minimum(buf_slot, E_loc * C_exp - 1)] \
            * keep2[:, None].astype(xb.dtype)
        send_back = jax.lax.all_to_all(back, "model", 0, 0, tiled=True)

        contrib = send_back[jnp.minimum(send_slot, M * C_send - 1)] \
            * keep[:, None].astype(xb.dtype)
        contrib = contrib * gate_w.reshape(-1)[:, None].astype(xb.dtype)
        y = jnp.zeros((N_loc, d), xb.dtype).at[tok].add(contrib)
        return y, aux

    wg = p["w_gate"].astype(x.dtype)
    wu = p["w_up"].astype(x.dtype)
    wd = p["w_down"].astype(x.dtype)
    def body4(xb4, rw, wg, wu, wd):
        # xb4: (B_loc, 1, T//M, d) — explicit (batch, model-slice) layout so
        # the boundary reshard is a local slice, not GSPMD's replication
        # fallback
        B_loc = xb4.shape[0]
        y, aux = body(xb4.reshape(-1, d), rw, wg, wu, wd)
        return y.reshape(B_loc, 1, -1, d), aux

    sm = _shard_map(
        body4, mesh=mesh,
        in_specs=(P("data", "model", None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P("data", "model", None, None), P()),
        **{_CHECK_KW: False})
    x4 = x.reshape(B, M, T // M, d)
    y, aux = sm(x4, p["router"]["w"], wg, wu, wd)
    return y.reshape(B, T, d), aux
