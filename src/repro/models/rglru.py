"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The recurrence  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)  is a
first-order linear recurrence, evaluated over full sequences with
``jax.lax.associative_scan`` (log-depth, TPU-friendly) and in O(1) per token
at decode time.  a_t = exp(-c * softplus(Lambda) * r_t) with recurrence gate
r_t and input gate i_t.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RGLRUConfig
from repro.models.layers import init_linear, linear_apply
from repro.models.shard_hints import hint

Params = Dict[str, Any]

_C = 8.0  # Griffin's fixed scaling constant


def init_rglru(key, r: RGLRUConfig, d_model: int, dtype) -> Params:
    w = r.lru_width or d_model
    ks = jax.random.split(key, 6)
    return {
        # gated "recurrent unit" branch + linear gate branch (Griffin block)
        "in_x": init_linear(ks[0], d_model, w, dtype),
        "in_gate": init_linear(ks[1], d_model, w, dtype),
        "conv_w": (jax.random.normal(ks[2], (r.d_conv, w))
                   * r.d_conv ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": init_linear(ks[3], w, w, jnp.float32, bias=True),
        "w_i": init_linear(ks[4], w, w, jnp.float32, bias=True),
        # Lambda init so a^c is in (0.9, 0.999) at r=1 (Griffin appendix)
        "lam": jnp.log(jnp.expm1(
            -jnp.log(jnp.linspace(0.9, 0.999, w)) / _C)).astype(jnp.float32),
        "out": init_linear(ks[5], w, d_model, dtype),
    }


def _gates(p: Params, xw: jnp.ndarray):
    xf = xw.astype(jnp.float32)
    r_g = jax.nn.sigmoid(linear_apply(p["w_a"], xf))
    i_g = jax.nn.sigmoid(linear_apply(p["w_i"], xf))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r_g       # (..., w), <= 0
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed stably via log: 0.5*log1p(-exp(2 log_a))
    beta = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, 1.0))
    return a, beta * i_g * xf


def _conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):
        out = out + pad[:, i:i + x.shape[1]].astype(jnp.float32) \
            * w[K - 1 - i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def rglru_apply(p: Params, r: RGLRUConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Full-sequence path.  x: (B, T, d_model)."""
    gate = jax.nn.gelu(linear_apply(p["in_gate"], x))
    xw = _conv(linear_apply(p["in_x"], x), p["conv_w"], p["conv_b"])
    xw = hint(xw, "data", None, "model")
    a, b = _gates(p, xw)                                 # (B,T,w) fp32

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = h.astype(x.dtype) * gate
    return linear_apply(p["out"], y)


def rglru_init_state(r: RGLRUConfig, d_model: int, batch: int, dtype) -> Params:
    w = r.lru_width or d_model
    return {
        "conv": jnp.zeros((batch, r.d_conv - 1, w), dtype),
        "h": jnp.zeros((batch, w), jnp.float32),
    }


def rglru_decode(p: Params, r: RGLRUConfig, x: jnp.ndarray, state: Params
                 ) -> Tuple[jnp.ndarray, Params]:
    """One-token decode.  x: (B, 1, d_model)."""
    gate = jax.nn.gelu(linear_apply(p["in_gate"], x[:, 0]))
    xw_t = linear_apply(p["in_x"], x[:, 0])
    hist = jnp.concatenate([state["conv"], xw_t[:, None]], axis=1)
    # tap order: conv_w[0] multiplies the NEWEST sample (matches prefill)
    wconv = p["conv_w"][::-1].astype(jnp.float32)
    xw = (jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32), wconv)
          + p["conv_b"].astype(jnp.float32)).astype(x.dtype)
    a, b = _gates(p, xw)
    h = a * state["h"] + b
    y = h.astype(x.dtype) * gate
    out = linear_apply(p["out"], y)[:, None]
    return out, {"conv": hist[:, 1:].astype(state["conv"].dtype), "h": h}
