"""Rotary position embeddings, including the decoupled/partial variant used
by MLA (only ``rope_head_dim`` dims rotate)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """(head_dim//2,) inverse frequencies."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10_000.0) -> jnp.ndarray:
    """x: (..., T, n_heads, head_dim); positions: (..., T) or (T,).

    Rotates pairs (x[2i], x[2i+1]).  Returns same shape/dtype.
    """
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                       # (hd/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., T, hd/2)
    cos = jnp.cos(angles)[..., None, :]                        # (...,T,1,hd/2)
    sin = jnp.sin(angles)[..., None, :]
    xf = x.astype(jnp.float32)
    x1 = xf[..., 0::2]
    x2 = xf[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)
