"""Activation sharding hints.

Model code calls ``hint(x, "data", None, "model", None)`` at layout-critical
points (post-QKV reshape, MoE dispatch buffers, logits).  Outside a mesh
context this is a no-op, so unit tests and the CPU simulation backend are
untouched; under the dry-run / production mesh it emits
``with_sharding_constraint`` so GSPMD keeps heads/experts/vocab on the
``model`` axis instead of silently replicating them through reshapes
(observed: 16x per-device FLOP inflation without these hints).

Axes that do not divide the corresponding dimension are dropped per-call
(e.g. 8 KV heads on a 16-way model axis -> replicated KV, which is exactly
GQA's semantic).
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, Optional

import jax
from jax.sharding import PartitionSpec as P

_AXIS_ENV: contextvars.ContextVar = contextvars.ContextVar(
    "repro_axis_env", default=None)


_MESH: contextvars.ContextVar = contextvars.ContextVar(
    "repro_mesh", default=None)


@contextlib.contextmanager
def activation_sharding(mesh):
    """Enable hints for the given mesh (axis-name -> size)."""
    env = {name: int(size) for name, size in
           zip(mesh.axis_names, mesh.devices.shape)}
    tok = _AXIS_ENV.set(env)
    tok_m = _MESH.set(mesh)
    try:
        yield
    finally:
        _AXIS_ENV.reset(tok)
        _MESH.reset(tok_m)


def current_mesh():
    """The mesh of the active activation_sharding context, or None."""
    return _MESH.get()


def axis_env_size(name: str) -> int:
    """Mesh axis size under the active activation_sharding context, else 1.
    Lets model code pick shard-local formulations (e.g. per-data-group MoE
    dispatch) without importing the mesh."""
    env = _AXIS_ENV.get()
    return int(env.get(name, 1)) if env else 1


def hint(x, *axes):
    env: Optional[Dict[str, int]] = _AXIS_ENV.get()
    if env is None:
        return x
    ndim = getattr(x, "ndim", None)
    if ndim is None or len(axes) != ndim:
        return x
    spec = []
    for dim, ax in zip(x.shape, axes):
        if ax is None or ax not in env:
            spec.append(None)
        elif dim % env[ax] == 0 and dim >= env[ax]:
            spec.append(ax)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))
