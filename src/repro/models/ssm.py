"""Mamba-2 SSD (state-space duality) block — chunked dual form
[arXiv:2405.21060].

The sequence is split into chunks of length Q.  Within a chunk the SSD is
evaluated in its quadratic "attention-like" dual form (MXU-friendly); across
chunks a compact (heads, head_dim, d_state) recurrent state is carried with
``lax.scan``.  Decode is a single-step recurrence on the same state — O(1)
per token, which is why mamba2 runs long_500k natively.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import init_linear, init_rmsnorm, linear_apply, rmsnorm_apply
from repro.models.shard_hints import hint

Params = Dict[str, Any]


def d_inner(s: SSMConfig, d_model: int) -> int:
    return s.expand * d_model


def init_ssm(key, s: SSMConfig, d_model: int, dtype) -> Params:
    di = d_inner(s, d_model)
    assert di == s.n_heads * s.head_dim, (di, s.n_heads, s.head_dim)
    conv_ch = di + 2 * s.d_state
    ks = jax.random.split(key, 4)
    return {
        # projects to [z (di), x (di), B (n), C (n), dt (h)]
        "in_proj": init_linear(ks[0], d_model,
                               2 * di + 2 * s.d_state + s.n_heads, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_ch))
                   * s.d_conv ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((s.n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((s.n_heads,), jnp.float32),
        "D": jnp.ones((s.n_heads,), jnp.float32),
        "norm": init_rmsnorm(di),
        "out_proj": init_linear(ks[2], di, d_model, dtype),
    }


def _split_proj(s: SSMConfig, proj: jnp.ndarray, d_model: int):
    di = d_inner(s, d_model)
    n = s.d_state
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * n]
    dt = proj[..., di + di + 2 * n:]
    return z, xbc, dt


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray
                 ) -> jnp.ndarray:
    """Depthwise causal conv over time.  xbc: (B, T, Ch); w: (K, Ch)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(K):  # K is tiny (4); unrolled taps beat a conv call here
        out = out + pad[:, i:i + xbc.shape[1]].astype(jnp.float32) \
            * w[K - 1 - i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(xbc.dtype)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
                B_in: jnp.ndarray, C_in: jnp.ndarray, D: jnp.ndarray, *,
                chunk: int, init_state: jnp.ndarray = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD over a full sequence.

    x: (B, T, h, p); dt: (B, T, h) (post-softplus); B_in/C_in: (B, T, n);
    a_log: (h,) (A = -exp(a_log)).  Returns (y: (B,T,h,p), final_state:
    (B, h, p, n)).
    """
    Bsz, T, h, p_dim = x.shape
    n = B_in.shape[-1]
    Q = min(chunk, T)
    assert T % Q == 0, (T, Q)
    nc = T // Q
    A = -jnp.exp(a_log)                                        # (h,) < 0

    xd = x.astype(jnp.float32) * dt[..., None]                 # x * dt
    dA = dt * A                                                # (B,T,h) <= 0

    def reshape_c(v, tail):
        return v.reshape((Bsz, nc, Q) + tail).transpose(
            (1, 0, 2) + tuple(range(3, 3 + len(tail))))

    xd_c = reshape_c(xd, (h, p_dim))        # (nc,B,Q,h,p)
    dA_c = reshape_c(dA, (h,))              # (nc,B,Q,h)
    B_c = reshape_c(B_in.astype(jnp.float32), (n,))
    C_c = reshape_c(C_in.astype(jnp.float32), (n,))

    if init_state is None:
        init_state = jnp.zeros((Bsz, h, p_dim, n), jnp.float32)

    def body(S, inp):
        xd_k, dA_k, B_k, C_k = inp
        cum = jnp.cumsum(dA_k, axis=1)                         # (B,Q,h)
        total = cum[:, -1]                                     # (B,h)
        # intra-chunk (dual quadratic form)
        rel = cum[:, :, None, :] - cum[:, None, :, :]          # (B,q,k,h)
        causal = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])
        L = jnp.exp(jnp.where(causal[None, :, :, None], rel, -jnp.inf))
        scores = jnp.einsum("bqn,bkn->bqk", C_k, B_k)          # (B,q,k)
        y_intra = jnp.einsum("bqk,bqkh,bkhp->bqhp", scores, L, xd_k)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bqn,bhpn,bqh->bqhp", C_k, S, jnp.exp(cum))
        # state update
        w_end = jnp.exp(total[:, None, :] - cum)               # (B,Q,h)
        S_new = jnp.exp(total)[:, :, None, None] * S + jnp.einsum(
            "bqh,bqn,bqhp->bhpn", w_end, B_k, xd_k)
        return S_new, y_intra + y_inter

    S_final, y = jax.lax.scan(body, init_state, (xd_c, dA_c, B_c, C_c))
    y = y.transpose(1, 0, 2, 3, 4).reshape(Bsz, T, h, p_dim)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y, S_final


def ssm_apply(p: Params, s: SSMConfig, d_model: int, x: jnp.ndarray
              ) -> jnp.ndarray:
    """Full-sequence (train/prefill) path.  x: (B, T, d_model)."""
    Bsz, T, _ = x.shape
    di = d_inner(s, d_model)
    proj = linear_apply(p["in_proj"], x)
    z, xbc, dt = _split_proj(s, proj, d_model)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = hint(xbc[..., :di].reshape(Bsz, T, s.n_heads, s.head_dim),
              "data", None, "model", None)
    B_in = xbc[..., di:di + s.d_state]
    C_in = xbc[..., di + s.d_state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    y, _ = ssd_chunked(xs, dt, p["A_log"], B_in, C_in, p["D"], chunk=s.chunk)
    y = y.reshape(Bsz, T, di).astype(x.dtype)
    y = rmsnorm_apply(p["norm"], y * jax.nn.silu(z))
    return linear_apply(p["out_proj"], y)


def ssm_init_state(s: SSMConfig, d_model: int, batch: int, dtype) -> Params:
    di = d_inner(s, d_model)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, di + 2 * s.d_state), dtype),
        "ssd": jnp.zeros((batch, s.n_heads, s.head_dim, s.d_state),
                         jnp.float32),
    }


def ssm_decode(p: Params, s: SSMConfig, d_model: int, x: jnp.ndarray,
               state: Params) -> Tuple[jnp.ndarray, Params]:
    """One-token decode.  x: (B, 1, d_model).  O(1) state update."""
    Bsz = x.shape[0]
    di = d_inner(s, d_model)
    proj = linear_apply(p["in_proj"], x[:, 0])
    z, xbc, dt = _split_proj(s, proj, d_model)
    # conv over [state ++ current]
    hist = jnp.concatenate([state["conv"], xbc[:, None]], axis=1)  # (B,K,Ch)
    # tap order: conv_w[0] multiplies the NEWEST sample (matches prefill)
    w = p["conv_w"][::-1].astype(jnp.float32)
    conv = jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32), w)
    xbc_t = jax.nn.silu(conv + p["conv_b"].astype(jnp.float32)
                        ).astype(x.dtype)
    xs = xbc_t[..., :di].reshape(Bsz, s.n_heads, s.head_dim)
    B_in = xbc_t[..., di:di + s.d_state].astype(jnp.float32)
    C_in = xbc_t[..., di + s.d_state:].astype(jnp.float32)
    dt_t = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,h)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt_t * A)                                     # (B,h)
    xd = xs.astype(jnp.float32) * dt_t[..., None]
    S = decay[:, :, None, None] * state["ssd"] + jnp.einsum(
        "bn,bhp->bhpn", B_in, xd)
    y = jnp.einsum("bn,bhpn->bhp", C_in, S)
    y = y + xs.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(Bsz, di).astype(x.dtype)
    y = rmsnorm_apply(p["norm"], y * jax.nn.silu(z))
    out = linear_apply(p["out_proj"], y)[:, None]
    new_state = {"conv": hist[:, 1:].astype(state["conv"].dtype), "ssd": S}
    return out, new_state
