"""Decoder-only LM composition covering all assigned families:
dense / moe / mla / hybrid(rglru) / ssm / vlm (decoder of enc-dec lives in
``encdec.py`` but reuses the same layer machinery).

Layers are grouped into *cycles* (the smallest repeating structural unit —
e.g. gemma2's (local, global), recurrentgemma's (rglru, rglru, attn)) and the
body of the network is a ``lax.scan`` over stacked cycle parameters.  This
keeps trace/compile time O(cycle) instead of O(n_layers) — essential for the
60-layer MoE dry-runs — and gives the checkpointing policy a natural remat
unit.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.shard_hints import hint
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (embedding_apply, gated_mlp_apply,
                                 init_embedding, init_gated_mlp, init_linear,
                                 linear_apply, make_norm, softcap,
                                 unembed_apply)

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


# ---------------------------------------------------------------------------
# Layer structure
# ---------------------------------------------------------------------------

def layer_sig(cfg: ModelConfig, i: int) -> Tuple:
    """Structural signature of layer i: (mixer, window, is_moe)."""
    kind = cfg.block_kind(i)
    window = cfg.attn_window(i) if kind == "attn" else None
    is_moe = bool(cfg.moe.n_experts) and i >= cfg.moe.first_dense_layers
    return (kind, window, is_moe)


def cycle_period(cfg: ModelConfig) -> int:
    p = len(cfg.attention.layer_pattern)
    if cfg.rglru is not None:
        p = p * len(cfg.rglru.block_pattern) // math.gcd(
            p, len(cfg.rglru.block_pattern))
    return p


def layer_plan(cfg: ModelConfig) -> Tuple[List[int], int, int, List[int]]:
    """Returns (prefix_layers, n_cycles, period, suffix_layers)."""
    start = cfg.moe.first_dense_layers if cfg.moe.n_experts else 0
    start = min(start, cfg.n_layers)
    P = cycle_period(cfg)
    body = cfg.n_layers - start
    n_cycles = body // P
    n_suffix = body - n_cycles * P
    prefix = list(range(start))
    suffix = list(range(cfg.n_layers - n_suffix, cfg.n_layers))
    return prefix, n_cycles, P, suffix


# ---------------------------------------------------------------------------
# Single layer
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, i: int) -> Params:
    dt = _dtype(cfg)
    kind, _, is_moe = layer_sig(cfg, i)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    norm_init, _ = make_norm(cfg.norm, cfg.d_model)
    p: Params = {"norm1": norm_init(), "norm2": norm_init()}
    if kind == "attn":
        a = cfg.attention
        if a.kind == "mla":
            p["mixer"] = attn.init_mla(k1, a, cfg.d_model, dt)
        else:
            p["mixer"] = attn.init_gqa(k1, a, cfg.d_model, dt)
    elif kind == "rglru":
        p["mixer"] = rglru_mod.init_rglru(k1, cfg.rglru, cfg.d_model, dt)
    elif kind == "ssm":
        p["mixer"] = ssm_mod.init_ssm(k1, cfg.ssm, cfg.d_model, dt)
    else:
        raise ValueError(kind)
    if cfg.family == "ssm":
        p.pop("norm2")          # mamba2: single mixer per block, no FFN
    elif is_moe:
        p["ffn"] = moe_mod.init_moe(k2, cfg.moe, cfg.d_model, dt)
    else:
        p["ffn"] = init_gated_mlp(k2, cfg.d_model, cfg.d_ff, dt)
    if cfg.encoder is not None:
        # enc-dec decoder layer: cross-attention to encoder memory
        p["norm_x"] = norm_init()
        p["xattn"] = _init_xattn(k3, cfg, dt)
    return p


def _init_xattn(key, cfg: ModelConfig, dt) -> Params:
    a = cfg.attention
    ks = jax.random.split(key, 4)
    ed = cfg.encoder.d_model
    h, hd = a.n_heads, a.head_dim
    return {
        "wq": init_linear(ks[0], cfg.d_model, h * hd, dt),
        "wk": init_linear(ks[1], ed, h * hd, dt),
        "wv": init_linear(ks[2], ed, h * hd, dt),
        "wo": init_linear(ks[3], h * hd, cfg.d_model, dt),
    }


def _xattn_apply(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                 memory: jnp.ndarray) -> jnp.ndarray:
    a = cfg.attention
    B, T, _ = x.shape
    S = memory.shape[1]
    h, hd = a.n_heads, a.head_dim
    q = linear_apply(p["wq"], x).reshape(B, T, h, hd)
    k = linear_apply(p["wk"], memory).reshape(B, S, h, hd)
    v = linear_apply(p["wv"], memory).reshape(B, S, h, hd)
    out = attn.chunked_attention(q, k, v, causal=False,
                                 chunk=min(512, S))
    return linear_apply(p["wo"], out.reshape(B, T, -1))


def layer_apply(p: Params, cfg: ModelConfig, sig: Tuple, x: jnp.ndarray, *,
                positions: jnp.ndarray, memory: Optional[jnp.ndarray],
                chunk: int = 512) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence layer.  Returns (x, moe_aux)."""
    kind, window, is_moe = sig
    _, norm_apply = make_norm(cfg.norm, cfg.d_model)
    aux = jnp.zeros((), jnp.float32)
    h = norm_apply(p["norm1"], x)
    if kind == "attn":
        a = cfg.attention
        if a.kind == "mla":
            y = attn.mla_apply(p["mixer"], a, h, positions=positions,
                               window=window, chunk=chunk)
        else:
            y = attn.gqa_apply(p["mixer"], a, h, window=window,
                               positions=positions, chunk=chunk)
    elif kind == "rglru":
        y = rglru_mod.rglru_apply(p["mixer"], cfg.rglru, h)
    else:
        y = ssm_mod.ssm_apply(p["mixer"], cfg.ssm, cfg.d_model, h)
    x = x + y
    if memory is not None and "xattn" in p:
        x = x + _xattn_apply(p["xattn"], cfg, norm_apply(p["norm_x"], x),
                             memory)
    if "norm2" in p:
        h2 = norm_apply(p["norm2"], x)
        if is_moe:
            y2, aux = moe_mod.moe_apply(p["ffn"], cfg.moe, h2)
        else:
            y2 = gated_mlp_apply(p["ffn"], h2)
        x = x + y2
    return x, aux


# ---------------------------------------------------------------------------
# Decode-path cache per layer
# ---------------------------------------------------------------------------

def layer_init_cache(cfg: ModelConfig, i: int, batch: int, cache_len: int,
                     long_mode: bool) -> Params:
    dt = _dtype(cfg)
    kind, window, _ = layer_sig(cfg, i)
    if kind == "attn":
        a = cfg.attention
        L = cache_len
        if window is not None:
            L = min(L, window)
        if long_mode and cfg.long_context == "window":
            L = min(L, cfg.long_window)
        if a.kind == "mla":
            return attn.mla_init_cache(a, batch, L, dt)
        return attn.gqa_init_cache(a, batch, L, dt)
    if kind == "rglru":
        return rglru_mod.rglru_init_state(cfg.rglru, cfg.d_model, batch, dt)
    return ssm_mod.ssm_init_state(cfg.ssm, cfg.d_model, batch, dt)


def layer_decode(p: Params, cfg: ModelConfig, sig: Tuple, x: jnp.ndarray,
                 cache: Params, t: jnp.ndarray,
                 memory: Optional[jnp.ndarray]
                 ) -> Tuple[jnp.ndarray, Params]:
    kind, _, _ = sig
    _, norm_apply = make_norm(cfg.norm, cfg.d_model)
    h = norm_apply(p["norm1"], x)
    if kind == "attn":
        a = cfg.attention
        if a.kind == "mla":
            y, cache = attn.mla_decode(p["mixer"], a, h, cache, t)
        else:
            y, cache = attn.gqa_decode(p["mixer"], a, h, cache, t)
    elif kind == "rglru":
        y, cache = rglru_mod.rglru_decode(p["mixer"], cfg.rglru, h, cache)
    else:
        y, cache = ssm_mod.ssm_decode(p["mixer"], cfg.ssm, cfg.d_model, h,
                                      cache)
    x = x + y
    if memory is not None and "xattn" in p:
        x = x + _xattn_apply(p["xattn"], cfg, norm_apply(p["norm_x"], x),
                             memory)
    if "norm2" in p:
        h2 = norm_apply(p["norm2"], x)
        if sig[2]:
            y2, _ = moe_mod.moe_apply(p["ffn"], cfg.moe, h2)
        else:
            y2 = gated_mlp_apply(p["ffn"], h2)
        x = x + y2
    return x, cache


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------

def _tree_stack(trees: List[Params]) -> Params:
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def init_lm(key, cfg: ModelConfig) -> Params:
    dt = _dtype(cfg)
    prefix, n_cycles, P, suffix = layer_plan(cfg)
    keys = jax.random.split(key, cfg.n_layers + 4)
    p: Params = {"embed": init_embedding(keys[0], cfg.vocab, cfg.d_model, dt)}
    norm_init, _ = make_norm(cfg.norm, cfg.d_model)
    p["final_norm"] = norm_init()
    if not cfg.tie_embeddings:
        p["unembed"] = init_linear(keys[1], cfg.d_model, cfg.vocab, dt)
    if cfg.modality.kind == "vision":
        p["projector"] = init_linear(keys[2], cfg.modality.feat_dim,
                                     cfg.d_model, dt)
    p["prefix"] = [init_layer(keys[4 + i], cfg, i) for i in prefix]
    base = len(prefix)
    cycles = []
    for c in range(n_cycles):
        cyc = [init_layer(keys[4 + base + c * P + j], cfg, base + c * P + j)
               for j in range(P)]
        cycles.append(cyc)
    p["body"] = _tree_stack(cycles) if cycles else None
    p["suffix"] = [init_layer(keys[4 + i], cfg, i) for i in suffix]
    return p


def body_sigs(cfg: ModelConfig) -> List[Tuple]:
    prefix, n_cycles, P, _ = layer_plan(cfg)
    base = len(prefix)
    return [layer_sig(cfg, base + j) for j in range(P)]


# ---------------------------------------------------------------------------
# Whole-model forward (train / prefill)
# ---------------------------------------------------------------------------

def embed_inputs(p: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                 patches: Optional[jnp.ndarray]) -> jnp.ndarray:
    dt = _dtype(cfg)
    x = embedding_apply(p["embed"], tokens, compute_dtype=dt)
    x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    if cfg.modality.kind == "vision" and patches is not None:
        vis = linear_apply(p["projector"], patches.astype(dt))
        x = jnp.concatenate([vis, x], axis=1)
    return hint(x, "data", None, None)


def lm_apply(p: Params, cfg: ModelConfig, tokens: jnp.ndarray, *,
             patches: Optional[jnp.ndarray] = None,
             memory: Optional[jnp.ndarray] = None,
             remat: bool = True,
             chunk: int = 512) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens: (B, T_text).  Returns (logits over the *text* positions,
    moe_aux_loss).  For VLM, ``patches`` prepend cfg.modality.n_tokens
    embeddings; logits for those positions are dropped."""
    prefix, n_cycles, P, suffix = layer_plan(cfg)
    x = embed_inputs(p, cfg, tokens, patches)
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    aux = jnp.zeros((), jnp.float32)

    for i, lp in zip(prefix, p["prefix"]):
        x, a = layer_apply(lp, cfg, layer_sig(cfg, i), x,
                           positions=positions, memory=memory, chunk=chunk)
        aux = aux + a

    if p["body"] is not None:
        sigs = body_sigs(cfg)

        def cycle(carry, cyc_params):
            x, aux = carry
            for j in range(P):
                x, a = layer_apply(
                    cyc_params[j], cfg, sigs[j], x, positions=positions,
                    memory=memory, chunk=chunk)
                aux = aux + a
            return (x, aux), None

        body_fn = jax.checkpoint(cycle) if remat else cycle
        (x, aux), _ = jax.lax.scan(body_fn, (x, aux), p["body"])

    for i, lp in zip(suffix, p["suffix"]):
        x, a = layer_apply(lp, cfg, layer_sig(cfg, i), x,
                           positions=positions, memory=memory, chunk=chunk)
        aux = aux + a

    _, norm_apply = make_norm(cfg.norm, cfg.d_model)
    x = norm_apply(p["final_norm"], x)
    n_vis = cfg.modality.n_tokens if cfg.modality.kind == "vision" else 0
    if n_vis:
        x = x[:, n_vis:]
    if cfg.tie_embeddings:
        logits = unembed_apply(p["embed"], x)
    else:
        logits = linear_apply(p["unembed"], x)
    logits = hint(logits, "data", None, "model")
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits, aux


# ---------------------------------------------------------------------------
# Whole-model decode
# ---------------------------------------------------------------------------

def lm_init_cache(cfg: ModelConfig, batch: int, cache_len: int,
                  long_mode: bool = False) -> Params:
    prefix, n_cycles, P, suffix = layer_plan(cfg)
    base = len(prefix)
    cache: Params = {
        "prefix": [layer_init_cache(cfg, i, batch, cache_len, long_mode)
                   for i in prefix],
        "suffix": [layer_init_cache(cfg, i, batch, cache_len, long_mode)
                   for i in suffix],
    }
    if n_cycles:
        cyc = [[layer_init_cache(cfg, base + j, batch, cache_len, long_mode)
                for j in range(P)] for _ in range(n_cycles)]
        cache["body"] = _tree_stack(cyc)
    else:
        cache["body"] = None
    return cache


def lm_decode(p: Params, cfg: ModelConfig, token: jnp.ndarray,
              cache: Params, t: jnp.ndarray, *,
              memory: Optional[jnp.ndarray] = None
              ) -> Tuple[jnp.ndarray, Params]:
    """token: (B,) int32; t: (B,) absolute positions.  One decode step."""
    prefix, n_cycles, P, suffix = layer_plan(cfg)
    dt = _dtype(cfg)
    x = embedding_apply(p["embed"], token[:, None], compute_dtype=dt)
    x = x * jnp.asarray(cfg.d_model ** 0.5, dt)

    new_cache: Params = {"prefix": [], "suffix": [], "body": None}
    for i, lp, lc in zip(prefix, p["prefix"], cache["prefix"]):
        x, nc = layer_decode(lp, cfg, layer_sig(cfg, i), x, lc, t, memory)
        new_cache["prefix"].append(nc)

    if p["body"] is not None:
        sigs = body_sigs(cfg)

        def cycle(x, scanned):
            cyc_params, cyc_cache = scanned
            new_cc = []
            for j in range(P):
                x, nc = layer_decode(cyc_params[j], cfg, sigs[j], x,
                                     cyc_cache[j], t, memory)
                new_cc.append(nc)
            return x, new_cc

        x, new_body = jax.lax.scan(cycle, x, (p["body"], cache["body"]))
        new_cache["body"] = new_body

    for i, lp, lc in zip(suffix, p["suffix"], cache["suffix"]):
        x, nc = layer_decode(lp, cfg, layer_sig(cfg, i), x, lc, t, memory)
        new_cache["suffix"].append(nc)

    _, norm_apply = make_norm(cfg.norm, cfg.d_model)
    x = norm_apply(p["final_norm"], x)
    if cfg.tie_embeddings:
        logits = unembed_apply(p["embed"], x)
    else:
        logits = linear_apply(p["unembed"], x)
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits[:, 0], new_cache
