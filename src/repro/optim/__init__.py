from repro.optim.schedules import constant, polynomial_decay, step_decay
from repro.optim.sgd import (clip_by_global_norm, global_norm, init_momentum,
                             momentum_update)

__all__ = ["constant", "polynomial_decay", "step_decay",
           "clip_by_global_norm", "global_norm", "init_momentum",
           "momentum_update"]
