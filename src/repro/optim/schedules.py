"""Learning-rate schedules from the paper's training tables (App. C)."""
from __future__ import annotations

from typing import Callable, Sequence

import jax.numpy as jnp


def step_decay(eta0: float, boundaries: Sequence[int], factor: float = 0.1
               ) -> Callable:
    """'divides by 10 at epoch 64 and 96' — boundaries in *steps*."""
    bounds = jnp.asarray(list(boundaries))

    def lr(step):
        n = jnp.sum(step >= bounds)
        return eta0 * factor ** n
    return lr


def polynomial_decay(eta0: float, max_steps: int, power: float = 0.5
                     ) -> Callable:
    def lr(step):
        frac = jnp.clip(step / max_steps, 0.0, 1.0)
        return eta0 * (1.0 - frac) ** power
    return lr


def constant(eta0: float) -> Callable:
    return lambda step: jnp.asarray(eta0)
