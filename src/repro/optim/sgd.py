"""Vanilla momentum SGD — the optimizer form the paper's Algorithms 1-3 are
written against:  u <- m*u - eta*grad ;  w <- w + u.

Weight decay is applied as L2-in-gradient (Caffe semantics, matching the
paper's training setup tables)."""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Params = Any


def init_momentum(params: Params) -> Params:
    return jax.tree_util.tree_map(jnp.zeros_like, params)


def momentum_update(params: Params, grads: Params, velocity: Params, *,
                    lr: jnp.ndarray, momentum: float = 0.9,
                    weight_decay: float = 0.0
                    ) -> Tuple[Params, Params, Params]:
    """Returns (new_params, new_velocity, update).  ``update`` is the weight
    delta u applied this step — what Gaia/DGC accumulate and exchange."""
    def upd(w, g, u):
        g = g + weight_decay * w
        u_new = momentum * u - lr * g
        return u_new
    new_v = jax.tree_util.tree_map(upd, params, grads, velocity)
    new_p = jax.tree_util.tree_map(lambda w, u: w + u, params, new_v)
    return new_p, new_v, new_v


def global_norm(tree: Params) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree: Params, max_norm: float) -> Params:
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-12))
    return jax.tree_util.tree_map(lambda l: l * scale, tree)
