"""Communication-fabric subsystem: who talks to whom, and what it costs.

Module map
----------
``graphs.py``
    :class:`Topology` (edge list + symmetric doubly-stochastic mixing
    matrix + per-edge LAN/WAN class) and the builders: ``fully_connected``,
    ``ring``, ``torus``, ``random_regular`` (expander), ``hierarchical``
    (geo-WAN datacenters), ``d_cliques`` (label-aware cliques from
    partition label histograms).  ``build_topology`` is the registry keyed
    by ``CommConfig.topology``.

``costs.py``
    :class:`LinkProfile` (per-class bandwidth/latency presets in
    ``LINK_PROFILES``: uniform | datacenter | geo-wan) and
    :class:`CommLedger`, which turns each algorithm's exchanged floats
    into per-link traffic, LAN/WAN totals, and a simulated wall-clock
    step time.  The ledger is threaded through ``core/trainer.py`` and
    prices SkewScout's ``C(theta)/CM`` objective in WAN-weighted cost.

Downstream consumers
--------------------
``core/algorithms/dpsgd.py`` (gossip averaging = ``W @ params`` on graph
edges, via the ``kernels/neighbor_mix.py`` Pallas kernel),
``benchmarks/fig_topology.py`` (topology x skew sweep), and
``examples/train_topology.py`` (the geo-WAN scenario end-to-end).
"""
from repro.topology.costs import LINK_PROFILES, CommLedger, LinkProfile
from repro.topology.graphs import (Topology, build_topology, d_cliques,
                                   fully_connected, hierarchical,
                                   metropolis_weights, random_regular,
                                   ring, torus)

__all__ = ["LINK_PROFILES", "CommLedger", "LinkProfile", "Topology",
           "build_topology", "d_cliques", "fully_connected",
           "hierarchical", "metropolis_weights", "random_regular",
           "ring", "torus"]
