"""Communication-fabric subsystem: who talks to whom, when, and at what
cost.

Module map
----------
``graphs.py``
    :class:`Topology` (edge list + symmetric doubly-stochastic mixing
    matrix + per-edge LAN/WAN class + cached adjacency) and the static
    builders: ``fully_connected``, ``ring``, ``torus``,
    ``random_regular`` (expander), ``hierarchical`` (geo-WAN
    datacenters), ``hierarchical_cliques`` (bounded-degree
    cliques-of-cliques — the 10k+-node ledger-scale fabric; past
    ``MIXING_AUTO_MAX`` nodes the dense mixing matrix is skipped),
    ``d_cliques`` (label-aware cliques from partition
    label histograms).  :class:`TopologySchedule` generalizes the fabric
    to one graph *per round*: ``constant_schedule`` wraps any static
    graph, ``time_varying_d_cliques`` is Bellet et al.'s
    one-peer-per-round variant, ``random_matching_schedule`` is the
    EquiTopo-style i.i.d. matching fabric, and ``topology_ladder``
    builds SkewScout's rungs (full -> hierarchical -> dcliques -> ring).
    ``build_topology`` / ``build_schedule`` are the registries keyed by
    ``CommConfig.topology``.

``costs.py``
    :class:`LinkProfile` (per-class bandwidth/latency/handshake presets
    in ``LINK_PROFILES``: uniform | datacenter | geo-wan) and
    :class:`CommLedger`, which prices each algorithm's exchanged floats
    against the *active edge set of the round's graph*, tracks LAN/WAN
    totals and a simulated wall-clock step time, and charges an explicit
    online re-wiring cost — control-plane floats plus per-class
    handshake latency — whenever the active edge set changes (schedule
    rotation or a SkewScout rung switch via ``switch_schedule``).  Two
    timing models share the float accounting: synchronous rounds cost
    the slowest activated link; ``async_mode`` (AD-PSGD) gives every
    link its own virtual clock — a round costs the activated edges' max
    clock, bounded staleness amortizes link latency, and per-node
    busy/idle/clock-skew accounting exposes the stragglers.  All
    bookkeeping lives in flat arrays over a stable edge index — one
    gossip round is O(active edges) of vectorized work, so 10k+-node
    fabrics price in milliseconds per round.  Reads go through the
    frozen :class:`LedgerView` snapshot (``CommLedger.view()``); the
    old per-quantity accessors survive as deprecated shims.  The ledger
    is threaded through ``core/trainer.py`` and prices SkewScout's
    ``C(theta)/CM`` objective in WAN-weighted cost (sync) or simulated
    wall-clock (async); SkewScout probe shipments are booked per edge
    via ``record_probe``.

``links.py``
    :class:`LinkModel`, the stochastic-heterogeneous-link sampler: each
    edge draws a persistent base latency/bandwidth from its class's
    distribution (``hetero``), every activation applies a median-1
    lognormal jitter (``jitter``), and a per-edge Markov chain produces
    bursty transient slowdowns (``straggler_rate`` / ``straggler_exit``
    / ``straggler_slowdown``).  All draws are keyed by ``(seed, edge,
    activation index)`` — bit-identical replay across ledger rebuilds.
    The ledger samples it when ``link_model=`` is attached, folds each
    observation into per-edge EWMA *measured* costs
    (``measured_full_exchange_time/cost``), and amortizes re-wiring
    handshakes over ``amortize_window`` activations.
    ``make_link_model`` builds it from a ``LinkConfig``
    (``CommConfig.fabric.link``).  :class:`Participation` is the seeded
    per-round node sampler behind partial participation: the same mask
    gates the ledger's priced traffic, the gossip mixing weights, and
    SkewScout's probe routes, on a key stream disjoint from the link
    draws.

Downstream consumers
--------------------
``core/algorithms/dpsgd.py`` (gossip averaging = ``W_t @ params`` on the
round's edges, per-round neighbor operands through the
``kernels/neighbor_mix.py`` Pallas kernel — one compilation per run),
``core/algorithms/adpsgd.py`` (bounded-staleness async gossip over the
same kernel's src-gather variant), ``core/skewscout.py`` (topology and
staleness as ladder rungs), ``benchmarks/fig_topology.py`` (topology x
skew x schedule sweep + sync-vs-async column), and
``examples/train_topology.py`` (the geo-WAN scenario end-to-end).
"""
from repro.topology.costs import (LINK_PROFILES, CommLedger, LedgerView,
                                  LinkProfile)
from repro.topology.links import (LinkModel, Participation,
                                  make_link_model)
from repro.topology.graphs import (LABEL_AWARE_TOPOLOGIES,
                                   MIXING_AUTO_MAX, Topology,
                                   TopologySchedule, as_schedule,
                                   build_schedule, build_topology,
                                   constant_schedule, d_cliques,
                                   fully_connected,
                                   greedy_clique_assignment, hierarchical,
                                   hierarchical_cliques,
                                   metropolis_weights,
                                   random_matching_schedule, random_regular,
                                   ring, topology_ladder, torus,
                                   time_varying_d_cliques)

__all__ = ["LINK_PROFILES", "CommLedger", "LedgerView", "LinkProfile",
           "LinkModel", "MIXING_AUTO_MAX", "Participation",
           "Topology", "TopologySchedule", "LABEL_AWARE_TOPOLOGIES",
           "as_schedule", "build_schedule", "build_topology",
           "constant_schedule", "d_cliques", "fully_connected",
           "greedy_clique_assignment", "hierarchical",
           "hierarchical_cliques", "make_link_model",
           "metropolis_weights", "random_matching_schedule",
           "random_regular", "ring", "topology_ladder", "torus",
           "time_varying_d_cliques"]
