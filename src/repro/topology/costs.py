"""Link-level communication cost accounting.

Replaces the flat ``comm_floats`` scalar with per-link traffic: every
exchange is attributed to the edges of the run's :class:`Topology`, split
into LAN vs WAN totals, and priced into a simulated wall-clock step time
(synchronous rounds: a step costs the slowest link's latency + transfer).

Units: traffic in *floats* (the repo's communication currency, 4 bytes
each); bandwidth in floats/second; latency in seconds.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Union

import numpy as np

from repro.topology.graphs import Topology


@dataclass(frozen=True)
class LinkProfile:
    """Per-class bandwidth/latency.  ``uniform`` removes the LAN/WAN
    distinction (every link is LAN-priced) — the seed repo's behaviour."""
    name: str
    lan_bandwidth: float        # floats / second
    wan_bandwidth: float
    lan_latency: float = 0.0    # seconds
    wan_latency: float = 0.0

    def bandwidth(self, cls: str) -> float:
        return self.wan_bandwidth if cls == "wan" else self.lan_bandwidth

    def latency(self, cls: str) -> float:
        return self.wan_latency if cls == "wan" else self.lan_latency

    def price_per_float(self, cls: str) -> float:
        """Seconds per float — the scarcity weight used by SkewScout."""
        return 1.0 / self.bandwidth(cls)


# 4-byte floats: 10 Gb/s LAN ~ 312.5e6 floats/s; 100 Mb/s WAN ~ 3.125e6
LINK_PROFILES: Dict[str, LinkProfile] = {
    "uniform": LinkProfile("uniform", 312.5e6, 312.5e6, 0.0, 0.0),
    "datacenter": LinkProfile("datacenter", 312.5e6, 312.5e6,
                              1e-4, 1e-4),
    "geo-wan": LinkProfile("geo-wan", 312.5e6, 3.125e6, 1e-4, 5e-2),
}


class CommLedger:
    """Accumulates per-edge traffic and simulated time for one run.

    ``record_exchange(c)``: all-to-all style — each node's ``c`` exchanged
    floats are spread uniformly over its incident edges (the sum over
    edges conserves ``K * c``).  ``record_gossip(m)``: D-PSGD style — every
    edge carries the full model once per direction (``2m`` per edge).
    """

    def __init__(self, topology: Topology, profile: LinkProfile):
        self.topology = topology
        self.profile = profile
        E = len(topology.edges)
        self.edge_traffic = np.zeros(E)
        self._deg = topology.degrees().astype(np.float64)
        self._edge_bw = np.asarray(
            [profile.bandwidth(c) for c in topology.edge_class])
        self._edge_lat = np.asarray(
            [profile.latency(c) for c in topology.edge_class])
        self._is_wan = np.asarray(
            [c == "wan" for c in topology.edge_class], bool)
        self.lan_floats = 0.0
        self.wan_floats = 0.0
        self.sim_time_s = 0.0
        # communication rounds recorded — includes probe/overhead
        # exchanges, so this is NOT the trainer's step count
        self.rounds = 0

    # ---- recording ----
    def _add(self, per_edge: np.ndarray) -> None:
        self.edge_traffic += per_edge
        self.lan_floats += float(per_edge[~self._is_wan].sum())
        self.wan_floats += float(per_edge[self._is_wan].sum())
        active = per_edge > 0
        if active.any():
            self.sim_time_s += float(np.max(
                np.where(active,
                         self._edge_lat + per_edge / self._edge_bw, 0.0)))
        self.rounds += 1

    def record_exchange(self,
                        floats_per_node: Union[float, Sequence[float]]
                        ) -> None:
        """All-to-all exchange of ``floats_per_node`` floats per node,
        routed uniformly over each node's incident edges."""
        K = self.topology.n_nodes
        c = np.broadcast_to(np.asarray(floats_per_node, np.float64), (K,))
        per_edge = np.zeros(len(self.topology.edges))
        share = np.where(self._deg > 0, c / np.maximum(self._deg, 1), 0.0)
        for e, (i, j) in enumerate(self.topology.edges):
            per_edge[e] = share[i] + share[j]
        self._add(per_edge)

    def record_gossip(self, model_floats: float) -> None:
        """One gossip round: the full model crosses every edge, both
        directions."""
        self._add(np.full(len(self.topology.edges), 2.0 * model_floats))

    # ---- pricing ----
    @property
    def total_floats(self) -> float:
        return self.lan_floats + self.wan_floats

    def priced_cost(self) -> float:
        """Cumulative bandwidth-weighted cost (seconds of link time);
        WAN floats dominate under the geo-wan profile, matching the
        paper's Gaia objective of pricing scarce WAN bytes."""
        return (self.lan_floats * self.profile.price_per_float("lan")
                + self.wan_floats * self.profile.price_per_float("wan"))

    def full_exchange_cost(self, model_floats: float) -> float:
        """Priced cost of one BSP-style full-model exchange on this
        topology — SkewScout's CM denominator."""
        K = self.topology.n_nodes
        share = model_floats / np.maximum(self._deg, 1)
        cost = 0.0
        for e, (i, j) in enumerate(self.topology.edges):
            cls = self.topology.edge_class[e]
            cost += (share[i] + share[j]) * self.profile.price_per_float(cls)
        return max(cost, 1e-30)

    def summary(self) -> Dict[str, float]:
        return dict(lan_floats=self.lan_floats, wan_floats=self.wan_floats,
                    total_floats=self.total_floats,
                    sim_time_s=self.sim_time_s,
                    priced_cost=self.priced_cost(), rounds=self.rounds)
