"""Link-level communication cost accounting.

Replaces the flat ``comm_floats`` scalar with per-link traffic: every
exchange is attributed to the edges of the run's fabric, split into LAN
vs WAN totals, and priced into a simulated wall-clock step time
(synchronous rounds: a step costs the slowest link's latency + transfer).

The fabric is a :class:`~repro.topology.graphs.TopologySchedule` (a bare
:class:`Topology` is wrapped into its constant schedule): gossip rounds
are priced against the *active edge set of that round's graph*, not one
frozen graph.  When the active edge set changes — a time-varying
schedule rotating its matchings, or SkewScout switching topology rungs
mid-run — each newly-activated link is charged an explicit online
re-wiring cost (``rewire_floats_per_edge`` control-plane floats plus the
link's latency for the handshake).  Re-wiring traffic is booked on the
links it crosses, so the LAN/WAN split still covers every priced float
and SkewScout's C(θ)/CM objective sees schedule switches as real cost.

Units: traffic in *floats* (the repo's communication currency, 4 bytes
each); bandwidth in floats/second; latency in seconds.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Union

import numpy as np

from repro.topology.graphs import (Edge, Topology, TopologySchedule,
                                   as_schedule)


@dataclass(frozen=True)
class LinkProfile:
    """Per-class bandwidth/latency.  ``uniform`` removes the LAN/WAN
    distinction (every link is LAN-priced) — the seed repo's behaviour."""
    name: str
    lan_bandwidth: float        # floats / second
    wan_bandwidth: float
    lan_latency: float = 0.0    # seconds
    wan_latency: float = 0.0

    def bandwidth(self, cls: str) -> float:
        return self.wan_bandwidth if cls == "wan" else self.lan_bandwidth

    def latency(self, cls: str) -> float:
        return self.wan_latency if cls == "wan" else self.lan_latency

    def price_per_float(self, cls: str) -> float:
        """Seconds per float — the scarcity weight used by SkewScout."""
        return 1.0 / self.bandwidth(cls)


# 4-byte floats: 10 Gb/s LAN ~ 312.5e6 floats/s; 100 Mb/s WAN ~ 3.125e6
LINK_PROFILES: Dict[str, LinkProfile] = {
    "uniform": LinkProfile("uniform", 312.5e6, 312.5e6, 0.0, 0.0),
    "datacenter": LinkProfile("datacenter", 312.5e6, 312.5e6,
                              1e-4, 1e-4),
    "geo-wan": LinkProfile("geo-wan", 312.5e6, 3.125e6, 1e-4, 5e-2),
}


class _GraphPricing:
    """Cached per-edge pricing arrays + a vectorized traffic accumulator
    for one graph of the schedule (the per-step hot path stays numpy;
    the per-edge dict is only materialized in cold accessors)."""

    def __init__(self, graph: Topology, profile: LinkProfile):
        self.graph = graph
        self.deg = graph.degrees().astype(np.float64)
        self.bw = np.asarray([profile.bandwidth(c)
                              for c in graph.edge_class])
        self.lat = np.asarray([profile.latency(c)
                               for c in graph.edge_class])
        self.is_wan = np.asarray([c == "wan" for c in graph.edge_class],
                                 bool)
        self.active = frozenset(graph.edges)
        self.edge_index = {e: n for n, e in enumerate(graph.edges)}
        # edge endpoint arrays for vectorized per-node routing
        self.ei = np.asarray([i for i, _ in graph.edges], np.int64)
        self.ej = np.asarray([j for _, j in graph.edges], np.int64)
        self.traffic = np.zeros(len(graph.edges))

    def flush_into(self, traffic: Dict[Edge, float]) -> None:
        for e, f in zip(self.graph.edges, self.traffic):
            if f:
                traffic[e] = traffic.get(e, 0.0) + float(f)
        self.traffic[:] = 0.0


class CommLedger:
    """Accumulates per-edge traffic and simulated time for one run.

    ``record_exchange(c)``: all-to-all style — each node's ``c`` exchanged
    floats are spread uniformly over its incident edges (the sum over
    edges conserves ``K * c``); priced on the schedule's union graph
    (parameter-server-style traffic has no per-round edge set).
    ``record_gossip(m, t)``: D-PSGD style — every edge *active in round
    t's graph* carries the full model once per direction (``2m`` per
    active edge).
    """

    def __init__(self, fabric: Union[Topology, TopologySchedule],
                 profile: LinkProfile, *,
                 rewire_floats_per_edge: float = 0.0):
        self.profile = profile
        self.rewire_floats_per_edge = float(rewire_floats_per_edge)
        # source of truth for per-edge traffic survives schedule switches
        self._traffic: Dict[Edge, float] = {}
        self.lan_floats = 0.0
        self.wan_floats = 0.0
        self.sim_time_s = 0.0
        # online re-wiring accounting (also included in lan/wan totals)
        self.rewire_lan_floats = 0.0
        self.rewire_wan_floats = 0.0
        self.rewire_events = 0
        # communication rounds recorded — includes probe/overhead
        # exchanges, so this is NOT the trainer's step count
        self.rounds = 0
        self._last_active: Optional[frozenset] = None
        self._pricing: Dict[int, _GraphPricing] = {}
        self._attach(as_schedule(fabric))

    def _attach(self, schedule: TopologySchedule) -> None:
        self.schedule = schedule
        self.topology = schedule.union()
        self._union_pricing = _GraphPricing(self.topology, self.profile)

    def _graph_pricing(self, graph: Topology) -> _GraphPricing:
        p = self._pricing.get(id(graph))
        if p is None:
            p = self._pricing[id(graph)] = _GraphPricing(graph,
                                                         self.profile)
        return p

    # ---- recording ----
    def _book(self, pricing: _GraphPricing, per_edge: np.ndarray) -> None:
        """Attribute ``per_edge`` floats (aligned with ``pricing.graph``'s
        edge list) to links, totals, and simulated time — all vectorized;
        the per-edge dict only materializes in the cold accessors."""
        pricing.traffic += per_edge
        self.lan_floats += float(per_edge[~pricing.is_wan].sum())
        self.wan_floats += float(per_edge[pricing.is_wan].sum())
        active = per_edge > 0
        if active.any():
            self.sim_time_s += float(np.max(
                np.where(active,
                         pricing.lat + per_edge / pricing.bw, 0.0)))

    def _rewire(self, pricing: _GraphPricing) -> None:
        """Charge the online re-wiring cost for links that were not
        active in the previous gossip round: a control-plane handshake
        of ``rewire_floats_per_edge`` floats per new link, priced at
        that link's class.  Booked into the LAN/WAN totals too, so
        ``lan_floats + wan_floats`` still covers every priced float.
        Only gossip rounds carry an active edge set — union-routed
        exchanges (probes) never re-wire and never reset the tracking."""
        if self._last_active is None or \
                pricing.active == self._last_active:
            self._last_active = pricing.active
            return
        new = pricing.active - self._last_active
        self._last_active = pricing.active
        if not new or self.rewire_floats_per_edge <= 0.0:
            return
        per_edge = np.zeros(len(pricing.graph.edges))
        for e in new:
            per_edge[pricing.edge_index[e]] = self.rewire_floats_per_edge
        self._book(pricing, per_edge)
        self.rewire_lan_floats += float(per_edge[~pricing.is_wan].sum())
        self.rewire_wan_floats += float(per_edge[pricing.is_wan].sum())
        self.rewire_events += len(new)

    def record_exchange(self,
                        floats_per_node: Union[float, Sequence[float]]
                        ) -> None:
        """All-to-all exchange of ``floats_per_node`` floats per node,
        routed uniformly over each node's incident edges of the union
        fabric.  Union routing has no per-round active edge set, so it
        neither pays nor resets re-wiring."""
        pricing = self._union_pricing
        K = self.topology.n_nodes
        c = np.broadcast_to(np.asarray(floats_per_node, np.float64), (K,))
        share = np.where(pricing.deg > 0,
                         c / np.maximum(pricing.deg, 1), 0.0)
        self._book(pricing, share[pricing.ei] + share[pricing.ej])
        self.rounds += 1

    def record_gossip(self, model_floats: float,
                      t: Optional[int] = None) -> None:
        """One gossip round at round index ``t``: the full model crosses
        every edge active in ``schedule.at(t)``, both directions.
        ``t=None`` keeps the legacy one-graph behaviour (round 0)."""
        graph = self.schedule.at(0 if t is None else t)
        pricing = self._graph_pricing(graph)
        self._rewire(pricing)
        self._book(pricing,
                   np.full(len(graph.edges), 2.0 * model_floats))
        self.rounds += 1

    def switch_schedule(self, fabric: Union[Topology, TopologySchedule]
                        ) -> None:
        """Swap the fabric mid-run (SkewScout climbing a topology rung).
        Accumulated traffic is preserved (see ``traffic_by_edge``); the
        first gossip round on the new schedule pays re-wiring for every
        link the old round's active set did not have."""
        self._flush_traffic()
        self._attach(as_schedule(fabric))
        self._pricing.clear()

    def _flush_traffic(self) -> None:
        """Fold the vectorized per-graph accumulators into the canonical
        per-edge dict (cold path: accessors and schedule switches)."""
        self._union_pricing.flush_into(self._traffic)
        for p in self._pricing.values():
            p.flush_into(self._traffic)

    # ---- pricing ----
    def traffic_by_edge(self) -> Dict[Edge, float]:
        """Every float ever booked, keyed by canonical edge — survives
        schedule switches (``sum(...) == total_floats`` always)."""
        self._flush_traffic()
        return dict(self._traffic)

    @property
    def edge_traffic(self) -> np.ndarray:
        """Per-edge floats, aligned with ``self.topology.edges`` — a
        *view* onto the current schedule's union graph.  After a
        ``switch_schedule`` to a sparser fabric, traffic booked on links
        the new union lacks is not shown here (use ``traffic_by_edge``
        for the lossless history)."""
        self._flush_traffic()
        return np.asarray([self._traffic.get(e, 0.0)
                           for e in self.topology.edges])

    @property
    def total_floats(self) -> float:
        return self.lan_floats + self.wan_floats

    def priced_cost(self) -> float:
        """Cumulative bandwidth-weighted cost (seconds of link time);
        WAN floats dominate under the geo-wan profile, matching the
        paper's Gaia objective of pricing scarce WAN bytes.  Includes
        re-wiring traffic, so a controller that flaps between schedules
        pays for it in C(θ)."""
        return (self.lan_floats * self.profile.price_per_float("lan")
                + self.wan_floats * self.profile.price_per_float("wan"))

    @property
    def rewire_floats(self) -> float:
        return self.rewire_lan_floats + self.rewire_wan_floats

    def rewiring_cost(self) -> float:
        """Priced cost of the re-wiring traffic alone — the component of
        ``priced_cost`` a schedule-flapping controller is paying for
        link churn."""
        return (self.rewire_lan_floats * self.profile.price_per_float("lan")
                + self.rewire_wan_floats
                * self.profile.price_per_float("wan"))

    def full_exchange_cost(self, model_floats: float) -> float:
        """Priced cost of one BSP-style full-model exchange on the union
        fabric — SkewScout's CM denominator."""
        pricing = self._union_pricing
        share = model_floats / np.maximum(pricing.deg, 1)
        cost = 0.0
        for e, (i, j) in enumerate(self.topology.edges):
            cls = self.topology.edge_class[e]
            cost += (share[i] + share[j]) * self.profile.price_per_float(cls)
        return max(cost, 1e-30)

    def summary(self) -> Dict[str, float]:
        return dict(lan_floats=self.lan_floats, wan_floats=self.wan_floats,
                    total_floats=self.total_floats,
                    sim_time_s=self.sim_time_s,
                    priced_cost=self.priced_cost(), rounds=self.rounds,
                    rewire_floats=self.rewire_floats,
                    rewire_events=self.rewire_events)
