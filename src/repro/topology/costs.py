"""Link-level communication cost accounting.

Replaces the flat ``comm_floats`` scalar with per-link traffic: every
exchange is attributed to the edges of the run's fabric, split into LAN
vs WAN totals, and priced into a simulated wall-clock step time.

The fabric is a :class:`~repro.topology.graphs.TopologySchedule` (a bare
:class:`Topology` is wrapped into its constant schedule): gossip rounds
are priced against the *active edge set of that round's graph*, not one
frozen graph.  When the active edge set changes — a time-varying
schedule rotating its matchings, or SkewScout switching topology rungs
mid-run — each newly-activated link is charged an explicit online
re-wiring cost: ``rewire_floats_per_edge`` control-plane floats plus a
per-class handshake latency (WAN setup is far slower than LAN), both
added to the simulated step time.  Re-wiring traffic is booked on the
links it crosses, so the LAN/WAN split still covers every priced float
and SkewScout's C(θ)/CM objective sees schedule switches as real cost.

Two timing models share the float accounting:

*Synchronous* (default, D-PSGD stop-and-wait): every round ends when its
slowest activated link finishes, so ``sim_time_s`` grows by the max of
``latency + transfer`` over the round's active edges — one geo-WAN
straggler gates every node.

*Asynchronous* (``async_mode=True``, AD-PSGD): every link carries a
**virtual clock** that advances only by that link's own cost, and a
round's wall-clock is the max of the *activated* edges' clocks — links
never wait for each other, so the global clock is a max of per-edge
sums instead of a sum of per-round maxes (always <=, and strictly <
once different links bottleneck different rounds or latency is
amortized).  Bounded staleness is what licenses the overlap: a link
whose payloads may arrive up to ``s`` rounds stale keeps ``s + 1``
deliveries in flight, so its propagation latency is re-paid once per
``s + 1`` activations (``s = 0`` degrades to stop-and-wait per edge).
Per-node busy time (max cost over the node's own activated links each
round) and the resulting idle time / clock skew expose who was gated.

Units: traffic in *floats* (the repo's communication currency, 4 bytes
each); bandwidth in floats/second; latency in seconds.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Union

import numpy as np

from repro.topology.graphs import (Edge, Topology, TopologySchedule,
                                   as_schedule)


@dataclass(frozen=True)
class LinkProfile:
    """Per-class bandwidth/latency.  ``uniform`` removes the LAN/WAN
    distinction (every link is LAN-priced) — the seed repo's behaviour.
    ``*_handshake`` is the connection-setup latency a newly-activated
    link pays once (re-wiring); it defaults to 3x the link's propagation
    latency (SYN / SYN-ACK / ACK) when not given."""
    name: str
    lan_bandwidth: float        # floats / second
    wan_bandwidth: float
    lan_latency: float = 0.0    # seconds
    wan_latency: float = 0.0
    lan_handshake: Optional[float] = None   # seconds; None -> 3x latency
    wan_handshake: Optional[float] = None

    def bandwidth(self, cls: str) -> float:
        return self.wan_bandwidth if cls == "wan" else self.lan_bandwidth

    def latency(self, cls: str) -> float:
        return self.wan_latency if cls == "wan" else self.lan_latency

    def handshake(self, cls: str) -> float:
        h = self.wan_handshake if cls == "wan" else self.lan_handshake
        return 3.0 * self.latency(cls) if h is None else h

    def price_per_float(self, cls: str) -> float:
        """Seconds per float — the scarcity weight used by SkewScout."""
        return 1.0 / self.bandwidth(cls)


# 4-byte floats: 10 Gb/s LAN ~ 312.5e6 floats/s; 100 Mb/s WAN ~ 3.125e6
LINK_PROFILES: Dict[str, LinkProfile] = {
    "uniform": LinkProfile("uniform", 312.5e6, 312.5e6, 0.0, 0.0),
    "datacenter": LinkProfile("datacenter", 312.5e6, 312.5e6,
                              1e-4, 1e-4),
    "geo-wan": LinkProfile("geo-wan", 312.5e6, 3.125e6, 1e-4, 5e-2),
}


class _GraphPricing:
    """Cached per-edge pricing arrays + a vectorized traffic accumulator
    for one graph of the schedule (the per-step hot path stays numpy;
    the per-edge dict is only materialized in cold accessors)."""

    def __init__(self, graph: Topology, profile: LinkProfile):
        self.graph = graph
        self.deg = graph.degrees().astype(np.float64)
        self.bw = np.asarray([profile.bandwidth(c)
                              for c in graph.edge_class])
        self.lat = np.asarray([profile.latency(c)
                               for c in graph.edge_class])
        self.hs = np.asarray([profile.handshake(c)
                              for c in graph.edge_class])
        self.is_wan = np.asarray([c == "wan" for c in graph.edge_class],
                                 bool)
        self.active = frozenset(graph.edges)
        self.edge_index = {e: n for n, e in enumerate(graph.edges)}
        # edge endpoint arrays for vectorized per-node routing
        self.ei = np.asarray([i for i, _ in graph.edges], np.int64)
        self.ej = np.asarray([j for _, j in graph.edges], np.int64)
        self.traffic = np.zeros(len(graph.edges))

    def flush_into(self, traffic: Dict[Edge, float]) -> None:
        for e, f in zip(self.graph.edges, self.traffic):
            if f:
                traffic[e] = traffic.get(e, 0.0) + float(f)
        self.traffic[:] = 0.0


class CommLedger:
    """Accumulates per-edge traffic and simulated time for one run.

    ``record_exchange(c)``: all-to-all style — each node's ``c`` exchanged
    floats are spread uniformly over its incident edges (the sum over
    edges conserves ``K * c``); priced on the schedule's union graph
    (parameter-server-style traffic has no per-round edge set).
    ``record_gossip(m, t)``: D-PSGD style — every edge *active in round
    t's graph* carries the full model once per direction (``2m`` per
    active edge).  In ``async_mode`` a per-edge ``staleness`` bound
    (AD-PSGD) amortizes each link's latency over ``staleness + 1``
    in-flight deliveries.
    ``record_probe(edges, m)``: SkewScout model traveling — ``m`` floats
    cross each probed union link once.
    """

    def __init__(self, fabric: Union[Topology, TopologySchedule],
                 profile: LinkProfile, *,
                 rewire_floats_per_edge: float = 0.0,
                 async_mode: bool = False):
        self.profile = profile
        self.rewire_floats_per_edge = float(rewire_floats_per_edge)
        self.async_mode = bool(async_mode)
        # source of truth for per-edge traffic survives schedule switches
        self._traffic: Dict[Edge, float] = {}
        self.lan_floats = 0.0
        self.wan_floats = 0.0
        self.sim_time_s = 0.0
        # per-edge virtual clocks (canonical edge -> seconds); in sync
        # mode every activated edge snaps to the global clock, in async
        # mode each advances by its own cost only
        self._edge_clock: Dict[Edge, float] = {}
        # online re-wiring accounting (floats also in lan/wan totals)
        self.rewire_lan_floats = 0.0
        self.rewire_wan_floats = 0.0
        self.rewire_events = 0
        self.rewire_time_s = 0.0     # handshake seconds booked on links
        # communication rounds recorded — includes probe/overhead
        # exchanges, so this is NOT the trainer's step count
        self.rounds = 0
        self._last_active: Optional[frozenset] = None
        self._pricing: Dict[int, _GraphPricing] = {}
        self._attach(as_schedule(fabric))
        # per-node busy time: each round a node participates in, it
        # works for the max cost over its own activated incident links
        self.node_busy_s = np.zeros(self.topology.n_nodes)

    def _attach(self, schedule: TopologySchedule) -> None:
        self.schedule = schedule
        self.topology = schedule.union()
        self._union_pricing = _GraphPricing(self.topology, self.profile)

    def _graph_pricing(self, graph: Topology) -> _GraphPricing:
        p = self._pricing.get(id(graph))
        if p is None:
            p = self._pricing[id(graph)] = _GraphPricing(graph,
                                                         self.profile)
        return p

    # ---- recording ----
    def _book_floats(self, pricing: _GraphPricing,
                     per_edge: np.ndarray) -> None:
        """Attribute ``per_edge`` floats (aligned with ``pricing.graph``'s
        edge list) to links and LAN/WAN totals — all vectorized; the
        per-edge dict only materializes in the cold accessors."""
        pricing.traffic += per_edge
        self.lan_floats += float(per_edge[~pricing.is_wan].sum())
        self.wan_floats += float(per_edge[pricing.is_wan].sum())

    def _charge_time(self, pricing: _GraphPricing,
                     cost: np.ndarray, active: np.ndarray) -> None:
        """Advance the clocks by ``cost`` seconds per edge (aligned with
        ``pricing.graph.edges``; only ``active`` entries count).

        sync: stop-and-wait — the global clock grows by the round's max
        cost and every activated edge snaps to it.  async: each edge's
        clock advances by its own cost; the global clock is the max of
        the *activated* edges' clocks (monotone by construction)."""
        if not active.any():
            return
        edges = pricing.graph.edges
        if self.async_mode:
            frontier = 0.0
            for n in np.flatnonzero(active):
                e = edges[n]
                c = self._edge_clock.get(e, 0.0) + float(cost[n])
                self._edge_clock[e] = c
                frontier = max(frontier, c)
            self.sim_time_s = max(self.sim_time_s, frontier)
        else:
            self.sim_time_s += float(cost[active].max())
            for n in np.flatnonzero(active):
                self._edge_clock[edges[n]] = self.sim_time_s
        busy = np.zeros(len(self.node_busy_s))
        own = np.where(active, cost, 0.0)
        np.maximum.at(busy, pricing.ei, own)
        np.maximum.at(busy, pricing.ej, own)
        self.node_busy_s += busy

    def _rewire(self, pricing: _GraphPricing) -> None:
        """Charge the online re-wiring cost for links that were not
        active in the previous gossip round: a control-plane handshake
        of ``rewire_floats_per_edge`` floats per new link *plus the
        link's per-class setup latency* (``LinkProfile.handshake``:
        WAN >> LAN), priced at that link's class and added to the
        simulated step time.  Floats are booked into the LAN/WAN totals
        too, so ``lan_floats + wan_floats`` still covers every priced
        float.  Only gossip rounds carry an active edge set —
        union-routed exchanges (probes) never re-wire and never reset
        the tracking."""
        if self._last_active is None or \
                pricing.active == self._last_active:
            self._last_active = pricing.active
            return
        new = pricing.active - self._last_active
        self._last_active = pricing.active
        if not new:
            return
        if self.async_mode:
            # a (re)activated link joins at the global frontier: it
            # cannot have banked transfer time while it did not exist.
            # Without this, a rung switch would hand the controller a
            # free window (the new fabric's clocks lag the ratcheted
            # global max, so C(θ) reads ~0 until they catch up).
            for e in new:
                self._edge_clock[e] = max(self._edge_clock.get(e, 0.0),
                                          self.sim_time_s)
        is_new = np.asarray([e in new for e in pricing.graph.edges])
        per_edge = np.where(is_new, self.rewire_floats_per_edge, 0.0)
        if self.rewire_floats_per_edge > 0.0:
            self._book_floats(pricing, per_edge)
            self.rewire_lan_floats += float(per_edge[~pricing.is_wan].sum())
            self.rewire_wan_floats += float(per_edge[pricing.is_wan].sum())
        # handshake setup latency + the control-plane transfer itself
        cost = np.where(is_new,
                        pricing.hs + pricing.lat + per_edge / pricing.bw,
                        0.0)
        self.rewire_time_s += float(cost[is_new].sum())
        self._charge_time(pricing, cost, cost > 0)
        self.rewire_events += len(new)

    def record_exchange(self,
                        floats_per_node: Union[float, Sequence[float]]
                        ) -> None:
        """All-to-all exchange of ``floats_per_node`` floats per node,
        routed uniformly over each node's incident edges of the union
        fabric.  Union routing has no per-round active edge set, so it
        neither pays nor resets re-wiring."""
        pricing = self._union_pricing
        K = self.topology.n_nodes
        c = np.broadcast_to(np.asarray(floats_per_node, np.float64), (K,))
        share = np.where(pricing.deg > 0,
                         c / np.maximum(pricing.deg, 1), 0.0)
        per_edge = share[pricing.ei] + share[pricing.ej]
        self._book_floats(pricing, per_edge)
        active = per_edge > 0
        self._charge_time(pricing,
                          np.where(active,
                                   pricing.lat + per_edge / pricing.bw,
                                   0.0), active)
        self.rounds += 1

    def record_gossip(self, model_floats: float,
                      t: Optional[int] = None,
                      staleness: Union[None, int, Sequence[int]] = None
                      ) -> None:
        """One gossip round at round index ``t``: the full model crosses
        every edge active in ``schedule.at(t)``, both directions.
        ``t=None`` keeps the legacy one-graph behaviour (round 0).

        ``staleness`` (async mode only): per-edge bounded-staleness
        values (scalar broadcasts) — a link tolerating ``s``-stale
        deliveries pipelines ``s + 1`` payloads, so its latency is paid
        once per ``s + 1`` activations.  Ignored in sync mode, where
        every round is stop-and-wait regardless of the algorithm."""
        graph = self.schedule.at(0 if t is None else t)
        pricing = self._graph_pricing(graph)
        self._rewire(pricing)
        n_edges = len(graph.edges)
        per_edge = np.full(n_edges, 2.0 * model_floats)
        self._book_floats(pricing, per_edge)
        if self.async_mode and staleness is not None:
            s = np.broadcast_to(np.asarray(staleness, np.float64),
                                (n_edges,))
            assert (s >= 0).all(), "staleness must be non-negative"
            lat = pricing.lat / (1.0 + s)
        else:
            lat = pricing.lat
        active = per_edge > 0
        self._charge_time(pricing,
                          np.where(active, lat + per_edge / pricing.bw,
                                   0.0), active)
        self.rounds += 1

    def record_probe(self, edges: Sequence[Edge],
                     floats_each: float) -> None:
        """SkewScout model traveling: ``floats_each`` floats cross each
        probed link once (one direction).  Probes ride union-fabric
        links (probe routing follows active edges, which are union
        members), are booked into the LAN/WAN totals and per-edge
        traffic, block on delivery (staleness 0 — the measurement needs
        the fresh model), and neither pay nor reset re-wiring."""
        pricing = self._union_pricing
        per_edge = np.zeros(len(pricing.graph.edges))
        for i, j in edges:
            e = (min(i, j), max(i, j))
            assert e in pricing.edge_index, \
                f"probe edge {e} is not on the union fabric"
            per_edge[pricing.edge_index[e]] += float(floats_each)
        self._book_floats(pricing, per_edge)
        active = per_edge > 0
        self._charge_time(pricing,
                          np.where(active,
                                   pricing.lat + per_edge / pricing.bw,
                                   0.0), active)
        self.rounds += 1

    def switch_schedule(self, fabric: Union[Topology, TopologySchedule]
                        ) -> None:
        """Swap the fabric mid-run (SkewScout climbing a topology rung).
        Accumulated traffic and per-edge clocks are preserved (see
        ``traffic_by_edge``); the first gossip round on the new schedule
        pays re-wiring for every link the old round's active set did not
        have."""
        schedule = as_schedule(fabric)
        assert schedule.n_nodes == self.topology.n_nodes, \
            (schedule.n_nodes, self.topology.n_nodes)
        self._flush_traffic()
        self._attach(schedule)
        self._pricing.clear()

    def _flush_traffic(self) -> None:
        """Fold the vectorized per-graph accumulators into the canonical
        per-edge dict (cold path: accessors and schedule switches)."""
        self._union_pricing.flush_into(self._traffic)
        for p in self._pricing.values():
            p.flush_into(self._traffic)

    # ---- pricing ----
    def traffic_by_edge(self) -> Dict[Edge, float]:
        """Every float ever booked, keyed by canonical edge — survives
        schedule switches (``sum(...) == total_floats`` always)."""
        self._flush_traffic()
        return dict(self._traffic)

    @property
    def edge_traffic(self) -> np.ndarray:
        """Per-edge floats, aligned with ``self.topology.edges`` — a
        *view* onto the current schedule's union graph.  After a
        ``switch_schedule`` to a sparser fabric, traffic booked on links
        the new union lacks is not shown here (use ``traffic_by_edge``
        for the lossless history)."""
        self._flush_traffic()
        return np.asarray([self._traffic.get(e, 0.0)
                           for e in self.topology.edges])

    # ---- clocks ----
    def edge_clocks(self) -> Dict[Edge, float]:
        """Per-link virtual clocks (seconds), keyed by canonical edge —
        survives schedule switches.  Monotone non-decreasing per edge in
        both modes; in sync mode activated edges snap to the global
        clock, in async mode each advances by its own cost only."""
        return dict(self._edge_clock)

    def node_clocks(self) -> np.ndarray:
        """When each node last finished a communication: the max clock
        over its incident links (0 if it never communicated)."""
        clk = np.zeros(self.topology.n_nodes)
        for (i, j), c in self._edge_clock.items():
            if i < len(clk):
                clk[i] = max(clk[i], c)
            if j < len(clk):
                clk[j] = max(clk[j], c)
        return clk

    def clock_skew_s(self) -> float:
        """Spread of the per-node clocks — 0 when every node finishes
        rounds in lockstep (sync, constant fabric); positive when async
        lets fast nodes run ahead of the stragglers."""
        clk = self.node_clocks()
        return float(clk.max() - clk.min()) if len(clk) else 0.0

    @property
    def node_idle_s(self) -> np.ndarray:
        """Per-node idle time: the global clock minus the node's own
        busy time.  In sync mode this is time spent waiting on other
        nodes' slower links; in async mode, time a fast node is done
        before the last link drains."""
        return np.maximum(self.sim_time_s - self.node_busy_s, 0.0)

    @property
    def total_floats(self) -> float:
        return self.lan_floats + self.wan_floats

    def priced_cost(self) -> float:
        """Cumulative bandwidth-weighted cost (seconds of link time);
        WAN floats dominate under the geo-wan profile, matching the
        paper's Gaia objective of pricing scarce WAN bytes.  Includes
        re-wiring traffic, so a controller that flaps between schedules
        pays for it in C(θ)."""
        return (self.lan_floats * self.profile.price_per_float("lan")
                + self.wan_floats * self.profile.price_per_float("wan"))

    @property
    def rewire_floats(self) -> float:
        return self.rewire_lan_floats + self.rewire_wan_floats

    def rewiring_cost(self) -> float:
        """Priced cost of the re-wiring traffic alone — the component of
        ``priced_cost`` a schedule-flapping controller is paying for
        link churn."""
        return (self.rewire_lan_floats * self.profile.price_per_float("lan")
                + self.rewire_wan_floats
                * self.profile.price_per_float("wan"))

    def full_exchange_cost(self, model_floats: float) -> float:
        """Priced cost of one BSP-style full-model exchange on the union
        fabric — SkewScout's CM denominator (bandwidth-seconds)."""
        pricing = self._union_pricing
        share = model_floats / np.maximum(pricing.deg, 1)
        cost = 0.0
        for e, (i, j) in enumerate(self.topology.edges):
            cls = self.topology.edge_class[e]
            cost += (share[i] + share[j]) * self.profile.price_per_float(cls)
        return max(cost, 1e-30)

    def full_exchange_time(self, model_floats: float) -> float:
        """Wall-clock of one BSP-style full-model exchange on the union
        fabric (slowest link's latency + transfer) — the CM denominator
        when SkewScout prices C(θ) in async simulated time."""
        pricing = self._union_pricing
        if not len(pricing.graph.edges):
            return 1e-30
        share = model_floats / np.maximum(pricing.deg, 1)
        per_edge = share[pricing.ei] + share[pricing.ej]
        return max(float(np.max(pricing.lat + per_edge / pricing.bw)),
                   1e-30)

    def summary(self) -> Dict[str, float]:
        return dict(lan_floats=self.lan_floats, wan_floats=self.wan_floats,
                    total_floats=self.total_floats,
                    sim_time_s=self.sim_time_s,
                    priced_cost=self.priced_cost(), rounds=self.rounds,
                    rewire_floats=self.rewire_floats,
                    rewire_events=self.rewire_events,
                    rewire_time_s=self.rewire_time_s,
                    async_mode=float(self.async_mode),
                    clock_skew_s=self.clock_skew_s(),
                    busy_s_max=float(self.node_busy_s.max()),
                    idle_s_mean=float(self.node_idle_s.mean()))
