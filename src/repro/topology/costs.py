"""Link-level communication cost accounting (array-native).

Replaces the flat ``comm_floats`` scalar with per-link traffic: every
exchange is attributed to the edges of the run's fabric, split into LAN
vs WAN totals, and priced into a simulated wall-clock step time.

The fabric is a :class:`~repro.topology.graphs.TopologySchedule` (a bare
:class:`Topology` is wrapped into its constant schedule): gossip rounds
are priced against the *active edge set of that round's graph*, not one
frozen graph.  When the active edge set changes — a time-varying
schedule rotating its matchings, or SkewScout switching topology rungs
mid-run — each newly-activated link is charged an explicit online
re-wiring cost: ``rewire_floats`` control-plane floats plus a per-class
handshake latency (WAN setup is far slower than LAN), both added to the
simulated step time.  Re-wiring traffic is booked on the links it
crosses, so the LAN/WAN split still covers every priced float and
SkewScout's C(θ)/CM objective sees schedule switches as real cost.

Two timing models share the float accounting:

*Synchronous* (default, D-PSGD stop-and-wait): every round ends when its
slowest activated link finishes, so ``sim_time_s`` grows by the max of
``latency + transfer`` over the round's active edges — one geo-WAN
straggler gates every node.

*Asynchronous* (``async_mode=True``, AD-PSGD): every link carries a
**virtual clock** that advances only by that link's own cost, and a
round's wall-clock is the max of the *activated* edges' clocks — links
never wait for each other, so the global clock is a max of per-edge
sums instead of a sum of per-round maxes (always <=, and strictly <
once different links bottleneck different rounds or latency is
amortized).  Bounded staleness is what licenses the overlap: a link
whose payloads may arrive up to ``s`` rounds stale keeps ``s + 1``
deliveries in flight, so its propagation latency is re-paid once per
``s + 1`` activations (``s = 0`` degrades to stop-and-wait per edge).
Per-node busy time (max cost over the node's own activated links each
round) and the resulting idle time / clock skew expose who was gated.

Stochastic links (``link_model=``): a
:class:`~repro.topology.links.LinkModel` replaces the class-constant
pricing with seeded per-edge sampling — persistent per-edge base draws,
lognormal per-activation jitter, and a Markov transient-slowdown state
for bursty stragglers.  Both timing models price the *sampled* per-edge
times, so the async max-of-per-edge-sums diverges from the sync
sum-of-per-round-maxes under transient stragglers, not only persistent
WAN gaps.  Every observation also feeds per-edge EWMA **measured**
costs that SkewScout's C(θ)/CM pricing consumes in place of profile
constants.

Amortized re-wiring (``amortize_window=W``): a newly-activated link's
handshake is paid in ``handshake / W`` installments over its first ``W``
activations instead of up front — a rung switch that persists gets
cheaper per round.  A link dropped before its window completes forfeits
the unamortized balance immediately (the setup work was really done;
tearing down just stops deferring the booking), so thrashing between
schedules stays exactly as expensive as un-amortized switching.  A run
that ends mid-window leaves the remainder in
``view().pending_handshake_s`` (reported in ``summary()``):
``rewire_time_s + pending_handshake_s`` is the horizon-independent
handshake total to compare across windows.

Array layout (the 10k-node redesign): every canonical edge the ledger
ever prices gets a stable integer **edge id** (eid) the first time a
graph containing it is registered; all bookkeeping — virtual clocks,
booked traffic, EWMA measured costs, handshake installment balances —
lives in flat float64 arrays indexed by eid.  A gossip round is a
handful of vectorized array ops over the round graph's edge list
(gathered through the per-graph ``eids`` index), so pricing scales with
the active edge count, not with ``K * degree`` Python-dict updates.
The array core reproduces the retired dict-backed ledger bit-for-bit
(``tests/test_fabric_scale.py`` holds them equal on every invariant
scenario): sequential accumulations that are order-sensitive in IEEE
float (installment payments, forfeit charges, the non-worst full
exchange sum) keep their original fold order, everything order-invariant
(maxes, elementwise folds, independent per-edge adds) is vectorized.

Partial participation (``participation=``): a seeded
:class:`~repro.topology.links.Participation` mask decides which nodes
show up for each gossip round; an edge is active iff *both* endpoints
participate.  Non-participating edges book no floats, pay no
installments, and do not advance their link-model draw counters — but
the round's re-wiring tracking still follows the schedule's full active
set (sampling out of a round does not tear the link down).  With
``participation=None`` (or fraction 1.0) every round prices exactly as
before, bit-for-bit.

Read API: :meth:`CommLedger.view` returns a frozen :class:`LedgerView`
snapshot — scalars plus eid-aligned arrays — rebuilt only when the
ledger has mutated since the last call.  The ~20 legacy accessors
(``edge_clocks``/``traffic_by_edge``/``measured_*``/...) survive as thin
deprecated shims that each fire one ``DeprecationWarning`` and return
the same values as before.

Units: traffic in *floats* (the repo's communication currency, 4 bytes
each); bandwidth in floats/second; latency in seconds.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.configs.base import FabricConfig
from repro.topology.graphs import (Edge, Topology, TopologySchedule,
                                   as_schedule)


@dataclass(frozen=True)
class LinkProfile:
    """Per-class bandwidth/latency.  ``uniform`` removes the LAN/WAN
    distinction (every link is LAN-priced) — the seed repo's behaviour.
    ``*_handshake`` is the connection-setup latency a newly-activated
    link pays once (re-wiring); it defaults to 3x the link's propagation
    latency (SYN / SYN-ACK / ACK) when not given."""
    name: str
    lan_bandwidth: float        # floats / second
    wan_bandwidth: float
    lan_latency: float = 0.0    # seconds
    wan_latency: float = 0.0
    lan_handshake: Optional[float] = None   # seconds; None -> 3x latency
    wan_handshake: Optional[float] = None

    def bandwidth(self, cls: str) -> float:
        return self.wan_bandwidth if cls == "wan" else self.lan_bandwidth

    def latency(self, cls: str) -> float:
        return self.wan_latency if cls == "wan" else self.lan_latency

    def handshake(self, cls: str) -> float:
        h = self.wan_handshake if cls == "wan" else self.lan_handshake
        return 3.0 * self.latency(cls) if h is None else h

    def price_per_float(self, cls: str) -> float:
        """Seconds per float — the scarcity weight used by SkewScout."""
        return 1.0 / self.bandwidth(cls)


# 4-byte floats: 10 Gb/s LAN ~ 312.5e6 floats/s; 100 Mb/s WAN ~ 3.125e6
LINK_PROFILES: Dict[str, LinkProfile] = {
    "uniform": LinkProfile("uniform", 312.5e6, 312.5e6, 0.0, 0.0),
    "datacenter": LinkProfile("datacenter", 312.5e6, 312.5e6,
                              1e-4, 1e-4),
    "geo-wan": LinkProfile("geo-wan", 312.5e6, 3.125e6, 1e-4, 5e-2),
}


def _seqsum(v: np.ndarray) -> float:
    """Sequential left-fold sum — bit-equal to a Python accumulation
    loop (``np.cumsum`` accumulates in order; ``np.sum`` is pairwise)."""
    return float(np.cumsum(v)[-1]) if len(v) else 0.0


def _wan_mask(graph: Topology) -> np.ndarray:
    return np.asarray(graph.edge_class) == "wan" if graph.edge_class \
        else np.zeros(0, bool)


class _GraphPricing:
    """Cached per-edge pricing arrays for one graph of the schedule:
    class constants gathered once, endpoint index arrays for per-node
    routing, the graph's global eid index, and a per-graph traffic
    accumulator (flushed into the ledger's eid-indexed traffic array on
    cold reads / schedule switches, preserving the dict-era fold
    grouping)."""

    def __init__(self, graph: Topology, profile: LinkProfile,
                 eids: np.ndarray):
        self.graph = graph
        self.deg = graph.degrees().astype(np.float64)
        self.is_wan = _wan_mask(graph)
        self.bw = np.where(self.is_wan, profile.wan_bandwidth,
                           profile.lan_bandwidth)
        self.lat = np.where(self.is_wan, profile.wan_latency,
                            profile.lan_latency)
        self.hs = np.where(self.is_wan, profile.handshake("wan"),
                           profile.handshake("lan"))
        self.active = frozenset(graph.edges)
        self.eids = eids
        # eid -> position in this graph's edge list (installment loop)
        self.pos_of: Dict[int, int] = {
            int(g): n for n, g in enumerate(eids)}
        self.edge_index = {e: n for n, e in enumerate(graph.edges)}
        # edge endpoint arrays for vectorized per-node routing
        self.ei = np.asarray([i for i, _ in graph.edges], np.int64)
        self.ej = np.asarray([j for _, j in graph.edges], np.int64)
        self.traffic = np.zeros(len(graph.edges))

    def flush_into(self, traffic: np.ndarray) -> None:
        if len(self.eids):
            traffic[self.eids] = traffic[self.eids] + self.traffic
        self.traffic[:] = 0.0


@dataclass(frozen=True, eq=False)
class LedgerView:
    """Frozen snapshot of a :class:`CommLedger` — the read API.

    Scalars are plain floats/ints; per-edge arrays are **eid-aligned**
    (``edges[k]`` is the canonical edge with eid ``k``, stable across
    schedule switches) and are copies (a view survives later ledger
    mutation).  ``union_eids`` selects the current union fabric's edges
    out of the eid space (``edge_traffic[union_eids]`` is the old
    ``edge_traffic`` property).  The ``full_exchange_*`` /
    ``measured_*`` / ``cm_denominator`` pricing helpers evaluate against
    the *live* ledger (EWMA state moves with new observations).

    ``view()`` is version-cached: repeated calls between ledger
    mutations return the same object with zero rebuild cost — the fix
    for the old per-call dict rebuilds in SkewScout's probe loop."""
    n_nodes: int
    async_mode: bool
    rounds: int
    amortize_window: int
    sim_time_s: float
    lan_floats: float
    wan_floats: float
    total_floats: float
    priced_cost: float
    sampled_priced_cost: float
    window_cost: float
    rewire_lan_floats: float
    rewire_wan_floats: float
    rewire_floats: float
    rewiring_cost: float
    rewire_events: int
    rewire_time_s: float
    pending_handshake_s: float
    clock_skew_s: float
    edges: Tuple[Edge, ...]
    edge_clock: np.ndarray = dataclasses.field(repr=False)
    edge_seen: np.ndarray = dataclasses.field(repr=False)
    edge_traffic: np.ndarray = dataclasses.field(repr=False)
    union_eids: np.ndarray = dataclasses.field(repr=False)
    ewma_latency_s: np.ndarray = dataclasses.field(repr=False)
    ewma_price_s: np.ndarray = dataclasses.field(repr=False)
    ewma_seen: np.ndarray = dataclasses.field(repr=False)
    node_clock: np.ndarray = dataclasses.field(repr=False)
    node_busy_s: np.ndarray = dataclasses.field(repr=False)
    node_idle_s: np.ndarray = dataclasses.field(repr=False)
    _ledger: "CommLedger" = dataclasses.field(repr=False, compare=False)

    # ---- pricing helpers (delegate to the live ledger) ----
    def full_exchange_cost(self, model_floats: float) -> float:
        return self._ledger._full_exchange_cost(model_floats)

    def full_exchange_time(self, model_floats: float) -> float:
        return self._ledger._full_exchange_time(model_floats)

    def measured_latency_s(self, e: Edge, cls: str = "lan") -> float:
        return self._ledger._measured_latency_s(e, cls)

    def measured_price_per_float(self, e: Edge,
                                 cls: str = "lan") -> float:
        return self._ledger._measured_price_per_float(e, cls)

    def measured_full_exchange_cost(self, model_floats: float,
                                    fabric=None) -> float:
        return self._ledger._measured_full_exchange_cost(
            model_floats, fabric=fabric)

    def measured_full_exchange_time(self, model_floats: float,
                                    fabric=None) -> float:
        return self._ledger._measured_full_exchange_time(
            model_floats, fabric=fabric)

    def cm_denominator(self, model_floats: float, fabric=None) -> float:
        return self._ledger._cm_denominator(model_floats, fabric=fabric)

    # ---- dict conveniences (tests / debugging; O(E) builds) ----
    def edge_clock_map(self) -> Dict[Edge, float]:
        """Per-link virtual clocks keyed by canonical edge (only edges
        that were ever clock-charged appear — the legacy
        ``edge_clocks()`` contract)."""
        idx = np.flatnonzero(self.edge_seen)
        return {self.edges[k]: float(self.edge_clock[k]) for k in idx}

    def traffic_map(self) -> Dict[Edge, float]:
        """Every float ever booked keyed by canonical edge (edges with
        zero traffic omitted — the legacy ``traffic_by_edge()``
        contract)."""
        idx = np.flatnonzero(self.edge_traffic)
        return {self.edges[k]: float(self.edge_traffic[k]) for k in idx}


def _deprecated(replacement: str):
    """Mark a legacy CommLedger accessor: one DeprecationWarning per
    call, then delegate to the private implementation."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(self, *args, **kwargs):
            warnings.warn(
                f"CommLedger.{fn.__name__} is deprecated; use "
                f"{replacement}", DeprecationWarning, stacklevel=2)
            return fn(self, *args, **kwargs)
        return wrapper
    return deco


class CommLedger:
    """Accumulates per-edge traffic and simulated time for one run.

    ``record_exchange(c)``: all-to-all style — each node's ``c`` exchanged
    floats are spread uniformly over its incident edges (the sum over
    edges conserves ``K * c``); priced on the schedule's union graph
    (parameter-server-style traffic has no per-round edge set).
    ``record_gossip(m, t)``: D-PSGD style — every edge *active in round
    t's graph* carries the full model once per direction (``2m`` per
    active edge), masked down to the round's participants when a
    ``participation`` sampler is attached.  In ``async_mode`` a per-edge
    ``staleness`` bound (AD-PSGD) amortizes each link's latency over
    ``staleness + 1`` in-flight deliveries.
    ``record_probe(edges, m)``: SkewScout model traveling — ``m`` floats
    cross each probed union link once.

    Construction takes the typed :class:`~repro.configs.base.FabricConfig`
    (``config=``) for the amortization/re-wiring knobs; the loose
    ``rewire_floats_per_edge=`` / ``amortize_window=`` kwargs are
    deprecated.  Read results through :meth:`view`.
    """

    def __init__(self, fabric: Union[Topology, TopologySchedule],
                 profile: LinkProfile, *,
                 config: Optional[FabricConfig] = None,
                 async_mode: bool = False,
                 link_model=None,
                 participation=None,
                 ewma_alpha: float = 0.1,
                 rewire_floats_per_edge: Optional[float] = None,
                 amortize_window: Optional[int] = None):
        if rewire_floats_per_edge is not None or \
                amortize_window is not None:
            warnings.warn(
                "CommLedger(rewire_floats_per_edge=..., amortize_window"
                "=...) is deprecated; pass config=FabricConfig(...)",
                DeprecationWarning, stacklevel=2)
        if config is not None:
            if rewire_floats_per_edge is None:
                rewire_floats_per_edge = config.rewire_floats
            if amortize_window is None:
                amortize_window = config.amortize_window
        self.profile = profile
        self.rewire_floats_per_edge = float(rewire_floats_per_edge or 0.0)
        self.async_mode = bool(async_mode)
        # stochastic per-link sampler (repro.topology.links.LinkModel);
        # None keeps the class-constant pricing
        self.links = link_model
        # per-round client sampler (repro.topology.links.Participation);
        # None = everyone participates every round (the legacy pricing)
        self.participation = participation
        amortize_window = 1 if amortize_window is None \
            else int(amortize_window)
        assert amortize_window >= 1, amortize_window
        self.amortize_window = amortize_window
        assert 0.0 < ewma_alpha <= 1.0, ewma_alpha
        self.ewma_alpha = float(ewma_alpha)
        # ---- the eid-indexed array core ----
        # canonical edge -> stable edge id; grown at graph registration
        self._eid: Dict[Edge, int] = {}
        self._edge_of_eid: List[Edge] = []
        self._eid_i = np.zeros(0, np.int64)   # endpoint arrays by eid
        self._eid_j = np.zeros(0, np.int64)
        self._clock = np.zeros(0)             # per-edge virtual clock (s)
        self._clock_seen = np.zeros(0, bool)  # ever clock-charged
        self._traffic = np.zeros(0)           # floats booked, by eid
        # per-edge EWMA measured costs (observed latency seconds and
        # price seconds/float) — SkewScout's measured-cost denominators
        self._ewma_lat = np.zeros(0)
        self._ewma_price = np.zeros(0)
        self._ewma_seen = np.zeros(0, bool)
        # handshake amortization: unpaid balance + per-activation
        # installment by eid; `_pending` keeps the dict-era insertion
        # order (the sequential pay/forfeit folds are order-sensitive)
        self._hs_bal = np.zeros(0)
        self._hs_inst = np.zeros(0)
        self._pending: Dict[int, None] = {}
        # running transfer seconds with every float priced at the
        # bandwidth its activation actually sampled — the sync C(θ)
        # numerator that stays in the same currency as the measured CM
        self._sampled_cost_s = 0.0
        self.lan_floats = 0.0
        self.wan_floats = 0.0
        self.sim_time_s = 0.0
        # online re-wiring accounting (floats also in lan/wan totals)
        self.rewire_lan_floats = 0.0
        self.rewire_wan_floats = 0.0
        self.rewire_events = 0
        self.rewire_time_s = 0.0     # handshake seconds booked on links
        # communication rounds recorded — includes probe/overhead
        # exchanges, so this is NOT the trainer's step count
        self.rounds = 0
        self._last_active: Optional[frozenset] = None
        self._pricing: Dict[int, _GraphPricing] = {}
        self._measured_ids: Dict[int, tuple] = {}
        self._version = 0
        self._view: Optional[LedgerView] = None
        self._view_version = -1
        self._attach(as_schedule(fabric))
        # per-node busy time: each round a node participates in, it
        # works for the max cost over its own activated incident links
        self.node_busy_s = np.zeros(self.topology.n_nodes)

    # ---- edge registration ----
    def _register(self, graph: Topology) -> np.ndarray:
        """Assign stable eids to any of ``graph``'s edges the ledger has
        not seen, growing the flat bookkeeping arrays; returns the
        graph's eid index array."""
        eid = self._eid
        miss = [e for e in graph.edges if e not in eid]
        if miss:
            start = len(self._edge_of_eid)
            for k, e in enumerate(miss):
                eid[e] = start + k
            self._edge_of_eid.extend(miss)
            add = len(miss)
            self._eid_i = np.concatenate(
                [self._eid_i, np.asarray([i for i, _ in miss], np.int64)])
            self._eid_j = np.concatenate(
                [self._eid_j, np.asarray([j for _, j in miss], np.int64)])
            z = np.zeros(add)
            zb = np.zeros(add, bool)
            self._clock = np.concatenate([self._clock, z])
            self._clock_seen = np.concatenate([self._clock_seen, zb])
            self._traffic = np.concatenate([self._traffic, z])
            self._ewma_lat = np.concatenate([self._ewma_lat, z])
            self._ewma_price = np.concatenate([self._ewma_price, z])
            self._ewma_seen = np.concatenate([self._ewma_seen, zb])
            self._hs_bal = np.concatenate([self._hs_bal, z])
            self._hs_inst = np.concatenate([self._hs_inst, z])
        if not graph.edges:
            return np.zeros(0, np.int64)
        return np.fromiter((eid[e] for e in graph.edges), np.int64,
                           len(graph.edges))

    def _attach(self, schedule: TopologySchedule) -> None:
        self.schedule = schedule
        self.topology = schedule.union()
        self._union_pricing = _GraphPricing(
            self.topology, self.profile, self._register(self.topology))

    def _graph_pricing(self, graph: Topology) -> _GraphPricing:
        p = self._pricing.get(id(graph))
        if p is None:
            p = self._pricing[id(graph)] = _GraphPricing(
                graph, self.profile, self._register(graph))
        return p

    # ---- recording ----
    def _book_floats(self, pricing: _GraphPricing,
                     per_edge: np.ndarray) -> None:
        """Attribute ``per_edge`` floats (aligned with ``pricing.graph``'s
        edge list) to links and LAN/WAN totals — all vectorized; the
        eid-indexed traffic array only absorbs the per-graph accumulator
        on cold reads (``view``/``switch_schedule``)."""
        pricing.traffic += per_edge
        self.lan_floats += float(per_edge[~pricing.is_wan].sum())
        self.wan_floats += float(per_edge[pricing.is_wan].sum())

    def _link_rates(self, pricing: _GraphPricing, active: np.ndarray
                    ) -> tuple:
        """Per-edge (latency, bandwidth) for one activation of the
        ``active`` edges: the graph's class constants, or — with a
        ``link_model`` attached — the sampled values, each observation
        folded into the per-edge EWMA measured costs (one vectorized
        elementwise fold; bit-equal to the per-edge scalar fold)."""
        if self.links is None or not self.links.stochastic:
            # identity sampling: constants are the truth, the EWMA fold
            # would only re-derive them — keep the hot path draw-free
            return pricing.lat, pricing.bw
        lat, bw = self.links.sample(pricing.graph.edges, pricing.lat,
                                    pricing.bw, active)
        act = np.flatnonzero(active)
        if act.size:
            ids = pricing.eids[act]
            a = self.ewma_alpha
            obs_lat = lat[act]
            obs_price = 1.0 / bw[act]
            seen = self._ewma_seen[ids]
            self._ewma_lat[ids] = np.where(
                seen, (1.0 - a) * self._ewma_lat[ids] + a * obs_lat,
                obs_lat)
            self._ewma_price[ids] = np.where(
                seen, (1.0 - a) * self._ewma_price[ids] + a * obs_price,
                obs_price)
            self._ewma_seen[ids] = True
        return lat, bw

    def _book_sampled_cost(self, per_edge: np.ndarray, bw: np.ndarray,
                           active: np.ndarray) -> None:
        """Accumulate the transfer seconds of ``per_edge`` floats at the
        (possibly sampled) ``bw`` of this activation — the sampled
        analogue of ``priced_cost``'s float-times-constant-price sum.
        No-op without a stochastic link model: ``sampled_priced_cost``
        falls back to ``priced_cost`` there."""
        if self.links is not None and self.links.stochastic:
            self._sampled_cost_s += float(
                (per_edge[active] / bw[active]).sum())

    def _pay_installments(self, pricing: _GraphPricing,
                          active: np.ndarray) -> Optional[np.ndarray]:
        """Handshake installments due this round: each active edge with
        an unpaid balance pays ``handshake / amortize_window`` into its
        round cost.  Returns the per-edge installment array (None when
        nothing is owed).  The loop runs over the pending set only
        (empty in steady state) in insertion order — the sequential
        ``rewire_time_s`` fold is order-sensitive."""
        if not self._pending:
            return None
        inst = None
        for g in list(self._pending):
            n = pricing.pos_of.get(g)
            if n is None or not active[n]:
                continue
            bal = float(self._hs_bal[g])
            pay = min(float(self._hs_inst[g]), bal)
            if inst is None:
                inst = np.zeros(len(pricing.graph.edges))
            inst[n] += pay
            self.rewire_time_s += pay
            bal -= pay
            if bal <= 1e-18:
                del self._pending[g]
                self._hs_bal[g] = 0.0
                self._hs_inst[g] = 0.0
            else:
                self._hs_bal[g] = bal
        return inst

    def _charge_time(self, pricing: _GraphPricing,
                     cost: np.ndarray, active: np.ndarray) -> None:
        """Advance the clocks by ``cost`` seconds per edge (aligned with
        ``pricing.graph.edges``; only ``active`` entries count).

        sync: stop-and-wait — the global clock grows by the round's max
        cost and every activated edge snaps to it.  async: each edge's
        clock advances by its own cost; the global clock is the max of
        the *activated* edges' clocks (monotone by construction)."""
        if not active.any():
            return
        ids = pricing.eids[active]
        if self.async_mode:
            newc = self._clock[ids] + cost[active]
            self._clock[ids] = newc
            self.sim_time_s = max(self.sim_time_s, float(newc.max()))
        else:
            self.sim_time_s += float(cost[active].max())
            self._clock[ids] = self.sim_time_s
        self._clock_seen[ids] = True
        busy = np.zeros(len(self.node_busy_s))
        own = np.where(active, cost, 0.0)
        np.maximum.at(busy, pricing.ei, own)
        np.maximum.at(busy, pricing.ej, own)
        self.node_busy_s += busy

    def _rewire(self, pricing: _GraphPricing) -> None:
        """Charge the online re-wiring cost for links that were not
        active in the previous gossip round: a control-plane handshake
        of ``rewire_floats_per_edge`` floats per new link, priced at the
        link's class and added to the simulated step time; the link's
        per-class *setup latency* (``LinkProfile.handshake``: WAN >>
        LAN) is charged as its own serial setup event at the default
        ``amortize_window=1`` (the exact legacy behaviour), or scheduled
        as ``handshake / amortize_window`` installments paid into the
        link's first ``amortize_window`` gossip activations.  Links
        dropped before their window completes forfeit the unpaid
        balance immediately.
        Floats are booked into the LAN/WAN totals too, so ``lan_floats +
        wan_floats`` still covers every priced float.  Only gossip
        rounds carry an active edge set — union-routed exchanges
        (probes) never re-wire and never reset the tracking."""
        if self._last_active is None or \
                pricing.active is self._last_active or \
                pricing.active == self._last_active:
            self._last_active = pricing.active
            return
        prev = self._last_active
        new = pricing.active - prev
        dropped = prev - pricing.active
        self._last_active = pricing.active
        # teardown: a dropped link's unamortized handshake balance is
        # charged now — the setup work was spent; only the booking was
        # deferred.  This is what keeps schedule thrashing as expensive
        # as un-amortized switching.
        if dropped and self._pending:
            forfeit_max = 0.0
            forfeited = []
            busy = np.zeros(len(self.node_busy_s))
            for e in dropped:
                g = self._eid.get(e)
                if g is None or g not in self._pending:
                    continue
                bal = float(self._hs_bal[g])
                del self._pending[g]
                self._hs_bal[g] = 0.0
                self._hs_inst[g] = 0.0
                if bal <= 0.0:
                    continue
                forfeited.append(g)
                self.rewire_time_s += bal
                # the endpoints did this work: keep busy/idle/clock-skew
                # accounting comparable across amortize_window settings
                # (at window 1 the same seconds flow through the round's
                # _charge_time and land on the endpoints there)
                for k in e:
                    if k < len(busy):
                        busy[k] = max(busy[k], bal)
                if self.async_mode:
                    c = float(self._clock[g]) + bal
                    self._clock[g] = c
                    self._clock_seen[g] = True
                    self.sim_time_s = max(self.sim_time_s, c)
                else:
                    forfeit_max = max(forfeit_max, bal)
            # sync: teardowns run in parallel across the dropped links,
            # and the links that actually forfeited (only those — a
            # fully-paid dropped edge keeps its stale clock) snap to the
            # global clock
            self.sim_time_s += forfeit_max
            if forfeited and not self.async_mode:
                ids = np.asarray(forfeited, np.int64)
                self._clock[ids] = np.maximum(self._clock[ids],
                                              self.sim_time_s)
                self._clock_seen[ids] = True
            self.node_busy_s += busy
        if not new:
            return
        new_ids = np.fromiter((self._eid[e] for e in new), np.int64,
                              len(new))
        if self.async_mode:
            # a (re)activated link joins at the global frontier: it
            # cannot have banked transfer time while it did not exist.
            # Without this, a rung switch would hand the controller a
            # free window (the new fabric's clocks lag the ratcheted
            # global max, so C(θ) reads ~0 until they catch up).
            self._clock[new_ids] = np.maximum(self._clock[new_ids],
                                              self.sim_time_s)
            self._clock_seen[new_ids] = True
        is_new = np.zeros(len(self._edge_of_eid), bool)
        is_new[new_ids] = True
        is_new = is_new[pricing.eids]
        per_edge = np.where(is_new, self.rewire_floats_per_edge, 0.0)
        if self.rewire_floats_per_edge > 0.0:
            self._book_floats(pricing, per_edge)
            self.rewire_lan_floats += float(per_edge[~pricing.is_wan].sum())
            self.rewire_wan_floats += float(per_edge[pricing.is_wan].sum())
        # window 1 (the default) keeps the exact legacy behaviour: the
        # whole handshake is charged here as its own serial setup event.
        # W > 1 schedules it as installments over the link's first W
        # activations instead (re-activation restarts the window: the
        # old connection is gone)
        if self.amortize_window > 1:
            for n in np.flatnonzero(is_new):
                g = int(pricing.eids[n])
                hs = float(pricing.hs[n])
                if hs > 0.0:
                    self._hs_bal[g] = hs
                    self._hs_inst[g] = hs / self.amortize_window
                    self._pending[g] = None
            hs_now = 0.0
        else:
            hs_now = pricing.hs
        # the control-plane transfer itself (amortized handshake latency
        # is paid through the installments, starting with this round's
        # gossip; control-plane floats are priced at nominal constants)
        self._book_sampled_cost(per_edge, pricing.bw, is_new)
        cost = np.where(is_new,
                        hs_now + pricing.lat + per_edge / pricing.bw, 0.0)
        self.rewire_time_s += float(cost[is_new].sum())
        self._charge_time(pricing, cost, cost > 0)
        self.rewire_events += len(new)

    def record_exchange(self,
                        floats_per_node: Union[float, Sequence[float]]
                        ) -> None:
        """All-to-all exchange of ``floats_per_node`` floats per node,
        routed uniformly over each node's incident edges of the union
        fabric.  Union routing has no per-round active edge set, so it
        neither pays nor resets re-wiring."""
        pricing = self._union_pricing
        K = self.topology.n_nodes
        c = np.broadcast_to(np.asarray(floats_per_node, np.float64), (K,))
        share = np.where(pricing.deg > 0,
                         c / np.maximum(pricing.deg, 1), 0.0)
        per_edge = share[pricing.ei] + share[pricing.ej]
        self._book_floats(pricing, per_edge)
        active = per_edge > 0
        lat, bw = self._link_rates(pricing, active)
        self._book_sampled_cost(per_edge, bw, active)
        self._charge_time(pricing,
                          np.where(active, lat + per_edge / bw, 0.0),
                          active)
        self.rounds += 1
        self._version += 1

    def record_gossip(self, model_floats: float,
                      t: Optional[int] = None,
                      staleness: Union[None, int, Sequence[int]] = None
                      ) -> None:
        """One gossip round at round index ``t``: the full model crosses
        every edge active in ``schedule.at(t)``, both directions.
        ``t=None`` keeps the legacy one-graph behaviour (round 0).

        ``staleness`` (async mode only): per-edge bounded-staleness
        values (scalar broadcasts) — a link tolerating ``s``-stale
        deliveries pipelines ``s + 1`` payloads, so its latency is paid
        once per ``s + 1`` activations.  Ignored in sync mode, where
        every round is stop-and-wait regardless of the algorithm.

        With a ``participation`` sampler attached, the round's mask
        drops every edge whose endpoints did not both show up: no
        floats, no time, no installment payment, no link-model draw.
        Re-wiring still tracks the schedule's full active set (sampling
        out is not a teardown)."""
        graph = self.schedule.at(0 if t is None else t)
        pricing = self._graph_pricing(graph)
        self._rewire(pricing)
        n_edges = len(graph.edges)
        if self.participation is not None:
            m = self.participation.mask(0 if t is None else t)
            per_edge = np.where(m[pricing.ei] & m[pricing.ej],
                                2.0 * model_floats, 0.0)
        else:
            per_edge = np.full(n_edges, 2.0 * model_floats)
        self._book_floats(pricing, per_edge)
        active = per_edge > 0
        lat, bw = self._link_rates(pricing, active)
        self._book_sampled_cost(per_edge, bw, active)
        if self.async_mode and staleness is not None:
            s = np.broadcast_to(np.asarray(staleness, np.float64),
                                (n_edges,))
            assert (s >= 0).all(), "staleness must be non-negative"
            lat = lat / (1.0 + s)
        cost = np.where(active, lat + per_edge / bw, 0.0)
        inst = self._pay_installments(pricing, active)
        if inst is not None:
            cost = cost + inst
        self._charge_time(pricing, cost, active)
        self.rounds += 1
        self._version += 1

    def record_probe(self, edges: Sequence[Edge],
                     floats_each: float) -> None:
        """SkewScout model traveling: ``floats_each`` floats cross each
        probed link once (one direction).  Probes ride union-fabric
        links (probe routing follows active edges, which are union
        members), are booked into the LAN/WAN totals and per-edge
        traffic, block on delivery (staleness 0 — the measurement needs
        the fresh model), and neither pay nor reset re-wiring."""
        pricing = self._union_pricing
        per_edge = np.zeros(len(pricing.graph.edges))
        for i, j in edges:
            e = (min(i, j), max(i, j))
            assert e in pricing.edge_index, \
                f"probe edge {e} is not on the union fabric"
            per_edge[pricing.edge_index[e]] += float(floats_each)
        self._book_floats(pricing, per_edge)
        active = per_edge > 0
        lat, bw = self._link_rates(pricing, active)
        self._book_sampled_cost(per_edge, bw, active)
        self._charge_time(pricing,
                          np.where(active, lat + per_edge / bw, 0.0),
                          active)
        self.rounds += 1
        self._version += 1

    def switch_schedule(self, fabric: Union[Topology, TopologySchedule]
                        ) -> None:
        """Swap the fabric mid-run (SkewScout climbing a topology rung).
        Accumulated traffic and per-edge clocks are preserved (eids are
        stable for life); the first gossip round on the new schedule
        pays re-wiring for every link the old round's active set did not
        have."""
        schedule = as_schedule(fabric)
        assert schedule.n_nodes == self.topology.n_nodes, \
            (schedule.n_nodes, self.topology.n_nodes)
        self._flush_traffic()
        self._attach(schedule)
        self._pricing.clear()
        self._version += 1

    def _flush_traffic(self) -> None:
        """Fold the per-graph accumulators into the canonical
        eid-indexed traffic array (cold path: views and schedule
        switches) — one binary add per edge per flush, the dict-era
        grouping."""
        self._union_pricing.flush_into(self._traffic)
        for p in self._pricing.values():
            p.flush_into(self._traffic)

    # ---- the read API ----
    def view(self) -> LedgerView:
        """Frozen :class:`LedgerView` snapshot; version-cached, so
        repeated reads between mutations cost nothing."""
        if self._view is not None and self._view_version == self._version:
            return self._view
        self._flush_traffic()
        n = len(self._edge_of_eid)
        self._view = LedgerView(
            n_nodes=self.topology.n_nodes,
            async_mode=self.async_mode,
            rounds=self.rounds,
            amortize_window=self.amortize_window,
            sim_time_s=self.sim_time_s,
            lan_floats=self.lan_floats,
            wan_floats=self.wan_floats,
            total_floats=self._total_floats(),
            priced_cost=self._priced_cost(),
            sampled_priced_cost=self._sampled_priced_cost(),
            window_cost=self._window_cost(),
            rewire_lan_floats=self.rewire_lan_floats,
            rewire_wan_floats=self.rewire_wan_floats,
            rewire_floats=self._rewire_floats_total(),
            rewiring_cost=self._rewiring_cost(),
            rewire_events=self.rewire_events,
            rewire_time_s=self.rewire_time_s,
            pending_handshake_s=self._pending_handshake_s(),
            clock_skew_s=self._clock_skew_s(),
            edges=tuple(self._edge_of_eid),
            edge_clock=self._clock[:n].copy(),
            edge_seen=self._clock_seen[:n].copy(),
            edge_traffic=self._traffic[:n].copy(),
            union_eids=self._union_pricing.eids.copy(),
            ewma_latency_s=self._ewma_lat[:n].copy(),
            ewma_price_s=self._ewma_price[:n].copy(),
            ewma_seen=self._ewma_seen[:n].copy(),
            node_clock=self._node_clocks(),
            node_busy_s=self.node_busy_s.copy(),
            node_idle_s=self._node_idle_s(),
            _ledger=self,
        )
        self._view_version = self._version
        return self._view

    # ---- private implementations (shared by view() and the shims) ----
    def _total_floats(self) -> float:
        return self.lan_floats + self.wan_floats

    def _priced_cost(self) -> float:
        return (self.lan_floats * self.profile.price_per_float("lan")
                + self.wan_floats * self.profile.price_per_float("wan"))

    def _sampled_priced_cost(self) -> float:
        if self.links is None or not self.links.stochastic:
            return self._priced_cost()
        return self._sampled_cost_s

    def _rewire_floats_total(self) -> float:
        return self.rewire_lan_floats + self.rewire_wan_floats

    def _rewiring_cost(self) -> float:
        return (self.rewire_lan_floats * self.profile.price_per_float("lan")
                + self.rewire_wan_floats
                * self.profile.price_per_float("wan"))

    def _window_cost(self) -> float:
        if self.async_mode:
            return self.sim_time_s
        return self._sampled_priced_cost()

    def _pending_handshake_s(self) -> float:
        return float(sum(float(self._hs_bal[g]) for g in self._pending))

    def _node_clocks(self) -> np.ndarray:
        clk = np.zeros(self.topology.n_nodes)
        K = len(clk)
        seen = self._clock_seen
        ids = np.flatnonzero(seen)
        if ids.size:
            c = self._clock[ids]
            i = self._eid_i[ids]
            j = self._eid_j[ids]
            mi = i < K
            mj = j < K
            np.maximum.at(clk, i[mi], c[mi])
            np.maximum.at(clk, j[mj], c[mj])
        return clk

    def _clock_skew_s(self) -> float:
        clk = self._node_clocks()
        return float(clk.max() - clk.min()) if len(clk) else 0.0

    def _node_idle_s(self) -> np.ndarray:
        return np.maximum(self.sim_time_s - self.node_busy_s, 0.0)

    def _edge_clocks_map(self) -> Dict[Edge, float]:
        ids = np.flatnonzero(self._clock_seen)
        return {self._edge_of_eid[g]: float(self._clock[g]) for g in ids}

    def _traffic_map(self) -> Dict[Edge, float]:
        self._flush_traffic()
        ids = np.flatnonzero(self._traffic)
        return {self._edge_of_eid[g]: float(self._traffic[g])
                for g in ids}

    def _edge_traffic_union(self) -> np.ndarray:
        self._flush_traffic()
        return self._traffic[self._union_pricing.eids]

    def _full_exchange(self, model_floats: float, g: Topology,
                       lat_e: np.ndarray, price_e: np.ndarray,
                       worst: bool) -> float:
        """One BSP-style full-model exchange on ``g`` (each node's model
        share routed uniformly over its incident edges): the max link
        time (``worst=True``, latency + transfer) or the summed
        bandwidth-seconds (sequential fold — bit-equal to the retired
        per-edge loop).  The per-edge (latency, price) arrays come from
        the callers, so the constant and measured variants share one
        routing formula."""
        if not len(g.edges):
            return 1e-30
        deg = g.degrees().astype(np.float64)
        share = model_floats / np.maximum(deg, 1)
        ei = np.asarray([i for i, _ in g.edges], np.int64)
        ej = np.asarray([j for _, j in g.edges], np.int64)
        per_edge = share[ei] + share[ej]
        if worst:
            acc = max(0.0, float((lat_e + per_edge * price_e).max()))
        else:
            acc = _seqsum(per_edge * price_e)
        return max(acc, 1e-30)

    def _const_rates(self, g: Topology) -> tuple:
        is_wan = _wan_mask(g)
        lat = np.where(is_wan, self.profile.latency("wan"),
                       self.profile.latency("lan"))
        price = np.where(is_wan, self.profile.price_per_float("wan"),
                         self.profile.price_per_float("lan"))
        return lat, price

    def _full_exchange_cost(self, model_floats: float) -> float:
        lat, price = self._const_rates(self.topology)
        return self._full_exchange(model_floats, self.topology, lat,
                                   price, worst=False)

    def _full_exchange_time(self, model_floats: float) -> float:
        lat, price = self._const_rates(self.topology)
        return self._full_exchange(model_floats, self.topology, lat,
                                   price, worst=True)

    def _measured_latency_s(self, e: Edge, cls: str = "lan") -> float:
        g = self._eid.get(e)
        if g is not None and self._ewma_seen[g]:
            return float(self._ewma_lat[g])
        return self.profile.latency(cls)

    def _measured_price_per_float(self, e: Edge,
                                  cls: str = "lan") -> float:
        g = self._eid.get(e)
        if g is not None and self._ewma_seen[g]:
            return float(self._ewma_price[g])
        return self.profile.price_per_float(cls)

    def _measured_union(self, fabric) -> Topology:
        return self.topology if fabric is None \
            else as_schedule(fabric).union()

    def _measured_rates(self, g: Topology) -> tuple:
        """Per-edge EWMA measured (latency, price) with profile-constant
        fallback for never-observed links, cached per graph object."""
        ent = self._measured_ids.get(id(g))
        if ent is None or ent[0] is not g:
            ids = np.fromiter((self._eid.get(e, -1) for e in g.edges),
                              np.int64, len(g.edges))
            self._measured_ids[id(g)] = ent = (g, ids)
        ids = ent[1]
        lat_c, price_c = self._const_rates(g)
        seen = (ids >= 0) & self._ewma_seen[np.maximum(ids, 0)]
        safe = np.maximum(ids, 0)
        lat = np.where(seen, self._ewma_lat[safe], lat_c)
        price = np.where(seen, self._ewma_price[safe], price_c)
        return lat, price

    def _measured_full_exchange_cost(self, model_floats: float,
                                     fabric=None) -> float:
        g = self._measured_union(fabric)
        lat, price = self._measured_rates(g)
        return self._full_exchange(model_floats, g, lat, price,
                                   worst=False)

    def _measured_full_exchange_time(self, model_floats: float,
                                     fabric=None) -> float:
        g = self._measured_union(fabric)
        lat, price = self._measured_rates(g)
        return self._full_exchange(model_floats, g, lat, price,
                                   worst=True)

    def _cm_denominator(self, model_floats: float,
                        fabric=None) -> float:
        if self.links is not None:
            return (self._measured_full_exchange_time(model_floats,
                                                      fabric=fabric)
                    if self.async_mode
                    else self._measured_full_exchange_cost(model_floats,
                                                           fabric=fabric))
        return (self._full_exchange_time(model_floats) if self.async_mode
                else self._full_exchange_cost(model_floats))

    # ---- deprecated accessor shims (use view() instead) ----
    @_deprecated("CommLedger.view().traffic_map()")
    def traffic_by_edge(self) -> Dict[Edge, float]:
        """Deprecated: ``view().traffic_map()`` (or
        ``view().edge_traffic``, eid-aligned)."""
        return self._traffic_map()

    @property
    @_deprecated("CommLedger.view().edge_traffic[view().union_eids]")
    def edge_traffic(self) -> np.ndarray:
        """Deprecated: per-edge floats aligned with
        ``self.topology.edges`` — ``view().edge_traffic`` indexed by
        ``view().union_eids``."""
        return self._edge_traffic_union()

    @_deprecated("CommLedger.view().edge_clock_map()")
    def edge_clocks(self) -> Dict[Edge, float]:
        """Deprecated: ``view().edge_clock_map()`` (or
        ``view().edge_clock``, eid-aligned)."""
        return self._edge_clocks_map()

    @_deprecated("CommLedger.view().node_clock")
    def node_clocks(self) -> np.ndarray:
        """Deprecated: ``view().node_clock``."""
        return self._node_clocks()

    @_deprecated("CommLedger.view().clock_skew_s")
    def clock_skew_s(self) -> float:
        """Deprecated: ``view().clock_skew_s``."""
        return self._clock_skew_s()

    @property
    @_deprecated("CommLedger.view().node_idle_s")
    def node_idle_s(self) -> np.ndarray:
        """Deprecated: ``view().node_idle_s``."""
        return self._node_idle_s()

    @property
    @_deprecated("CommLedger.view().total_floats")
    def total_floats(self) -> float:
        """Deprecated: ``view().total_floats``."""
        return self._total_floats()

    @_deprecated("CommLedger.view().priced_cost")
    def priced_cost(self) -> float:
        """Deprecated: ``view().priced_cost``."""
        return self._priced_cost()

    @_deprecated("CommLedger.view().sampled_priced_cost")
    def sampled_priced_cost(self) -> float:
        """Deprecated: ``view().sampled_priced_cost``."""
        return self._sampled_priced_cost()

    @property
    @_deprecated("CommLedger.view().rewire_floats")
    def rewire_floats(self) -> float:
        """Deprecated: ``view().rewire_floats``."""
        return self._rewire_floats_total()

    @_deprecated("CommLedger.view().rewiring_cost")
    def rewiring_cost(self) -> float:
        """Deprecated: ``view().rewiring_cost``."""
        return self._rewiring_cost()

    @_deprecated("CommLedger.view().full_exchange_cost(m)")
    def full_exchange_cost(self, model_floats: float) -> float:
        """Deprecated: ``view().full_exchange_cost(m)``."""
        return self._full_exchange_cost(model_floats)

    @_deprecated("CommLedger.view().full_exchange_time(m)")
    def full_exchange_time(self, model_floats: float) -> float:
        """Deprecated: ``view().full_exchange_time(m)``."""
        return self._full_exchange_time(model_floats)

    @_deprecated("CommLedger.view().measured_latency_s(e, cls)")
    def measured_latency_s(self, e: Edge, cls: str = "lan") -> float:
        """Deprecated: ``view().measured_latency_s(e, cls)``."""
        return self._measured_latency_s(e, cls)

    @_deprecated("CommLedger.view().measured_price_per_float(e, cls)")
    def measured_price_per_float(self, e: Edge,
                                 cls: str = "lan") -> float:
        """Deprecated: ``view().measured_price_per_float(e, cls)``."""
        return self._measured_price_per_float(e, cls)

    @_deprecated("CommLedger.view().measured_full_exchange_cost(m)")
    def measured_full_exchange_cost(self, model_floats: float,
                                    fabric=None) -> float:
        """Deprecated: ``view().measured_full_exchange_cost(m)``."""
        return self._measured_full_exchange_cost(model_floats,
                                                 fabric=fabric)

    @_deprecated("CommLedger.view().measured_full_exchange_time(m)")
    def measured_full_exchange_time(self, model_floats: float,
                                    fabric=None) -> float:
        """Deprecated: ``view().measured_full_exchange_time(m)``."""
        return self._measured_full_exchange_time(model_floats,
                                                 fabric=fabric)

    @_deprecated("CommLedger.view().window_cost")
    def window_cost(self) -> float:
        """Deprecated: ``view().window_cost``."""
        return self._window_cost()

    @_deprecated("CommLedger.view().cm_denominator(m)")
    def cm_denominator(self, model_floats: float, fabric=None) -> float:
        """Deprecated: ``view().cm_denominator(m)``."""
        return self._cm_denominator(model_floats, fabric=fabric)

    @property
    @_deprecated("CommLedger.view().pending_handshake_s")
    def pending_handshake_s(self) -> float:
        """Deprecated: ``view().pending_handshake_s``."""
        return self._pending_handshake_s()

    def summary(self) -> Dict[str, float]:
        return dict(lan_floats=self.lan_floats, wan_floats=self.wan_floats,
                    total_floats=self._total_floats(),
                    sim_time_s=self.sim_time_s,
                    priced_cost=self._priced_cost(), rounds=self.rounds,
                    rewire_floats=self._rewire_floats_total(),
                    rewire_events=self.rewire_events,
                    rewire_time_s=self.rewire_time_s,
                    async_mode=float(self.async_mode),
                    clock_skew_s=self._clock_skew_s(),
                    busy_s_max=float(self.node_busy_s.max()),
                    idle_s_mean=float(self._node_idle_s().mean()),
                    amortize_window=float(self.amortize_window),
                    pending_handshake_s=self._pending_handshake_s(),
                    **({"link_" + k: float(v)
                        for k, v in self.links.summary().items()}
                       if self.links is not None else {}),
                    **({"participation": float(self.participation.fraction)}
                       if self.participation is not None else {}))
