"""Communication graphs and mixing matrices.

Every topology is an undirected connected graph over the K nodes plus a
symmetric doubly-stochastic mixing matrix ``W`` (Metropolis–Hastings
weights), the gossip-averaging operator of D-PSGD (Lian et al., 2017):
``x_{t+1} = W @ x_t`` restricted to graph edges.  Edges carry a link
class ("lan" | "wan") consumed by the cost model in ``costs.py``.

Builders:
  fully_connected   all-to-all (W = 1/K everywhere: exact averaging)
  ring              cycle graph — the minimal-bandwidth baseline
  torus             2D wrap-around grid (near-square factorization of K)
  random_regular    d-regular expander via the pairing model
  hierarchical      geo-WAN: LAN cliques (datacenters) joined by WAN
                    links between gateway nodes (the paper's Gaia setting)
  d_cliques         label-aware cliques (Bellet et al., 2021): greedy
                    clique assembly so each clique's aggregate label
                    histogram is near-uniform; inter-clique ring over WAN
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

Edge = Tuple[int, int]


@dataclass(frozen=True)
class Topology:
    """An undirected communication graph with gossip weights.

    edges        canonical (i < j) undirected edge list
    mixing       (K, K) symmetric doubly-stochastic matrix, supported
                 exactly on edges + the diagonal
    edge_class   per-edge link class, "lan" or "wan"
    cliques      D-Cliques / datacenter grouping (empty when unused)
    """
    name: str
    n_nodes: int
    edges: Tuple[Edge, ...]
    mixing: np.ndarray
    edge_class: Tuple[str, ...] = ()
    cliques: Tuple[Tuple[int, ...], ...] = ()

    def __post_init__(self):
        if not self.edge_class:
            object.__setattr__(self, "edge_class",
                               ("lan",) * len(self.edges))
        assert len(self.edge_class) == len(self.edges)

    # ---- structure ----
    def neighbors(self, k: int) -> List[int]:
        out = [j for i, j in self.edges if i == k]
        out += [i for i, j in self.edges if j == k]
        return sorted(out)

    def degrees(self) -> np.ndarray:
        deg = np.zeros(self.n_nodes, np.int64)
        for i, j in self.edges:
            deg[i] += 1
            deg[j] += 1
        return deg

    @property
    def max_degree(self) -> int:
        return int(self.degrees().max()) if self.edges else 0

    @property
    def mean_degree(self) -> float:
        return float(self.degrees().mean()) if self.edges else 0.0

    def wan_edge_indices(self) -> np.ndarray:
        return np.asarray([e for e, c in enumerate(self.edge_class)
                           if c == "wan"], np.int64)

    # ---- spectral ----
    def spectral_gap(self) -> float:
        """1 - |lambda_2(W)|: larger gap => faster gossip consensus."""
        ev = np.sort(np.abs(np.linalg.eigvalsh(self.mixing)))
        return float(1.0 - ev[-2]) if len(ev) > 1 else 1.0

    # ---- kernel-facing layout ----
    def neighbor_arrays(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Padded (idx, weight, self_weight) arrays for the neighbor_mix
        kernel: idx (K, D) int32 padded with the node's own index, weight
        (K, D) float32 padded with 0, self_w (K,) float32 = diag(W)."""
        K, D = self.n_nodes, max(self.max_degree, 1)
        idx = np.tile(np.arange(K, dtype=np.int32)[:, None], (1, D))
        w = np.zeros((K, D), np.float32)
        fill = np.zeros(K, np.int64)
        for i, j in self.edges:
            for a, b in ((i, j), (j, i)):
                idx[a, fill[a]] = b
                w[a, fill[a]] = self.mixing[a, b]
                fill[a] += 1
        return idx, w, np.diag(self.mixing).astype(np.float32)


def _canonical(edges: Sequence[Edge]) -> List[Edge]:
    return sorted({(min(i, j), max(i, j)) for i, j in edges if i != j})


def metropolis_weights(n_nodes: int, edges: Sequence[Edge]) -> np.ndarray:
    """Symmetric doubly-stochastic W: W_ij = 1/(1 + max(deg_i, deg_j)) on
    edges, diagonal takes the slack.  Standard gossip weights — doubly
    stochastic for any graph, uniform 1/K on the complete graph."""
    deg = np.zeros(n_nodes, np.int64)
    for i, j in edges:
        deg[i] += 1
        deg[j] += 1
    W = np.zeros((n_nodes, n_nodes))
    for i, j in edges:
        W[i, j] = W[j, i] = 1.0 / (1.0 + max(deg[i], deg[j]))
    np.fill_diagonal(W, 1.0 - W.sum(axis=1))
    return W


def _connected(n_nodes: int, edges: Sequence[Edge]) -> bool:
    adj: Dict[int, List[int]] = {k: [] for k in range(n_nodes)}
    for i, j in edges:
        adj[i].append(j)
        adj[j].append(i)
    seen, stack = {0}, [0]
    while stack:
        for j in adj[stack.pop()]:
            if j not in seen:
                seen.add(j)
                stack.append(j)
    return len(seen) == n_nodes


def _build(name: str, n_nodes: int, edges: Sequence[Edge],
           edge_class: Sequence[str] = (),
           cliques: Sequence[Tuple[int, ...]] = ()) -> Topology:
    edges = _canonical(edges)
    if n_nodes > 1:
        assert _connected(n_nodes, edges), f"{name}: graph not connected"
    return Topology(name, n_nodes, tuple(edges),
                    metropolis_weights(n_nodes, edges),
                    tuple(edge_class), tuple(tuple(c) for c in cliques))


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------

def fully_connected(n_nodes: int) -> Topology:
    edges = [(i, j) for i in range(n_nodes) for j in range(i + 1, n_nodes)]
    return _build("full", n_nodes, edges)


def ring(n_nodes: int) -> Topology:
    edges = [(k, (k + 1) % n_nodes) for k in range(n_nodes)]
    return _build("ring", n_nodes, edges)


def torus(n_nodes: int, rows: Optional[int] = None) -> Topology:
    """2D wrap-around grid; K is factorized near-square when ``rows`` is
    omitted.  Falls back to a ring when K is prime or < 4."""
    if rows is None:
        rows = int(np.sqrt(n_nodes))
        while rows > 1 and n_nodes % rows:
            rows -= 1
    if rows <= 1 or n_nodes < 4:
        return ring(n_nodes)
    cols = n_nodes // rows
    edges = []
    for r in range(rows):
        for c in range(cols):
            k = r * cols + c
            edges.append((k, r * cols + (c + 1) % cols))
            edges.append((k, ((r + 1) % rows) * cols + c))
    return _build("torus", n_nodes, edges)


def random_regular(n_nodes: int, degree: int = 4,
                   seed: int = 0) -> Topology:
    """d-regular graph via the pairing model — an expander with high
    probability (good spectral gap at constant degree)."""
    assert (n_nodes * degree) % 2 == 0, "K * degree must be even"
    assert degree < n_nodes, (degree, n_nodes)
    rng = np.random.default_rng(seed)
    for _ in range(200):
        stubs = np.repeat(np.arange(n_nodes), degree)
        rng.shuffle(stubs)
        pairs = stubs.reshape(-1, 2)
        if any(i == j for i, j in pairs):
            continue
        edges = _canonical([tuple(p) for p in pairs])
        if len(edges) != n_nodes * degree // 2:   # multi-edge collapsed
            continue
        if _connected(n_nodes, edges):
            return _build(f"random{degree}", n_nodes, edges)
    # degenerate small cases: fall back to a ring (always connected)
    return ring(n_nodes)


def hierarchical(n_nodes: int, n_datacenters: Optional[int] = None
                 ) -> Topology:
    """Geo-WAN: nodes grouped into datacenters; each datacenter is a LAN
    clique, and datacenter gateways (first node of each group) form a WAN
    clique — the paper's Gaia deployment shape."""
    if n_datacenters is None:
        n_datacenters = max(2, int(round(np.sqrt(n_nodes))))
    n_datacenters = min(n_datacenters, n_nodes)
    groups = [list(range(n_nodes))[d::n_datacenters]
              for d in range(n_datacenters)]
    groups = [g for g in groups if g]
    edges, cls = [], []
    for g in groups:
        for a in range(len(g)):
            for b in range(a + 1, len(g)):
                edges.append((g[a], g[b]))
                cls.append("lan")
    gateways = [g[0] for g in groups]
    for a in range(len(gateways)):
        for b in range(a + 1, len(gateways)):
            edges.append((gateways[a], gateways[b]))
            cls.append("wan")
    ec = {(min(i, j), max(i, j)): c for (i, j), c in zip(edges, cls)}
    edges = _canonical(edges)
    return _build("geo-wan", n_nodes, edges, [ec[e] for e in edges],
                  cliques=groups)


def d_cliques(label_hist: np.ndarray, clique_size: Optional[int] = None,
              seed: int = 0) -> Topology:
    """Label-aware D-Cliques (Bellet et al., 2021).

    ``label_hist``: (K, C) per-node label counts.  Nodes are greedily
    grouped into cliques of ~``clique_size`` so each clique's aggregate
    label distribution tracks the global one (skew cancels *inside* the
    clique); cliques are LAN-connected internally and joined by a WAN
    ring of inter-clique edges.
    """
    K, C = label_hist.shape
    if clique_size is None:
        # one clique should be able to span the label space: with
        # exclusive-label partitions each node holds ~C/K classes, so C
        # nodes per clique recovers a near-uniform clique histogram
        # (Bellet et al. use cliques of size n_classes)
        clique_size = min(K, max(2, C))
    n_cliques = max(1, int(np.ceil(K / clique_size)))
    glob = label_hist.sum(axis=0) / max(label_hist.sum(), 1)

    rng = np.random.default_rng(seed)
    sizes = [K // n_cliques + (c < K % n_cliques)
             for c in range(n_cliques)]
    remaining = list(rng.permutation(K))
    cliques: List[List[int]] = []
    # greedy, one clique at a time: repeatedly absorb the node that most
    # reduces the clique's TV distance to the global label distribution,
    # so skew cancels inside each clique
    for size in sizes:
        cq: List[int] = []
        s = np.zeros(C)
        while len(cq) < size and remaining:
            def tv_with(k):
                t = s + label_hist[k]
                return 0.5 * np.abs(t / max(t.sum(), 1) - glob).sum()
            k = min(remaining, key=tv_with)
            cq.append(k)
            s += label_hist[k]
            remaining.remove(k)
        if cq:
            cliques.append(sorted(int(k) for k in cq))

    edges, cls = [], []
    for cq in cliques:
        for a in range(len(cq)):
            for b in range(a + 1, len(cq)):
                edges.append((cq[a], cq[b]))
                cls.append("lan")
    for c in range(len(cliques)):       # inter-clique ring (WAN)
        if len(cliques) > 1:
            nxt = cliques[(c + 1) % len(cliques)]
            edges.append((cliques[c][0], nxt[0]))
            cls.append("wan")
    ec = {(min(i, j), max(i, j)): c for (i, j), c in zip(edges, cls)}
    edges = _canonical(edges)
    return _build("dcliques", K, edges, [ec[e] for e in edges],
                  cliques=cliques)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def build_topology(name: str, n_nodes: int, *,
                   label_hist: Optional[np.ndarray] = None,
                   seed: int = 0, **kw) -> Topology:
    """Topology factory keyed by ``CommConfig.topology``."""
    if name in ("full", "fully_connected", "clique"):
        return fully_connected(n_nodes)
    if name == "ring":
        return ring(n_nodes)
    if name == "torus":
        return torus(n_nodes, **kw)
    if name in ("random", "expander"):
        deg = kw.pop("degree", min(4, n_nodes - 1))
        if (n_nodes * deg) % 2:
            deg = max(2, deg - 1)
        return random_regular(n_nodes, deg, seed=seed)
    if name in ("geo-wan", "hierarchical"):
        return hierarchical(n_nodes, **kw)
    if name in ("dcliques", "d-cliques"):
        assert label_hist is not None, \
            "dcliques topology needs per-node label histograms"
        return d_cliques(label_hist, seed=seed, **kw)
    raise ValueError(f"unknown topology {name!r}")
