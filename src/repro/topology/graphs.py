"""Communication graphs and mixing matrices.

Every topology is an undirected connected graph over the K nodes plus a
symmetric doubly-stochastic mixing matrix ``W`` (Metropolis–Hastings
weights), the gossip-averaging operator of D-PSGD (Lian et al., 2017):
``x_{t+1} = W @ x_t`` restricted to graph edges.  Edges carry a link
class ("lan" | "wan") consumed by the cost model in ``costs.py``.

Builders:
  fully_connected   all-to-all (W = 1/K everywhere: exact averaging)
  ring              cycle graph — the minimal-bandwidth baseline
  torus             2D wrap-around grid (near-square factorization of K)
  random_regular    d-regular expander via the pairing model
  hierarchical      geo-WAN: LAN cliques (datacenters) joined by WAN
                    links between gateway nodes (the paper's Gaia setting)
  hierarchical_cliques
                    cliques-of-cliques: LAN cliques whose gateways form
                    higher-level WAN cliques recursively — bounded degree,
                    the 10k+-node ledger-scale fabric
  d_cliques         label-aware cliques (Bellet et al., 2021): greedy
                    clique assembly so each clique's aggregate label
                    histogram is near-uniform; inter-clique ring over WAN

Schedules (:class:`TopologySchedule`): the fabric is a *sequence* of
graphs, one per gossip round, all over the same node set.  A single
frozen graph is the trivial constant schedule, so every consumer
(ledger, D-PSGD, SkewScout) speaks schedules and the one-graph-per-run
path keeps working unchanged.  Time-varying builders:
  constant_schedule          wrap any Topology
  time_varying_d_cliques     Bellet et al.'s one-peer-per-round variant:
                             round-robin matchings inside each label-
                             balanced clique + a single rotating WAN
                             inter-clique edge per round
  random_matching_schedule   EquiTopo-style i.i.d. random near-perfect
                             matchings (degree <= 1 per round)
  topology_ladder            SkewScout rungs, densest first:
                             full -> hierarchical -> (tv-)dcliques -> ring
``build_schedule`` is the registry keyed by ``CommConfig.topology``;
per-round graphs need not be connected — only the union over one period
must be (consensus still mixes across rounds).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

Edge = Tuple[int, int]


@dataclass(frozen=True)
class Topology:
    """An undirected communication graph with gossip weights.

    edges        canonical (i < j) undirected edge list
    mixing       (K, K) symmetric doubly-stochastic matrix, supported
                 exactly on edges + the diagonal — or ``None`` on
                 ledger-only fabrics past ``MIXING_AUTO_MAX`` nodes,
                 where the dense matrix alone would be gigabytes
    edge_class   per-edge link class, "lan" or "wan"
    cliques      D-Cliques / datacenter grouping (empty when unused)
    """
    name: str
    n_nodes: int
    edges: Tuple[Edge, ...]
    mixing: Optional[np.ndarray]
    edge_class: Tuple[str, ...] = ()
    cliques: Tuple[Tuple[int, ...], ...] = ()

    def __post_init__(self):
        if not self.edge_class:
            object.__setattr__(self, "edge_class",
                               ("lan",) * len(self.edges))
        assert len(self.edge_class) == len(self.edges)
        # adjacency cache, CSR layout: schedules rebuild neighbor sets
        # every round and the ledger gathers endpoints per round, so
        # neighbors() must be O(deg) and the build O(E) array work —
        # not a Python loop over 100k+ edges
        K = self.n_nodes
        if self.edges:
            pairs = np.asarray(self.edges, np.int64)
            ei, ej = pairs[:, 0], pairs[:, 1]
        else:
            ei = ej = np.zeros(0, np.int64)
        object.__setattr__(self, "_ei", ei)
        object.__setattr__(self, "_ej", ej)
        src = np.concatenate([ei, ej])
        dst = np.concatenate([ej, ei])
        deg = np.bincount(src, minlength=K).astype(np.int64)
        order = np.lexsort((dst, src))
        object.__setattr__(self, "_csr_dst", dst[order])
        object.__setattr__(self, "_csr_ptr",
                           np.concatenate([np.zeros(1, np.int64),
                                           np.cumsum(deg)]))
        object.__setattr__(self, "_deg", deg)

    # ---- structure ----
    def neighbors(self, k: int) -> List[int]:
        return self._csr_dst[self._csr_ptr[k]:self._csr_ptr[k + 1]] \
            .tolist()

    def edge_arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        """Endpoint arrays (ei, ej) aligned with ``edges`` — the
        vectorized consumers' layout (ledger pricing, full-exchange
        routing)."""
        return self._ei, self._ej

    def degrees(self) -> np.ndarray:
        return self._deg.copy()

    @property
    def max_degree(self) -> int:
        return int(self.degrees().max()) if self.edges else 0

    @property
    def mean_degree(self) -> float:
        return float(self.degrees().mean()) if self.edges else 0.0

    def wan_edge_indices(self) -> np.ndarray:
        return np.asarray([e for e, c in enumerate(self.edge_class)
                           if c == "wan"], np.int64)

    # ---- spectral ----
    def spectral_gap(self) -> float:
        """1 - |lambda_2(W)|: larger gap => faster gossip consensus."""
        assert self.mixing is not None, \
            f"{self.name}: no mixing matrix (ledger-only fabric past " \
            f"{MIXING_AUTO_MAX} nodes); rebuild with with_mixing=True"
        ev = np.sort(np.abs(np.linalg.eigvalsh(self.mixing)))
        return float(1.0 - ev[-2]) if len(ev) > 1 else 1.0

    # ---- kernel-facing layout ----
    def neighbor_arrays(self, pad_degree: Optional[int] = None
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Padded (idx, weight, self_weight) arrays for the neighbor_mix
        kernel: idx (K, D) int32 padded with the node's own index, weight
        (K, D) float32 padded with 0, self_w (K,) float32 = diag(W).

        ``pad_degree`` widens D beyond this graph's max degree so every
        round of a schedule (and every rung of a topology ladder) shares
        one operand shape — the jitted step never retraces."""
        assert self.mixing is not None, \
            f"{self.name}: no mixing matrix (ledger-only fabric past " \
            f"{MIXING_AUTO_MAX} nodes); rebuild with with_mixing=True"
        K = self.n_nodes
        D = max(self.max_degree if pad_degree is None else pad_degree, 1)
        assert D >= self.max_degree, (D, self.max_degree)
        idx = np.tile(np.arange(K, dtype=np.int32)[:, None], (1, D))
        w = np.zeros((K, D), np.float32)
        fill = np.zeros(K, np.int64)
        for i, j in self.edges:
            for a, b in ((i, j), (j, i)):
                idx[a, fill[a]] = b
                w[a, fill[a]] = self.mixing[a, b]
                fill[a] += 1
        return idx, w, np.diag(self.mixing).astype(np.float32)


def _canonical(edges: Sequence[Edge]) -> List[Edge]:
    return sorted({(min(i, j), max(i, j)) for i, j in edges if i != j})


def metropolis_weights(n_nodes: int, edges: Sequence[Edge]) -> np.ndarray:
    """Symmetric doubly-stochastic W: W_ij = 1/(1 + max(deg_i, deg_j)) on
    edges, diagonal takes the slack.  Standard gossip weights — doubly
    stochastic for any graph, uniform 1/K on the complete graph."""
    W = np.zeros((n_nodes, n_nodes))
    if edges:
        pairs = np.asarray(list(edges), np.int64)
        ei, ej = pairs[:, 0], pairs[:, 1]
        deg = np.bincount(np.concatenate([ei, ej]), minlength=n_nodes)
        w = 1.0 / (1.0 + np.maximum(deg[ei], deg[ej]))
        W[ei, ej] = w
        W[ej, ei] = w
    np.fill_diagonal(W, 1.0 - W.sum(axis=1))
    return W


def _connected(n_nodes: int, edges: Sequence[Edge]) -> bool:
    """Label-propagation connected-components over endpoint arrays
    (hook to the min label, then pointer-jump until stable) — O(E log K)
    array work instead of a Python DFS, so the 125k-edge 10k-node
    fabrics stay cheap to validate."""
    if n_nodes <= 1:
        return True
    if not edges:
        return False
    pairs = np.asarray(list(edges), np.int64)
    ei, ej = pairs[:, 0], pairs[:, 1]
    comp = np.arange(n_nodes)
    while True:
        prev = comp.copy()
        lo = np.minimum(comp[ei], comp[ej])
        np.minimum.at(comp, ei, lo)
        np.minimum.at(comp, ej, lo)
        while True:
            jumped = comp[comp]
            if np.array_equal(jumped, comp):
                break
            comp = jumped
        if np.array_equal(comp, prev):
            break
    return int(comp.max()) == 0


MIXING_AUTO_MAX = 4096
"""Above this node count ``_build`` skips the dense mixing matrix: the
ledger, link model, and schedules only need edge lists, and (K, K)
float64 at 10k nodes is 800 MB.  Consumers that genuinely need W
(spectral gap, neighbor_mix operands) assert it is present."""


def _build(name: str, n_nodes: int, edges: Sequence[Edge],
           edge_class: Sequence[str] = (),
           cliques: Sequence[Tuple[int, ...]] = (),
           require_connected: bool = True,
           with_mixing: Optional[bool] = None) -> Topology:
    """``require_connected=False`` is for the per-round graphs of a
    time-varying schedule (matchings are never connected on their own —
    only the union over a period must be).  ``with_mixing=None`` builds
    W only up to ``MIXING_AUTO_MAX`` nodes; pass True/False to force."""
    edges = _canonical(edges)
    if n_nodes > 1 and require_connected:
        assert _connected(n_nodes, edges), f"{name}: graph not connected"
    if with_mixing is None:
        with_mixing = n_nodes <= MIXING_AUTO_MAX
    mixing = metropolis_weights(n_nodes, edges) if with_mixing else None
    return Topology(name, n_nodes, tuple(edges), mixing,
                    tuple(edge_class), tuple(tuple(c) for c in cliques))


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------

def fully_connected(n_nodes: int) -> Topology:
    edges = [(i, j) for i in range(n_nodes) for j in range(i + 1, n_nodes)]
    return _build("full", n_nodes, edges)


def ring(n_nodes: int) -> Topology:
    edges = [(k, (k + 1) % n_nodes) for k in range(n_nodes)]
    return _build("ring", n_nodes, edges)


def torus(n_nodes: int, rows: Optional[int] = None) -> Topology:
    """2D wrap-around grid; K is factorized near-square when ``rows`` is
    omitted.  Falls back to a ring when K is prime or < 4."""
    if rows is None:
        rows = int(np.sqrt(n_nodes))
        while rows > 1 and n_nodes % rows:
            rows -= 1
    if rows <= 1 or n_nodes < 4:
        return ring(n_nodes)
    cols = n_nodes // rows
    edges = []
    for r in range(rows):
        for c in range(cols):
            k = r * cols + c
            edges.append((k, r * cols + (c + 1) % cols))
            edges.append((k, ((r + 1) % rows) * cols + c))
    return _build("torus", n_nodes, edges)


def random_regular(n_nodes: int, degree: int = 4,
                   seed: int = 0) -> Topology:
    """d-regular graph via the pairing model — an expander with high
    probability (good spectral gap at constant degree)."""
    assert (n_nodes * degree) % 2 == 0, "K * degree must be even"
    assert degree < n_nodes, (degree, n_nodes)
    rng = np.random.default_rng(seed)
    for _ in range(200):
        stubs = np.repeat(np.arange(n_nodes), degree)
        rng.shuffle(stubs)
        pairs = stubs.reshape(-1, 2)
        if any(i == j for i, j in pairs):
            continue
        edges = _canonical([tuple(p) for p in pairs])
        if len(edges) != n_nodes * degree // 2:   # multi-edge collapsed
            continue
        if _connected(n_nodes, edges):
            return _build(f"random{degree}", n_nodes, edges)
    # degenerate small cases: fall back to a ring (always connected)
    return ring(n_nodes)


def hierarchical(n_nodes: int, n_datacenters: Optional[int] = None
                 ) -> Topology:
    """Geo-WAN: nodes grouped into datacenters; each datacenter is a LAN
    clique, and datacenter gateways (first node of each group) form a WAN
    clique — the paper's Gaia deployment shape."""
    if n_datacenters is None:
        n_datacenters = max(2, int(round(np.sqrt(n_nodes))))
    n_datacenters = min(n_datacenters, n_nodes)
    groups = [list(range(n_nodes))[d::n_datacenters]
              for d in range(n_datacenters)]
    groups = [g for g in groups if g]
    edges, cls = [], []
    for g in groups:
        for a in range(len(g)):
            for b in range(a + 1, len(g)):
                edges.append((g[a], g[b]))
                cls.append("lan")
    gateways = [g[0] for g in groups]
    for a in range(len(gateways)):
        for b in range(a + 1, len(gateways)):
            edges.append((gateways[a], gateways[b]))
            cls.append("wan")
    ec = {(min(i, j), max(i, j)): c for (i, j), c in zip(edges, cls)}
    edges = _canonical(edges)
    return _build("geo-wan", n_nodes, edges, [ec[e] for e in edges],
                  cliques=groups)


def hierarchical_cliques(n_nodes: int, clique_size: int = 25) -> Topology:
    """Cliques-of-cliques: the bounded-degree fabric that scales the
    geo-WAN shape to 10k+ nodes.

    Level 0 groups consecutive nodes into LAN cliques of ``clique_size``;
    each clique's first member is its gateway, and the gateways are
    recursively grouped into higher-level WAN cliques of the same size
    until a single top clique remains.  Every node keeps degree
    O(clique_size * levels) — at K=10000, c=25 that is ~125k edges and
    max degree 63, vs the flat :func:`hierarchical`'s sqrt(K)-degree
    gateways — and construction is O(E), so ledger-only pricing runs at
    fabric sizes where a dense mixing matrix is not even materialized
    (see ``MIXING_AUTO_MAX``)."""
    assert clique_size >= 2, clique_size
    edges: List[Edge] = []
    cls: List[str] = []
    groups = [list(range(n_nodes))[a:a + clique_size]
              for a in range(0, n_nodes, clique_size)]
    level0 = [g for g in groups if g]
    groups, wan = level0, False
    while True:
        for g in groups:
            for a in range(len(g)):
                for b in range(a + 1, len(g)):
                    edges.append((g[a], g[b]))
                    cls.append("wan" if wan else "lan")
        if len(groups) <= 1:
            break
        gateways = [g[0] for g in groups]
        groups = [gateways[a:a + clique_size]
                  for a in range(0, len(gateways), clique_size)]
        wan = True
    ec = {(min(i, j), max(i, j)): c for (i, j), c in zip(edges, cls)}
    edges = _canonical(edges)
    return _build("hier-cliques", n_nodes, edges,
                  [ec[e] for e in edges], cliques=level0)


def greedy_clique_assignment(label_hist: np.ndarray,
                             clique_size: Optional[int] = None,
                             seed: int = 0) -> List[List[int]]:
    """Greedy label-balanced clique assignment shared by the constant and
    time-varying D-Cliques builders: repeatedly absorb the node that most
    reduces the clique's TV distance to the global label distribution,
    so skew cancels *inside* each clique.

    The ``seed`` is the *only* source of randomness (one private
    ``default_rng``), and both builders route through this one helper —
    the same ``(label_hist, clique_size, seed)`` always yields the same
    assignment, and nothing another subsystem draws (e.g. the stochastic
    link model's keyed streams) can perturb it.  Callers that need the
    constant and time-varying variants to agree on cliques can also
    precompute the assignment here and pass it via ``cliques=``."""
    K, C = label_hist.shape
    if clique_size is None:
        # one clique should be able to span the label space: with
        # exclusive-label partitions each node holds ~C/K classes, so C
        # nodes per clique recovers a near-uniform clique histogram
        # (Bellet et al. use cliques of size n_classes)
        clique_size = min(K, max(2, C))
    n_cliques = max(1, int(np.ceil(K / clique_size)))
    glob = label_hist.sum(axis=0) / max(label_hist.sum(), 1)

    rng = np.random.default_rng(seed)
    sizes = [K // n_cliques + (c < K % n_cliques)
             for c in range(n_cliques)]
    remaining = list(rng.permutation(K))
    cliques: List[List[int]] = []
    for size in sizes:
        cq: List[int] = []
        s = np.zeros(C)
        while len(cq) < size and remaining:
            def tv_with(k):
                t = s + label_hist[k]
                return 0.5 * np.abs(t / max(t.sum(), 1) - glob).sum()
            k = min(remaining, key=tv_with)
            cq.append(k)
            s += label_hist[k]
            remaining.remove(k)
        if cq:
            cliques.append(sorted(int(k) for k in cq))
    return cliques


def d_cliques(label_hist: np.ndarray, clique_size: Optional[int] = None,
              seed: int = 0,
              cliques: Optional[List[List[int]]] = None) -> Topology:
    """Label-aware D-Cliques (Bellet et al., 2021).

    ``label_hist``: (K, C) per-node label counts.  Nodes are greedily
    grouped into cliques of ~``clique_size`` so each clique's aggregate
    label distribution tracks the global one; cliques are LAN-connected
    internally and joined by a WAN ring of inter-clique edges.
    ``cliques`` overrides the greedy assignment with a precomputed one
    (:func:`greedy_clique_assignment`).
    """
    K = label_hist.shape[0]
    if cliques is None:
        cliques = greedy_clique_assignment(label_hist, clique_size, seed)

    edges, cls = [], []
    for cq in cliques:
        for a in range(len(cq)):
            for b in range(a + 1, len(cq)):
                edges.append((cq[a], cq[b]))
                cls.append("lan")
    for c in range(len(cliques)):       # inter-clique ring (WAN)
        if len(cliques) > 1:
            nxt = cliques[(c + 1) % len(cliques)]
            edges.append((cliques[c][0], nxt[0]))
            cls.append("wan")
    ec = {(min(i, j), max(i, j)): c for (i, j), c in zip(edges, cls)}
    edges = _canonical(edges)
    return _build("dcliques", K, edges, [ec[e] for e in edges],
                  cliques=cliques)


# ---------------------------------------------------------------------------
# schedules: one graph per round
# ---------------------------------------------------------------------------

class TopologySchedule:
    """A periodic sequence of communication graphs over one node set.

    ``at(t)`` is round ``t``'s graph; gossip, the ledger, and SkewScout
    all consume schedules, with a single frozen graph as the trivial
    constant schedule.  Per-round graphs may be disconnected (matchings
    usually are) — consensus only needs the *union* over one period to
    be connected, which is asserted here.
    """

    def __init__(self, name: str, graphs: Sequence[Topology]):
        assert graphs, "schedule needs at least one graph"
        K = graphs[0].n_nodes
        assert all(g.n_nodes == K for g in graphs), \
            "all graphs in a schedule must share the node set"
        self.name = name
        self._graphs = tuple(graphs)
        self._union: Optional[Topology] = None
        self._round_gaps: Dict[int, float] = {}
        if K > 1:
            union_edges = sorted({e for g in graphs for e in g.edges})
            assert _connected(K, union_edges), \
                f"{name}: union over one period is not connected"

    # ---- structure ----
    @property
    def n_nodes(self) -> int:
        return self._graphs[0].n_nodes

    @property
    def period(self) -> int:
        return len(self._graphs)

    @property
    def is_constant(self) -> bool:
        return len(self._graphs) == 1

    def at(self, t: int) -> Topology:
        return self._graphs[int(t) % len(self._graphs)]

    def graphs(self) -> Tuple[Topology, ...]:
        """The unique per-round graphs of one period."""
        return self._graphs

    @property
    def max_degree(self) -> int:
        """Max degree over the whole period — the kernel padding width
        that keeps every round's operands one shape."""
        return max(g.max_degree for g in self._graphs)

    def mean_round_edges(self) -> float:
        """Mean active edges per round — the communication-cost metric
        that orders SkewScout's topology ladder (densest first)."""
        return float(np.mean([len(g.edges) for g in self._graphs]))

    def union(self) -> Topology:
        """Union graph over one period: the set of links that exist at
        all.  The ledger prices re-wiring against it and SkewScout's CM
        (one full-model exchange) is defined on it.  An edge is WAN if
        any round classifies it WAN."""
        if self._union is None:
            cls: Dict[Edge, str] = {}
            cliques: Tuple[Tuple[int, ...], ...] = ()
            for g in self._graphs:
                if g.cliques and not cliques:
                    cliques = g.cliques
                for e, c in zip(g.edges, g.edge_class):
                    if c == "wan" or e not in cls:
                        cls[e] = c
            edges = sorted(cls)
            self._union = _build(f"{self.name}:union", self.n_nodes,
                                 edges, [cls[e] for e in edges],
                                 cliques=cliques,
                                 require_connected=self.n_nodes > 1)
        return self._union

    # ---- kernel-facing layout ----
    def neighbor_arrays(self, t: int, pad_degree: Optional[int] = None
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Round ``t``'s padded neighbor operands, padded to the
        schedule-wide max degree by default (one shape, no retrace)."""
        pad = self.max_degree if pad_degree is None else pad_degree
        return self.at(t).neighbor_arrays(pad_degree=pad)

    # ---- spectral ----
    def round_spectral_gap(self, t: int) -> float:
        """Spectral gap of round ``t``'s graph alone (0 for matchings —
        a single disconnected round does not mix to consensus)."""
        i = int(t) % len(self._graphs)
        if i not in self._round_gaps:
            self._round_gaps[i] = self._graphs[i].spectral_gap()
        return self._round_gaps[i]

    def spectral_gap(self) -> float:
        """Effective per-round gap of one period: the consensus error
        contracts by the spectral radius of ``prod_t (W_t - J)`` per
        period (J = 11^T/K), so the per-round rate is its period-th
        root.  Reduces exactly to ``1 - |lambda_2(W)|`` for a constant
        schedule."""
        K = self.n_nodes
        if K == 1:
            return 1.0
        J = np.full((K, K), 1.0 / K)
        M = np.eye(K)
        for g in self._graphs:
            assert g.mixing is not None, \
                f"{g.name}: no mixing matrix (ledger-only fabric past " \
                f"{MIXING_AUTO_MAX} nodes)"
            M = (g.mixing - J) @ M
        rate = float(np.max(np.abs(np.linalg.eigvals(M))))
        return 1.0 - rate ** (1.0 / self.period)


def constant_schedule(topology: Topology) -> TopologySchedule:
    """The one-graph-per-run path, expressed as a schedule."""
    return TopologySchedule(topology.name, [topology])


def as_schedule(fabric: Union[Topology, TopologySchedule]
                ) -> TopologySchedule:
    if isinstance(fabric, TopologySchedule):
        return fabric
    assert isinstance(fabric, Topology), type(fabric)
    return constant_schedule(fabric)


def _round_robin_matching(members: Sequence[int], r: int
                          ) -> List[Edge]:
    """Round ``r`` of the circle-method round robin over ``members``:
    a (near-)perfect matching; over ``m-1`` rounds (m even, one bye
    added when odd) every pair meets exactly once."""
    m = list(members)
    if len(m) % 2:
        m.append(-1)                      # bye
    n = len(m)
    if n < 2:
        return []
    k = r % (n - 1)
    rest = m[1:]
    arr = [m[0]] + rest[k:] + rest[:k]
    return [(arr[i], arr[n - 1 - i]) for i in range(n // 2)
            if arr[i] >= 0 and arr[n - 1 - i] >= 0]


def time_varying_d_cliques(label_hist: np.ndarray,
                           clique_size: Optional[int] = None,
                           seed: int = 0,
                           cliques: Optional[List[List[int]]] = None
                           ) -> TopologySchedule:
    """One-peer-per-round D-Cliques (Bellet et al., 2021, §time-varying).

    Same greedy label-balanced cliques as :func:`d_cliques`, but each
    round every node talks to *one* clique peer (round-robin matching
    inside the clique) and a *single* rotating WAN edge joins
    consecutive cliques — instead of the constant variant's full
    intra-clique mesh plus one WAN edge per clique, every round.  Over
    one period the union covers the whole constant graph, so the mixing
    rate survives while per-round traffic (and especially per-round WAN
    traffic) drops by the clique size.  Both variants share
    :func:`greedy_clique_assignment` (same ``seed`` => same cliques);
    ``cliques`` passes a precomputed assignment explicitly.
    """
    K = label_hist.shape[0]
    if cliques is None:
        cliques = greedy_clique_assignment(label_hist, clique_size, seed)
    n_cl = len(cliques)
    # period: lcm of the per-clique round-robin cycles and the WAN ring
    # rotation, so the union over one period is the full constant graph
    period = 1
    for cq in cliques:
        m = len(cq) + (len(cq) % 2)
        period = math.lcm(period, max(m - 1, 1))
    if n_cl > 1:
        period = math.lcm(period, n_cl)
    graphs = []
    for r in range(period):
        edges: List[Edge] = []
        cls: List[str] = []
        for cq in cliques:
            for a, b in _round_robin_matching(cq, r):
                edges.append((a, b))
                cls.append("lan")
        if n_cl > 1:
            c = r % n_cl
            nxt = cliques[(c + 1) % n_cl]
            edges.append((cliques[c][0], nxt[0]))
            cls.append("wan")
        ec = {(min(i, j), max(i, j)): c for (i, j), c in zip(edges, cls)}
        edges = _canonical(edges)
        graphs.append(_build(f"tv-dcliques[{r}]", K, edges,
                             [ec[e] for e in edges], cliques=cliques,
                             require_connected=False))
    return TopologySchedule("tv-dcliques", graphs)


def random_matching_schedule(n_nodes: int, period: Optional[int] = None,
                             seed: int = 0,
                             n_sites: Optional[int] = None
                             ) -> TopologySchedule:
    """EquiTopo-style schedule: an independent random (near-)perfect
    matching each round — degree <= 1 per round, expander-grade mixing
    from the randomness across rounds.  The period is resampled until
    the union is connected (whp after O(log K) matchings).

    ``n_sites``: nodes live in datacenters (the same ``d::n_sites``
    grouping and sqrt-K default as :func:`hierarchical`), and an edge
    crossing sites is WAN.  Random matchings are placement-blind, so
    most of their edges cross sites — the honest geo-WAN price of the
    fabric, and exactly what locality-aware D-Cliques avoid.  Pass
    ``n_sites=1`` for a single-LAN cluster."""
    if period is None:
        period = max(4, 2 * int(np.ceil(np.log2(max(n_nodes, 2)))))
    if n_sites is None:
        n_sites = min(max(2, int(round(np.sqrt(n_nodes)))), n_nodes)
    site = {k: k % n_sites for k in range(n_nodes)}

    def build_round(r, edges):
        cls = ["wan" if site[i] != site[j] else "lan" for i, j in edges]
        return _build(f"random-matching[{r}]", n_nodes, edges, cls,
                      require_connected=False)

    rng = np.random.default_rng(seed)
    for _ in range(200):
        graphs = []
        for r in range(period):
            perm = rng.permutation(n_nodes)
            edges = _canonical([(int(perm[2 * i]), int(perm[2 * i + 1]))
                                for i in range(n_nodes // 2)])
            graphs.append(build_round(r, edges))
        union = sorted({e for g in graphs for e in g.edges})
        if n_nodes == 1 or _connected(n_nodes, union):
            return TopologySchedule("random-matching", graphs)
    # degenerate tiny-K case: splice in a ring round to force connectivity
    graphs[-1] = build_round(period - 1,
                             _canonical(ring(n_nodes).edges))
    return TopologySchedule("random-matching", graphs)


def topology_ladder(n_nodes: int, label_hist: Optional[np.ndarray] = None,
                    seed: int = 0, time_varying: bool = True
                    ) -> List[TopologySchedule]:
    """SkewScout's topology rungs: full, hierarchical, (tv-)dcliques,
    ring — *sorted* most-communication-heavy -> most relaxed by mean
    per-round edge count (the THETA_LADDERS convention).  Sorting
    matters: hill climbing needs the ladder monotone in cost, and a
    time-varying D-Cliques rung is cheaper per round than a ring, not
    between hierarchical and ring.  Without label histograms the
    label-aware rung degrades to a torus."""
    rungs = [constant_schedule(fully_connected(n_nodes)),
             constant_schedule(hierarchical(n_nodes))]
    if label_hist is not None:
        rungs.append(time_varying_d_cliques(label_hist, seed=seed)
                     if time_varying
                     else constant_schedule(d_cliques(label_hist,
                                                      seed=seed)))
    else:
        rungs.append(constant_schedule(torus(n_nodes)))
    rungs.append(constant_schedule(ring(n_nodes)))
    rungs.sort(key=TopologySchedule.mean_round_edges, reverse=True)
    # small-K builders can collapse (torus(<4) is a ring): drop duplicates
    seen, out = set(), []
    for s in rungs:
        if s.name not in seen:
            seen.add(s.name)
            out.append(s)
    return out


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def build_topology(name: str, n_nodes: int, *,
                   label_hist: Optional[np.ndarray] = None,
                   seed: int = 0, **kw) -> Topology:
    """Topology factory keyed by ``CommConfig.topology``."""
    if name in ("full", "fully_connected", "clique"):
        return fully_connected(n_nodes)
    if name == "ring":
        return ring(n_nodes)
    if name == "torus":
        return torus(n_nodes, **kw)
    if name in ("random", "expander"):
        deg = kw.pop("degree", min(4, n_nodes - 1))
        if (n_nodes * deg) % 2:
            deg = max(2, deg - 1)
        return random_regular(n_nodes, deg, seed=seed)
    if name in ("geo-wan", "hierarchical"):
        return hierarchical(n_nodes, **kw)
    if name in ("hier-cliques", "hierarchical-cliques"):
        return hierarchical_cliques(n_nodes, **kw)
    if name in ("dcliques", "d-cliques"):
        assert label_hist is not None, \
            "dcliques topology needs per-node label histograms"
        return d_cliques(label_hist, seed=seed, **kw)
    raise ValueError(f"unknown topology {name!r}")


#: topology names that require per-node label histograms to build
LABEL_AWARE_TOPOLOGIES = ("dcliques", "d-cliques", "tv-dcliques",
                          "time-varying-dcliques")


def full_skew_label_hist(n_nodes: int,
                         n_classes: Optional[int] = None) -> np.ndarray:
    """Synthetic (K, C) per-node label histogram for the paper's
    *full-skew* setting — each node holds one label exclusively.  What
    compile-only dry-runs and demo drivers feed the label-aware builders
    when no real partition exists to derive histograms from."""
    if n_classes is None:
        n_classes = max(2, n_nodes)
    hist = np.zeros((n_nodes, n_classes))
    hist[np.arange(n_nodes), np.arange(n_nodes) % n_classes] = 100
    return hist


def build_demo_schedule(name: str, n_nodes: int,
                        seed: int = 0) -> "TopologySchedule":
    """:func:`build_schedule` with the full-skew synthetic histogram
    supplied automatically for label-aware fabrics — the one import-safe
    home for compile-only dry-runs and demo drivers that have no real
    partition to derive histograms from."""
    label_hist = (full_skew_label_hist(n_nodes)
                  if name in LABEL_AWARE_TOPOLOGIES else None)
    return build_schedule(name, n_nodes, label_hist=label_hist, seed=seed)


def build_schedule(name: str, n_nodes: int, *,
                   label_hist: Optional[np.ndarray] = None,
                   seed: int = 0, **kw) -> TopologySchedule:
    """Schedule factory keyed by ``CommConfig.topology``: every static
    topology name becomes its constant schedule; ``tv-dcliques`` and
    ``random-matching`` are the time-varying builders."""
    if name in ("tv-dcliques", "time-varying-dcliques"):
        assert label_hist is not None, \
            "tv-dcliques schedule needs per-node label histograms"
        return time_varying_d_cliques(label_hist, seed=seed, **kw)
    if name in ("random-matching", "equitopo"):
        return random_matching_schedule(n_nodes, seed=seed, **kw)
    return constant_schedule(build_topology(name, n_nodes,
                                            label_hist=label_hist,
                                            seed=seed, **kw))
