"""Stochastic heterogeneous links + per-round client participation.

``LinkProfile`` prices every link of a class (lan | wan) from two
constants, which makes AD-PSGD's headline advantage unmeasurable: the
async ledger only wins when *different* links bottleneck different
rounds, and with class constants the same WAN edge is the bottleneck
forever.  :class:`LinkModel` replaces the constants with a seeded,
replayable sampler with three layers of structure:

*Per-edge base draws* (``hetero``): each link draws a persistent
latency/bandwidth multiplier once, lognormal with sigma ``hetero``
around the class constants — some links are just slower than others,
forever.  At ``hetero=0`` every link's base equals the class constants.

*Per-activation jitter* (``jitter``): every activation multiplies the
link's cost by an independent median-1 lognormal, ``exp(jitter * z)``
with ``z ~ N(0,1)`` — latency is multiplied, bandwidth divided, so the
whole edge cost scales by the draw.

*Markov transient slowdowns* (``straggler_rate``): each link carries a
two-state chain (normal <-> slow).  A normal link enters the slow state
with probability ``straggler_rate`` per activation and leaves it with
probability ``straggler_exit``; while slow, latency is multiplied and
bandwidth divided by ``straggler_slowdown``.  Bursty, *occasional*
stragglers — the regime where async gossip strictly beats stop-and-wait
even on an all-LAN fabric (Lian et al., AD-PSGD).

Seeding and replay: every draw is a pure function of
``(seed, edge, activation index)`` — a counter-based hash stream from
``kernels/rng.py`` (the same lowbias32 stream the Pallas kernels
generate in-kernel), evaluated vectorized over all of a round's active
edges at once.  Activation ``n`` of an edge owns uniform counters
``[4n, 4n+4)`` on that edge's round stream: the jitter normal consumes
``4n``/``4n+1`` (Box–Muller), the Markov transition uniform is ``4n+2``,
and ``4n+3`` is reserved.  A rebuilt model (same seed) replaying the
same sequence of ledger calls therefore produces bit-identical sampled
times, in any interleaving of edges; the Markov state is a fold over the
keyed draws, so it replays too.  With all three knobs at zero,
:meth:`LinkModel.sample` returns the class-constant arrays unchanged
(bitwise), which is what lets a "sampled" ledger at zero rates reproduce
the constant-profile ledger exactly.

Array layout (the 10k-node redesign): per-link state — stream key, base
multipliers, draw counter, Markov bit — lives in flat arrays indexed by
a slot id; an edge list is resolved to its slot array once (cached per
edge-tuple object) and every later activation is pure gather/scatter.
Slot admission keys whole edge sets in one :func:`rng.fold_keys` batch,
bit-equal to the retired per-edge ``fold_key`` loop.

:class:`Participation` is the client-sampling analogue: a seeded
per-round Bernoulli node mask (tag-disjoint from both link streams, so
toggling sampling can never perturb link draws and vice versa).  The
ledger prices only edges whose endpoints both participate; dpsgd/adpsgd
zero the corresponding mixing weights; SkewScout probes route around
absent nodes.

Consumed by :class:`~repro.topology.costs.CommLedger` (``link_model=`` /
``participation=``): gossip, exchange, and probe rounds all price
sampled per-edge times, and the ledger folds each observation into
per-edge EWMA *measured* costs that SkewScout's C(θ)/CM pricing reads in
place of profile constants.
"""
from __future__ import annotations

import warnings
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import LinkConfig
from repro.kernels import rng
from repro.topology.costs import LinkProfile

Edge = Tuple[int, int]

# draw-key tags: keep the per-edge base stream, the per-activation
# stream, and the participation stream disjoint (all keyed under one
# model seed)
_TAG_BASE = 0x0B
_TAG_ROUND = 0x0A
_TAG_PART = 0x0C


class LinkModel:
    """Seeded per-link latency/bandwidth sampler (see module docstring).

    ``sample`` maps a graph's per-edge class-constant (latency,
    bandwidth) arrays to sampled arrays for one activation, advancing
    each active edge's draw counter and Markov state — all flat-array
    gather/scatter after the edge set's one-time slot admission.
    """

    def __init__(self, profile: LinkProfile, *, seed: int = 0,
                 jitter: float = 0.0, hetero: float = 0.0,
                 straggler_rate: float = 0.0, straggler_exit: float = 0.5,
                 straggler_slowdown: float = 10.0):
        assert jitter >= 0 and hetero >= 0, (jitter, hetero)
        assert 0.0 <= straggler_rate <= 1.0, straggler_rate
        assert 0.0 < straggler_exit <= 1.0, straggler_exit
        assert straggler_slowdown >= 1.0, straggler_slowdown
        self.profile = profile
        self.seed = int(seed)
        self.jitter = float(jitter)
        self.hetero = float(hetero)
        self.straggler_rate = float(straggler_rate)
        self.straggler_exit = float(straggler_exit)
        self.straggler_slowdown = float(straggler_slowdown)
        # per-link state, slot-indexed flat arrays
        self._slot: Dict[Edge, int] = {}
        self._key = np.zeros(0, np.uint32)   # round-stream keys
        self._lat_mult = np.ones(0)          # persistent base draws
        self._bw_mult = np.ones(0)
        self._n = np.zeros(0, np.int64)      # activations (draw counter)
        self._slow = np.zeros(0, bool)       # Markov slow state
        # edge-tuple object -> its slot index array (the per-graph cache)
        self._slots_cache: Dict[int, tuple] = {}
        # counters for the trainer's straggler/jitter extras
        self.activations = 0
        self.slow_activations = 0

    @property
    def stochastic(self) -> bool:
        """False when every knob is zero — sampling is the identity and
        the hot path can skip the per-edge draws entirely."""
        return (self.jitter > 0 or self.hetero > 0
                or self.straggler_rate > 0)

    # ---- slot admission ----
    def _admit(self, edges: Sequence[Edge]) -> None:
        """Create slots for unseen edges, keying and base-drawing the
        whole batch in one vectorized pass (bit-equal to the per-edge
        scalar ``fold_key``/``normal01`` calls it replaces)."""
        start = len(self._key)
        for k, e in enumerate(edges):
            self._slot[e] = start + k
        ii = np.asarray([i for i, _ in edges], np.int64)
        jj = np.asarray([j for _, j in edges], np.int64)
        key = rng.fold_keys(rng.fold_key(self.seed, _TAG_ROUND), ii, jj)
        n = len(edges)
        if self.hetero > 0:
            base = rng.fold_keys(rng.fold_key(self.seed, _TAG_BASE),
                                 ii, jj)
            z0 = rng.normal01(base, np.zeros(n, np.int64))
            z1 = rng.normal01(base, np.ones(n, np.int64))
            lat_mult = np.exp(self.hetero * z0)
            bw_mult = np.exp(-self.hetero * z1)
        else:
            lat_mult = np.ones(n)
            bw_mult = np.ones(n)
        self._key = np.concatenate([self._key, key.astype(np.uint32)])
        self._lat_mult = np.concatenate([self._lat_mult, lat_mult])
        self._bw_mult = np.concatenate([self._bw_mult, bw_mult])
        self._n = np.concatenate([self._n, np.zeros(n, np.int64)])
        self._slow = np.concatenate([self._slow, np.zeros(n, bool)])

    def _slots_for(self, edges: Sequence[Edge]) -> np.ndarray:
        """Slot index array for ``edges``, cached per edge-tuple object
        (graphs are long-lived; the cache keeps a reference so the id
        key cannot be recycled)."""
        ent = self._slots_cache.get(id(edges))
        if ent is not None and ent[0] is edges:
            return ent[1]
        miss = [e for e in edges if e not in self._slot]
        if miss:
            self._admit(miss)
        slots = np.fromiter((self._slot[e] for e in edges), np.int64,
                            len(edges))
        self._slots_cache[id(edges)] = (edges, slots)
        return slots

    def sample(self, edges: Sequence[Edge], lat: np.ndarray,
               bw: np.ndarray, active: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Sampled (latency, bandwidth) arrays for one activation of the
        ``active`` edges, starting from the graph's class-constant
        arrays.  Inactive edges keep the constants (their cost is masked
        by the caller anyway) and do not advance their counters.

        All active edges draw in one vectorized hash evaluation: keys
        and counters are gathered from the slot arrays, the jitter
        normals and Markov uniforms come from one ``kernels/rng.py``
        batch each, and the state write-back is a scatter."""
        if not self.stochastic:
            return lat, bw
        s_lat = lat.astype(np.float64).copy()
        s_bw = bw.astype(np.float64).copy()
        idx = np.flatnonzero(active)
        if idx.size == 0:
            return s_lat, s_bw
        sl = self._slots_for(edges)[idx]
        keys = self._key[sl]
        ctr = self._n[sl]
        # activation n owns uniform counters [4n, 4n+4) on the edge's
        # round stream: Box-Muller jitter at 4n/4n+1, Markov u at 4n+2
        mult = np.ones(idx.size, np.float64)
        if self.jitter > 0:
            z = rng.normal01(keys, 2 * ctr)
            mult *= np.exp(self.jitter * z)
        if self.straggler_rate > 0:
            u = rng.uniform01(keys, (4 * ctr + 2).astype(np.uint32)
                              ).astype(np.float64)
            slow = self._slow[sl]
            mult = np.where(slow, mult * self.straggler_slowdown, mult)
            self.slow_activations += int(np.sum(slow))
            next_slow = np.where(slow, u >= self.straggler_exit,
                                 u < self.straggler_rate)
        else:
            next_slow = self._slow[sl]
        self.activations += idx.size
        self._n[sl] = ctr + 1
        self._slow[sl] = next_slow
        s_lat[idx] = lat[idx] * self._lat_mult[sl] * mult
        s_bw[idx] = bw[idx] * self._bw_mult[sl] / mult
        return s_lat, s_bw

    # ---- reporting ----
    def slow_fraction(self) -> float:
        """Fraction of activations that hit a straggler's slow state."""
        return self.slow_activations / max(self.activations, 1)

    def summary(self) -> Dict[str, float]:
        return dict(jitter=self.jitter, hetero=self.hetero,
                    straggler_rate=self.straggler_rate,
                    straggler_slowdown=self.straggler_slowdown,
                    activations=float(self.activations),
                    slow_activations=float(self.slow_activations),
                    slow_fraction=self.slow_fraction())


class Participation:
    """Seeded per-round client sampling: round ``t``'s Bernoulli node
    mask is a pure function of ``(seed, t)`` on its own tag-disjoint
    hash stream — replayable, order-independent, and isolated from the
    link model's draws (toggling one can never shift the other).

    Semantics: a masked-out node skips the round's *communication* only
    (local updates continue); an edge is active iff both endpoints
    participate.  ``fraction=1.0`` is the exact pre-sampling behaviour
    (all-true masks).  Masks are cached (read by the ledger, the mixing
    operands, and SkewScout in the same round) and frozen read-only."""

    def __init__(self, n_nodes: int, fraction: float, *, seed: int = 0):
        assert 0.0 < float(fraction) <= 1.0, fraction
        self.n_nodes = int(n_nodes)
        self.fraction = float(fraction)
        self.seed = int(seed)
        self._cache: Dict[int, np.ndarray] = {}

    def mask(self, t) -> np.ndarray:
        """Boolean (n_nodes,) participant mask for round ``t``."""
        t = int(t)
        m = self._cache.get(t)
        if m is None:
            if self.fraction >= 1.0:
                m = np.ones(self.n_nodes, bool)
            else:
                key = np.uint32(rng.fold_key(self.seed, _TAG_PART, t))
                u = rng.uniform01(key, np.arange(self.n_nodes,
                                                 dtype=np.uint32))
                m = np.asarray(u < np.float32(self.fraction))
            m.flags.writeable = False
            if len(self._cache) >= 16:
                self._cache.pop(next(iter(self._cache)))
            self._cache[t] = m
        return m

    def summary(self) -> Dict[str, float]:
        return dict(fraction=self.fraction, n_nodes=float(self.n_nodes))


def make_link_model(link, profile: LinkProfile, *,
                    seed: int = 0) -> Optional[LinkModel]:
    """Build the :class:`LinkModel` a :class:`LinkConfig` asks for
    (``None`` for the constant-profile ledger).  The model draws from
    its own keyed streams, so the link seed can never perturb anything
    else seeded from the run seed (clique assignment, data order, init).

    Passing a full ``CommConfig`` is deprecated; pass
    ``comm.fabric.link``."""
    if hasattr(link, "fabric"):          # a CommConfig (deprecated)
        warnings.warn(
            "make_link_model(comm, ...) is deprecated; pass "
            "comm.fabric.link", DeprecationWarning, stacklevel=2)
        link = link.fabric.link
    assert isinstance(link, LinkConfig), link
    if link.model == "constant":
        return None
    if link.model != "sampled":
        raise ValueError(
            f"unknown link_model {link.model!r} (constant | sampled)")
    return LinkModel(profile, seed=seed, jitter=link.jitter,
                     hetero=link.hetero,
                     straggler_rate=link.straggler_rate,
                     straggler_exit=link.straggler_exit,
                     straggler_slowdown=link.straggler_slowdown)
