"""Stochastic heterogeneous links: per-edge latency/bandwidth sampling.

``LinkProfile`` prices every link of a class (lan | wan) from two
constants, which makes AD-PSGD's headline advantage unmeasurable: the
async ledger only wins when *different* links bottleneck different
rounds, and with class constants the same WAN edge is the bottleneck
forever.  :class:`LinkModel` replaces the constants with a seeded,
replayable sampler with three layers of structure:

*Per-edge base draws* (``hetero``): each link draws a persistent
latency/bandwidth multiplier once, lognormal with sigma ``hetero``
around the class constants — some links are just slower than others,
forever.  At ``hetero=0`` every link's base equals the class constants.

*Per-activation jitter* (``jitter``): every activation multiplies the
link's cost by an independent median-1 lognormal, ``exp(jitter * z)``
with ``z ~ N(0,1)`` — latency is multiplied, bandwidth divided, so the
whole edge cost scales by the draw.

*Markov transient slowdowns* (``straggler_rate``): each link carries a
two-state chain (normal <-> slow).  A normal link enters the slow state
with probability ``straggler_rate`` per activation and leaves it with
probability ``straggler_exit``; while slow, latency is multiplied and
bandwidth divided by ``straggler_slowdown``.  Bursty, *occasional*
stragglers — the regime where async gossip strictly beats stop-and-wait
even on an all-LAN fabric (Lian et al., AD-PSGD).

Seeding and replay: every draw is a pure function of
``(seed, edge, activation index)`` — a counter-based hash stream from
``kernels/rng.py`` (the same lowbias32 stream the Pallas kernels
generate in-kernel), evaluated vectorized over all of a round's active
edges at once instead of constructing one ``np.random.Generator`` per
edge per activation.  Activation ``n`` of an edge owns uniform counters
``[4n, 4n+4)`` on that edge's round stream: the jitter normal consumes
``4n``/``4n+1`` (Box–Muller), the Markov transition uniform is ``4n+2``,
and ``4n+3`` is reserved.  A rebuilt model (same seed) replaying the
same sequence of ledger calls therefore produces bit-identical sampled
times, in any interleaving of edges; the Markov state is a fold over the
keyed draws, so it replays too.  With all three knobs at zero,
:meth:`sample` returns the class-constant arrays unchanged (bitwise),
which is what lets a "sampled" ledger at zero rates reproduce the
constant-profile ledger exactly.

Consumed by :class:`~repro.topology.costs.CommLedger` (``link_model=``):
gossip, exchange, and probe rounds all price sampled per-edge times, and
the ledger folds each observation into per-edge EWMA *measured* costs
that SkewScout's C(θ)/CM pricing reads in place of profile constants.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.kernels import rng
from repro.topology.costs import LinkProfile

Edge = Tuple[int, int]

# draw-key tags: keep the per-edge base stream and the per-activation
# stream disjoint (both are keyed under the same model seed)
_TAG_BASE = 0x0B
_TAG_ROUND = 0x0A


@dataclass
class _EdgeState:
    """Mutable per-link sampling state (replayable: a pure fold over the
    keyed draws, advanced once per activation)."""
    key: int = 0              # cached per-edge round-stream key
    lat_mult: float = 1.0     # persistent per-edge base draw (hetero)
    bw_mult: float = 1.0
    n: int = 0                # activations so far (the draw counter)
    slow: bool = False        # Markov transient-slowdown state


class LinkModel:
    """Seeded per-link latency/bandwidth sampler (see module docstring).

    ``sample`` maps a graph's per-edge class-constant (latency,
    bandwidth) arrays to sampled arrays for one activation, advancing
    each active edge's draw counter and Markov state.
    """

    def __init__(self, profile: LinkProfile, *, seed: int = 0,
                 jitter: float = 0.0, hetero: float = 0.0,
                 straggler_rate: float = 0.0, straggler_exit: float = 0.5,
                 straggler_slowdown: float = 10.0):
        assert jitter >= 0 and hetero >= 0, (jitter, hetero)
        assert 0.0 <= straggler_rate <= 1.0, straggler_rate
        assert 0.0 < straggler_exit <= 1.0, straggler_exit
        assert straggler_slowdown >= 1.0, straggler_slowdown
        self.profile = profile
        self.seed = int(seed)
        self.jitter = float(jitter)
        self.hetero = float(hetero)
        self.straggler_rate = float(straggler_rate)
        self.straggler_exit = float(straggler_exit)
        self.straggler_slowdown = float(straggler_slowdown)
        self._edges: Dict[Edge, _EdgeState] = {}
        # counters for the trainer's straggler/jitter extras
        self.activations = 0
        self.slow_activations = 0

    @property
    def stochastic(self) -> bool:
        """False when every knob is zero — sampling is the identity and
        the hot path can skip the per-edge draws entirely."""
        return (self.jitter > 0 or self.hetero > 0
                or self.straggler_rate > 0)

    # ---- draws ----
    def _state(self, e: Edge) -> _EdgeState:
        st = self._edges.get(e)
        if st is None:
            st = _EdgeState(key=rng.fold_key(self.seed, _TAG_ROUND,
                                             e[0], e[1]))
            if self.hetero > 0:
                base = rng.fold_key(self.seed, _TAG_BASE, e[0], e[1])
                z = rng.normal01(np.uint32(base), np.arange(2))
                st.lat_mult = float(np.exp(self.hetero * z[0]))
                st.bw_mult = float(np.exp(-self.hetero * z[1]))
            self._edges[e] = st
        return st

    def sample(self, edges: Sequence[Edge], lat: np.ndarray,
               bw: np.ndarray, active: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Sampled (latency, bandwidth) arrays for one activation of the
        ``active`` edges, starting from the graph's class-constant
        arrays.  Inactive edges keep the constants (their cost is masked
        by the caller anyway) and do not advance their counters.

        All active edges draw in one vectorized hash evaluation: keys
        and counters are gathered from the per-edge states, the jitter
        normals and Markov uniforms come from one ``kernels/rng.py``
        batch each, and only the state write-back walks the edges."""
        if not self.stochastic:
            return lat, bw
        s_lat = lat.astype(np.float64).copy()
        s_bw = bw.astype(np.float64).copy()
        idx = np.flatnonzero(active)
        if idx.size == 0:
            return s_lat, s_bw
        states = [self._state(edges[n]) for n in idx]
        keys = np.array([st.key for st in states], np.uint32)
        ctr = np.array([st.n for st in states], np.int64)
        # activation n owns uniform counters [4n, 4n+4) on the edge's
        # round stream: Box-Muller jitter at 4n/4n+1, Markov u at 4n+2
        mult = np.ones(idx.size, np.float64)
        if self.jitter > 0:
            z = rng.normal01(keys, 2 * ctr)
            mult *= np.exp(self.jitter * z)
        if self.straggler_rate > 0:
            u = rng.uniform01(keys, (4 * ctr + 2).astype(np.uint32)
                              ).astype(np.float64)
            slow = np.array([st.slow for st in states], bool)
            mult = np.where(slow, mult * self.straggler_slowdown, mult)
            self.slow_activations += int(np.sum(slow))
            next_slow = np.where(slow, u >= self.straggler_exit,
                                 u < self.straggler_rate)
        else:
            next_slow = np.array([st.slow for st in states], bool)
        self.activations += idx.size
        for j, st in enumerate(states):
            st.n += 1
            st.slow = bool(next_slow[j])
        base_lat = np.array([st.lat_mult for st in states], np.float64)
        base_bw = np.array([st.bw_mult for st in states], np.float64)
        s_lat[idx] = lat[idx] * base_lat * mult
        s_bw[idx] = bw[idx] * base_bw / mult
        return s_lat, s_bw

    # ---- reporting ----
    def slow_fraction(self) -> float:
        """Fraction of activations that hit a straggler's slow state."""
        return self.slow_activations / max(self.activations, 1)

    def summary(self) -> Dict[str, float]:
        return dict(jitter=self.jitter, hetero=self.hetero,
                    straggler_rate=self.straggler_rate,
                    straggler_slowdown=self.straggler_slowdown,
                    activations=float(self.activations),
                    slow_activations=float(self.slow_activations),
                    slow_fraction=self.slow_fraction())


def make_link_model(comm, profile: LinkProfile,
                    seed: int = 0) -> Optional[LinkModel]:
    """Build the :class:`LinkModel` a ``CommConfig`` asks for (``None``
    for the constant-profile ledger).  The model draws from its own
    keyed streams, so the link seed can never perturb anything else
    seeded from the run seed (clique assignment, data order, init)."""
    if comm.link_model == "constant":
        return None
    if comm.link_model != "sampled":
        raise ValueError(
            f"unknown link_model {comm.link_model!r} (constant | sampled)")
    return LinkModel(profile, seed=seed, jitter=comm.link_jitter,
                     hetero=comm.link_hetero,
                     straggler_rate=comm.straggler_rate,
                     straggler_exit=comm.straggler_exit,
                     straggler_slowdown=comm.straggler_slowdown)
