"""Frozen pre-redesign dict-backed ledger + link model (verbatim copy).

This module is the bit-equality reference for ``tests/test_fabric_scale.py``:
it preserves the exact per-edge Python-dict bookkeeping (`DictCommLedger`)
and per-edge-state link sampler (`DictLinkModel`) that the array-native
`repro.topology.costs.CommLedger` / `repro.topology.links.LinkModel`
replaced.  Do not "fix" or modernize this file — its value is that it is
the old implementation, byte-for-byte in semantics, so the equivalence
suite can assert the rewrite reproduced every float exactly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple, Union

import numpy as np

from repro.kernels import rng
from repro.topology.graphs import (Edge, Topology, TopologySchedule,
                                   as_schedule)




@dataclass(frozen=True)
class LinkProfile:
    """Per-class bandwidth/latency.  ``uniform`` removes the LAN/WAN
    distinction (every link is LAN-priced) — the seed repo's behaviour.
    ``*_handshake`` is the connection-setup latency a newly-activated
    link pays once (re-wiring); it defaults to 3x the link's propagation
    latency (SYN / SYN-ACK / ACK) when not given."""
    name: str
    lan_bandwidth: float        # floats / second
    wan_bandwidth: float
    lan_latency: float = 0.0    # seconds
    wan_latency: float = 0.0
    lan_handshake: Optional[float] = None   # seconds; None -> 3x latency
    wan_handshake: Optional[float] = None

    def bandwidth(self, cls: str) -> float:
        return self.wan_bandwidth if cls == "wan" else self.lan_bandwidth

    def latency(self, cls: str) -> float:
        return self.wan_latency if cls == "wan" else self.lan_latency

    def handshake(self, cls: str) -> float:
        h = self.wan_handshake if cls == "wan" else self.lan_handshake
        return 3.0 * self.latency(cls) if h is None else h

    def price_per_float(self, cls: str) -> float:
        """Seconds per float — the scarcity weight used by SkewScout."""
        return 1.0 / self.bandwidth(cls)


# 4-byte floats: 10 Gb/s LAN ~ 312.5e6 floats/s; 100 Mb/s WAN ~ 3.125e6
LINK_PROFILES: Dict[str, LinkProfile] = {
    "uniform": LinkProfile("uniform", 312.5e6, 312.5e6, 0.0, 0.0),
    "datacenter": LinkProfile("datacenter", 312.5e6, 312.5e6,
                              1e-4, 1e-4),
    "geo-wan": LinkProfile("geo-wan", 312.5e6, 3.125e6, 1e-4, 5e-2),
}


class _GraphPricing:
    """Cached per-edge pricing arrays + a vectorized traffic accumulator
    for one graph of the schedule (the per-step hot path stays numpy;
    the per-edge dict is only materialized in cold accessors)."""

    def __init__(self, graph: Topology, profile: LinkProfile):
        self.graph = graph
        self.deg = graph.degrees().astype(np.float64)
        self.bw = np.asarray([profile.bandwidth(c)
                              for c in graph.edge_class])
        self.lat = np.asarray([profile.latency(c)
                               for c in graph.edge_class])
        self.hs = np.asarray([profile.handshake(c)
                              for c in graph.edge_class])
        self.is_wan = np.asarray([c == "wan" for c in graph.edge_class],
                                 bool)
        self.active = frozenset(graph.edges)
        self.edge_index = {e: n for n, e in enumerate(graph.edges)}
        # edge endpoint arrays for vectorized per-node routing
        self.ei = np.asarray([i for i, _ in graph.edges], np.int64)
        self.ej = np.asarray([j for _, j in graph.edges], np.int64)
        self.traffic = np.zeros(len(graph.edges))

    def flush_into(self, traffic: Dict[Edge, float]) -> None:
        for e, f in zip(self.graph.edges, self.traffic):
            if f:
                traffic[e] = traffic.get(e, 0.0) + float(f)
        self.traffic[:] = 0.0


class DictCommLedger:
    """Accumulates per-edge traffic and simulated time for one run.

    ``record_exchange(c)``: all-to-all style — each node's ``c`` exchanged
    floats are spread uniformly over its incident edges (the sum over
    edges conserves ``K * c``); priced on the schedule's union graph
    (parameter-server-style traffic has no per-round edge set).
    ``record_gossip(m, t)``: D-PSGD style — every edge *active in round
    t's graph* carries the full model once per direction (``2m`` per
    active edge).  In ``async_mode`` a per-edge ``staleness`` bound
    (AD-PSGD) amortizes each link's latency over ``staleness + 1``
    in-flight deliveries.
    ``record_probe(edges, m)``: SkewScout model traveling — ``m`` floats
    cross each probed union link once.
    """

    def __init__(self, fabric: Union[Topology, TopologySchedule],
                 profile: LinkProfile, *,
                 rewire_floats_per_edge: float = 0.0,
                 async_mode: bool = False,
                 link_model=None, amortize_window: int = 1,
                 ewma_alpha: float = 0.1):
        self.profile = profile
        self.rewire_floats_per_edge = float(rewire_floats_per_edge)
        self.async_mode = bool(async_mode)
        # stochastic per-link sampler (repro.topology.links.LinkModel);
        # None keeps the class-constant pricing
        self.links = link_model
        assert int(amortize_window) >= 1, amortize_window
        self.amortize_window = int(amortize_window)
        # handshake amortization: canonical edge -> unpaid balance (s)
        # and the per-activation installment it is paid down in
        self._pending_hs: Dict[Edge, float] = {}
        self._hs_inst: Dict[Edge, float] = {}
        # per-edge EWMA measured costs (observed latency seconds and
        # price seconds/float) — SkewScout's measured-cost denominators
        assert 0.0 < ewma_alpha <= 1.0, ewma_alpha
        self.ewma_alpha = float(ewma_alpha)
        self._ewma_lat: Dict[Edge, float] = {}
        self._ewma_price: Dict[Edge, float] = {}
        # running transfer seconds with every float priced at the
        # bandwidth its activation actually sampled — the sync C(θ)
        # numerator that stays in the same currency as the measured CM
        self._sampled_cost_s = 0.0
        # source of truth for per-edge traffic survives schedule switches
        self._traffic: Dict[Edge, float] = {}
        self.lan_floats = 0.0
        self.wan_floats = 0.0
        self.sim_time_s = 0.0
        # per-edge virtual clocks (canonical edge -> seconds); in sync
        # mode every activated edge snaps to the global clock, in async
        # mode each advances by its own cost only
        self._edge_clock: Dict[Edge, float] = {}
        # online re-wiring accounting (floats also in lan/wan totals)
        self.rewire_lan_floats = 0.0
        self.rewire_wan_floats = 0.0
        self.rewire_events = 0
        self.rewire_time_s = 0.0     # handshake seconds booked on links
        # communication rounds recorded — includes probe/overhead
        # exchanges, so this is NOT the trainer's step count
        self.rounds = 0
        self._last_active: Optional[frozenset] = None
        self._pricing: Dict[int, _GraphPricing] = {}
        self._attach(as_schedule(fabric))
        # per-node busy time: each round a node participates in, it
        # works for the max cost over its own activated incident links
        self.node_busy_s = np.zeros(self.topology.n_nodes)

    def _attach(self, schedule: TopologySchedule) -> None:
        self.schedule = schedule
        self.topology = schedule.union()
        self._union_pricing = _GraphPricing(self.topology, self.profile)

    def _graph_pricing(self, graph: Topology) -> _GraphPricing:
        p = self._pricing.get(id(graph))
        if p is None:
            p = self._pricing[id(graph)] = _GraphPricing(graph,
                                                         self.profile)
        return p

    # ---- recording ----
    def _book_floats(self, pricing: _GraphPricing,
                     per_edge: np.ndarray) -> None:
        """Attribute ``per_edge`` floats (aligned with ``pricing.graph``'s
        edge list) to links and LAN/WAN totals — all vectorized; the
        per-edge dict only materializes in the cold accessors."""
        pricing.traffic += per_edge
        self.lan_floats += float(per_edge[~pricing.is_wan].sum())
        self.wan_floats += float(per_edge[pricing.is_wan].sum())

    def _link_rates(self, pricing: _GraphPricing, active: np.ndarray
                    ) -> tuple:
        """Per-edge (latency, bandwidth) for one activation of the
        ``active`` edges: the graph's class constants, or — with a
        ``link_model`` attached — the sampled values, each observation
        folded into the per-edge EWMA measured costs."""
        if self.links is None or not self.links.stochastic:
            # identity sampling: constants are the truth, the EWMA fold
            # would only re-derive them — keep the hot path dict-free
            return pricing.lat, pricing.bw
        lat, bw = self.links.sample(pricing.graph.edges, pricing.lat,
                                    pricing.bw, active)
        a = self.ewma_alpha
        for n in np.flatnonzero(active):
            e = pricing.graph.edges[n]
            obs_lat, obs_price = float(lat[n]), 1.0 / float(bw[n])
            old_lat = self._ewma_lat.get(e)
            old_price = self._ewma_price.get(e)
            self._ewma_lat[e] = obs_lat if old_lat is None \
                else (1.0 - a) * old_lat + a * obs_lat
            self._ewma_price[e] = obs_price if old_price is None \
                else (1.0 - a) * old_price + a * obs_price
        return lat, bw

    def _book_sampled_cost(self, per_edge: np.ndarray, bw: np.ndarray,
                           active: np.ndarray) -> None:
        """Accumulate the transfer seconds of ``per_edge`` floats at the
        (possibly sampled) ``bw`` of this activation — the sampled
        analogue of ``priced_cost``'s float-times-constant-price sum.
        No-op without a stochastic link model: ``sampled_priced_cost``
        falls back to ``priced_cost`` there."""
        if self.links is not None and self.links.stochastic:
            self._sampled_cost_s += float(
                (per_edge[active] / bw[active]).sum())

    def _pay_installments(self, pricing: _GraphPricing,
                          active: np.ndarray) -> Optional[np.ndarray]:
        """Handshake installments due this round: each active edge with
        an unpaid balance pays ``handshake / amortize_window`` into its
        round cost.  Returns the per-edge installment array (None when
        nothing is owed)."""
        if not self._pending_hs:
            return None
        inst = None
        for e in list(self._pending_hs):
            n = pricing.edge_index.get(e)
            if n is None or not active[n]:
                continue
            bal = self._pending_hs[e]
            pay = min(self._hs_inst.get(e, bal), bal)
            if inst is None:
                inst = np.zeros(len(pricing.graph.edges))
            inst[n] += pay
            self.rewire_time_s += pay
            bal -= pay
            if bal <= 1e-18:
                del self._pending_hs[e]
                self._hs_inst.pop(e, None)
            else:
                self._pending_hs[e] = bal
        return inst

    def _charge_time(self, pricing: _GraphPricing,
                     cost: np.ndarray, active: np.ndarray) -> None:
        """Advance the clocks by ``cost`` seconds per edge (aligned with
        ``pricing.graph.edges``; only ``active`` entries count).

        sync: stop-and-wait — the global clock grows by the round's max
        cost and every activated edge snaps to it.  async: each edge's
        clock advances by its own cost; the global clock is the max of
        the *activated* edges' clocks (monotone by construction)."""
        if not active.any():
            return
        edges = pricing.graph.edges
        if self.async_mode:
            frontier = 0.0
            for n in np.flatnonzero(active):
                e = edges[n]
                c = self._edge_clock.get(e, 0.0) + float(cost[n])
                self._edge_clock[e] = c
                frontier = max(frontier, c)
            self.sim_time_s = max(self.sim_time_s, frontier)
        else:
            self.sim_time_s += float(cost[active].max())
            for n in np.flatnonzero(active):
                self._edge_clock[edges[n]] = self.sim_time_s
        busy = np.zeros(len(self.node_busy_s))
        own = np.where(active, cost, 0.0)
        np.maximum.at(busy, pricing.ei, own)
        np.maximum.at(busy, pricing.ej, own)
        self.node_busy_s += busy

    def _rewire(self, pricing: _GraphPricing) -> None:
        """Charge the online re-wiring cost for links that were not
        active in the previous gossip round: a control-plane handshake
        of ``rewire_floats_per_edge`` floats per new link, priced at the
        link's class and added to the simulated step time; the link's
        per-class *setup latency* (``LinkProfile.handshake``: WAN >>
        LAN) is charged as its own serial setup event at the default
        ``amortize_window=1`` (the exact legacy behaviour), or scheduled
        as ``handshake / amortize_window`` installments paid into the
        link's first ``amortize_window`` gossip activations.  Links
        dropped before their window completes forfeit the unpaid
        balance immediately.
        Floats are booked into the LAN/WAN totals too, so ``lan_floats +
        wan_floats`` still covers every priced float.  Only gossip
        rounds carry an active edge set — union-routed exchanges
        (probes) never re-wire and never reset the tracking."""
        if self._last_active is None or \
                pricing.active == self._last_active:
            self._last_active = pricing.active
            return
        prev = self._last_active
        new = pricing.active - prev
        dropped = prev - pricing.active
        self._last_active = pricing.active
        # teardown: a dropped link's unamortized handshake balance is
        # charged now — the setup work was spent; only the booking was
        # deferred.  This is what keeps schedule thrashing as expensive
        # as un-amortized switching.
        if dropped and self._pending_hs:
            forfeit_max = 0.0
            forfeited = []
            busy = np.zeros(len(self.node_busy_s))
            for e in dropped:
                bal = self._pending_hs.pop(e, 0.0)
                self._hs_inst.pop(e, None)
                if bal <= 0.0:
                    continue
                forfeited.append(e)
                self.rewire_time_s += bal
                # the endpoints did this work: keep busy/idle/clock-skew
                # accounting comparable across amortize_window settings
                # (at window 1 the same seconds flow through the round's
                # _charge_time and land on the endpoints there)
                for k in e:
                    if k < len(busy):
                        busy[k] = max(busy[k], bal)
                if self.async_mode:
                    c = self._edge_clock.get(e, 0.0) + bal
                    self._edge_clock[e] = c
                    self.sim_time_s = max(self.sim_time_s, c)
                else:
                    forfeit_max = max(forfeit_max, bal)
            # sync: teardowns run in parallel across the dropped links,
            # and the links that actually forfeited (only those — a
            # fully-paid dropped edge keeps its stale clock) snap to the
            # global clock
            self.sim_time_s += forfeit_max
            for e in forfeited:
                if not self.async_mode:
                    self._edge_clock[e] = max(
                        self._edge_clock.get(e, 0.0), self.sim_time_s)
            self.node_busy_s += busy
        if not new:
            return
        if self.async_mode:
            # a (re)activated link joins at the global frontier: it
            # cannot have banked transfer time while it did not exist.
            # Without this, a rung switch would hand the controller a
            # free window (the new fabric's clocks lag the ratcheted
            # global max, so C(θ) reads ~0 until they catch up).
            for e in new:
                self._edge_clock[e] = max(self._edge_clock.get(e, 0.0),
                                          self.sim_time_s)
        is_new = np.asarray([e in new for e in pricing.graph.edges])
        per_edge = np.where(is_new, self.rewire_floats_per_edge, 0.0)
        if self.rewire_floats_per_edge > 0.0:
            self._book_floats(pricing, per_edge)
            self.rewire_lan_floats += float(per_edge[~pricing.is_wan].sum())
            self.rewire_wan_floats += float(per_edge[pricing.is_wan].sum())
        # window 1 (the default) keeps the exact legacy behaviour: the
        # whole handshake is charged here as its own serial setup event.
        # W > 1 schedules it as installments over the link's first W
        # activations instead (re-activation restarts the window: the
        # old connection is gone)
        if self.amortize_window > 1:
            for n in np.flatnonzero(is_new):
                e = pricing.graph.edges[n]
                hs = float(pricing.hs[n])
                if hs > 0.0:
                    self._pending_hs[e] = hs
                    self._hs_inst[e] = hs / self.amortize_window
            hs_now = 0.0
        else:
            hs_now = pricing.hs
        # the control-plane transfer itself (amortized handshake latency
        # is paid through the installments, starting with this round's
        # gossip; control-plane floats are priced at nominal constants)
        self._book_sampled_cost(per_edge, pricing.bw, is_new)
        cost = np.where(is_new,
                        hs_now + pricing.lat + per_edge / pricing.bw, 0.0)
        self.rewire_time_s += float(cost[is_new].sum())
        self._charge_time(pricing, cost, cost > 0)
        self.rewire_events += len(new)

    def record_exchange(self,
                        floats_per_node: Union[float, Sequence[float]]
                        ) -> None:
        """All-to-all exchange of ``floats_per_node`` floats per node,
        routed uniformly over each node's incident edges of the union
        fabric.  Union routing has no per-round active edge set, so it
        neither pays nor resets re-wiring."""
        pricing = self._union_pricing
        K = self.topology.n_nodes
        c = np.broadcast_to(np.asarray(floats_per_node, np.float64), (K,))
        share = np.where(pricing.deg > 0,
                         c / np.maximum(pricing.deg, 1), 0.0)
        per_edge = share[pricing.ei] + share[pricing.ej]
        self._book_floats(pricing, per_edge)
        active = per_edge > 0
        lat, bw = self._link_rates(pricing, active)
        self._book_sampled_cost(per_edge, bw, active)
        self._charge_time(pricing,
                          np.where(active, lat + per_edge / bw, 0.0),
                          active)
        self.rounds += 1

    def record_gossip(self, model_floats: float,
                      t: Optional[int] = None,
                      staleness: Union[None, int, Sequence[int]] = None
                      ) -> None:
        """One gossip round at round index ``t``: the full model crosses
        every edge active in ``schedule.at(t)``, both directions.
        ``t=None`` keeps the legacy one-graph behaviour (round 0).

        ``staleness`` (async mode only): per-edge bounded-staleness
        values (scalar broadcasts) — a link tolerating ``s``-stale
        deliveries pipelines ``s + 1`` payloads, so its latency is paid
        once per ``s + 1`` activations.  Ignored in sync mode, where
        every round is stop-and-wait regardless of the algorithm."""
        graph = self.schedule.at(0 if t is None else t)
        pricing = self._graph_pricing(graph)
        self._rewire(pricing)
        n_edges = len(graph.edges)
        per_edge = np.full(n_edges, 2.0 * model_floats)
        self._book_floats(pricing, per_edge)
        active = per_edge > 0
        lat, bw = self._link_rates(pricing, active)
        self._book_sampled_cost(per_edge, bw, active)
        if self.async_mode and staleness is not None:
            s = np.broadcast_to(np.asarray(staleness, np.float64),
                                (n_edges,))
            assert (s >= 0).all(), "staleness must be non-negative"
            lat = lat / (1.0 + s)
        cost = np.where(active, lat + per_edge / bw, 0.0)
        inst = self._pay_installments(pricing, active)
        if inst is not None:
            cost = cost + inst
        self._charge_time(pricing, cost, active)
        self.rounds += 1

    def record_probe(self, edges: Sequence[Edge],
                     floats_each: float) -> None:
        """SkewScout model traveling: ``floats_each`` floats cross each
        probed link once (one direction).  Probes ride union-fabric
        links (probe routing follows active edges, which are union
        members), are booked into the LAN/WAN totals and per-edge
        traffic, block on delivery (staleness 0 — the measurement needs
        the fresh model), and neither pay nor reset re-wiring."""
        pricing = self._union_pricing
        per_edge = np.zeros(len(pricing.graph.edges))
        for i, j in edges:
            e = (min(i, j), max(i, j))
            assert e in pricing.edge_index, \
                f"probe edge {e} is not on the union fabric"
            per_edge[pricing.edge_index[e]] += float(floats_each)
        self._book_floats(pricing, per_edge)
        active = per_edge > 0
        lat, bw = self._link_rates(pricing, active)
        self._book_sampled_cost(per_edge, bw, active)
        self._charge_time(pricing,
                          np.where(active, lat + per_edge / bw, 0.0),
                          active)
        self.rounds += 1

    def switch_schedule(self, fabric: Union[Topology, TopologySchedule]
                        ) -> None:
        """Swap the fabric mid-run (SkewScout climbing a topology rung).
        Accumulated traffic and per-edge clocks are preserved (see
        ``traffic_by_edge``); the first gossip round on the new schedule
        pays re-wiring for every link the old round's active set did not
        have."""
        schedule = as_schedule(fabric)
        assert schedule.n_nodes == self.topology.n_nodes, \
            (schedule.n_nodes, self.topology.n_nodes)
        self._flush_traffic()
        self._attach(schedule)
        self._pricing.clear()

    def _flush_traffic(self) -> None:
        """Fold the vectorized per-graph accumulators into the canonical
        per-edge dict (cold path: accessors and schedule switches)."""
        self._union_pricing.flush_into(self._traffic)
        for p in self._pricing.values():
            p.flush_into(self._traffic)

    # ---- pricing ----
    def traffic_by_edge(self) -> Dict[Edge, float]:
        """Every float ever booked, keyed by canonical edge — survives
        schedule switches (``sum(...) == total_floats`` always)."""
        self._flush_traffic()
        return dict(self._traffic)

    @property
    def edge_traffic(self) -> np.ndarray:
        """Per-edge floats, aligned with ``self.topology.edges`` — a
        *view* onto the current schedule's union graph.  After a
        ``switch_schedule`` to a sparser fabric, traffic booked on links
        the new union lacks is not shown here (use ``traffic_by_edge``
        for the lossless history)."""
        self._flush_traffic()
        return np.asarray([self._traffic.get(e, 0.0)
                           for e in self.topology.edges])

    # ---- clocks ----
    def edge_clocks(self) -> Dict[Edge, float]:
        """Per-link virtual clocks (seconds), keyed by canonical edge —
        survives schedule switches.  Monotone non-decreasing per edge in
        both modes; in sync mode activated edges snap to the global
        clock, in async mode each advances by its own cost only."""
        return dict(self._edge_clock)

    def node_clocks(self) -> np.ndarray:
        """When each node last finished a communication: the max clock
        over its incident links (0 if it never communicated)."""
        clk = np.zeros(self.topology.n_nodes)
        for (i, j), c in self._edge_clock.items():
            if i < len(clk):
                clk[i] = max(clk[i], c)
            if j < len(clk):
                clk[j] = max(clk[j], c)
        return clk

    def clock_skew_s(self) -> float:
        """Spread of the per-node clocks — 0 when every node finishes
        rounds in lockstep (sync, constant fabric); positive when async
        lets fast nodes run ahead of the stragglers."""
        clk = self.node_clocks()
        return float(clk.max() - clk.min()) if len(clk) else 0.0

    @property
    def node_idle_s(self) -> np.ndarray:
        """Per-node idle time: the global clock minus the node's own
        busy time.  In sync mode this is time spent waiting on other
        nodes' slower links; in async mode, time a fast node is done
        before the last link drains."""
        return np.maximum(self.sim_time_s - self.node_busy_s, 0.0)

    @property
    def total_floats(self) -> float:
        return self.lan_floats + self.wan_floats

    def priced_cost(self) -> float:
        """Cumulative bandwidth-weighted cost (seconds of link time);
        WAN floats dominate under the geo-wan profile, matching the
        paper's Gaia objective of pricing scarce WAN bytes.  Includes
        re-wiring traffic, so a controller that flaps between schedules
        pays for it in C(θ)."""
        return (self.lan_floats * self.profile.price_per_float("lan")
                + self.wan_floats * self.profile.price_per_float("wan"))

    def sampled_priced_cost(self) -> float:
        """``priced_cost`` in *sampled* currency: every booked float
        priced at the bandwidth its activation actually sampled, so a
        sync SkewScout window numerator stays unit-consistent with the
        EWMA-measured CM denominator (constant-priced floats against a
        measured CM would read systematically cheap and drift during
        EWMA warm-up).  Falls back to ``priced_cost`` when no stochastic
        link model is attached — the constants are the truth there."""
        if self.links is None or not self.links.stochastic:
            return self.priced_cost()
        return self._sampled_cost_s

    @property
    def rewire_floats(self) -> float:
        return self.rewire_lan_floats + self.rewire_wan_floats

    def rewiring_cost(self) -> float:
        """Priced cost of the re-wiring traffic alone — the component of
        ``priced_cost`` a schedule-flapping controller is paying for
        link churn."""
        return (self.rewire_lan_floats * self.profile.price_per_float("lan")
                + self.rewire_wan_floats
                * self.profile.price_per_float("wan"))

    def _full_exchange(self, model_floats: float, g: Topology,
                       lat_of, price_of, worst: bool) -> float:
        """One BSP-style full-model exchange on ``g`` (each node's model
        share routed uniformly over its incident edges): the max link
        time (``worst=True``, latency + transfer) or the summed
        bandwidth-seconds.  The per-edge (latency, price) come from the
        accessors, so the constant and measured variants share one
        routing formula."""
        if not len(g.edges):
            return 1e-30
        deg = g.degrees().astype(np.float64)
        share = model_floats / np.maximum(deg, 1)
        acc = 0.0
        for n, (i, j) in enumerate(g.edges):
            cls = g.edge_class[n]
            per_edge = share[i] + share[j]
            if worst:
                acc = max(acc, lat_of((i, j), cls)
                          + per_edge * price_of((i, j), cls))
            else:
                acc += per_edge * price_of((i, j), cls)
        return max(acc, 1e-30)

    def full_exchange_cost(self, model_floats: float) -> float:
        """Priced cost of one BSP-style full-model exchange on the union
        fabric — SkewScout's CM denominator (bandwidth-seconds)."""
        return self._full_exchange(
            model_floats, self.topology,
            lambda e, cls: self.profile.latency(cls),
            lambda e, cls: self.profile.price_per_float(cls), worst=False)

    def full_exchange_time(self, model_floats: float) -> float:
        """Wall-clock of one BSP-style full-model exchange on the union
        fabric (slowest link's latency + transfer) — the CM denominator
        when SkewScout prices C(θ) in async simulated time."""
        return self._full_exchange(
            model_floats, self.topology,
            lambda e, cls: self.profile.latency(cls),
            lambda e, cls: self.profile.price_per_float(cls), worst=True)

    # ---- measured costs (per-edge EWMA over sampled observations) ----
    def measured_latency_s(self, e: Edge, cls: str = "lan") -> float:
        """EWMA of the link's observed latency; profile constant until
        the link has been observed (or when no link model is attached —
        the constants *are* the truth then)."""
        return self._ewma_lat.get(e, self.profile.latency(cls))

    def measured_price_per_float(self, e: Edge, cls: str = "lan") -> float:
        """EWMA of the link's observed seconds-per-float (inverse
        sampled bandwidth), with the same profile-constant fallback."""
        return self._ewma_price.get(e, self.profile.price_per_float(cls))

    def _measured_union(self, fabric) -> Topology:
        return self.topology if fabric is None \
            else as_schedule(fabric).union()

    def measured_full_exchange_cost(self, model_floats: float,
                                    fabric=None) -> float:
        """``full_exchange_cost`` priced from the per-edge EWMA measured
        costs instead of profile constants — SkewScout's CM denominator
        when a link model makes the constants a fiction.  ``fabric``
        pins the exchange graph (e.g. the densest ladder rung) so the
        denominator stays comparable across rung switches."""
        return self._full_exchange(
            model_floats, self._measured_union(fabric),
            self.measured_latency_s, self.measured_price_per_float,
            worst=False)

    def measured_full_exchange_time(self, model_floats: float,
                                    fabric=None) -> float:
        """``full_exchange_time`` from measured per-edge costs — the CM
        denominator for an async ledger under a link model."""
        return self._full_exchange(
            model_floats, self._measured_union(fabric),
            self.measured_latency_s, self.measured_price_per_float,
            worst=True)

    # ---- controller-facing pricing policy ----
    def window_cost(self) -> float:
        """The running counter SkewScout cuts C(θ) windows from — the
        one place the numerator currency is chosen: simulated wall-clock
        for an async ledger; for a sync ledger, bandwidth-seconds priced
        at the sampled bandwidths when a stochastic link model is
        attached (``sampled_priced_cost``) and at the profile constants
        otherwise."""
        if self.async_mode:
            return self.sim_time_s
        return self.sampled_priced_cost()

    def cm_denominator(self, model_floats: float, fabric=None) -> float:
        """The CM denominator matching :meth:`window_cost`'s currency —
        one full-model exchange priced as wall-clock (async) or
        bandwidth-seconds (sync), from the per-edge EWMA measured costs
        when a link model is attached and from the profile constants
        otherwise.  ``fabric`` pins the exchange graph (constants-only
        callers that need a pin use a precomputed ``cm_ref`` instead,
        since constants never drift)."""
        if self.links is not None:
            return (self.measured_full_exchange_time(model_floats,
                                                     fabric=fabric)
                    if self.async_mode
                    else self.measured_full_exchange_cost(model_floats,
                                                          fabric=fabric))
        return (self.full_exchange_time(model_floats) if self.async_mode
                else self.full_exchange_cost(model_floats))

    @property
    def pending_handshake_s(self) -> float:
        """Unpaid handshake balance still being amortized (seconds) —
        cost already incurred by the links but deferred into their
        remaining window; ``rewire_time_s + pending_handshake_s`` is the
        horizon-independent handshake total."""
        return float(sum(self._pending_hs.values()))

    def summary(self) -> Dict[str, float]:
        return dict(lan_floats=self.lan_floats, wan_floats=self.wan_floats,
                    total_floats=self.total_floats,
                    sim_time_s=self.sim_time_s,
                    priced_cost=self.priced_cost(), rounds=self.rounds,
                    rewire_floats=self.rewire_floats,
                    rewire_events=self.rewire_events,
                    rewire_time_s=self.rewire_time_s,
                    async_mode=float(self.async_mode),
                    clock_skew_s=self.clock_skew_s(),
                    busy_s_max=float(self.node_busy_s.max()),
                    idle_s_mean=float(self.node_idle_s.mean()),
                    amortize_window=float(self.amortize_window),
                    pending_handshake_s=self.pending_handshake_s,
                    **({"link_" + k: float(v)
                        for k, v in self.links.summary().items()}
                       if self.links is not None else {}))





# draw-key tags: keep the per-edge base stream and the per-activation
# stream disjoint (both are keyed under the same model seed)
_TAG_BASE = 0x0B
_TAG_ROUND = 0x0A


@dataclass
class _EdgeState:
    """Mutable per-link sampling state (replayable: a pure fold over the
    keyed draws, advanced once per activation)."""
    key: int = 0              # cached per-edge round-stream key
    lat_mult: float = 1.0     # persistent per-edge base draw (hetero)
    bw_mult: float = 1.0
    n: int = 0                # activations so far (the draw counter)
    slow: bool = False        # Markov transient-slowdown state


class DictLinkModel:
    """Seeded per-link latency/bandwidth sampler (see module docstring).

    ``sample`` maps a graph's per-edge class-constant (latency,
    bandwidth) arrays to sampled arrays for one activation, advancing
    each active edge's draw counter and Markov state.
    """

    def __init__(self, profile: LinkProfile, *, seed: int = 0,
                 jitter: float = 0.0, hetero: float = 0.0,
                 straggler_rate: float = 0.0, straggler_exit: float = 0.5,
                 straggler_slowdown: float = 10.0):
        assert jitter >= 0 and hetero >= 0, (jitter, hetero)
        assert 0.0 <= straggler_rate <= 1.0, straggler_rate
        assert 0.0 < straggler_exit <= 1.0, straggler_exit
        assert straggler_slowdown >= 1.0, straggler_slowdown
        self.profile = profile
        self.seed = int(seed)
        self.jitter = float(jitter)
        self.hetero = float(hetero)
        self.straggler_rate = float(straggler_rate)
        self.straggler_exit = float(straggler_exit)
        self.straggler_slowdown = float(straggler_slowdown)
        self._edges: Dict[Edge, _EdgeState] = {}
        # counters for the trainer's straggler/jitter extras
        self.activations = 0
        self.slow_activations = 0

    @property
    def stochastic(self) -> bool:
        """False when every knob is zero — sampling is the identity and
        the hot path can skip the per-edge draws entirely."""
        return (self.jitter > 0 or self.hetero > 0
                or self.straggler_rate > 0)

    # ---- draws ----
    def _state(self, e: Edge) -> _EdgeState:
        st = self._edges.get(e)
        if st is None:
            st = _EdgeState(key=rng.fold_key(self.seed, _TAG_ROUND,
                                             e[0], e[1]))
            if self.hetero > 0:
                base = rng.fold_key(self.seed, _TAG_BASE, e[0], e[1])
                z = rng.normal01(np.uint32(base), np.arange(2))
                st.lat_mult = float(np.exp(self.hetero * z[0]))
                st.bw_mult = float(np.exp(-self.hetero * z[1]))
            self._edges[e] = st
        return st

    def sample(self, edges: Sequence[Edge], lat: np.ndarray,
               bw: np.ndarray, active: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Sampled (latency, bandwidth) arrays for one activation of the
        ``active`` edges, starting from the graph's class-constant
        arrays.  Inactive edges keep the constants (their cost is masked
        by the caller anyway) and do not advance their counters.

        All active edges draw in one vectorized hash evaluation: keys
        and counters are gathered from the per-edge states, the jitter
        normals and Markov uniforms come from one ``kernels/rng.py``
        batch each, and only the state write-back walks the edges."""
        if not self.stochastic:
            return lat, bw
        s_lat = lat.astype(np.float64).copy()
        s_bw = bw.astype(np.float64).copy()
        idx = np.flatnonzero(active)
        if idx.size == 0:
            return s_lat, s_bw
        states = [self._state(edges[n]) for n in idx]
        keys = np.array([st.key for st in states], np.uint32)
        ctr = np.array([st.n for st in states], np.int64)
        # activation n owns uniform counters [4n, 4n+4) on the edge's
        # round stream: Box-Muller jitter at 4n/4n+1, Markov u at 4n+2
        mult = np.ones(idx.size, np.float64)
        if self.jitter > 0:
            z = rng.normal01(keys, 2 * ctr)
            mult *= np.exp(self.jitter * z)
        if self.straggler_rate > 0:
            u = rng.uniform01(keys, (4 * ctr + 2).astype(np.uint32)
                              ).astype(np.float64)
            slow = np.array([st.slow for st in states], bool)
            mult = np.where(slow, mult * self.straggler_slowdown, mult)
            self.slow_activations += int(np.sum(slow))
            next_slow = np.where(slow, u >= self.straggler_exit,
                                 u < self.straggler_rate)
        else:
            next_slow = np.array([st.slow for st in states], bool)
        self.activations += idx.size
        for j, st in enumerate(states):
            st.n += 1
            st.slow = bool(next_slow[j])
        base_lat = np.array([st.lat_mult for st in states], np.float64)
        base_bw = np.array([st.bw_mult for st in states], np.float64)
        s_lat[idx] = lat[idx] * base_lat * mult
        s_bw[idx] = bw[idx] * base_bw / mult
        return s_lat, s_bw

    # ---- reporting ----
    def slow_fraction(self) -> float:
        """Fraction of activations that hit a straggler's slow state."""
        return self.slow_activations / max(self.activations, 1)

    def summary(self) -> Dict[str, float]:
        return dict(jitter=self.jitter, hetero=self.hetero,
                    straggler_rate=self.straggler_rate,
                    straggler_slowdown=self.straggler_slowdown,
                    activations=float(self.activations),
                    slow_activations=float(self.slow_activations),
                    slow_fraction=self.slow_fraction())
