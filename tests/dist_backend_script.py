"""Subprocess helper for test_dist_backend.py — needs its own process so
xla_force_host_platform_device_count doesn't leak into other tests.

Runs the SPMD train step on a (2,2,2) pod/data/model mesh with a REAL
reduced model and real arrays, and checks:
 1. every strategy (bsp/gaia/fedavg/dgc/dpsgd/adpsgd) executes with
    finite loss,
 2. the distributed Gaia update == the simulation-backend Gaia update
    (same arithmetic, two backends; the full per-strategy equivalence
    matrix lives in launch_gossip_script.py),
 3. serve_step executes on the mesh.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import CommConfig
from repro.configs.registry import get_config
from repro.launch.sharding import (batch_shardings, cache_shardings,
                                   param_shardings, train_state_shardings)
from repro.launch.steps import (gossip_operands, make_serve_step,
                                make_train_step, make_train_state)
from repro.models.model import init_cache, init_model
from repro.models.shard_hints import activation_sharding
from repro.topology.graphs import ring


def main():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_config("qwen3-0.6b").reduced()
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    B_per_pod, T = 4, 32
    tokens = jax.random.randint(key, (2, B_per_pod, T), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.PRNGKey(1), (2, B_per_pod, T), 0,
                                cfg.vocab)
    batch = {"tokens": tokens, "labels": labels}

    losses = {}
    states = {}
    fabric = ring(2)
    for strategy in ("bsp", "gaia", "fedavg", "dgc", "dpsgd", "adpsgd"):
        comm = CommConfig(strategy=strategy, gaia_t0=0.01,
                          iter_local=1, dgc_sparsity=0.75, max_staleness=1)
        state = make_train_state(params, comm, 2)
        with mesh, activation_sharding(mesh):
            s_shard = train_state_shardings(
                jax.eval_shape(lambda: state), mesh)
            b_shard = batch_shardings(batch, mesh, pod_stacked=True)
            step = make_train_step(cfg, comm, mesh=mesh, lr=1e-2,
                                   remat=False, chunk=16)
            if strategy in ("dpsgd", "adpsgd"):
                mix = gossip_operands(
                    fabric, 0,
                    staleness=1 if strategy == "adpsgd" else None,
                    max_staleness=comm.max_staleness)
                jitted = jax.jit(step,
                                 in_shardings=(s_shard, b_shard, None,
                                               None))
                new_state, metrics = jitted(state, batch, jnp.int32(0),
                                            mix)
            else:
                jitted = jax.jit(step,
                                 in_shardings=(s_shard, b_shard, None))
                new_state, metrics = jitted(state, batch, jnp.int32(0))
            loss = float(metrics["loss"])
        assert np.isfinite(loss), (strategy, loss)
        losses[strategy] = loss
        states[strategy] = jax.device_get(new_state)
        print(f"dist {strategy}: loss={loss:.4f} OK", flush=True)

    # --- cross-backend check: dist gaia == hand-computed reference ---
    # recompute per-pod grads with plain jax (no mesh) and apply Algorithm 1
    from repro.models.model import loss_fn

    def pod_loss(p, b):
        l, _ = loss_fn(p, cfg, b, remat=False, chunk=16)
        return l
    g0 = jax.grad(pod_loss)(params, {"tokens": tokens[0], "labels": labels[0]})
    g1 = jax.grad(pod_loss)(params, {"tokens": tokens[1], "labels": labels[1]})
    tmap = jax.tree_util.tree_map
    lr, t0 = 1e-2, 0.01
    vel = tmap(lambda a, b: jnp.stack([-lr * a.astype(jnp.float32),
                                       -lr * b.astype(jnp.float32)]), g0, g1)
    p_stack = tmap(lambda l: jnp.stack([l.astype(jnp.float32)] * 2), params)
    p_local = tmap(lambda w, u: w + u, p_stack, vel)
    acc = vel

    def exchange(w, v):
        mask = (jnp.abs(v) > t0 * jnp.abs(w)).astype(v.dtype)
        sel = v * mask
        total = jnp.sum(sel, axis=0, keepdims=True)
        return w + (total - sel), v * (1 - mask)
    pairs = tmap(exchange, p_local, acc)
    p_ref = tmap(lambda pr: pr[0], pairs,
                 is_leaf=lambda x: isinstance(x, tuple))

    got = states["gaia"]["params"]
    ref_leaves = jax.tree_util.tree_leaves(p_ref)
    got_leaves = jax.tree_util.tree_leaves(got)
    worst = 0.0
    for r, g in zip(ref_leaves, got_leaves):
        diff = np.max(np.abs(np.asarray(r, np.float32)
                             - np.asarray(g, np.float32)))
        scale = np.max(np.abs(np.asarray(r, np.float32))) + 1e-6
        worst = max(worst, float(diff / scale))
    assert worst < 5e-2, f"dist vs ref gaia mismatch: {worst}"
    print(f"gaia dist==ref OK (worst rel diff {worst:.2e})", flush=True)

    # --- serve step on the mesh ---
    with mesh, activation_sharding(mesh):
        p_shard = param_shardings(jax.eval_shape(lambda: params), mesh)
        cache = init_cache(cfg, 8, 64)
        c_shard = cache_shardings(jax.eval_shape(lambda: cache), mesh,
                                  batch_sharded=True)
        sbatch = {"token": jnp.zeros((8,), jnp.int32),
                  "t": jnp.zeros((8,), jnp.int32)}
        b_shard = batch_shardings(sbatch, mesh, pod_stacked=False)
        serve = jax.jit(make_serve_step(cfg),
                        in_shardings=(p_shard, c_shard, b_shard))
        tok, _ = serve(params, cache, sbatch)
        assert tok.shape == (8,)
    print("serve OK", flush=True)
    print("ALL_DIST_OK")


if __name__ == "__main__":
    main()
