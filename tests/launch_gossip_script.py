"""Subprocess helper for test_launch_gossip.py — needs its own process so
xla_force_host_platform_device_count doesn't leak into other tests.

Launch-vs-core equivalence: steps the SPMD backend (repro.launch.steps,
(4, 2, 1) pod/data/model mesh) and the simulation backend
(repro.core.algorithms) on *identical* inputs — same reduced transformer,
same per-node batches, same hyper-parameters — and compares the parameter
updates strategy by strategy:

  bsp / fedavg / dpsgd / adpsgd   smooth updates: max rel err < 1e-3
  gaia / dgc                      threshold-masked updates: a handful of
                                  entries sitting within float noise of
                                  the significance/top-k boundary may
                                  flip, so assert the *fraction* of
                                  mismatched entries instead (still
                                  catches a wrong threshold or a missing
                                  clip, which mismatch a large fraction)

plus the pod-gossip contracts:
  - adpsgd at staleness 0 is bit-for-bit dpsgd,
  - one compilation across schedule rotation AND staleness moves,
  - the exchange lowers to collective-permutes on the pod axis only.

Prints one EQ_OK <strategy> marker per passing strategy and
ALL_LAUNCH_GOSSIP_OK at the end.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import CommConfig, FabricConfig
from repro.configs.registry import get_config
from repro.core.algorithms.adpsgd import ADPSGD
from repro.core.algorithms.base import ModelFns
from repro.core.algorithms.bsp import BSP
from repro.core.algorithms.dgc import DGC
from repro.core.algorithms.dpsgd import DPSGD
from repro.core.algorithms.fedavg import FedAvg
from repro.core.algorithms.gaia import Gaia
from repro.launch import hlo_analysis
from repro.launch.sharding import batch_shardings, train_state_shardings
from repro.launch.steps import (gossip_operands, make_train_state,
                                make_train_step, train_state_shape)
from repro.models.model import init_model, loss_fn
from repro.topology.graphs import constant_schedule, ring, \
    random_matching_schedule

K = 4                       # pods == simulation nodes
B, T = 2, 16
LR0 = 2e-2                  # reference lr for Gaia's threshold decay
LRS = [2e-2, 1e-2, 5e-3, 2.5e-3]
MOM, WD = 0.9, 5e-4
CHUNK = 16

tmap = jax.tree_util.tree_map
leaves = jax.tree_util.tree_leaves


def stacked(tree):
    return tmap(lambda l: jnp.broadcast_to(l, (K,) + l.shape), tree)


def update_rel_errs(launch_p, core_p, p0):
    """Per-entry |launch_update - core_update| / max|core_update| (per
    leaf), flattened over the whole tree."""
    rels = []
    for g, r, p in zip(leaves(launch_p), leaves(core_p), leaves(p0)):
        ug = np.asarray(g, np.float64) - np.asarray(p, np.float64)
        ur = np.asarray(r, np.float64) - np.asarray(p, np.float64)
        scale = np.max(np.abs(ur)) + 1e-12
        rels.append((np.abs(ug - ur) / scale).ravel())
    return np.concatenate(rels)


def main():
    mesh = jax.make_mesh((K, 2, 1), ("pod", "data", "model"))
    cfg = get_config("qwen3-0.6b").reduced()
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    p0_stack = stacked(params)
    tokens = jax.random.randint(key, (K, B, T), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.PRNGKey(1), (K, B, T), 0,
                                cfg.vocab)
    batch = {"tokens": tokens, "labels": labels}

    # --- core-side model adapter: the same transformer loss ---
    def loss_and_grad(p, ms, b):
        loss, grads = jax.value_and_grad(
            lambda q: loss_fn(q, cfg, b, remat=False, chunk=CHUNK)[0])(p)
        return loss, grads, ms
    fns = ModelFns(loss_and_grad=loss_and_grad)
    mstate = {}

    # a clip that is ACTIVE from step 0, so a launch path that forgot to
    # clip cannot pass the dgc comparison
    g0 = jax.grad(lambda q: loss_fn(
        q, cfg, {"tokens": tokens[0], "labels": labels[0]},
        remat=False, chunk=CHUNK)[0])(params)
    gnorm = float(jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2)
                               for l in leaves(g0))))
    clip = 0.6 * gnorm
    print(f"grad norm {gnorm:.3f} -> dgc clip {clip:.3f}", flush=True)

    def run_launch(comm, n_steps, *, lr0=None, mix_for=None,
                   sparsity_for=None, count=None):
        """Step the SPMD backend; returns the final state."""
        step = make_train_step(cfg, comm, mesh=mesh, lr=LRS[0], lr0=lr0,
                               momentum=MOM, weight_decay=WD,
                               remat=False, chunk=CHUNK)

        def counting(*a, **kw):
            if count is not None:
                count.append(1)
            return step(*a, **kw)
        jitted = jax.jit(counting)
        state = jax.device_put(
            make_train_state(params, comm, K),
            train_state_shardings(train_state_shape(cfg, comm, K), mesh))
        b = jax.device_put(batch, batch_shardings(
            jax.eval_shape(lambda: batch), mesh, pod_stacked=True))
        with mesh:
            for t in range(n_steps):
                kw = {"lr": jnp.asarray(LRS[t], jnp.float32)}
                if mix_for is not None:
                    kw["mix"] = mix_for(t)
                if sparsity_for is not None:
                    kw["sparsity"] = jnp.asarray(sparsity_for(t),
                                                 jnp.float32)
                state, metrics = jitted(state, b, jnp.int32(t), **kw)
            assert np.isfinite(float(metrics["loss"])), comm.strategy
        return jax.device_get(state)

    def run_core(algo, n_steps, *, kw_for=None, on_step=None):
        state = algo.init(params, mstate)
        for t in range(n_steps):
            if on_step is not None:
                on_step(algo, t)
            kw = kw_for(t) if kw_for is not None else {}
            state, metrics = algo.step(state, batch,
                                       jnp.asarray(LRS[t], jnp.float32),
                                       jnp.asarray(t, jnp.int32), **kw)
        # non-vacuity: the strategy actually exchanged something, so the
        # equivalence below compares real cross-node traffic
        assert float(metrics["comm_floats"]) > 0, algo.name
        return jax.device_get(state)

    def check(name, launch_state, core_params_stacked, *,
              frac_tol=None):
        rels = update_rel_errs(launch_state["params"],
                               core_params_stacked, p0_stack)
        if frac_tol is None:
            assert rels.max() < 1e-3, (name, rels.max())
            print(f"EQ_OK {name} (max rel {rels.max():.2e})", flush=True)
        else:
            # threshold-masked strategies: entries whose |v| sits inside
            # the quantization band of the two threshold algorithms
            # (256-bin histogram vs exact quantile) legitimately flip,
            # but each such entry's value is ~the threshold, far below
            # the largest exchanged update — so bound the fraction of
            # *large* per-entry errors plus the mean error.  A wrong
            # threshold scale or a missing clip moves a large fraction
            # of entries by a large amount and still fails both.
            for bar in (1e-3, 1e-2, 5e-2):
                print(f"  {name}: frac(rel>{bar:g}) = "
                      f"{float(np.mean(rels > bar)):.4f}", flush=True)
            frac = float(np.mean(rels > 5e-2))
            assert frac < frac_tol, (name, frac, frac_tol)
            assert float(np.mean(rels)) < 1e-2, (name, np.mean(rels))
            print(f"EQ_OK {name} (mismatch frac {frac:.4f}, "
                  f"mean rel {np.mean(rels):.2e})", flush=True)

    # ---------------- bsp ----------------
    st = run_launch(CommConfig(strategy="bsp"), 3)
    core = run_core(BSP(fns, K, momentum=MOM, weight_decay=WD), 3)
    check("bsp", st, stacked(core["params"]))

    # ---------------- gaia (threshold decays with lr) ----------------
    st = run_launch(CommConfig(strategy="gaia", gaia_t0=0.05), 3, lr0=LR0)
    core = run_core(Gaia(fns, K, momentum=MOM, weight_decay=WD,
                         t0=0.05, lr0=LR0), 3)
    check("gaia", st, core["params"], frac_tol=0.02)

    # ---------------- fedavg ----------------
    st = run_launch(CommConfig(strategy="fedavg", iter_local=2), 4)
    core = run_core(FedAvg(fns, K, momentum=MOM, weight_decay=WD,
                           iter_local=2), 4)
    check("fedavg", st, core["params"])

    # ---------------- dgc (clip + runtime warm-up sparsity) ----------
    # late-warm-up sparsities: at 0.75 the 256-bin histogram threshold
    # and the exact quantile disagree by up to a bin *inside the dense
    # bulk* of |v| and the backends legitimately select different
    # slivers; at the paper's operating sparsities the threshold sits in
    # the sparse tail and the two agree on all but a handful of entries
    warm = [0.996, 0.996, 0.999, 0.999]
    st = run_launch(CommConfig(strategy="dgc", dgc_clip=clip), 4,
                    sparsity_for=lambda t: warm[t])
    core = run_core(DGC(fns, K, momentum=MOM, weight_decay=WD, clip=clip),
                    4, kw_for=lambda t: {
                        "sparsity": jnp.asarray(warm[t], jnp.float32)})
    check("dgc", st, stacked(core["params"]), frac_tol=0.05)

    # ---------------- dpsgd on a rotating schedule ----------------
    sched_rm = random_matching_schedule(K, seed=1)
    traces = []
    st_dpsgd = run_launch(
        CommConfig(strategy="dpsgd",
                   fabric=FabricConfig(topology="random-matching")), 4,
        mix_for=lambda t: gossip_operands(sched_rm, t), count=traces)
    assert len(traces) == 1, f"dpsgd retraced across rotation: {traces}"
    core = run_core(DPSGD(fns, K, topology=sched_rm, momentum=MOM,
                          weight_decay=WD), 4)
    check("dpsgd", st_dpsgd, core["params"])
    print("COMPILE_ONCE_OK dpsgd rotation", flush=True)

    # ---------------- adpsgd: stale gossip + staleness move ----------
    sched_ring = constant_schedule(ring(K))
    stale_of = lambda t: 2 if t < 2 else 1
    traces = []
    st = run_launch(
        CommConfig(strategy="adpsgd", fabric=FabricConfig(topology="ring"),
                   max_staleness=2), 4,
        mix_for=lambda t: gossip_operands(sched_ring, t,
                                          staleness=stale_of(t),
                                          max_staleness=2),
        count=traces)
    assert len(traces) == 1, f"adpsgd retraced on staleness move: {traces}"
    algo = ADPSGD(fns, K, topology=sched_ring, momentum=MOM,
                  weight_decay=WD, max_staleness=2, staleness=2)
    core = run_core(algo, 4, on_step=lambda a, t: a.set_staleness(
        stale_of(t)))
    check("adpsgd", st, core["params"])
    print("COMPILE_ONCE_OK adpsgd staleness move", flush=True)

    # ---------------- adpsgd @ staleness 0 == dpsgd, bit for bit -----
    st0 = run_launch(
        CommConfig(strategy="adpsgd",
                   fabric=FabricConfig(topology="random-matching"),
                   max_staleness=2), 4,
        mix_for=lambda t: gossip_operands(sched_rm, t, staleness=0,
                                          max_staleness=2))
    for a, b in zip(leaves(st0["params"]), leaves(st_dpsgd["params"])):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            "adpsgd(staleness=0) diverged bitwise from dpsgd"
    print("BITWISE_OK adpsgd0==dpsgd", flush=True)

    # ---------------- exchange lowers to pod-axis collectives --------
    comm = CommConfig(strategy="dpsgd", fabric=FabricConfig(topology="ring"))
    step = make_train_step(cfg, comm, mesh=mesh, lr=LRS[0], momentum=MOM,
                           weight_decay=WD, remat=False, chunk=CHUNK)
    state_shape = train_state_shape(cfg, comm, K)
    st_sh = train_state_shardings(state_shape, mesh)
    b_sh = batch_shardings(jax.eval_shape(lambda: batch), mesh,
                           pod_stacked=True)
    SDS = jax.ShapeDtypeStruct
    with mesh:
        jitted = jax.jit(step, in_shardings=(st_sh, b_sh, None, None))
        args = (tmap(lambda l, s: SDS(l.shape, l.dtype, sharding=s),
                     state_shape, st_sh),
                tmap(lambda l, s: SDS(l.shape, l.dtype, sharding=s),
                     jax.eval_shape(lambda: batch), b_sh),
                SDS((), jnp.int32),
                gossip_operands(constant_schedule(ring(K)), 0))
        hlo = jitted.lower(*args).compile().as_text()
    rep = hlo_analysis.pod_exchange_report(hlo, devices_per_pod=2)
    print(f"pod exchange: permute cross {rep.permute_cross_bytes:.0f}B "
          f"local {rep.permute_local_bytes:.0f}B, reduce cross "
          f"{rep.reduce_cross_bytes:.0f}B local "
          f"{rep.reduce_local_bytes:.0f}B, unparsed {rep.unparsed}",
          flush=True)
    assert rep.pod_axis_only, "cross-pod permute left the pod axis"
    assert rep.permute_cross_bytes > 0, "gossip exchange vanished"
    assert rep.reduce_cross_bytes < rep.permute_cross_bytes, \
        "cross-pod reduces dominate: exchange fell back to reductions"
    print("PODAXIS_OK", flush=True)

    print("ALL_LAUNCH_GOSSIP_OK")


if __name__ == "__main__":
    main()
