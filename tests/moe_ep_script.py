"""Subprocess: EP MoE == dense MoE when capacity is generous (no drops)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
import jax
import jax.numpy as jnp
from repro.configs.base import MoEConfig
from repro.models import moe as moe_mod
from repro.models import moe_ep

mesh = jax.make_mesh((4, 2), ("data", "model"))
m = MoEConfig(n_experts=4, n_shared=0, top_k=2, d_ff_expert=16,
              capacity_factor=16.0)   # generous: nothing drops either way
d = 8
p = moe_mod.init_moe(jax.random.PRNGKey(0), m, d, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, d))
y_dense, aux_dense = moe_mod.moe_apply(p, m, x)
with mesh:
    y_ep, aux_ep = jax.jit(lambda p, x: moe_ep.moe_apply_ep(p, m, x, mesh))(p, x)
err = float(jnp.max(jnp.abs(y_dense - y_ep)))
print("max err", err, "aux", float(aux_dense), float(aux_ep))
assert err < 1e-4, err
# aux estimators differ (global-mean vs mean of per-shard products) — both
# positive load-balance signals of the same scale
assert 0 < float(aux_ep) < 10 * float(aux_dense) + 1e-3
# gradients flow
def loss(p):
    with mesh:
        y, aux = moe_ep.moe_apply_ep(p, m, x, mesh)
    return jnp.sum(y ** 2) + aux
g = jax.jit(jax.grad(loss))(p)
assert float(jnp.abs(g["w_gate"]).sum()) > 0
assert float(jnp.abs(g["router"]["w"]).sum()) > 0
print("EP_MOE_OK")
