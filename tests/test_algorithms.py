"""Semantics of the four decentralized algorithms, validated step-by-step on
a tiny quadratic model where every quantity is analytically checkable."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.algorithms.base import ModelFns, tree_size
from repro.core.algorithms.bsp import BSP
from repro.core.algorithms.dgc import DGC, warmup_sparsity
from repro.core.algorithms.fedavg import FedAvg
from repro.core.algorithms.gaia import Gaia

K = 3
DIM = 8


def make_quadratic_fns():
    """loss_k(w) = 0.5 * ||w - target||^2 with per-node targets in batch."""
    def loss_and_grad(params, mstate, batch):
        w = params["w"]
        diff = w - batch["target"]
        loss = 0.5 * jnp.sum(diff ** 2)
        return loss, {"w": diff}, mstate
    return ModelFns(loss_and_grad=loss_and_grad)


def make_batch(targets):
    return {"target": jnp.asarray(targets)}


@pytest.fixture
def setup():
    fns = make_quadratic_fns()
    params = {"w": jnp.zeros((DIM,))}
    mstate = {"dummy": jnp.zeros((1,))}
    targets = np.stack([np.full(DIM, float(k + 1)) for k in range(K)])
    return fns, params, mstate, targets


def test_bsp_equals_centralized_sgd(setup):
    fns, params, mstate, targets = setup
    algo = BSP(fns, K, momentum=0.0, weight_decay=0.0)
    state = algo.init(params, mstate)
    lr = 0.1
    w = np.zeros(DIM)
    for t in range(5):
        state, m = algo.step(state, make_batch(targets),
                             jnp.float32(lr), jnp.int32(t))
        g = np.mean([w - targets[k] for k in range(K)], axis=0)
        w = w - lr * g
        np.testing.assert_allclose(np.asarray(state["params"]["w"]), w,
                                   rtol=1e-5)
    assert float(m["comm_floats"]) == tree_size(params)


def test_gaia_threshold_zero_equals_bsp_sum(setup):
    """With T=0 every update is significant: all nodes apply everyone's
    update each step -> all replicas identical."""
    fns, params, mstate, targets = setup
    algo = Gaia(fns, K, momentum=0.0, t0=0.0)
    state = algo.init(params, mstate)
    for t in range(3):
        state, m = algo.step(state, make_batch(targets),
                             jnp.float32(0.05), jnp.int32(t))
    w = np.asarray(state["params"]["w"])
    for k in range(1, K):
        np.testing.assert_allclose(w[k], w[0], rtol=1e-5)
    # acc fully cleared when everything is significant
    assert float(jnp.abs(state["acc"]["w"]).max()) < 1e-7


def test_gaia_huge_threshold_is_fully_local(setup):
    """With T=inf nothing is exchanged: each node converges to its own
    target (the §4.3 specialization failure mode, distilled)."""
    fns, params, mstate, targets = setup
    algo = Gaia(fns, K, momentum=0.0, t0=1e9)
    state = algo.init(params, mstate)
    for t in range(200):
        state, m = algo.step(state, make_batch(targets),
                             jnp.float32(0.1), jnp.int32(t))
    w = np.asarray(state["params"]["w"])
    for k in range(K):
        np.testing.assert_allclose(w[k], targets[k], atol=1e-3)
    assert float(m["comm_floats"]) == 0.0


def test_fedavg_syncs_only_at_interval(setup):
    fns, params, mstate, targets = setup
    algo = FedAvg(fns, K, momentum=0.0, iter_local=5)
    state = algo.init(params, mstate)
    comm = []
    for t in range(10):
        state, m = algo.step(state, make_batch(targets),
                             jnp.float32(0.1), jnp.int32(t))
        comm.append(float(m["comm_floats"]))
        w = np.asarray(state["params"]["w"])
        if (t % 5) == 4:                      # just synced: replicas equal
            np.testing.assert_allclose(w[0], w[1], rtol=1e-6)
    assert sum(c > 0 for c in comm) == 2      # steps 4 and 9


def test_fedavg_local_models_diverge_between_syncs(setup):
    fns, params, mstate, targets = setup
    algo = FedAvg(fns, K, momentum=0.0, iter_local=50)
    state = algo.init(params, mstate)
    for t in range(3):
        state, m = algo.step(state, make_batch(targets),
                             jnp.float32(0.1), jnp.int32(t))
    w = np.asarray(state["params"]["w"])
    assert not np.allclose(w[0], w[1])


def test_dgc_exchanges_only_top_fraction(setup):
    fns, params, mstate, targets = setup
    # make one coordinate's gradient dominant on each node
    targets = np.zeros((K, DIM))
    targets[:, 0] = 100.0
    algo = DGC(fns, K, momentum=0.0, clip=1e9, sparsity=0.875)  # keep 1/8
    state = algo.init(params, mstate)
    state, m = algo.step(state, make_batch(targets),
                         jnp.float32(0.1), jnp.int32(0))
    w = np.asarray(state["params"]["w"])
    # only coordinate 0 was exchanged and applied
    assert abs(w[0]) > 0
    np.testing.assert_allclose(w[1:], 0.0, atol=1e-7)
    # residual keeps the unexchanged mass
    acc = np.asarray(state["acc"]["w"])
    assert np.all(acc[:, 0] == 0.0)


def test_dgc_momentum_factor_masking(setup):
    fns, params, mstate, targets = setup
    targets = np.zeros((K, DIM))
    targets[:, 0] = 100.0
    algo = DGC(fns, K, momentum=0.9, clip=1e9, sparsity=0.875)
    state = algo.init(params, mstate)
    state, _ = algo.step(state, make_batch(targets),
                         jnp.float32(0.1), jnp.int32(0))
    vel = np.asarray(state["vel"]["w"])
    assert np.all(vel[:, 0] == 0.0)           # cleared where exchanged


def test_warmup_schedule():
    assert warmup_sparsity(0, 4) == 0.75
    assert warmup_sparsity(4, 4) == 0.9375
    assert warmup_sparsity(100, 4) == 0.999


def test_comm_accounting_gaia_decreases_with_threshold(setup):
    fns, params, mstate, targets = setup
    comm = {}
    for t0 in (0.0, 0.5, 1e9):
        algo = Gaia(fns, K, momentum=0.0, t0=t0)
        state = algo.init(params, mstate)
        total = 0.0
        for t in range(5):
            state, m = algo.step(state, make_batch(targets),
                                 jnp.float32(0.05), jnp.int32(t))
            total += float(m["comm_floats"])
        comm[t0] = total
    assert comm[0.0] >= comm[0.5] >= comm[1e9]
    assert comm[1e9] == 0.0
