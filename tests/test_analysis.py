"""Static-analysis gate: planted violations are caught with the right
rule id (unkeyed np.random draw -> RA101, half-registered kernel op ->
PA301-304, untested rule id -> PA305, f32-widened bf16 exchange ->
GA202, off-axis permute -> GA201, host callback -> GA203, donation
drift -> GA204, plus the jaxpr-level JA400-405 twins caught before
lowering), suppression comments and the baseline grandfather findings,
and the real repo is clean under every pass."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.analysis import (ALL_RULES, apply_baseline, astlint,
                            audit_hlo, audit_jaxpr, check_parity,
                            lint_file, load_baseline, write_baseline)
from repro.analysis.base import Finding, is_suppressed

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def lint_snippet(tmp_path, code, name="planted.py"):
    p = tmp_path / name
    p.write_text(textwrap.dedent(code))
    return lint_file(str(p), str(tmp_path))


def rules_of(findings):
    return sorted(f.rule for f in findings)


# ---------------------------------------------------------------- RA10x

class TestAstLint:
    def test_unkeyed_np_random_draw_is_ra101(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            import numpy as np
            x = np.random.uniform(size=8)
        """)
        assert rules_of(fs) == ["RA101"]
        assert "np.random.uniform" in fs[0].message

    def test_np_random_seed_is_ra101(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            import numpy as np
            np.random.seed(0)
        """)
        assert rules_of(fs) == ["RA101"]

    def test_argless_default_rng_is_ra101(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            import numpy as np
            from numpy.random import default_rng
            a = np.random.default_rng()
            b = default_rng()
        """)
        assert rules_of(fs) == ["RA101", "RA101"]

    def test_keyed_rng_constructions_pass(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            import numpy as np
            rng = np.random.default_rng(7)
            gen = np.random.Generator(np.random.PCG64(3))
            x = rng.uniform(size=8)
        """)
        assert fs == []

    def test_item_in_jitted_fn_is_ra102(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            import jax

            @jax.jit
            def step(x):
                return x.sum().item()
        """)
        assert rules_of(fs) == ["RA102"]

    def test_host_cast_of_param_in_jit_is_ra102(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            import jax
            import numpy as np

            @jax.jit
            def step(x, n):
                return x * float(n) + np.asarray(x)
        """)
        assert rules_of(fs) == ["RA102", "RA102"]

    def test_host_cast_outside_jit_passes(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            def setup(x):
                return float(x)
        """)
        assert fs == []

    def test_jit_lambda_body_linted(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            import jax
            f = jax.jit(lambda x: x.mean().item())
        """)
        assert rules_of(fs) == ["RA102"]

    def test_jit_call_in_loop_is_ra103(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            import jax
            for lr in (0.1, 0.2):
                step = jax.jit(lambda x: x * lr)
        """)
        assert "RA103" in rules_of(fs)

    def test_jit_def_in_loop_is_ra103(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            import jax
            while True:
                @jax.jit
                def step(x):
                    return x
        """)
        assert rules_of(fs) == ["RA103"]

    def test_nested_def_resets_loop_context(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            import jax
            for _ in range(3):
                def make():
                    return jax.jit(lambda x: x)
        """)
        assert fs == []

    def test_broad_except_is_ra104(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            try:
                x = 1
            except Exception:
                pass
            try:
                y = 2
            except (ValueError, BaseException):
                pass
            try:
                z = 3
            except:
                pass
        """)
        assert rules_of(fs) == ["RA104", "RA104", "RA104"]

    def test_concrete_except_passes(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            try:
                x = 1
            except (OSError, ValueError):
                pass
        """)
        assert fs == []

    def test_syntax_error_is_ra100(self, tmp_path):
        fs = lint_snippet(tmp_path, "def broken(:\n")
        assert rules_of(fs) == ["RA100"]


class TestSuppression:
    def test_inline_allow_silences_rule(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            try:
                x = 1
            except Exception:  # repro-allow: RA104 — trial sweep
                pass
        """)
        assert fs == []

    def test_family_wildcard(self):
        assert is_suppressed("RA104", "pass  # repro-allow: RA*")
        assert not is_suppressed("GA201", "pass  # repro-allow: RA*")

    def test_allow_is_per_rule(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            import numpy as np
            x = np.random.uniform()  # repro-allow: RA104
        """)
        assert rules_of(fs) == ["RA101"]


class TestBaseline:
    def test_grandfather_and_expire(self, tmp_path):
        f1 = Finding(rule="RA104", path="a.py", line=3, message="m",
                     source="except Exception:")
        f2 = Finding(rule="RA101", path="b.py", line=9, message="m",
                     source="np.random.seed(0)")
        bl = tmp_path / "baseline.json"
        write_baseline(str(bl), [f1])
        fps = load_baseline(str(bl))
        apply_baseline([f1, f2], fps)
        assert f1.baselined and not f2.baselined
        # fingerprints are line-free: moving the finding keeps it known
        moved = Finding(rule="RA104", path="a.py", line=77, message="m",
                        source="except Exception:")
        apply_baseline([moved], fps)
        assert moved.baselined
        # but editing the flagged line expires the grandfather
        edited = Finding(rule="RA104", path="a.py", line=3, message="m",
                         source="except ValueError:")
        apply_baseline([edited], fps)
        assert not edited.baselined

    def test_stale_fingerprints_returned(self, tmp_path):
        f1 = Finding(rule="RA104", path="a.py", line=3, message="m",
                     source="except Exception:")
        f2 = Finding(rule="RA101", path="b.py", line=9, message="m",
                     source="np.random.seed(0)")
        bl = tmp_path / "baseline.json"
        write_baseline(str(bl), [f1, f2])
        fps = load_baseline(str(bl))
        # f2's flagged line was since fixed: its fingerprint is stale
        stale = apply_baseline([f1], fps)
        assert stale == [f2.fingerprint]
        assert f1.baselined
        # nothing stale when every entry still matches
        assert apply_baseline([f1, f2], fps) == []


# ---------------------------------------------------------------- PA30x

OPS_TEMPLATE = '''
import jax.numpy as jnp
from repro.kernels import ref as _ref


def _decide(op, *a, **k):
    return "oracle"


def wired_op(x):
    if _decide("wired_op", x.size) == "oracle":
        return _ref.wired_op_ref(x)
    return x


def half_op(x):
    return jnp.tanh(x)
'''

REF_TEMPLATE = '''
def wired_op_ref(x):
    return x
'''


def plant_tree(tmp_path, *, bench="ops.wired_op",
               test_body="wired_op"):
    """A minimal repo layout with one fully wired op and one half op."""
    k = tmp_path / "src" / "repro" / "kernels"
    k.mkdir(parents=True)
    (k / "ops.py").write_text(OPS_TEMPLATE)
    (k / "ref.py").write_text(REF_TEMPLATE)
    b = tmp_path / "benchmarks"
    b.mkdir()
    (b / "kernels_bench.py").write_text(f"ROWS = ['{bench}']\n")
    t = tmp_path / "tests"
    t.mkdir()
    (t / "test_planted.py").write_text(f"# exercises {test_body}\n")
    return str(tmp_path)


class TestParity:
    def test_half_registered_op_fails_all_four_legs(self, tmp_path):
        root = plant_tree(tmp_path)
        fs = check_parity(root)
        by_op = {}
        for f in fs:
            by_op.setdefault(f.source, []).append(f.rule)
        # wired_op PA304 passes because "wired_op" appears in the test;
        # half_op fails every leg except PA304 ("half_op" shares no
        # mention) — plant a test tree where it is mentioned nowhere
        assert "wired_op" not in by_op
        assert sorted(by_op["half_op"]) == ["PA301", "PA302", "PA303",
                                           "PA304"]

    def test_bench_row_and_test_reference_checked(self, tmp_path):
        root = plant_tree(tmp_path, bench="nothing",
                          test_body="half_op only")
        fs = check_parity(root)
        wired = sorted(f.rule for f in fs if f.source == "wired_op")
        assert wired == ["PA303", "PA304"]

    def test_missing_ops_module_is_single_finding(self, tmp_path):
        fs = check_parity(str(tmp_path))
        assert rules_of(fs) == ["PA301"]
        assert "not found" in fs[0].message

    def test_helper_indirection_resolves(self, tmp_path):
        """``_oracle = jit(_ref.x_ref)`` one level away still counts."""
        root = plant_tree(tmp_path)
        ops = (tmp_path / "src" / "repro" / "kernels" / "ops.py")
        ops.write_text('''
from repro.kernels import ref as _ref

_oracle = staticmethod(_ref.wired_op_ref)


def _decide(op):
    return "oracle"


def wired_op(x):
    _decide("wired_op")
    return _oracle(x)
''')
        fs = check_parity(root)
        assert not any(f.rule == "PA301" and f.source == "wired_op"
                       for f in fs)

    def test_untested_analysis_rule_is_pa305(self, tmp_path):
        root = plant_tree(tmp_path)
        (tmp_path / "tests" / "test_analysis.py").write_text(
            "# this planted gate only ever mentions RA101\n")
        pa305 = {f.source for f in check_parity(root)
                 if f.rule == "PA305"}
        # every registered rule the planted file omits is flagged...
        assert {"GA202", "JA402", "PA305"} <= pa305
        # ...but the one it mentions is not
        assert "RA101" not in pa305

    def test_pa305_skipped_without_analysis_tests(self, tmp_path):
        # the default planted tree has no tests/test_analysis.py: the
        # meta-rule must not red-herring a partial layout
        root = plant_tree(tmp_path)
        assert not any(f.rule == "PA305" for f in check_parity(root))


# ---------------------------------------------------------------- GA20x

HLO_HEAD = ("HloModule planted, input_output_alias={ {0}: (0, {}, "
            "may-alias) }\n\n")

HLO_GOOD = HLO_HEAD + """\
ENTRY %main (p0: bf16[8,8]) -> (bf16[8,8]) {
  %p0 = bf16[8,8]{1,0} parameter(0)
  %cp = bf16[8,8]{1,0} collective-permute(%p0), source_target_pairs={{0,2},{2,0},{1,3},{3,1}}
  ROOT %out = (bf16[8,8]{1,0}) tuple(%cp)
}
"""


def planted_hlo(*, dtype="bf16", pairs="{{0,2},{2,0},{1,3},{3,1}}",
                extra="", alias=True, out_dtype=None):
    out_dtype = out_dtype or dtype
    head = HLO_HEAD if alias else "HloModule planted\n\n"
    return head + f"""\
ENTRY %main (p0: bf16[8,8]) -> ({out_dtype}[8,8]) {{
  %p0 = bf16[8,8]{{1,0}} parameter(0)
  %cv = {dtype}[8,8]{{1,0}} convert(%p0)
  %cp = {dtype}[8,8]{{1,0}} collective-permute(%cv), source_target_pairs={pairs}
{extra}  ROOT %out = ({out_dtype}[8,8]{{1,0}}) tuple(%cp)
}}
"""


class TestGraphAudit:
    def test_clean_gossip_step_passes(self):
        ga = audit_hlo(HLO_GOOD, devices_per_pod=2, expect_donation=True)
        assert ga.ok, [f.format() for f in ga.findings]
        assert ga.expected_wire_dtype == "bf16"
        assert ga.pod_exchange.pod_axis_only
        assert ga.donated_pairs == 1

    def test_widened_wire_dtype_is_ga202(self):
        # bf16 leaf, f32 on the wire: the adpsgd payload bug from PR 4
        ga = audit_hlo(planted_hlo(dtype="f32", out_dtype="f32",
                                   alias=False),
                       devices_per_pod=2)
        assert [f.rule for f in ga.findings] == ["GA202"]
        assert "bf16" in ga.findings[0].message
        assert ga.cross_pod_dtype_bytes == {"f32": 256.0}

    def test_off_pod_axis_permute_is_ga201(self):
        # 0->3 crosses pods AND changes the intra-pod coordinate
        ga = audit_hlo(planted_hlo(pairs="{{0,3},{3,0}}"),
                       devices_per_pod=2)
        assert "GA201" in [f.rule for f in ga.findings]

    def test_host_callback_is_ga203(self):
        extra = ('  %cb = bf16[8,8]{1,0} custom-call(%p0), '
                 'custom_call_target="xla_python_cpu_callback"\n')
        ga = audit_hlo(planted_hlo(extra=extra), devices_per_pod=2)
        assert "GA203" in [f.rule for f in ga.findings]
        assert ga.host_callbacks == ["xla_python_cpu_callback"]

    def test_infeed_is_ga203(self):
        extra = "  %inf = ((bf16[8,8]{1,0}), token[]) infeed(%p0)\n"
        ga = audit_hlo(planted_hlo(extra=extra), devices_per_pod=2)
        assert "GA203" in [f.rule for f in ga.findings]

    def test_missing_alias_map_is_ga204_only_when_expected(self):
        ga = audit_hlo(planted_hlo(alias=False), devices_per_pod=2,
                       expect_donation=True)
        assert [f.rule for f in ga.findings] == ["GA204"]
        ga2 = audit_hlo(planted_hlo(alias=False), devices_per_pod=2)
        assert ga2.ok

    def test_output_type_drift_is_ga204(self):
        # donated param is bf16 but the aliased output comes back f32:
        # step t's output cannot feed step t+1 without a realloc
        ga = audit_hlo(planted_hlo(dtype="f32", out_dtype="f32",
                                   pairs="{{0,1},{1,0}}"),
                       devices_per_pod=4)  # single pod: no GA202
        assert [f.rule for f in ga.findings] == ["GA204"]
        assert "drift" in ga.findings[0].message

    def test_unclassifiable_collective_is_ga205(self):
        extra = ("  %s = (bf16[8,8]{1,0}, u32[], token[]) send(%p0), "
                 "channel_id=1\n")
        ga = audit_hlo(planted_hlo(extra=extra), devices_per_pod=2)
        assert "GA205" in [f.rule for f in ga.findings]

    def test_to_json_shape(self):
        j = audit_hlo(HLO_GOOD, devices_per_pod=2).to_json()
        assert j["ok"] and j["pod_exchange"]["devices_per_pod"] == 2
        assert set(j) >= {"tag", "findings", "expected_wire_dtype",
                          "host_callbacks", "donated_pairs"}


# ---------------------------------------------------------------- JA4xx

POD_ENV = [("pod", 2)]
PERM = [(0, 1), (1, 0)]


def jaxpr_of(fn, *avals, axis_env=None):
    return jax.make_jaxpr(fn, axis_env=axis_env or POD_ENV)(*avals)


def aval(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


class TestJaxprAudit:
    def test_clean_gossip_like_step_passes(self):
        def step(x):
            return lax.ppermute(x, "pod", PERM)
        a = audit_jaxpr(jaxpr_of(step, aval(8, 8, dtype=jnp.bfloat16)))
        assert a.ok, [f.format() for f in a.findings]
        assert a.n_collectives == 1 and a.collective_axes == ["pod"]

    def test_debug_print_is_ja401(self):
        def step(x):
            jax.debug.print("loss {}", x.sum())
            return x * 2
        a = audit_jaxpr(jaxpr_of(step, aval(4)))
        assert "JA401" in [f.rule for f in a.findings]

    def test_pure_callback_is_ja401(self):
        def step(x):
            return jax.pure_callback(lambda v: v, aval(4), x)
        a = audit_jaxpr(jaxpr_of(step, aval(4)))
        assert "JA401" in [f.rule for f in a.findings]

    def test_widen_into_collective_is_ja402(self):
        # the adpsgd wire bug, pre-lowering: a bf16 leaf widened to f32
        # right before the exchange — XLA would fold the convert into
        # the collective lowering, the jaxpr still shows it
        def step(x):
            return lax.ppermute(x.astype(jnp.float32), "pod", PERM)
        a = audit_jaxpr(jaxpr_of(step, aval(8, 8, dtype=jnp.bfloat16)))
        assert [f.rule for f in a.findings] == ["JA402"]
        assert "convert_element_type" in a.findings[0].message

    def test_accumulate_then_narrow_is_clean(self):
        # the legitimate pattern: accumulate in f32, narrow back to the
        # leaf dtype BEFORE the wire — the operand itself is bf16, so
        # no finding even though a widening convert exists upstream
        def step(x):
            acc = (x.astype(jnp.float32) * 2.0).astype(x.dtype)
            return lax.ppermute(acc, "pod", PERM)
        a = audit_jaxpr(jaxpr_of(step, aval(8, 8, dtype=jnp.bfloat16)))
        assert a.ok, [f.format() for f in a.findings]

    def test_off_pod_axis_collective_is_ja403(self):
        def step(x):
            return lax.psum(x, "data")
        a = audit_jaxpr(jaxpr_of(step, aval(8),
                                 axis_env=[("pod", 2), ("data", 2)]))
        assert [f.rule for f in a.findings] == ["JA403"]
        assert "'data'" in a.findings[0].message

    def test_large_closed_constant_is_ja404(self):
        big = np.ones((64, 64), np.float32)          # 16 KiB

        def step(x):
            return x @ jnp.asarray(big)
        a = audit_jaxpr(jaxpr_of(step, aval(8, 64)),
                        const_threshold_bytes=1024)
        assert [f.rule for f in a.findings] == ["JA404"]
        assert a.max_const_bytes == big.nbytes
        # the same const under the default 1 MiB threshold is fine
        assert audit_jaxpr(jaxpr_of(step, aval(8, 64))).ok

    def test_const_seed_rng_is_ja405_exactly_once(self):
        # PRNGKey(0) baked into the trace: the step replays the same
        # stream every call.  The whole seed->wrap->sample chain must
        # collapse to ONE finding at the root, not one per RNG prim.
        def step(x):
            return x + jax.random.normal(jax.random.PRNGKey(0), x.shape)
        a = audit_jaxpr(jaxpr_of(step, aval(4)))
        assert [f.rule for f in a.findings] == ["JA405"]
        assert a.n_rng_prims >= 1

    def test_key_threaded_through_args_is_clean(self):
        def step(x, key):
            return x + jax.random.normal(key, x.shape)
        a = audit_jaxpr(jaxpr_of(step, aval(4),
                                 aval(2, dtype=jnp.uint32)))
        assert a.ok, [f.format() for f in a.findings]

    @pytest.mark.slow
    def test_broken_combo_is_ja400_row(self):
        # own process: audit_combos builds the 8-device forced-host
        # mesh, so jax must not have been initialized by another test
        code = textwrap.dedent("""
            from repro.analysis import audit_combos
            rows = audit_combos(
                combos=[("train_4k", "dpsgd", "not-a-topology")])
            (combo, a), = rows
            assert combo == "train_4k/dpsgd/not-a-topology", combo
            assert a.error is not None
            assert [f.rule for f in a.findings] == ["JA400"], a.findings
            print("JA400_ROW_OK")
        """)
        r = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            env=dict(os.environ,
                     PYTHONPATH=os.path.join(REPO_ROOT, "src")),
            cwd=REPO_ROOT, timeout=300)
        assert "JA400_ROW_OK" in r.stdout, r.stdout + r.stderr

    def test_to_json_shape(self):
        def step(x):
            return lax.ppermute(x, "pod", PERM)
        j = audit_jaxpr(jaxpr_of(step, aval(4, 4))).to_json()
        assert j["ok"] and j["n_collectives"] == 1
        assert set(j) >= {"tag", "findings", "collective_axes",
                          "max_const_bytes", "n_rng_prims", "error"}


# ------------------------------------------------------------- the repo

class TestRepoIsClean:
    def test_ast_lints_clean(self):
        assert [f.format() for f in astlint.lint_paths(REPO_ROOT)] == []

    def test_registry_parity_clean(self):
        assert [f.format() for f in check_parity(REPO_ROOT)] == []

    def test_rule_ids_unique_across_passes(self):
        # RA100-104, PA301-305, GA201-205, JA400-405
        assert len(ALL_RULES) == 5 + 5 + 5 + 6

    @pytest.mark.slow
    def test_jaxpr_sweep_covers_matrix_and_is_clean(self):
        # own process: the sweep traces on the 8-device forced-host
        # mesh (launch-test convention — see launch_gossip_script.py)
        code = textwrap.dedent("""
            from repro.analysis import audit_combos
            rows = audit_combos()
            combos = [c for c, _ in rows]
            assert len(combos) == len(set(combos)) == 22, combos
            assert "prefill_32k/-/-" in combos
            assert "decode_32k/-/-" in combos
            assert "train_4k/adpsgd/tv-dcliques" in combos
            bad = [(c, a.error or [f.format() for f in a.findings])
                   for c, a in rows if not a.ok]
            assert bad == [], bad
            print("JAXPR_SWEEP_CLEAN_OK")
        """)
        r = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            env=dict(os.environ,
                     PYTHONPATH=os.path.join(REPO_ROOT, "src")),
            cwd=REPO_ROOT, timeout=300)
        assert "JAXPR_SWEEP_CLEAN_OK" in r.stdout, r.stdout + r.stderr

    @pytest.mark.slow
    def test_cli_skip_graph_exits_zero(self, tmp_path):
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(REPO_ROOT, "src"))
        out = tmp_path / "AUDIT.json"
        r = subprocess.run(
            [sys.executable, "-m", "repro.analysis", "--skip-graph",
             "--json", str(out)],
            capture_output=True, text=True, env=env, cwd=REPO_ROOT,
            timeout=180)
        assert r.returncode == 0, r.stdout + r.stderr
        payload = json.loads(out.read_text())
        assert payload["ok"] and payload["counts"]["ast"] == 0

    @pytest.mark.slow
    def test_cli_graph_hlo_end_to_end(self, tmp_path):
        """Crafted HLO in -> exit code + AUDIT.json schema out, then
        the same violation grandfathered via the baseline."""
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(REPO_ROOT, "src"))
        hlo = tmp_path / "step.hlo"
        hlo.write_text(planted_hlo(dtype="f32", out_dtype="f32",
                                   alias=False))
        out = tmp_path / "AUDIT.json"
        bl = tmp_path / "baseline.json"
        cmd = [sys.executable, "-m", "repro.analysis",
               "--graph-hlo", str(hlo), "--devices-per-pod", "2",
               "--json", str(out), "--baseline", str(bl)]
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           cwd=REPO_ROOT, timeout=180)
        assert r.returncode == 1, r.stdout + r.stderr
        payload = json.loads(out.read_text())
        assert not payload["ok"]
        assert payload["counts"]["graph"] == 1
        assert payload["counts"]["jaxpr"] == 0   # --graph-hlo: no sweep
        assert payload["counts"]["baselined"] == 0
        assert [f["rule"] for f in payload["findings"]] == ["GA202"]
        assert payload["graph"]["findings"], "graph block carries them"
        assert set(payload["rules"]) == set(ALL_RULES)
        # grandfather the finding, rerun: baselined semantics, exit 0
        r2 = subprocess.run(cmd + ["--update-baseline"],
                            capture_output=True, text=True, env=env,
                            cwd=REPO_ROOT, timeout=180)
        assert r2.returncode == 0, r2.stdout + r2.stderr
        r3 = subprocess.run(cmd, capture_output=True, text=True, env=env,
                            cwd=REPO_ROOT, timeout=180)
        assert r3.returncode == 0, r3.stdout + r3.stderr
        payload3 = json.loads(out.read_text())
        assert payload3["ok"] and payload3["counts"]["baselined"] == 1
        assert payload3["findings"][0]["baselined"]

    @pytest.mark.slow
    def test_cli_default_gate_clean_with_coverage(self, tmp_path):
        """The full default gate (AST + parity + jaxpr sweep + smoke
        compile) is clean on the repo and writes the coverage matrix."""
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(REPO_ROOT, "src"))
        out = tmp_path / "AUDIT.json"
        r = subprocess.run(
            [sys.executable, "-m", "repro.analysis", "-q",
             "--json", str(out)],
            capture_output=True, text=True, env=env, cwd=REPO_ROOT,
            timeout=420)
        assert r.returncode == 0, r.stdout + r.stderr
        payload = json.loads(out.read_text())
        assert payload["ok"] and payload["stale_baseline"] == []
        cov = payload["coverage"]
        assert len(cov) == 22
        smoke = [row for row in cov
                 if row["combo"] == "train_4k/dpsgd/ring"]
        assert smoke and smoke[0]["hlo"] is not None
        assert smoke[0]["hlo"]["ok"] and "GA201" in smoke[0]["hlo"]["rules"]
        assert all(row["jaxpr"]["ok"] for row in cov)

    @pytest.mark.slow
    def test_cli_fail_on_stale(self, tmp_path):
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(REPO_ROOT, "src"))
        bl = tmp_path / "baseline.json"
        bl.write_text('["XX999|nowhere.py|long gone line"]\n')
        out = tmp_path / "AUDIT.json"
        cmd = [sys.executable, "-m", "repro.analysis", "--skip-graph",
               "--json", str(out), "--baseline", str(bl)]
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           cwd=REPO_ROOT, timeout=180)
        assert r.returncode == 0, r.stdout + r.stderr   # stale = warn
        assert json.loads(out.read_text())["stale_baseline"] == \
            ["XX999|nowhere.py|long gone line"]
        r2 = subprocess.run(cmd + ["--fail-on-stale"], capture_output=True,
                            text=True, env=env, cwd=REPO_ROOT, timeout=180)
        assert r2.returncode == 1, r2.stdout + r2.stderr
        assert "stale" in r2.stdout
