"""Per-architecture smoke tests: a REDUCED variant of each assigned config
(2 layers, d_model<=256, <=4 experts) runs one forward + one train step +
one decode step on CPU, asserting output shapes and no NaNs.  The FULL
configs are exercised only via the dry-run (ShapeDtypeStructs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import decode_step, forward, init_cache, init_model, loss_fn

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, T=64):
    b = {"tokens": jax.random.randint(KEY, (B, T), 0, cfg.vocab),
         "labels": jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                                      cfg.vocab)}
    if cfg.modality.kind == "vision":
        b["patches"] = jax.random.normal(
            KEY, (B, cfg.modality.n_tokens, cfg.modality.feat_dim))
    if cfg.encoder is not None:
        b["frames"] = jax.random.normal(
            KEY, (B, cfg.encoder.n_frames, cfg.modality.feat_dim))
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    assert cfg.moe.n_experts <= 4
    p = init_model(KEY, cfg)
    B, T = 2, 64
    batch = make_batch(cfg, B, T)
    logits, aux = forward(p, cfg, batch, chunk=32)
    assert logits.shape == (B, T, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_one_train_step_no_nan(arch):
    cfg = get_config(arch).reduced()
    p = init_model(KEY, cfg)
    batch = make_batch(cfg)

    def loss(p):
        l, _ = loss_fn(p, cfg, batch, chunk=32)
        return l
    l0, grads = jax.value_and_grad(loss)(p)
    assert bool(jnp.isfinite(l0))
    finite = jax.tree_util.tree_map(
        lambda g: bool(jnp.all(jnp.isfinite(g))), grads)
    assert all(jax.tree_util.tree_leaves(finite)), arch
    # apply an SGD step and verify the loss is still finite (and params moved)
    p2 = jax.tree_util.tree_map(lambda w, g: w - 1e-3 * g.astype(w.dtype),
                                p, grads)
    l1 = loss(p2)
    assert bool(jnp.isfinite(l1))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_decode_step(arch):
    cfg = get_config(arch).reduced()
    p = init_model(KEY, cfg)
    B = 2
    batch = make_batch(cfg, B)
    cache = init_cache(cfg, B, 32)
    db = {"token": batch["tokens"][:, 0], "t": jnp.zeros((B,), jnp.int32)}
    if "frames" in batch:
        db["frames"] = batch["frames"]
    logits, cache2 = decode_step(p, cfg, db, cache)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-780m",
                                  "recurrentgemma-2b"])
def test_reduced_prefill_decode_agree(arch):
    """Greedy next-token from full forward == from step-by-step decode."""
    cfg = get_config(arch).reduced()
    p = init_model(KEY, cfg)
    B, T = 1, 24
    batch = make_batch(cfg, B, T)
    logits, _ = forward(p, cfg, batch, chunk=8)
    cache = init_cache(cfg, B, T)
    for t in range(T):
        db = {"token": batch["tokens"][:, t], "t": jnp.full((B,), t)}
        step_logits, cache = decode_step(p, cfg, db, cache)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(logits[:, -1]),
                               atol=2e-3, rtol=2e-3)


def test_n_params_sane():
    """Config-derived parameter counts are within family expectations."""
    expect = {
        "qwen3-0.6b": (0.4e9, 1.1e9),
        "gemma2-9b": (8e9, 12e9),
        "deepseek-v2-236b": (180e9, 280e9),
        "deepseek-v2-lite-16b": (12e9, 20e9),
        "mamba2-780m": (0.5e9, 1.1e9),
        "minicpm3-4b": (3e9, 5.5e9),
        "starcoder2-3b": (2.5e9, 4.5e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).n_params()
        assert lo < n < hi, (arch, n)
    # MoE active < total
    ds = get_config("deepseek-v2-236b")
    assert ds.n_active_params() < 0.2 * ds.n_params()
