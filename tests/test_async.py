"""Asynchronous gossip backend: per-edge virtual clocks (CommLedger
async mode), bounded-staleness AD-PSGD mixing, per-class re-wiring
handshake latency, and the sync-vs-async acceptance claim — same
schedule, accuracy within noise, strictly lower simulated wall-clock."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CommConfig, FabricConfig
from repro.core.algorithms.adpsgd import ADPSGD
from repro.core.algorithms.base import ModelFns
from repro.core.algorithms.dpsgd import DPSGD
from repro.kernels import ops, ref
from repro.topology import (LINK_PROFILES, CommLedger, fully_connected,
                            hierarchical, ring, time_varying_d_cliques)
from repro.topology.graphs import _build

K = 4
DIM = 8


def exclusive_hist(n_nodes: int, n_classes: int) -> np.ndarray:
    hist = np.zeros((n_nodes, n_classes))
    for k in range(n_nodes):
        hist[k, k % n_classes] = 100
    return hist


def make_quadratic_fns():
    def loss_and_grad(params, mstate, batch):
        diff = params["w"] - batch["target"]
        return 0.5 * jnp.sum(diff ** 2), {"w": diff}, mstate
    return ModelFns(loss_and_grad=loss_and_grad)


def quad_setup(n_nodes=K):
    fns = make_quadratic_fns()
    params = {"w": jnp.zeros((DIM,))}
    mstate = {"dummy": jnp.zeros((1,))}
    targets = np.stack([np.full(DIM, float(k + 1)) for k in range(n_nodes)])
    return fns, params, mstate, {"target": jnp.asarray(targets)}


# ---------------------------------------------------------------------------
# async ledger invariants
# ---------------------------------------------------------------------------

def test_async_edge_clocks_monotone_and_sim_time_monotone():
    """Invariant: every link's virtual clock is non-decreasing, and the
    global clock (max over activated clocks) never runs backwards."""
    sched = time_varying_d_cliques(exclusive_hist(9, 3), seed=0)
    led = CommLedger(sched, LINK_PROFILES["geo-wan"], async_mode=True)
    last_clocks, last_t = {}, 0.0
    for t in range(3 * sched.period):
        led.record_gossip(500.0, t=t, staleness=1)
        clocks = led.view().edge_clock_map()
        for e, c in clocks.items():
            assert c >= last_clocks.get(e, 0.0), (e, c)
        assert led.sim_time_s >= last_t
        assert led.sim_time_s == pytest.approx(max(clocks.values()))
        last_clocks, last_t = clocks, led.sim_time_s


def test_sync_edge_clocks_snap_to_global_clock():
    led = CommLedger(ring(5), LINK_PROFILES["geo-wan"])
    for t in range(3):
        led.record_gossip(100.0, t=t)
        for c in led.view().edge_clock_map().values():
            assert c == pytest.approx(led.sim_time_s)
    assert led.view().clock_skew_s == pytest.approx(0.0)


def test_async_lan_wan_partition_covers_all_priced_floats():
    """lan + wan == total must survive async mode, with gossip, probes,
    and re-wiring traffic all booked."""
    sched = time_varying_d_cliques(exclusive_hist(9, 3), seed=0)
    led = CommLedger(sched, LINK_PROFILES["geo-wan"],
                     config=FabricConfig(rewire_floats=32.0),
                     async_mode=True)
    union = led.topology
    for t in range(2 * sched.period):
        led.record_gossip(500.0, t=t, staleness=2)
        led.record_probe([union.edges[t % len(union.edges)]], 100.0)
    assert led.view().total_floats == pytest.approx(
        led.lan_floats + led.wan_floats)
    v = led.view()
    assert v.edge_traffic[v.union_eids].sum() == pytest.approx(
        v.total_floats)
    assert led.view().rewire_floats > 0
    assert led.rewire_time_s > 0          # handshakes priced into time


def test_async_never_slower_than_sync_same_traffic():
    """Max-of-per-edge-sums <= sum-of-per-round-maxes: for identical
    traffic the async clock can never exceed the sync clock, and with
    staleness amortizing WAN latency it is strictly lower."""
    topo = hierarchical(6)
    prof = LINK_PROFILES["geo-wan"]
    times = {}
    for name, async_mode, stale in (("sync", False, None),
                                    ("async-s0", True, 0),
                                    ("async-s2", True, 2)):
        led = CommLedger(topo, prof, async_mode=async_mode)
        for t in range(10):
            led.record_gossip(1000.0, t=t, staleness=stale)
        times[name] = led.sim_time_s
    # staleness 0 degrades to stop-and-wait per edge: on a constant
    # fabric the WAN edge bottlenecks every round either way
    assert times["async-s0"] == pytest.approx(times["sync"])
    assert times["async-s2"] < times["sync"]
    # the win is the amortized WAN latency: 10 rounds pay it ~1/3 times
    expect = 10 * (prof.wan_latency / 3.0 + 2000.0 / prof.wan_bandwidth)
    assert times["async-s2"] == pytest.approx(expect)


def test_async_per_node_busy_idle_and_clock_skew():
    """Sync: LAN-only nodes idle waiting on the WAN straggler.  Async:
    per-node clocks diverge (positive skew) and idle shrinks."""
    topo = hierarchical(6)
    prof = LINK_PROFILES["geo-wan"]
    led_s = CommLedger(topo, prof)
    led_a = CommLedger(topo, prof, async_mode=True)
    for t in range(10):
        led_s.record_gossip(1000.0, t=t)
        led_a.record_gossip(1000.0, t=t, staleness=2)
    for led in (led_s, led_a):
        assert (led.node_busy_s <= led.sim_time_s + 1e-12).all()
        assert (led.view().node_idle_s >= 0).all()
    # gateways carry the WAN link: they are the busy ones; LAN-only
    # nodes spend most of the synchronous run waiting
    gateway_busy = led_s.node_busy_s.max()
    lan_busy = led_s.node_busy_s.min()
    assert gateway_busy > 10 * lan_busy
    assert led_s.view().node_idle_s.max() == pytest.approx(
        led_s.sim_time_s - lan_busy)
    assert led_s.view().clock_skew_s == pytest.approx(0.0)
    assert led_a.view().clock_skew_s > 0.0


def test_record_probe_books_floats_and_blocks_on_latency():
    topo = hierarchical(6)
    prof = LINK_PROFILES["geo-wan"]
    led = CommLedger(topo, prof, async_mode=True)
    wan_edge = topo.edges[int(topo.wan_edge_indices()[0])]
    led.record_probe([wan_edge], 500.0)
    assert led.view().total_floats == pytest.approx(500.0)
    assert led.wan_floats == pytest.approx(500.0)
    # probes block on the fresh model: full latency, no amortization
    assert led.sim_time_s == pytest.approx(
        prof.wan_latency + 500.0 / prof.wan_bandwidth)
    assert led.view().traffic_map()[wan_edge] == pytest.approx(500.0)
    with pytest.raises(AssertionError, match="union"):
        led.record_probe([(0, 0)], 1.0)


def test_async_reactivated_edges_join_at_the_global_frontier():
    """A rung switch must not hand out a free window: the new fabric's
    links start from the current global clock, so gossip on them costs
    at least what a fresh ledger would charge for the same rounds."""
    prof = LINK_PROFILES["geo-wan"]
    # connected 6-node fabric sharing no edge with ring(6)
    disjoint = _build("disjoint", 6,
                      [(0, 2), (2, 4), (0, 4), (1, 3), (3, 5), (1, 5),
                       (0, 3)], ["lan"] * 7)
    led = CommLedger(ring(6), prof, async_mode=True)
    for t in range(50):
        led.record_gossip(1000.0, t=t, staleness=1)
    before = led.sim_time_s
    led.switch_schedule(disjoint)
    for t in range(10):
        led.record_gossip(1000.0, t=t, staleness=1)
    fresh = CommLedger(disjoint, prof, async_mode=True)
    for t in range(10):
        fresh.record_gossip(1000.0, t=t, staleness=1)
    assert led.sim_time_s - before >= fresh.sim_time_s, \
        (led.sim_time_s, before, fresh.sim_time_s)


def test_probe_neither_pays_nor_resets_rewiring_async():
    sched = time_varying_d_cliques(exclusive_hist(9, 3), seed=0)
    led = CommLedger(sched, LINK_PROFILES["uniform"],
                     config=FabricConfig(rewire_floats=100.0),
                     async_mode=True)
    led.record_gossip(10.0, t=0)
    led.record_probe([led.topology.edges[0]], 5.0)
    assert led.rewire_events == 0
    led.record_gossip(10.0, t=1)
    new_edges = len(set(sched.at(1).edges) - set(sched.at(0).edges))
    assert led.rewire_events == new_edges


# ---------------------------------------------------------------------------
# re-wiring handshake latency (satellite: WAN >> LAN setup cost)
# ---------------------------------------------------------------------------

def ring_plus(n: int, extra, cls: str):
    """ring(n) plus one extra edge of the given link class (classes are
    remapped to _build's canonical edge order)."""
    cls_map = {e: "lan" for e in ring(n).edges}
    cls_map[(min(extra), max(extra))] = cls
    edges = sorted(cls_map)
    return _build(f"ring+{cls}", n, edges, [cls_map[e] for e in edges])

def test_link_profile_handshake_defaults_scale_with_latency():
    prof = LINK_PROFILES["geo-wan"]
    assert prof.handshake("wan") == pytest.approx(3 * prof.wan_latency)
    assert prof.handshake("lan") == pytest.approx(3 * prof.lan_latency)
    assert prof.handshake("wan") > 100 * prof.handshake("lan")
    # explicit override wins
    from repro.topology import LinkProfile
    p = LinkProfile("x", 1.0, 1.0, 0.1, 0.2, lan_handshake=0.0,
                    wan_handshake=1.5)
    assert p.handshake("lan") == 0.0 and p.handshake("wan") == 1.5


def test_rewire_charges_handshake_latency_even_with_zero_floats():
    """The docstring's promise: the handshake is priced at the link's
    setup latency, not only its control-plane floats.  Switching to a
    fabric that activates a WAN link costs WAN handshake time even when
    FabricConfig.rewire_floats == 0."""
    prof = LINK_PROFILES["geo-wan"]
    led = CommLedger(ring(6), prof, config=FabricConfig(rewire_floats=0.0))
    led.record_gossip(100.0, t=0)
    before = led.sim_time_s
    # splice in a WAN link the ring never had: its activation must pay
    # the WAN setup handshake even though no control-plane floats do
    led.switch_schedule(ring_plus(6, (0, 3), "wan"))
    led.record_gossip(100.0, t=1)
    assert led.sim_time_s - before >= prof.handshake("wan")
    assert led.rewire_time_s >= prof.handshake("wan")
    assert led.rewire_events == 1
    assert led.view().rewire_floats == 0.0       # no control-plane floats asked


def test_rewire_wan_handshake_dominates_lan():
    """Activating one WAN link must cost more setup time than activating
    one LAN link of the same shape."""
    prof = LINK_PROFILES["geo-wan"]
    deltas = {}
    for cls in ("lan", "wan"):
        led = CommLedger(ring(6), prof,
                         config=FabricConfig(rewire_floats=8.0))
        led.record_gossip(10.0, t=0)
        led.switch_schedule(ring_plus(6, (0, 3), cls))
        led.record_gossip(10.0, t=1)
        deltas[cls] = led.rewire_time_s
    assert deltas["wan"] > 10 * deltas["lan"], deltas


# ---------------------------------------------------------------------------
# AD-PSGD: bounded-staleness mixing
# ---------------------------------------------------------------------------

def test_adpsgd_staleness_zero_is_bit_identical_to_dpsgd():
    fns, params, mstate, batch = quad_setup()
    dp = DPSGD(fns, K, topology=ring(K), momentum=0.9)
    ad = ADPSGD(fns, K, topology=ring(K), momentum=0.9,
                max_staleness=2, staleness=0)
    sd, sa = dp.init(params, mstate), ad.init(params, mstate)
    for t in range(10):
        sd, _ = dp.step(sd, batch, jnp.float32(0.05), jnp.int32(t))
        sa, m = ad.step(sa, batch, jnp.float32(0.05), jnp.int32(t))
    np.testing.assert_allclose(np.asarray(sd["params"]["w"]),
                               np.asarray(sa["params"]["w"]), atol=1e-6)
    assert float(m["mean_staleness"]) == 0.0


def test_adpsgd_stale_mixing_uses_snapshots_from_s_rounds_ago():
    """Analytic check: with staleness 1, round t's neighbor term must be
    the neighbor's *pre-mix* stack from round t-1, not round t."""
    fns, params, mstate, batch = quad_setup()
    ad = ADPSGD(fns, K, topology=ring(K), momentum=0.0,
                max_staleness=1, staleness=1)
    s = ad.init(params, mstate)
    idx, w, sw = ad.mix_operands(0)
    lr = 0.05
    # hist[-1] is always the previous round's pre-mix stack; the buffer
    # is initialized with the starting params, so round 0's stale reads
    # see the initial weights
    hist = [np.zeros((K, DIM))]
    for t in range(3):
        # replicate the local update by hand (momentum 0)
        cur = np.asarray(s["params"]["w"])
        tgt = np.asarray(batch["target"])
        pre = cur - lr * (cur - tgt)
        src = hist[-1]                    # staleness 1: one round ago
        expect = np.asarray(sw)[:, None] * pre
        for k in range(K):
            for d in range(idx.shape[1]):
                if float(w[k, d]) > 0:
                    expect[k] += float(w[k, d]) * src[int(idx[k, d])]
        hist.append(pre)
        s, m = ad.step(s, batch, jnp.float32(lr), jnp.int32(t))
        np.testing.assert_allclose(np.asarray(s["params"]["w"]), expect,
                                   atol=1e-5)
    assert float(m["mean_staleness"]) == 1.0


def test_adpsgd_bounded_staleness_never_exceeded():
    fns, params, mstate, batch = quad_setup(9)
    sched = time_varying_d_cliques(exclusive_hist(9, 3), seed=0)
    ad = ADPSGD(fns, 9, topology=sched, momentum=0.0, max_staleness=2)
    s = ad.init(params, mstate)
    for t in range(2 * sched.period):
        s, m = ad.step(s, batch, jnp.float32(0.01), jnp.int32(t))
        assert int(m["max_staleness_used"]) <= ad.max_staleness
        assert (ad.edge_staleness(t) <= ad.max_staleness).all()
    assert s["snaps"].shape[0] == ad.max_staleness + 1
    with pytest.raises(AssertionError, match="bound"):
        ad.set_staleness(ad.max_staleness + 1)
    with pytest.raises(AssertionError, match="bound"):
        ad.set_staleness(-1)


def test_adpsgd_kernel_and_dense_stale_mix_agree():
    fns, params, mstate, batch = quad_setup()
    kw = dict(topology=ring(K), momentum=0.9, max_staleness=2,
              staleness=2)
    ad_k = ADPSGD(fns, K, use_kernel=True, **kw)
    ad_d = ADPSGD(fns, K, use_kernel=False, **kw)
    sk, sd = ad_k.init(params, mstate), ad_d.init(params, mstate)
    for t in range(6):
        sk, _ = ad_k.step(sk, batch, jnp.float32(0.05), jnp.int32(t))
        sd, _ = ad_d.step(sd, batch, jnp.float32(0.05), jnp.int32(t))
    np.testing.assert_allclose(np.asarray(sk["params"]["w"]),
                               np.asarray(sd["params"]["w"]), atol=1e-5)


def test_neighbor_mix_src_variant_matches_oracle():
    """The Pallas src-gather path (stale mixing) vs the dense oracle."""
    rng = np.random.default_rng(0)
    Kn, S, N, D = 5, 2, 1000, 3
    x = jnp.asarray(rng.normal(size=(Kn, N)), jnp.float32)
    src = jnp.asarray(rng.normal(size=((S + 1) * Kn, N)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, (S + 1) * Kn, size=(Kn, D)),
                      jnp.int32)
    w = jnp.asarray(rng.random((Kn, D)), jnp.float32)
    sw = jnp.asarray(rng.random((Kn,)), jnp.float32)
    out = ops.neighbor_mix(x, idx, w, sw, src=src)
    expect = ref.neighbor_mix_src_ref(x, src, idx, w, sw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5, rtol=1e-5)


def test_adpsgd_compiles_once_across_staleness_and_schedule_switches():
    """Acceptance: staleness values and neighbor sets are runtime
    operands — staleness rung moves, schedule rotation, and topology
    switches (within the pad) all reuse one compilation."""
    fns, params, mstate, batch = quad_setup(9)
    sched = time_varying_d_cliques(exclusive_hist(9, 3), seed=0)
    pad = max(sched.max_degree, fully_connected(9).max_degree)
    ad = ADPSGD(fns, 9, topology=sched, momentum=0.9, max_staleness=3,
                pad_degree=pad)
    s = ad.init(params, mstate)
    t = 0
    for stale in (3, 1, 0, 2):
        ad.set_staleness(stale)
        for _ in range(sched.period):
            s, _ = ad.step(s, batch, jnp.float32(0.05), jnp.int32(t))
            t += 1
    ad.set_schedule(fully_connected(9))       # rung-style fabric switch
    for _ in range(3):
        s, _ = ad.step(s, batch, jnp.float32(0.05), jnp.int32(t))
        t += 1
    assert ad.trace_count == 1, \
        f"stale gossip step retraced {ad.trace_count}x"


def test_adpsgd_converges_on_quadratic_with_staleness():
    """Stale gossip still settles near the global optimum; smaller lr,
    smaller error (Lian et al. 2018, bounded-staleness assumption)."""
    fns, params, mstate, batch = quad_setup()
    errs = {}
    for lr in (0.05, 0.01):
        ad = ADPSGD(fns, K, topology=ring(K), momentum=0.0,
                    max_staleness=2)
        s = ad.init(params, mstate)
        for t in range(1500):
            s, _ = ad.step(s, batch, jnp.float32(lr), jnp.int32(t))
        errs[lr] = np.abs(np.asarray(s["params"]["w"]) - 2.5).max()
    assert errs[0.05] < 0.2 and errs[0.01] < 0.05, errs
    assert errs[0.01] < errs[0.05]


# ---------------------------------------------------------------------------
# acceptance: sync D-PSGD vs async AD-PSGD end-to-end
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_adpsgd_async_matches_dpsgd_accuracy_with_lower_wall_clock():
    """Acceptance: AD-PSGD under geo-wan (gateway nodes own the slow WAN
    links) reaches accuracy within noise of sync D-PSGD on the same
    schedule, while the async ledger reports strictly lower simulated
    wall-clock per step."""
    from repro.configs.cnn_zoo import CNN_ZOO
    from repro.core.trainer import train_decentralized
    from repro.data.synthetic import synth_images
    n_nodes, n_classes = 6, 3
    ds = synth_images(1800, seed=0, noise=0.8, class_sep=0.35,
                      n_classes=n_classes)
    val = synth_images(600, seed=99, noise=0.8, class_sep=0.35,
                       n_classes=n_classes)
    parts = []
    for k in range(n_nodes):          # full skew: node k sees one class
        idx = np.where(ds.y == k % n_classes)[0][k // n_classes::2]
        parts.append((ds.x[idx], ds.y[idx]))
    steps = 150
    kw = dict(steps=steps, batch=10, lr=0.02, eval_every=steps)
    runs = {}
    for name, async_gossip in (("dpsgd", False), ("adpsgd", True)):
        runs[name] = train_decentralized(
            CNN_ZOO["gn-lenet"], name, parts, (val.x, val.y),
            comm=CommConfig(strategy=name,
                            fabric=FabricConfig(topology="geo-wan",
                                                profile="geo-wan"),
                            async_gossip=async_gossip, max_staleness=2),
            **kw)
    sync, asy = runs["dpsgd"], runs["adpsgd"]
    assert asy.val_acc > sync.val_acc - 0.06, (asy.val_acc, sync.val_acc)
    # identical float traffic, strictly lower wall-clock per step
    assert asy.comm_wan_floats == pytest.approx(sync.comm_wan_floats)
    assert asy.sim_time_s / steps < sync.sim_time_s / steps, \
        (asy.sim_time_s, sync.sim_time_s)
    # async exposes the straggler: fast nodes ran ahead of the gateways
    assert asy.extras["node_clock_skew_s"] > 0
    assert asy.extras["staleness_curve"][-1][1] == pytest.approx(2.0)


def test_trainer_adpsgd_staleness_rung_switch_end_to_end():
    """SkewScout staleness mode: under full label skew the controller
    starts fully asynchronous and tightens toward fresher reads, and the
    algorithm's staleness follows the rung."""
    from repro.configs.cnn_zoo import CNN_ZOO
    from repro.core.trainer import train_decentralized
    from repro.data.synthetic import synth_images
    ds = synth_images(360, seed=0, n_classes=3)
    K6 = 6
    parts = []
    for k in range(K6):
        i = np.where(ds.y == k % 3)[0][k // 3::2]
        parts.append((ds.x[i], ds.y[i]))
    comm = CommConfig(strategy="adpsgd",
                      fabric=FabricConfig(topology="geo-wan",
                                          profile="geo-wan"),
                      async_gossip=True,
                      max_staleness=2, skewscout=True, travel_every=3)
    r = train_decentralized(CNN_ZOO["gn-lenet"], "adpsgd", parts,
                            (ds.x, ds.y), comm=comm, steps=12, batch=5,
                            eval_every=12)
    assert r.extras["staleness_ladder"] == [0, 1, 2]
    moves = [(h.theta, h.new_theta) for h in r.skewscout_history]
    assert moves[0][0] == 2               # started fully async
    assert all(n in (0, 1, 2) for _, n in moves)
    # the staleness curve tracks the controller's moves
    curve = dict(r.extras["staleness_curve"])
    assert curve[0] == 2.0
    final_theta = moves[-1][1]
    assert curve[11] == float(final_theta)
    with pytest.raises(ValueError, match="staleness ladder"):
        train_decentralized(CNN_ZOO["gn-lenet"], "adpsgd", parts,
                            (ds.x, ds.y), comm=comm, steps=3, batch=5,
                            eval_every=3, theta_start_index=99)
    # a sync ledger prices every staleness rung identically — the
    # degenerate controller is refused up front
    import dataclasses
    with pytest.raises(ValueError, match="async_gossip"):
        train_decentralized(
            CNN_ZOO["gn-lenet"], "adpsgd", parts, (ds.x, ds.y),
            comm=dataclasses.replace(comm, async_gossip=False),
            steps=3, batch=5, eval_every=3)
