"""Data pipeline, synthetic generators, optimizer, checkpointing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing import latest_step, restore, save
from repro.data.pipeline import DecentralizedLoader, PartitionLoader
from repro.data.synthetic import (synth_geo_images, synth_images,
                                  synth_tokens)
from repro.optim import (clip_by_global_norm, global_norm, init_momentum,
                         momentum_update, polynomial_decay, step_decay)


def test_synth_images_deterministic_and_learnable_structure():
    a = synth_images(100, seed=3)
    b = synth_images(100, seed=3)
    np.testing.assert_array_equal(a.x, b.x)
    # same class, same world -> closer than different class (on average)
    c = synth_images(2000, seed=0, noise=0.3)
    x0 = c.x[c.y == 0].mean(0)
    x1 = c.x[c.y == 1].mean(0)
    assert np.abs(x0 - x1).mean() > 0.05


def test_synth_images_val_shares_world():
    tr = synth_images(500, seed=0)
    va = synth_images(500, seed=9)
    m_tr = [tr.x[tr.y == c].mean(0) for c in range(10) if (tr.y == c).any()]
    m_va = [va.x[va.y == c].mean(0) for c in range(10) if (va.y == c).any()]
    # prototypes match across splits (class_seed shared)
    d_same = np.mean([np.abs(a - b).mean() for a, b in zip(m_tr, m_va)])
    d_cross = np.abs(m_tr[0] - m_va[1]).mean()
    assert d_same < d_cross


def test_synth_geo_images_home_concentration():
    ds, region = synth_geo_images(4000, n_regions=5, n_classes=15,
                                  home_share=0.7, seed=0)
    # each class should be concentrated in one region
    shares = []
    for c in range(15):
        m = ds.y == c
        counts = np.bincount(region[m], minlength=5)
        shares.append(counts.max() / counts.sum())
    assert np.mean(shares) > 0.55      # ~0.7 + uniform remainder


def test_synth_tokens_markov_structure():
    ds = synth_tokens(8, 512, vocab=64, seed=0)
    assert ds.tokens.shape == (8, 512)
    # order-2 structure: bigram entropy < unigram entropy * 2
    flat = ds.tokens.reshape(-1)
    assert len(np.unique(flat)) > 10


def test_partition_loader_epochs_cover_data():
    x = np.arange(100).reshape(100, 1).astype(np.float32)
    y = np.arange(100).astype(np.int32)
    ld = PartitionLoader(x, y, batch=10, seed=0)
    seen = set()
    for _ in range(10):
        xb, yb = ld.next()
        seen.update(yb.tolist())
    assert seen == set(range(100))


def test_decentralized_loader_stacked_shapes():
    parts = [(np.zeros((50, 4), np.float32), np.zeros(50, np.int32)),
             (np.ones((60, 4), np.float32), np.ones(60, np.int32))]
    ld = DecentralizedLoader(parts, batch=8, seed=0)
    xs, ys = ld.next_stacked()
    assert xs.shape == (2, 8, 4) and ys.shape == (2, 8)
    assert xs[0].sum() == 0 and xs[1].sum() == 8 * 4


def test_momentum_update_matches_reference():
    params = {"w": jnp.ones((3,))}
    grads = {"w": jnp.full((3,), 2.0)}
    vel = init_momentum(params)
    p, v, u = momentum_update(params, grads, vel, lr=jnp.float32(0.1),
                              momentum=0.9, weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(v["w"]), -0.2)
    np.testing.assert_allclose(np.asarray(p["w"]), 0.8)
    p2, v2, _ = momentum_update(p, grads, v, lr=jnp.float32(0.1),
                                momentum=0.9)
    np.testing.assert_allclose(np.asarray(v2["w"]), 0.9 * -0.2 - 0.2)


def test_clip_by_global_norm():
    t = {"a": jnp.full((4,), 3.0)}          # norm 6
    c = clip_by_global_norm(t, 3.0)
    assert float(global_norm(c)) == pytest.approx(3.0, rel=1e-5)
    t2 = {"a": jnp.full((4,), 0.1)}
    c2 = clip_by_global_norm(t2, 3.0)
    np.testing.assert_allclose(np.asarray(c2["a"]), 0.1)


def test_schedules():
    lr = step_decay(1.0, [10, 20])
    assert float(lr(5)) == 1.0
    assert float(lr(15)) == pytest.approx(0.1)
    assert float(lr(25)) == pytest.approx(0.01)
    pd = polynomial_decay(1.0, 100, power=1.0)
    assert float(pd(50)) == pytest.approx(0.5)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16),
            "b": [jnp.zeros(4), {"c": jnp.ones((2, 2), jnp.int32)}]}
    path = str(tmp_path / "ckpt")
    save(path, tree, step=7)
    assert latest_step(path) == 7
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    got = restore(path, like, step=7)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype
