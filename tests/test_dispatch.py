"""Backend-aware dispatch: every routed path computes the same numbers.

Covers the three invariants the kernel-speed overhaul must not break:

* **path equivalence** — for each op, forced-oracle, forced-Pallas and
  auto-dispatched calls agree (bit-exact where the op has integer /
  select semantics, allclose for float reductions);
* **in-kernel RNG** — seeded masks generated from (seed, counter)
  hashes inside the kernel are bit-identical to the materialized
  generator baseline, so dispatch can never change which coordinates
  ship;
* **stickiness** — one timed trial per (op, bucket); warm caches (in
  memory or reloaded from the JSON file) never re-time.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch, ops, ref, rng
from repro.kernels.dgc_topk import (abs_histogram, abs_histogram_fused,
                                    threshold_from_histogram)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _isolated_dispatch(monkeypatch):
    """Keep tests off the real persisted cache and reset the process-wide
    dispatcher around every test (decisions here are test-local)."""
    monkeypatch.setenv("REPRO_DISPATCH_CACHE", "")
    monkeypatch.delenv("REPRO_KERNEL_DISPATCH", raising=False)
    dispatch.reset_dispatcher()
    yield
    dispatch.reset_dispatcher()


def _force(monkeypatch, value):
    monkeypatch.setenv("REPRO_KERNEL_DISPATCH", value)
    dispatch.reset_dispatcher()


def _three_ways(monkeypatch, fn):
    """Run ``fn`` under forced-oracle, forced-Pallas and auto dispatch."""
    _force(monkeypatch, "oracle")
    o = fn()
    _force(monkeypatch, "pallas")
    p = fn()
    monkeypatch.delenv("REPRO_KERNEL_DISPATCH")
    dispatch.reset_dispatcher()
    a = fn()
    return o, p, a


def test_gaia_select_paths_bit_exact(monkeypatch):
    v = jax.random.normal(KEY, (4096 + 17,))
    w = jax.random.normal(jax.random.PRNGKey(1), v.shape) * 0.3
    o, p, a = _three_ways(monkeypatch,
                          lambda: ops.gaia_select(v, w, 0.7))
    for sel, cnt in (p, a):
        np.testing.assert_array_equal(np.asarray(sel), np.asarray(o[0]))
        assert int(cnt) == int(o[1])


def test_dgc_sparsify_paths_bit_exact(monkeypatch):
    v = jax.random.normal(KEY, (8192 + 77,)) * \
        jax.random.gamma(jax.random.PRNGKey(2), 1.0, (8192 + 77,))
    o, p, a = _three_ways(monkeypatch,
                          lambda: ops.dgc_sparsify(v, 0.99))
    for sel, cnt, t in (p, a):
        assert float(t) == float(o[2])         # same quantized threshold
        assert int(cnt) == int(o[1])
        np.testing.assert_array_equal(np.asarray(sel), np.asarray(o[0]))


def test_rand_k_paths_bit_exact(monkeypatch):
    v = jax.random.normal(KEY, (4096 + 5,))
    o, p, a = _three_ways(
        monkeypatch, lambda: ops.rand_k_sparsify(v, 0.05, 123))
    for sel, cnt in (p, a):
        np.testing.assert_array_equal(np.asarray(sel), np.asarray(o[0]))
        assert int(cnt) == int(o[1])


def _ring(K, D=2):
    nbr = np.stack([(np.arange(K) - 1) % K, (np.arange(K) + 1) % K], 1)
    w = np.full((K, D), 1.0 / 3, np.float32)
    return jnp.asarray(nbr, jnp.int32), jnp.asarray(w), \
        jnp.full((K,), 1.0 / 3, jnp.float32)


def test_neighbor_mix_paths_close(monkeypatch):
    K = 8
    nbr, w, sw = _ring(K)
    x = jax.random.normal(KEY, (K, 512))
    o, p, a = _three_ways(
        monkeypatch, lambda: ops.neighbor_mix(x, nbr, w, sw))
    for y in (p, a):
        np.testing.assert_allclose(np.asarray(y), np.asarray(o),
                                   atol=1e-5, rtol=1e-5)


def test_neighbor_mix_src_paths_close(monkeypatch):
    K, M = 8, 24
    nbr = jax.random.randint(jax.random.PRNGKey(3), (K, 2), 0, M)
    w = jnp.full((K, 2), 0.25, jnp.float32)
    sw = jnp.full((K,), 0.5, jnp.float32)
    x = jax.random.normal(KEY, (K, 384))
    src = jax.random.normal(jax.random.PRNGKey(4), (M, 384))
    o, p, a = _three_ways(
        monkeypatch, lambda: ops.neighbor_mix(x, nbr, w, sw, src=src))
    for y in (p, a):
        np.testing.assert_allclose(np.asarray(y), np.asarray(o),
                                   atol=1e-5, rtol=1e-5)


def test_group_norm_paths_close(monkeypatch):
    x = jax.random.normal(KEY, (4, 8, 8, 64))
    sc = jnp.ones(64) * 1.3
    bi = jnp.zeros(64) + 0.1
    o, p, a = _three_ways(
        monkeypatch, lambda: ops.group_norm(x, sc, bi, group_size=2))
    for y in (p, a):
        np.testing.assert_allclose(np.asarray(y), np.asarray(o),
                                   atol=2e-5, rtol=2e-5)


def test_flash_attention_paths_close(monkeypatch):
    q = jax.random.normal(KEY, (1, 2, 128, 64))
    k = jax.random.normal(jax.random.PRNGKey(5), (1, 2, 128, 64))
    v = jax.random.normal(jax.random.PRNGKey(6), (1, 2, 128, 64))
    o, p, a = _three_ways(
        monkeypatch, lambda: ops.flash_attention(q, k, v, causal=True))
    for y in (p, a):
        np.testing.assert_allclose(np.asarray(y), np.asarray(o),
                                   atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------ in-kernel RNG

def test_rng_uniform_bit_exact_numpy_vs_jnp():
    ctr = np.arange(4096, dtype=np.int32)
    u_np = rng.uniform01(np.uint32(42), ctr)
    u_j = np.asarray(rng.uniform01(jnp.uint32(42),
                                   jnp.asarray(ctr)))
    np.testing.assert_array_equal(u_np, u_j)
    assert 0.0 <= u_np.min() and u_np.max() < 1.0


def test_in_kernel_rand_k_matches_materialized_generator():
    """The kernel draws uniforms from (seed, flat-index) counters on the
    fly; the oracle materializes the full array from the same hash.  The
    masks must be bit-identical."""
    v = jax.random.normal(KEY, (2048 + 9,))
    for seed in (0, 7, 2**31 - 1):
        sel_k, cnt_k = ops.rand_k_sparsify(v, 0.1, seed, interpret=True,
                                           block_rows=64)
        sel_r, cnt_r = ref.rand_k_select_ref(v, 0.1, seed)
        np.testing.assert_array_equal(np.asarray(sel_k), np.asarray(sel_r))
        assert int(cnt_k) == int(cnt_r)


def test_rand_k_streams_differ_by_seed():
    v = jnp.ones((4096,))
    _, c1 = ops.rand_k_sparsify(v, 0.5, 1, interpret=True)
    m1, _ = ops.rand_k_sparsify(v, 0.5, 1, interpret=True)
    m2, _ = ops.rand_k_sparsify(v, 0.5, 2, interpret=True)
    assert not np.array_equal(np.asarray(m1), np.asarray(m2))
    assert abs(int(c1) - 2048) < 200           # unbiased keep ratio


# ---------------------------------------------- fused v_max fold (histogram)

@pytest.mark.parametrize("n", [1000, 4096, 8192 + 333])
def test_fused_histogram_matches_two_pass(n):
    """Folding the |v| max into the histogram kernel's first sweep must
    leave the histogram, v_max — and therefore the DGC threshold and
    count — bit-identical to the old separate-pre-pass path."""
    v = jax.random.normal(KEY, (n,)) * 3.0
    hist_f, vmax_f = abs_histogram_fused(v, n_bins=256, block_rows=64,
                                         interpret=True)
    vmax = jnp.max(jnp.abs(v)).astype(jnp.float32)
    hist = abs_histogram(v, vmax, n_bins=256, block_rows=64, interpret=True)
    assert float(vmax_f) == float(vmax)
    np.testing.assert_array_equal(np.asarray(hist_f), np.asarray(hist))
    t_f = threshold_from_histogram(hist_f, vmax_f, jnp.float32(0.95))
    t = threshold_from_histogram(hist, vmax, jnp.float32(0.95))
    assert float(t_f) == float(t)


def test_bisection_oracle_matches_histogram_family():
    """`ref.dgc_sparsify_ref` finds the bin by bisection on cumulative
    counts; it must land on the same quantized threshold as the explicit
    histogram + searchsorted."""
    v = jax.random.normal(KEY, (50_000,)) * \
        jax.random.gamma(jax.random.PRNGKey(8), 0.7, (50_000,))
    for sp in (0.5, 0.9, 0.99, 0.999):
        _, _, t = ref.dgc_sparsify_ref(v, jnp.float32(sp))
        vm = jnp.max(jnp.abs(v)).astype(jnp.float32)
        hist = ref.abs_histogram_ref(v, 256, vm)
        t_h = threshold_from_histogram(hist, vm, jnp.float32(sp))
        assert float(t) == float(t_h)


# ------------------------------------------------------------- stickiness

def test_one_trial_then_sticky(monkeypatch, tmp_path):
    cache = tmp_path / "dispatch.json"
    monkeypatch.setenv("REPRO_DISPATCH_CACHE", str(cache))
    dispatch.reset_dispatcher()
    v = jax.random.normal(KEY, (2048,))
    w = jnp.ones((2048,))
    ops.gaia_select(v, w, 0.5)
    d = dispatch.get_dispatcher()
    assert d.trials == 1
    for _ in range(3):                         # same bucket: no re-timing
        ops.gaia_select(v, w, 0.5)
    assert d.trials == 1
    data = json.loads(cache.read_text())
    assert len(data) == 1
    (key, ent), = data.items()
    backend = jax.default_backend()
    assert key.startswith(f"{backend}/gaia_select/float32/")
    assert ent["label"] in ent["us"]

    # a fresh process (fresh dispatcher) reloads the file: zero trials
    dispatch.reset_dispatcher()
    ops.gaia_select(v, w, 0.5)
    assert dispatch.get_dispatcher().trials == 0


def test_distinct_buckets_get_distinct_trials(monkeypatch):
    d = dispatch.get_dispatcher()
    v = jax.random.normal(KEY, (1024,))
    ops.gaia_select(v, jnp.ones((1024,)), 0.5)
    t1 = d.trials
    big = jax.random.normal(KEY, (64 * 1024,))
    ops.gaia_select(big, jnp.ones((64 * 1024,)), 0.5)
    assert d.trials == t1 + 1                  # new size bucket → one trial


# -------------------------------------------------------------- overrides

def test_forced_paths_skip_trials(monkeypatch):
    _force(monkeypatch, "oracle")
    v = jax.random.normal(KEY, (4096,))
    ops.gaia_select(v, jnp.ones((4096,)), 0.5)
    assert dispatch.get_dispatcher().trials == 0
    _force(monkeypatch, "pallas")
    ops.gaia_select(v, jnp.ones((4096,)), 0.5)
    assert dispatch.get_dispatcher().trials == 0


def test_per_op_override_beats_global(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_DISPATCH", "pallas")
    monkeypatch.setenv("REPRO_KERNEL_DISPATCH_GAIA_SELECT", "oracle")
    dispatch.reset_dispatcher()
    d = dispatch.get_dispatcher()
    assert d.forced_path("gaia_select") == "oracle"
    assert d.forced_path("dgc_sparsify") == "pallas"


def test_invalid_override_raises(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_DISPATCH", "fastest")
    dispatch.reset_dispatcher()
    with pytest.raises(ValueError, match="fastest"):
        dispatch.get_dispatcher().forced_path("gaia_select")


def test_match_semantics():
    m = dispatch.KernelDispatch._match
    labels = ("oracle", "interpret:b256", "compiled:b64")
    assert m("oracle", labels) == "oracle"
    assert m("pallas", labels) == "interpret:b256"
    assert m("interpret", labels) == "interpret:b256"
    assert m("compiled", labels) == "compiled:b64"
    assert m("compiled", ("oracle", "interpret:b8")) is None
