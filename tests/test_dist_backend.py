"""End-to-end SPMD backend test (subprocess: needs its own device count)."""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_dist_backend_all_strategies():
    script = os.path.join(os.path.dirname(__file__), "dist_backend_script.py")
    out = subprocess.run([sys.executable, script], capture_output=True,
                         text=True, timeout=900)
    assert "ALL_DIST_OK" in out.stdout, out.stdout + "\n" + out.stderr
