"""Array-native fabric equivalence + scale suite.

Three pillars of the ledger/fabric redesign:

1. **Bit-equality** — the eid-indexed array `CommLedger` must reproduce
   the frozen pre-redesign dict ledger (`tests/_ledger_dictref.py`)
   float-for-float on every scenario shape the old suite exercised:
   sync/async, constant/sampled links, stragglers, probes, schedule
   rotation and mid-run switches, re-wiring floats, and amortized
   handshakes (windows 1 and 4, including thrash-forfeits).
2. **Participation** — the per-round client-sampling mask is seeded and
   replayable, fraction 1.0 is bit-exact legacy pricing, and the mask
   stream can never perturb the link model's draws.
3. **API surface** — every deprecated accessor shim fires exactly one
   DeprecationWarning and returns exactly what the `LedgerView`
   replacement reports; the 10k-node hierarchical builder and the
   mixing-matrix opt-out behave as documented.
"""
import warnings

import numpy as np
import pytest

from repro.kernels import rng
from repro.topology import (LINK_PROFILES, CommLedger, LinkModel,
                            MIXING_AUTO_MAX, Participation,
                            fully_connected, hierarchical,
                            hierarchical_cliques, ring,
                            time_varying_d_cliques)
from repro.configs.base import FabricConfig
from repro.topology.graphs import _build

from _ledger_dictref import DictCommLedger, DictLinkModel


def exclusive_hist(n_nodes: int, n_classes: int) -> np.ndarray:
    hist = np.zeros((n_nodes, n_classes))
    for k in range(n_nodes):
        hist[k, k % n_classes] = 100
    return hist


def ring_plus(n: int, extra, cls: str):
    cls_map = {e: "lan" for e in ring(n).edges}
    cls_map[(min(extra), max(extra))] = cls
    edges = sorted(cls_map)
    return _build(f"ring+{cls}", n, edges, [cls_map[e] for e in edges])


# ---------------------------------------------------------------------------
# 1. bit-equality vs the frozen dict ledger
# ---------------------------------------------------------------------------

def assert_ledgers_bit_equal(led: CommLedger, ref: DictCommLedger,
                             model_floats: float = 1234.0) -> None:
    """Every number the old dict ledger could report, bit-for-bit."""
    v = led.view()
    assert v.sim_time_s == ref.sim_time_s
    assert v.lan_floats == ref.lan_floats
    assert v.wan_floats == ref.wan_floats
    assert v.total_floats == ref.total_floats
    assert v.priced_cost == ref.priced_cost()
    assert v.sampled_priced_cost == ref.sampled_priced_cost()
    assert v.window_cost == ref.window_cost()
    assert v.rewire_lan_floats == ref.rewire_lan_floats
    assert v.rewire_wan_floats == ref.rewire_wan_floats
    assert v.rewire_floats == ref.rewire_floats
    assert v.rewiring_cost == ref.rewiring_cost()
    assert v.rewire_events == ref.rewire_events
    assert v.rewire_time_s == ref.rewire_time_s
    assert v.pending_handshake_s == ref.pending_handshake_s
    assert v.clock_skew_s == ref.clock_skew_s()
    assert v.rounds == ref.rounds
    assert v.edge_clock_map() == ref.edge_clocks()
    assert v.traffic_map() == ref.traffic_by_edge()
    np.testing.assert_array_equal(v.node_busy_s, ref.node_busy_s)
    np.testing.assert_array_equal(v.node_clock, ref.node_clocks())
    np.testing.assert_array_equal(v.node_idle_s, ref.node_idle_s)
    # measured-cost surface (EWMA state + pricing helpers)
    for n, e in enumerate(led.topology.edges):
        cls = led.topology.edge_class[n]
        assert v.measured_latency_s(e, cls) == \
            ref.measured_latency_s(e, cls), e
        assert v.measured_price_per_float(e, cls) == \
            ref.measured_price_per_float(e, cls), e
    assert v.full_exchange_cost(model_floats) == \
        ref.full_exchange_cost(model_floats)
    assert v.full_exchange_time(model_floats) == \
        ref.full_exchange_time(model_floats)
    assert v.measured_full_exchange_cost(model_floats) == \
        ref.measured_full_exchange_cost(model_floats)
    assert v.measured_full_exchange_time(model_floats) == \
        ref.measured_full_exchange_time(model_floats)
    assert v.cm_denominator(model_floats) == \
        ref.cm_denominator(model_floats)


def _pair(scn):
    """Build the (array ledger, dict reference) pair for one scenario."""
    prof = LINK_PROFILES[scn.get("profile", "geo-wan")]
    fabric = scn["fabric"]()
    lk = scn.get("link")
    lm = LinkModel(prof, **lk) if lk else None
    rlm = DictLinkModel(prof, **lk) if lk else None
    led = CommLedger(
        fabric, prof, async_mode=scn.get("async", False), link_model=lm,
        config=FabricConfig(rewire_floats=scn.get("rewire", 0.0),
                            amortize_window=scn.get("window", 1)),
        ewma_alpha=scn.get("ewma_alpha", 0.1))
    ref = DictCommLedger(
        fabric, prof, async_mode=scn.get("async", False), link_model=rlm,
        rewire_floats_per_edge=scn.get("rewire", 0.0),
        amortize_window=scn.get("window", 1),
        ewma_alpha=scn.get("ewma_alpha", 0.1))
    return led, ref


SCENARIOS = {
    # sync constant: gossip + exchange + probe on a rotating schedule
    "sync-tv-rewire": dict(
        fabric=lambda: time_varying_d_cliques(exclusive_hist(9, 3), seed=0),
        rewire=32.0, probe=True, exchange=True, rounds=12),
    # async bounded staleness on the same schedule
    "async-tv-stale": dict(
        fabric=lambda: time_varying_d_cliques(exclusive_hist(9, 3), seed=0),
        rewire=32.0, probe=True, exchange=True, rounds=12,
        **{"async": True}, staleness=2),
    # geo-wan hierarchy: WAN pricing dominates, sync and async
    "sync-hier": dict(fabric=lambda: hierarchical(6), rounds=10,
                      exchange=True),
    "async-hier": dict(fabric=lambda: hierarchical(6), rounds=10,
                       **{"async": True}, staleness=1),
    # sampled links: jitter + hetero + Markov stragglers, EWMA folds
    "sync-sampled": dict(
        fabric=lambda: ring(8), profile="datacenter", rounds=40,
        link=dict(seed=3, jitter=0.3, hetero=0.2, straggler_rate=0.1,
                  straggler_exit=0.4, straggler_slowdown=25.0),
        ewma_alpha=0.05, exchange=True),
    "async-sampled": dict(
        fabric=lambda: ring(8), profile="datacenter", rounds=40,
        link=dict(seed=7, jitter=0.3, straggler_rate=0.1,
                  straggler_slowdown=25.0),
        **{"async": True}, staleness=2, probe=True),
    # sampled on a rotating schedule (per-edge draw counters must agree
    # across graphs sharing edges)
    "async-sampled-tv": dict(
        fabric=lambda: time_varying_d_cliques(exclusive_hist(9, 3), seed=0),
        rounds=18, link=dict(seed=5, jitter=0.2, straggler_rate=0.05),
        **{"async": True}, staleness=1, exchange=True),
    # amortized handshake: persisting switch, window 4
    "amortize-w4": dict(
        fabric=lambda: ring(6), rounds=10, window=4, rewire=16.0,
        switch=[(1, lambda: ring_plus(6, (0, 3), "wan"))]),
    # thrash: drop links mid-window, forfeits booked (sync and async)
    "thrash-w4": dict(
        fabric=lambda: ring(6), rounds=9, window=4, rewire=16.0,
        switch=[(t, (lambda: ring_plus(6, (0, 3), "wan")) if t % 2
                 else (lambda: ring(6))) for t in range(1, 9)]),
    "thrash-w4-async": dict(
        fabric=lambda: ring(6), rounds=9, window=4, rewire=16.0,
        **{"async": True}, staleness=1,
        switch=[(t, (lambda: ring_plus(6, (0, 3), "wan")) if t % 2
                 else (lambda: ring(6))) for t in range(1, 9)]),
    # mid-run switch to a denser fabric (SkewScout rung climb)
    "switch-dense": dict(
        fabric=lambda: time_varying_d_cliques(exclusive_hist(9, 3), seed=0),
        rounds=8, rewire=50.0, probe=True,
        switch=[(4, lambda: fully_connected(9))]),
}


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_array_ledger_bit_equals_dict_reference(name):
    """Acceptance: the array-native ledger reproduces the frozen dict
    implementation bit-for-bit on every legacy scenario shape."""
    scn = SCENARIOS[name]
    led, ref = _pair(scn)
    switches = dict((t, fn) for t, fn in scn.get("switch", []))
    stale = scn.get("staleness")
    for t in range(scn["rounds"]):
        if t in switches:
            g = switches[t]()
            led.switch_schedule(g)
            ref.switch_schedule(g)
        for l in (led, ref):
            l.record_gossip(1000.0, t=t, staleness=stale)
        if scn.get("exchange"):
            for l in (led, ref):
                l.record_exchange(40.0)
        if scn.get("probe"):
            e = led.topology.edges[t % len(led.topology.edges)]
            for l in (led, ref):
                l.record_probe([e], 25.0)
        # equality must hold at every step, not only at the end
        if t % 5 == 0:
            assert_ledgers_bit_equal(led, ref)
    assert_ledgers_bit_equal(led, ref)


def test_view_is_version_cached_and_frozen():
    """Repeated view() calls between mutations return the same object;
    a held view is a snapshot that survives later mutation."""
    led = CommLedger(ring(6), LINK_PROFILES["geo-wan"])
    led.record_gossip(100.0, t=0)
    v1 = led.view()
    assert led.view() is v1
    before = v1.total_floats
    led.record_gossip(100.0, t=1)
    v2 = led.view()
    assert v2 is not v1
    assert v1.total_floats == before          # the snapshot did not move
    assert v2.total_floats > before


# ---------------------------------------------------------------------------
# 2. participation: seeded, replayable, isolated, bit-exact at 1.0
# ---------------------------------------------------------------------------

def test_participation_masks_replayable_and_fraction_bounds():
    p1 = Participation(64, 0.3, seed=9)
    p2 = Participation(64, 0.3, seed=9)
    other = Participation(64, 0.3, seed=10)
    seen_diff = False
    for t in range(50):
        m = p1.mask(t)
        np.testing.assert_array_equal(m, p2.mask(t))
        seen_diff |= (m != other.mask(t)).any()
        assert m.dtype == bool and m.shape == (64,)
    assert seen_diff                      # the seed actually matters
    # fraction endpoints
    assert Participation(16, 1.0, seed=0).mask(3).all()
    frac = np.mean([Participation(64, 0.25, seed=1).mask(t).mean()
                    for t in range(200)])
    assert abs(frac - 0.25) < 0.05, frac


def test_participation_fraction_one_is_bit_exact_legacy():
    prof = LINK_PROFILES["geo-wan"]
    sched = time_varying_d_cliques(exclusive_hist(9, 3), seed=0)
    plain = CommLedger(sched, prof, async_mode=True)
    everyone = CommLedger(sched, prof, async_mode=True,
                          participation=Participation(9, 1.0, seed=4))
    for t in range(12):
        for led in (plain, everyone):
            led.record_gossip(500.0, t=t, staleness=1)
    assert everyone.sim_time_s == plain.sim_time_s
    assert everyone.view().total_floats == plain.view().total_floats
    assert everyone.view().edge_clock_map() == plain.view().edge_clock_map()


def test_participation_prices_only_edges_with_both_endpoints_in():
    prof = LINK_PROFILES["uniform"]
    part = Participation(8, 0.5, seed=2)
    led = CommLedger(ring(8), prof, participation=part)
    full = CommLedger(ring(8), prof)
    for t in range(20):
        led.record_gossip(100.0, t=t)
        full.record_gossip(100.0, t=t)
    # cumulative total: recompute from the masks directly
    expect = sum(2 * 100.0
                 for t in range(20)
                 for (i, j) in ring(8).edges
                 if part.mask(t)[i] and part.mask(t)[j])
    assert led.view().total_floats == expect
    assert led.view().total_floats < full.view().total_floats


def test_participation_stream_cannot_perturb_link_draws():
    """Link sampling and participation masks are tag-disjoint streams
    under one seed: drawing masks between rounds must leave the sampled
    ledger's numbers untouched."""
    prof = LINK_PROFILES["datacenter"]

    def run(interleave: bool):
        lm = LinkModel(prof, seed=6, jitter=0.4, straggler_rate=0.2)
        led = CommLedger(ring(8), prof, link_model=lm)
        p = Participation(8, 0.5, seed=6)
        for t in range(30):
            if interleave:
                p.mask(t)                  # burn the mask stream
            led.record_gossip(1e4, t=t)
        return led.sim_time_s, led.view().sampled_priced_cost

    assert run(False) == run(True)


# ---------------------------------------------------------------------------
# 3. deprecated accessor shims: one warning, identical value
# ---------------------------------------------------------------------------

def _drive_shim_ledger():
    prof = LINK_PROFILES["geo-wan"]
    lm = LinkModel(prof, seed=1, jitter=0.2, straggler_rate=0.1)
    led = CommLedger(time_varying_d_cliques(exclusive_hist(9, 3), seed=0),
                     prof, async_mode=True, link_model=lm,
                     config=FabricConfig(rewire_floats=8.0,
                                         amortize_window=2))
    for t in range(6):
        led.record_gossip(500.0, t=t, staleness=1)
    return led


SHIM_CASES = [
    ("traffic_by_edge", lambda l: l.traffic_by_edge(),
     lambda v: v.traffic_map(), "eq"),
    ("edge_traffic", lambda l: l.edge_traffic,
     lambda v: v.edge_traffic[v.union_eids], "array"),
    ("edge_clocks", lambda l: l.edge_clocks(),
     lambda v: v.edge_clock_map(), "eq"),
    ("node_clocks", lambda l: l.node_clocks(),
     lambda v: v.node_clock, "array"),
    ("clock_skew_s", lambda l: l.clock_skew_s(),
     lambda v: v.clock_skew_s, "eq"),
    ("node_idle_s", lambda l: l.node_idle_s,
     lambda v: v.node_idle_s, "array"),
    ("total_floats", lambda l: l.total_floats,
     lambda v: v.total_floats, "eq"),
    ("priced_cost", lambda l: l.priced_cost(),
     lambda v: v.priced_cost, "eq"),
    ("sampled_priced_cost", lambda l: l.sampled_priced_cost(),
     lambda v: v.sampled_priced_cost, "eq"),
    ("rewire_floats", lambda l: l.rewire_floats,
     lambda v: v.rewire_floats, "eq"),
    ("rewiring_cost", lambda l: l.rewiring_cost(),
     lambda v: v.rewiring_cost, "eq"),
    ("full_exchange_cost", lambda l: l.full_exchange_cost(1e3),
     lambda v: v.full_exchange_cost(1e3), "eq"),
    ("full_exchange_time", lambda l: l.full_exchange_time(1e3),
     lambda v: v.full_exchange_time(1e3), "eq"),
    ("measured_latency_s", lambda l: l.measured_latency_s((0, 1), "lan"),
     lambda v: v.measured_latency_s((0, 1), "lan"), "eq"),
    ("measured_price_per_float",
     lambda l: l.measured_price_per_float((0, 1), "lan"),
     lambda v: v.measured_price_per_float((0, 1), "lan"), "eq"),
    ("measured_full_exchange_cost",
     lambda l: l.measured_full_exchange_cost(1e3),
     lambda v: v.measured_full_exchange_cost(1e3), "eq"),
    ("measured_full_exchange_time",
     lambda l: l.measured_full_exchange_time(1e3),
     lambda v: v.measured_full_exchange_time(1e3), "eq"),
    ("window_cost", lambda l: l.window_cost(),
     lambda v: v.window_cost, "eq"),
    ("cm_denominator", lambda l: l.cm_denominator(1e3),
     lambda v: v.cm_denominator(1e3), "eq"),
    ("pending_handshake_s", lambda l: l.pending_handshake_s,
     lambda v: v.pending_handshake_s, "eq"),
]


@pytest.mark.parametrize("name,old,new,kind",
                         SHIM_CASES, ids=[c[0] for c in SHIM_CASES])
def test_deprecated_shim_warns_once_and_matches_view(name, old, new, kind):
    led = _drive_shim_ledger()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        got = old(led)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1, (name, [str(w.message) for w in rec])
    assert name in str(dep[0].message)
    assert "view()" in str(dep[0].message)
    want = new(led.view())
    if kind == "array":
        np.testing.assert_array_equal(got, want)
    else:
        assert got == want, name


# ---------------------------------------------------------------------------
# 4. RNG: vectorized key fold == scalar fold
# ---------------------------------------------------------------------------

def test_fold_keys_matches_scalar_fold_key():
    """fold_keys continues an already-folded scalar key elementwise,
    bit-equal to the scalar fold_key over the same components."""
    ei = np.arange(7, dtype=np.int64)
    ej = np.arange(7, 14, dtype=np.int64)
    base = rng.fold_key(123, 0x0C)
    vec = rng.fold_keys(base, ei, ej)
    assert vec.dtype == np.uint32
    for n in range(7):
        assert int(vec[n]) == rng.fold_key(123, 0x0C, n, n + 7)
    # single-array continuation also matches
    np.testing.assert_array_equal(
        rng.fold_keys(rng.fold_key(5), np.arange(4)),
        np.array([rng.fold_key(5, k) for k in range(4)], np.uint32))


# ---------------------------------------------------------------------------
# 5. scale: hierarchical cliques + mixing-matrix opt-out
# ---------------------------------------------------------------------------

def test_hierarchical_cliques_structure():
    topo = hierarchical_cliques(1000, clique_size=10)
    assert topo.n_nodes == 1000
    # level 0: 100 cliques of 10 -> 45 LAN edges each; gateways recurse
    assert len(topo.cliques) == 100
    deg = topo.degrees()
    assert deg.min() >= 9                  # everyone is in a LAN clique
    assert len(topo.wan_edge_indices()) > 0
    # level-0 edges are LAN, gateway edges are WAN
    wan = set(int(n) for n in topo.wan_edge_indices())
    for n, (i, j) in enumerate(topo.edges):
        same_clique = i // 10 == j // 10
        assert (n not in wan) == same_clique, (n, i, j)
    # connected end to end (gossip can mix across the whole fabric)
    led = CommLedger(topo, LINK_PROFILES["geo-wan"])
    assert led.topology.n_nodes == 1000


def test_hierarchical_cliques_connected_at_10k():
    topo = hierarchical_cliques(10_000, clique_size=25)
    assert topo.n_nodes == 10_000
    assert topo.mixing is None             # past MIXING_AUTO_MAX
    assert topo.degrees().max() < 100      # bounded degree, not K^2
    # label-propagation connectivity check is itself vectorized
    from repro.topology.graphs import _connected
    assert _connected(10_000, topo.edges)


def test_mixing_auto_skip_and_guarded_accessors():
    big = ring(MIXING_AUTO_MAX + 1)
    assert big.mixing is None
    with pytest.raises(AssertionError, match="mixing"):
        big.spectral_gap()
    small = ring(8)
    assert small.mixing is not None
    assert small.spectral_gap() > 0


def test_scale_ledger_prices_10k_rounds_fast():
    """The CI-gated smoke in benchmarks/fig_topology.py --smoke-scale
    runs 50 rounds; here a short ledger-only sanity keeps the invariant
    under test without the bench budget."""
    import time
    topo = hierarchical_cliques(10_000, clique_size=25)
    prof = LINK_PROFILES["geo-wan"]
    lm = LinkModel(prof, seed=0, jitter=0.1, straggler_rate=0.05)
    led = CommLedger(topo, prof, async_mode=True, link_model=lm,
                     participation=Participation(10_000, 0.1, seed=0))
    t0 = time.perf_counter()
    for t in range(5):
        led.record_gossip(1e6, t=t, staleness=1)
    wall = time.perf_counter() - t0
    assert led.view().total_floats > 0
    assert wall < 5.0, wall                 # O(active edges) per round
