"""Direct unit tests for the HLO text parser (repro.analysis.hlo) on
crafted snippets: module/instruction parsing, replica-group decoding
(literal and iota forms), trip-count multiplicities, in-place
dynamic-update-slice byte modeling, and the pod-exchange classifier.
The shim ``repro.launch.hlo_analysis`` must keep re-exporting all of
it for external callers."""
import pytest

from repro.analysis import hlo

MODULE = """\
HloModule crafted

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

%body (t: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %t = (s32[], f32[4,4]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %x = f32[4,4]{1,0} get-tuple-element(%t), index=1
  %d = f32[4,4]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[4,4]{1,0} all-reduce(%d), replica_groups={{0,1},{2,3}}, to_apply=%add
  ROOT %out = (s32[], f32[4,4]{1,0}) tuple(%i, %ar)
}

%cond (t: (s32[], f32[4,4])) -> pred[] {
  %t = (s32[], f32[4,4]{1,0}) parameter(0)
  ROOT %p = pred[] constant(true)
}

ENTRY %main (p0: f32[4,4]) -> (s32[], f32[4,4]) {
  %p0 = f32[4,4]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %init = (s32[], f32[4,4]{1,0}) tuple(%c0, %p0)
  ROOT %w = (s32[], f32[4,4]{1,0}) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
}
"""


class TestParseModule:
    def test_computations_and_entry(self):
        comps = hlo.parse_module(MODULE)
        assert set(comps) == {"add", "body", "cond", "main"}
        assert comps["main"].is_entry
        assert not comps["body"].is_entry

    def test_instruction_fields(self):
        comps = hlo.parse_module(MODULE)
        d = next(i for i in comps["body"].instrs if i.name == "d")
        assert d.op == "dot"
        assert d.type_str == "f32[4,4]{1,0}"
        assert "lhs_contracting_dims={1}" in d.rest
        assert not d.is_root

    def test_root_flag_and_tuple_types(self):
        comps = hlo.parse_module(MODULE)
        root = next(i for i in comps["main"].instrs if i.is_root)
        assert root.name == "w" and root.op == "while"
        assert root.type_str.startswith("(s32[]")

    def test_shape_bytes(self):
        assert hlo._shape_bytes("f32[4,4]{1,0}") == 64
        assert hlo._shape_bytes("(s32[], f32[4,4]{1,0})") == 68
        assert hlo._shape_bytes("bf16[8]") == 16
        assert hlo._shape_bytes("token[]") == 0


class TestMultiplicities:
    def test_while_trip_count_composes(self):
        mult = hlo._multiplicities(hlo.parse_module(MODULE))
        assert mult["main"] == 1.0
        assert mult["body"] == 12.0
        # to_apply callee inherits the body's multiplicity
        assert mult["add"] == 12.0
        # condition computations are deliberately not costed
        assert "cond" not in mult

    def test_uncalled_computation_has_no_multiplicity(self):
        text = MODULE.replace(
            ", to_apply=%add", "").replace("to_apply=%add", "")
        mult = hlo._multiplicities(hlo.parse_module(text))
        assert "add" not in mult


class TestReplicaGroups:
    def test_literal_form(self):
        g = hlo._parse_replica_groups("replica_groups={{0,1},{2,3}}")
        assert g == [[0, 1], [2, 3]]

    def test_iota_form(self):
        g = hlo._parse_replica_groups("replica_groups=[2,2]<=[4]")
        assert g == [[0, 1], [2, 3]]

    def test_iota_with_transpose(self):
        g = hlo._parse_replica_groups(
            "replica_groups=[2,2]<=[2,2]T(1,0)")
        assert g == [[0, 2], [1, 3]]

    def test_absent_means_all_devices(self):
        assert hlo._parse_replica_groups("channel_id=1") == []

    def test_present_but_unparseable_is_none(self):
        assert hlo._parse_replica_groups(
            "replica_groups=<weird v3 form>") is None

    def test_pairs(self):
        p = hlo._parse_pairs("source_target_pairs={{0,1},{1,0}}")
        assert p == [(0, 1), (1, 0)]
        assert hlo._parse_pairs("replica_groups={{0,1}}") is None


class TestDusUpdateBytes:
    def test_bare_dus_counts_update_twice(self):
        text = """\
ENTRY %main (p0: f32[128,16], u: f32[1,16]) -> f32[128,16] {
  %p0 = f32[128,16]{1,0} parameter(0)
  %u = f32[1,16]{1,0} parameter(1)
  %z = s32[] constant(0)
  ROOT %dus = f32[128,16]{1,0} dynamic-update-slice(%p0, %u, %z, %z)
}
"""
        comps = hlo.parse_module(text)
        ent = comps["main"]
        symtab = {i.name: i.type_str for i in ent.instrs}
        dus = next(i for i in ent.instrs if i.op == "dynamic-update-slice")
        # modeled in-place traffic: 2x the 1x16 f32 update = 128 bytes,
        # NOT 2x the 128x16 buffer
        assert hlo._dus_update_bytes(dus, comps, symtab) == 128.0

    def test_non_dus_is_none(self):
        comps = hlo.parse_module(MODULE)
        ent = comps["main"]
        symtab = {i.name: i.type_str for i in ent.instrs}
        w = next(i for i in ent.instrs if i.op == "while")
        assert hlo._dus_update_bytes(w, comps, symtab) is None


class TestAnalyze:
    def test_dot_flops_trip_multiplied(self):
        cost = hlo.analyze(MODULE)
        # dot: 2 * 16 out elems * k=4 contraction = 128 flops x 12 trips
        assert cost.flops == 12 * 128

    def test_collective_bytes_trip_multiplied(self):
        cost = hlo.analyze(MODULE)
        assert cost.collective_bytes["all-reduce"] == 12 * 64
        assert cost.coll_total == 12 * 64


POD_HLO = """\
ENTRY %main (p0: bf16[32]) -> bf16[32] {
  %p0 = bf16[32]{0} parameter(0)
  %cp = bf16[32]{0} collective-permute(%p0), source_target_pairs={{0,2},{2,0},{1,3},{3,1}}
  %lp = bf16[32]{0} collective-permute(%cp), source_target_pairs={{0,1},{1,0}}
  %ar = bf16[32]{0} all-reduce(%lp), replica_groups={{0,1},{2,3}}
  ROOT %ag = bf16[32]{0} all-gather(%ar), replica_groups={{0,2},{1,3}}, dimensions={0}
}
"""


class TestPodExchange:
    def test_classification(self):
        rep = hlo.pod_exchange_report(POD_HLO, 2)
        assert rep.permute_cross_bytes == 64.0   # 0<->2, 1<->3
        assert rep.permute_local_bytes == 64.0   # 0<->1 inside pod 0
        assert rep.reduce_local_bytes == 64.0    # groups {0,1},{2,3}
        assert rep.reduce_cross_bytes == 64.0    # groups {0,2},{1,3}
        assert rep.pod_axis_only
        assert rep.unparsed == 0
        assert rep.cross_pod_bytes == 128.0

    def test_off_axis_pair_flips_pod_axis_only(self):
        text = POD_HLO.replace("{{0,2},{2,0},{1,3},{3,1}}",
                               "{{0,3},{3,0}}")
        rep = hlo.pod_exchange_report(text, 2)
        assert not rep.pod_axis_only

    def test_unparseable_groups_count_cross_and_unparsed(self):
        text = POD_HLO.replace("replica_groups={{0,1},{2,3}}",
                               "replica_groups=<v3>")
        rep = hlo.pod_exchange_report(text, 2)
        assert rep.unparsed == 1
        assert rep.reduce_cross_bytes == 128.0   # conservative bucket


class TestLaunchShim:
    def test_reexports(self):
        from repro.launch import hlo_analysis as shim
        for name in ("parse_module", "analyze", "pod_exchange_report",
                     "PodExchange", "HLOCost", "COLLECTIVES",
                     "_parse_replica_groups", "_dus_update_bytes"):
            assert getattr(shim, name) is getattr(hlo, name), name
