"""Per-kernel allclose vs the pure-jnp oracles, swept over shapes/dtypes
(interpret=True executes the Pallas kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("shape", [(1, 2, 128, 128, 64), (2, 1, 64, 192, 64),
                                   (1, 1, 200, 200, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kw", [
    dict(causal=True), dict(causal=False), dict(causal=True, window=64),
    dict(causal=True, logit_softcap=30.0)])
def test_flash_attention_matches_ref(shape, dtype, kw):
    B, H, Tq, Tk, D = shape
    q = jax.random.normal(KEY, (B, H, Tq, D), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, Tk, D), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, Tk, D), dtype)
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64, **kw)
    expect = ref.flash_attention_ref(q, k, v, **kw)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("n", [37, 1024, 8192 + 13])
@pytest.mark.parametrize("threshold", [0.0, 0.5, 2.0])
def test_gaia_select_matches_ref(n, threshold):
    v = jax.random.normal(KEY, (n,))
    w = jax.random.normal(jax.random.PRNGKey(1), (n,)) * 0.3
    sel, cnt = ops.gaia_select(v, w, threshold)
    rsel, rcnt = ref.gaia_select_ref(v, w, threshold)
    np.testing.assert_allclose(np.asarray(sel), np.asarray(rsel))
    assert int(cnt) == int(rcnt)


@pytest.mark.parametrize("shape", [(5000,), (100, 77), (17, 33, 9)])
@pytest.mark.parametrize("sparsity", [0.75, 0.99])
def test_dgc_sparsify_sparsity_bound(shape, sparsity):
    v = jax.random.normal(KEY, shape)
    sel, cnt, t = ops.dgc_sparsify(v, jnp.float32(sparsity))
    achieved = 1.0 - int(cnt) / v.size
    # histogram threshold is exact to one bin width
    assert abs(achieved - sparsity) < 0.02, (achieved, sparsity)
    # every surviving entry exceeds the threshold
    nz = np.asarray(sel)[np.asarray(sel) != 0]
    assert np.all(np.abs(nz) > float(t))


def test_dgc_histogram_matches_ref():
    v = jax.random.normal(KEY, (4096,))
    vmax = jnp.max(jnp.abs(v))
    from repro.kernels.dgc_topk import abs_histogram
    hist = abs_histogram(v, vmax, interpret=True)
    expect = ref.abs_histogram_ref(v, 256, vmax)
    np.testing.assert_array_equal(np.asarray(hist), np.asarray(expect))


@pytest.mark.parametrize("shape", [(2, 8, 8, 16), (4, 4, 4, 32)])
@pytest.mark.parametrize("group_size", [2, 4])
def test_group_norm_matches_ref(shape, group_size):
    x = jax.random.normal(KEY, shape)
    c = shape[-1]
    scale = jax.random.normal(jax.random.PRNGKey(1), (c,)) * 0.1 + 1.0
    bias = jax.random.normal(jax.random.PRNGKey(2), (c,)) * 0.1
    out = ops.group_norm(x, scale, bias, group_size=group_size)
    expect = ref.group_norm_ref(x, scale, bias, group_size=group_size)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5, rtol=1e-5)


def test_chunked_attention_matches_flash_ref():
    """The pure-jnp production attention agrees with the kernel oracle."""
    from repro.models.attention import chunked_attention
    B, H, T, D = 2, 4, 96, 32
    q = jax.random.normal(KEY, (B, T, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, H, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, H, D))
    out = chunked_attention(q, k, v, causal=True, chunk=32)
    expect = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


def test_chunked_attention_gqa_expansion():
    from repro.models.attention import chunked_attention
    B, Hq, Hkv, T, D = 1, 8, 2, 64, 16
    q = jax.random.normal(KEY, (B, T, Hq, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, Hkv, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, Hkv, D))
    out = chunked_attention(q, k, v, causal=True, chunk=16)
    # oracle: manual expansion
    km = jnp.repeat(k, Hq // Hkv, axis=2)
    vm = jnp.repeat(v, Hq // Hkv, axis=2)
    expect = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), km.transpose(0, 2, 1, 3),
        vm.transpose(0, 2, 1, 3), causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)
