"""Launch-vs-core equivalence for every communication strategy, plus the
pod-gossip contracts (compile-once, bit-exact staleness 0, pod-axis-only
exchange).  The heavy lifting runs once in a subprocess (it needs its own
XLA device count); the parametrized tests assert its per-strategy
markers, so a failure names the strategy that drifted."""
import os
import subprocess
import sys

import pytest

STRATEGIES = ("bsp", "gaia", "fedavg", "dgc", "dpsgd", "adpsgd")


@pytest.fixture(scope="module")
def gossip_output():
    script = os.path.join(os.path.dirname(__file__),
                          "launch_gossip_script.py")
    out = subprocess.run([sys.executable, script], capture_output=True,
                         text=True, timeout=900)
    assert "ALL_LAUNCH_GOSSIP_OK" in out.stdout, \
        out.stdout + "\n" + out.stderr
    return out.stdout


@pytest.mark.slow
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_launch_matches_core(gossip_output, strategy):
    assert f"EQ_OK {strategy}" in gossip_output


@pytest.mark.slow
def test_adpsgd_staleness0_bitwise_dpsgd(gossip_output):
    assert "BITWISE_OK adpsgd0==dpsgd" in gossip_output


@pytest.mark.slow
def test_pod_gossip_compiles_once(gossip_output):
    assert "COMPILE_ONCE_OK dpsgd rotation" in gossip_output
    assert "COMPILE_ONCE_OK adpsgd staleness move" in gossip_output


@pytest.mark.slow
def test_exchange_is_pod_axis_only(gossip_output):
    assert "PODAXIS_OK" in gossip_output
