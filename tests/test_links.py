"""Stochastic heterogeneous links: seeded determinism of the LinkModel,
exact constant-profile reproduction at zero rates, transient stragglers
(async strictly beats sync on an all-LAN fabric), amortized handshake
invariants, EWMA measured-cost convergence, SkewScout's measured CM
denominator, and the shared greedy-clique helper's seed isolation."""
import dataclasses

import numpy as np
import pytest

from repro.configs.base import CommConfig, FabricConfig, LinkConfig
from repro.topology import (LINK_PROFILES, CommLedger, LinkModel,
                            d_cliques, fully_connected,
                            greedy_clique_assignment, make_link_model,
                            ring, time_varying_d_cliques)
from repro.topology.graphs import _build


def exclusive_hist(n_nodes: int, n_classes: int) -> np.ndarray:
    hist = np.zeros((n_nodes, n_classes))
    for k in range(n_nodes):
        hist[k, k % n_classes] = 100
    return hist


def ring_plus(n: int, extra, cls: str):
    """ring(n) plus one extra edge of the given link class."""
    cls_map = {e: "lan" for e in ring(n).edges}
    cls_map[(min(extra), max(extra))] = cls
    edges = sorted(cls_map)
    return _build(f"ring+{cls}", n, edges, [cls_map[e] for e in edges])


# ---------------------------------------------------------------------------
# seeded determinism & replay
# ---------------------------------------------------------------------------

def test_link_model_same_seed_bit_identical_across_rebuilds():
    """Acceptance: same key => bit-identical sampled round times when a
    fresh LinkModel + ledger replay the same sequence of calls."""
    prof = LINK_PROFILES["datacenter"]
    sched = time_varying_d_cliques(exclusive_hist(9, 3), seed=0)

    def build():
        lm = LinkModel(prof, seed=3, jitter=0.3, hetero=0.2,
                       straggler_rate=0.05)
        led = CommLedger(sched, prof, async_mode=True, link_model=lm)
        for t in range(3 * sched.period):
            led.record_gossip(1e4, t=t, staleness=1)
            led.record_exchange(100.0)
        return led

    a, b = build(), build()
    assert a.sim_time_s == b.sim_time_s          # bitwise, not approx
    assert a.view().edge_clock_map() == b.view().edge_clock_map()
    np.testing.assert_array_equal(a.node_busy_s, b.node_busy_s)
    assert a.links.slow_activations == b.links.slow_activations


def test_link_model_different_seed_differs():
    prof = LINK_PROFILES["datacenter"]
    times = set()
    for seed in (0, 1, 2):
        lm = LinkModel(prof, seed=seed, jitter=0.5)
        led = CommLedger(ring(6), prof, link_model=lm)
        for t in range(10):
            led.record_gossip(1e4, t=t)
        times.add(led.sim_time_s)
    assert len(times) == 3, times


# ---------------------------------------------------------------------------
# zero rates == constant profile, exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("async_mode", [False, True],
                         ids=["sync", "async"])
def test_zero_rate_sampled_ledger_equals_constant_exactly(async_mode):
    """Acceptance: jitter = straggler = hetero = 0 and amortize_window=1
    must reproduce the constant-profile ledger's totals exactly —
    gossip, exchanges, probes, and schedule rotation included."""
    prof = LINK_PROFILES["geo-wan"]
    sched = time_varying_d_cliques(exclusive_hist(9, 3), seed=0)
    const = CommLedger(sched, prof,
                       config=FabricConfig(rewire_floats=32.0),
                       async_mode=async_mode)
    sampled = CommLedger(sched, prof,
                         config=FabricConfig(rewire_floats=32.0,
                                             amortize_window=1),
                         async_mode=async_mode,
                         link_model=LinkModel(prof, seed=7))
    probe_edge = const.topology.edges[0]
    for t in range(2 * sched.period):
        for led in (const, sampled):
            led.record_gossip(500.0, t=t,
                              staleness=1 if async_mode else None)
            led.record_exchange(40.0)
            led.record_probe([probe_edge], 25.0)
    assert sampled.sim_time_s == const.sim_time_s
    assert sampled.view().priced_cost == const.view().priced_cost
    assert sampled.lan_floats == const.lan_floats
    assert sampled.wan_floats == const.wan_floats
    assert sampled.rewire_time_s == const.rewire_time_s
    assert sampled.view().edge_clock_map() == const.view().edge_clock_map()


# ---------------------------------------------------------------------------
# transient stragglers: the async headline claim
# ---------------------------------------------------------------------------

def test_straggler_async_strictly_beats_sync_on_lan_fabric():
    """With straggler_rate > 0 on an otherwise-LAN fabric, async wall
    clock is strictly below sync for identical traffic: sync pays every
    round's slowest link (sum of per-round maxes), async only the hit
    link's own clock (max of per-edge sums)."""
    prof = LINK_PROFILES["datacenter"]
    times = {}
    for name, async_mode in (("sync", False), ("async", True)):
        lm = LinkModel(prof, seed=7, straggler_rate=0.1,
                       straggler_slowdown=25.0)
        led = CommLedger(ring(10), prof, async_mode=async_mode,
                         link_model=lm)
        for t in range(50):
            led.record_gossip(1e5, t=t,
                              staleness=2 if async_mode else None)
        times[name] = led.sim_time_s
        assert lm.slow_activations > 0       # the chain actually fired
    assert times["async"] < times["sync"], times


def test_straggler_gap_opens_only_when_stragglers_exist():
    """On an all-LAN fabric the sync/async ratio is ~1 without
    stragglers (nothing to overlap: every link costs the same) and
    opens wide once transient slowdowns appear — the claim the
    fig_topology straggler sweep plots.  (The ratio is *not* monotone
    in the rate: at saturating rates every edge is slow at once and
    async's per-edge sums inflate too.)"""
    prof = LINK_PROFILES["datacenter"]
    ratios = {}
    for rate in (0.0, 0.1):
        t = {}
        for name, async_mode in (("sync", False), ("async", True)):
            lm = LinkModel(prof, seed=11, straggler_rate=rate,
                           straggler_slowdown=25.0)
            led = CommLedger(ring(10), prof, async_mode=async_mode,
                             link_model=lm)
            for r in range(60):
                led.record_gossip(1e5, t=r,
                                  staleness=2 if async_mode else None)
            t[name] = led.sim_time_s
        ratios[rate] = t["sync"] / t["async"]
    # rate 0: only the bounded-staleness amortization of the (tiny) LAN
    # latency separates the modes — ratio within ~10% of 1
    assert ratios[0.0] == pytest.approx(1.0, abs=0.12), ratios
    assert ratios[0.1] > 2.0 * ratios[0.0], ratios


def test_markov_slow_fraction_tracks_stationary_distribution():
    """Two-state chain: stationary slow fraction = rate/(rate+exit)."""
    prof = LINK_PROFILES["datacenter"]
    lm = LinkModel(prof, seed=0, straggler_rate=0.2, straggler_exit=0.4)
    led = CommLedger(ring(8), prof, link_model=lm)
    for t in range(600):
        led.record_gossip(100.0, t=t)
    expect = 0.2 / (0.2 + 0.4)
    assert abs(lm.slow_fraction() - expect) < 0.08, \
        (lm.slow_fraction(), expect)


# ---------------------------------------------------------------------------
# amortized handshake invariants
# ---------------------------------------------------------------------------

def test_amortized_handshake_conserves_total_and_flattens_spike():
    """A persisting rung switch pays the same total handshake whatever
    the window, but the per-round spike flattens: the first round after
    the switch is strictly cheaper with W > 1, and the balance drains
    to zero within W activations."""
    prof = LINK_PROFILES["geo-wan"]
    first_round_delta, totals = {}, {}
    for W in (1, 4):
        led = CommLedger(ring(6), prof,
                         config=FabricConfig(amortize_window=W))
        led.record_gossip(100.0, t=0)
        led.switch_schedule(ring_plus(6, (0, 3), "wan"))
        before = led.sim_time_s
        led.record_gossip(100.0, t=1)
        first_round_delta[W] = led.sim_time_s - before
        for t in range(2, 10):
            led.record_gossip(100.0, t=t)
        assert led.view().pending_handshake_s == pytest.approx(0.0, abs=1e-15)
        totals[W] = led.rewire_time_s
    # total handshake seconds booked are window-independent
    assert totals[4] == pytest.approx(totals[1])
    assert totals[1] >= prof.handshake("wan")
    # ... but the switch-round spike is flattened by the window
    assert first_round_delta[4] < first_round_delta[1], first_round_delta
    # un-amortized spike carries the whole WAN handshake at once
    assert first_round_delta[1] - first_round_delta[4] > \
        0.5 * prof.handshake("wan")


def test_thrashing_forfeits_balance_and_stays_expensive():
    """Flapping between fabrics drops links mid-window: the unpaid
    balance is forfeited at teardown, so amortization gives thrashing
    no discount — same rewire seconds as the un-amortized ledger."""
    prof = LINK_PROFILES["geo-wan"]
    g1, g2 = ring(6), ring_plus(6, (0, 3), "wan")
    totals, busy = {}, {}
    for W in (1, 4):
        led = CommLedger(g1, prof,
                         config=FabricConfig(rewire_floats=16.0,
                                             amortize_window=W))
        led.record_gossip(100.0, t=0)
        for t in range(1, 9):
            led.switch_schedule(g2 if t % 2 else g1)
            led.record_gossip(100.0, t=t)
        totals[W] = led.rewire_time_s
        busy[W] = led.node_busy_s.copy()
        # conservation: lan + wan covers every priced float, with the
        # re-wiring control-plane floats booked too
        assert led.view().total_floats == pytest.approx(
            led.lan_floats + led.wan_floats)
        assert led.view().rewire_floats > 0
    assert totals[4] == pytest.approx(totals[1]), totals
    # forfeited balances land on the endpoints' busy accounting too, so
    # per-node busy/idle stays comparable across amortize_window values
    np.testing.assert_allclose(busy[4], busy[1], rtol=1e-9)


def test_amortize_window_validation():
    with pytest.raises(AssertionError):
        CommLedger(ring(4), LINK_PROFILES["uniform"],
                   config=FabricConfig(amortize_window=0))


# ---------------------------------------------------------------------------
# EWMA measured costs
# ---------------------------------------------------------------------------

def test_ewma_measured_cost_converges_to_sampling_mean():
    """The per-edge EWMA price converges to the model's true sampling
    mean: a median-1 lognormal with sigma s has mean exp(s^2/2), so the
    measured seconds/float approaches exp(s^2/2)/bandwidth."""
    prof = LINK_PROFILES["datacenter"]
    sigma = 0.3
    lm = LinkModel(prof, seed=5, jitter=sigma)
    led = CommLedger(ring(4), prof, link_model=lm, ewma_alpha=0.05)
    for t in range(800):
        led.record_gossip(1e4, t=t)
    expect = float(np.exp(sigma ** 2 / 2)) / prof.lan_bandwidth
    for e in led.topology.edges:
        got = led.view().measured_price_per_float(e, "lan")
        assert abs(got - expect) / expect < 0.2, (e, got, expect)


def test_measured_costs_fall_back_to_profile_until_observed():
    prof = LINK_PROFILES["geo-wan"]
    lm = LinkModel(prof, seed=0, jitter=0.4)
    led = CommLedger(hier6 := ring_plus(6, (0, 3), "wan"), prof,
                     link_model=lm)
    # nothing observed yet: measured == profile-derived exactly
    m = 1e6
    assert led.view().measured_full_exchange_cost(m) == pytest.approx(
        led.view().full_exchange_cost(m))
    assert led.view().measured_full_exchange_time(m) == pytest.approx(
        led.view().full_exchange_time(m))
    for t in range(50):
        led.record_gossip(1e4, t=t)
    # after observations the measured denominator departs the constants
    assert led.view().measured_full_exchange_cost(m) != pytest.approx(
        led.view().full_exchange_cost(m), rel=1e-6)
    assert len(hier6.edges) == len(led.topology.edges)


def test_sync_window_numerator_matches_measured_cm_currency():
    """Sync C(θ) under a link model is priced in *sampled* currency
    (floats at each activation's sampled bandwidth): slowdowns inflate
    it over the constant-priced cost, zero rates reproduce it exactly,
    and the window/CM ratio is therefore unit-consistent with the
    EWMA-measured denominator instead of systematically deflated."""
    from repro.core.skewscout import SkewScout
    prof = LINK_PROFILES["datacenter"]
    lm = LinkModel(prof, seed=3, straggler_rate=0.2,
                   straggler_slowdown=25.0)
    led = CommLedger(ring(6), prof, link_model=lm)
    for t in range(60):
        led.record_gossip(1e4, t=t)
    assert led.view().sampled_priced_cost > 1.5 * led.view().priced_cost
    scout = SkewScout(CommConfig(strategy="gaia", skewscout=True),
                      "gaia", 1000, lambda *a: 0.0, ledger=led)
    assert scout._ledger_cost() == led.view().sampled_priced_cost
    # zero rates: sampled currency degenerates to the constant pricing
    led0 = CommLedger(ring(6), prof, link_model=LinkModel(prof, seed=3))
    led0.record_gossip(1e4, t=0)
    assert led0.view().sampled_priced_cost == led0.view().priced_cost


def test_skewscout_cm_uses_measured_costs_under_link_model():
    """With a link model on the ledger, the scout's CM denominator must
    re-price from the EWMA measured costs on the pinned fabric."""
    from repro.core.skewscout import SkewScout
    prof = LINK_PROFILES["geo-wan"]
    lm = LinkModel(prof, seed=2, jitter=0.4)
    fabric = ring_plus(6, (0, 3), "wan")
    led = CommLedger(fabric, prof, link_model=lm)
    comm = CommConfig(strategy="gaia", skewscout=True)
    scout = SkewScout(comm, "gaia", 1000, lambda *a: 0.0, ledger=led,
                      cm_fabric=fully_connected(6))
    before = scout._cm()
    assert before == pytest.approx(
        led.view().measured_full_exchange_cost(1000.0,
                                        fabric=fully_connected(6)))
    for t in range(40):
        led.record_gossip(1e4, t=t)
    # the denominator tracked the observations (no pinned constant)
    assert scout._cm() != pytest.approx(before, rel=1e-6)
    assert scout._cm() == pytest.approx(
        led.view().measured_full_exchange_cost(1000.0,
                                        fabric=fully_connected(6)))


# ---------------------------------------------------------------------------
# clique assignment: shared helper, explicit seed, link-seed isolation
# ---------------------------------------------------------------------------

def test_greedy_clique_assignment_shared_and_seeded():
    """Both D-Cliques builders route through the one public helper: the
    same (hist, seed) yields the same cliques, an explicit precomputed
    assignment overrides, and a different seed may differ."""
    hist = exclusive_hist(10, 5)
    asg = greedy_clique_assignment(hist, seed=0)
    assert d_cliques(hist, seed=0).cliques == \
        tuple(tuple(c) for c in asg)
    tv = time_varying_d_cliques(hist, seed=0)
    assert tv.at(0).cliques == tuple(tuple(c) for c in asg)
    # explicit assignment wins over the seed
    override = [sorted(range(0, 5)), sorted(range(5, 10))]
    topo = d_cliques(hist, seed=123, cliques=override)
    assert topo.cliques == tuple(tuple(c) for c in override)


def test_link_model_draws_cannot_perturb_clique_assignment():
    """The stochastic link model draws from keyed streams, not the
    global/default RNG state — interleaving link sampling with clique
    building must not change the assignment."""
    hist = exclusive_hist(9, 3)
    clean = greedy_clique_assignment(hist, seed=0)
    prof = LINK_PROFILES["geo-wan"]
    lm = LinkModel(prof, seed=0, jitter=0.5, straggler_rate=0.3)
    led = CommLedger(ring(9), prof, link_model=lm)
    led.record_gossip(1e5, t=0)              # burn link-model draws
    assert greedy_clique_assignment(hist, seed=0) == clean
    led.record_gossip(1e5, t=1)
    assert d_cliques(hist, seed=0).cliques == \
        tuple(tuple(c) for c in clean)


# ---------------------------------------------------------------------------
# config plumbing + end-to-end acceptance
# ---------------------------------------------------------------------------

def test_make_link_model_registry():
    prof = LINK_PROFILES["uniform"]
    assert make_link_model(LinkConfig(), prof) is None
    lm = make_link_model(LinkConfig(model="sampled", jitter=0.2,
                                    straggler_rate=0.1), prof, seed=4)
    assert isinstance(lm, LinkModel) and lm.seed == 4
    assert lm.jitter == 0.2 and lm.straggler_rate == 0.1
    with pytest.raises(ValueError, match="link_model"):
        make_link_model(LinkConfig(model="quantum"), prof)


def test_trainer_straggler_async_beats_sync_at_equal_accuracy():
    """Acceptance: straggler_rate > 0 on an otherwise-LAN fabric —
    async AD-PSGD's simulated wall-clock is strictly below sync
    D-PSGD's at accuracy within noise, end-to-end through the trainer,
    and the run reports its straggler/jitter extras."""
    from repro.configs.cnn_zoo import CNN_ZOO
    from repro.core.trainer import train_decentralized
    from repro.data.synthetic import synth_images
    n_nodes, n_classes = 6, 3
    ds = synth_images(360, seed=0, n_classes=n_classes)
    parts = []
    for k in range(n_nodes):
        i = np.where(ds.y == k % n_classes)[0][k // n_classes::2]
        parts.append((ds.x[i], ds.y[i]))
    steps, runs = 12, {}
    for name, async_gossip in (("dpsgd", False), ("adpsgd", True)):
        comm = CommConfig(
            strategy=name,
            fabric=FabricConfig(
                topology="ring", profile="datacenter",
                link=LinkConfig(model="sampled", straggler_rate=0.2,
                                straggler_slowdown=25.0)),
            async_gossip=async_gossip, max_staleness=2)
        runs[name] = train_decentralized(
            CNN_ZOO["gn-lenet"], name, parts, (ds.x, ds.y), comm=comm,
            steps=steps, batch=5, eval_every=steps)
    sync, asy = runs["dpsgd"], runs["adpsgd"]
    assert asy.sim_time_s < sync.sim_time_s, \
        (asy.sim_time_s, sync.sim_time_s)
    assert asy.val_acc > sync.val_acc - 0.15, (asy.val_acc, sync.val_acc)
    for r in (sync, asy):
        lmx = r.extras["link_model"]
        assert lmx["straggler_rate"] == 0.2
        assert lmx["activations"] > 0
        assert 0.0 <= lmx["slow_fraction"] <= 1.0
    assert sync.extras["link_model"]["slow_activations"] > 0
    # zero-rate sampled trainer run must price like the constant ledger
    base, samp = {}, {}
    for tag, link_model in (("const", "constant"), ("samp", "sampled")):
        comm = CommConfig(strategy="dpsgd",
                          fabric=FabricConfig(
                              topology="ring", profile="datacenter",
                              link=LinkConfig(model=link_model)))
        r = train_decentralized(
            CNN_ZOO["gn-lenet"], "dpsgd", parts, (ds.x, ds.y), comm=comm,
            steps=3, batch=5, eval_every=3)
        (base if tag == "const" else samp).update(
            sim=r.sim_time_s, wan=r.comm_wan_floats,
            lan=r.comm_lan_floats)
    assert samp["sim"] == base["sim"]
    assert samp["lan"] == base["lan"] and samp["wan"] == base["wan"]


def test_ledger_summary_reports_link_and_amortization_state():
    prof = LINK_PROFILES["geo-wan"]
    lm = LinkModel(prof, seed=0, jitter=0.1, straggler_rate=0.05)
    led = CommLedger(ring(6), prof, link_model=lm,
                     config=FabricConfig(amortize_window=3))
    led.record_gossip(1e4, t=0)
    s = led.summary()
    assert s["amortize_window"] == 3.0
    assert s["link_straggler_rate"] == pytest.approx(0.05)
    assert s["link_activations"] > 0
    assert "pending_handshake_s" in s


def test_dataclass_replace_keeps_link_knobs():
    comm = CommConfig(fabric=FabricConfig(
        link=LinkConfig(model="sampled", straggler_rate=0.3),
        amortize_window=5))
    c2 = dataclasses.replace(
        comm, fabric=dataclasses.replace(comm.fabric, topology="ring"))
    assert c2.fabric.topology == "ring"
    assert c2.fabric.link.model == "sampled"
    assert c2.fabric.link.straggler_rate == 0.3
    assert c2.fabric.amortize_window == 5
