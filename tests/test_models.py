"""Model-component correctness: SSD vs naive recurrence, RG-LRU scan vs
step-by-step, MLA absorbed decode vs full attention, MoE properties,
prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (AttentionConfig, MoEConfig, RGLRUConfig,
                                SSMConfig)
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Mamba-2 SSD: chunked dual form == naive sequential recurrence
# ---------------------------------------------------------------------------

def naive_ssd(x, dt, a_log, B_in, C_in, D):
    Bsz, T, h, p = x.shape
    n = B_in.shape[-1]
    A = -np.exp(np.asarray(a_log))
    S = np.zeros((Bsz, h, p, n))
    ys = np.zeros((Bsz, T, h, p))
    x, dt, B_in, C_in = map(np.asarray, (x, dt, B_in, C_in))
    for t in range(T):
        decay = np.exp(dt[:, t] * A)                      # (B,h)
        xd = x[:, t] * dt[:, t][..., None]                # (B,h,p)
        S = decay[:, :, None, None] * S + np.einsum(
            "bn,bhp->bhpn", B_in[:, t], xd)
        ys[:, t] = np.einsum("bn,bhpn->bhp", C_in[:, t], S)
    ys += np.asarray(x) * np.asarray(D)[None, None, :, None]
    return ys, S


@pytest.mark.parametrize("T,chunk", [(32, 8), (64, 16), (48, 48)])
def test_ssd_chunked_matches_naive(T, chunk):
    Bsz, h, p, n = 2, 3, 4, 5
    x = jax.random.normal(KEY, (Bsz, T, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (Bsz, T, h)))
    a_log = jnp.zeros((h,))
    B_in = jax.random.normal(jax.random.PRNGKey(2), (Bsz, T, n))
    C_in = jax.random.normal(jax.random.PRNGKey(3), (Bsz, T, n))
    D = jnp.ones((h,))
    y, S = ssm_mod.ssd_chunked(x, dt, a_log, B_in, C_in, D, chunk=chunk)
    y_ref, S_ref = naive_ssd(x, dt, a_log, B_in, C_in, D)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(S), S_ref, atol=1e-4, rtol=1e-4)


def test_ssm_prefill_decode_consistency():
    """Running ssm_apply over T tokens == T ssm_decode steps."""
    s = SSMConfig(d_state=8, d_conv=4, expand=2, n_heads=4, head_dim=8,
                  chunk=8)
    d_model = 16
    p = ssm_mod.init_ssm(KEY, s, d_model, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d_model)) * 0.5
    full = ssm_mod.ssm_apply(p, s, d_model, x)
    state = ssm_mod.ssm_init_state(s, d_model, 2, jnp.float32)
    outs = []
    for t in range(16):
        o, state = ssm_mod.ssm_decode(p, s, d_model, x[:, t:t + 1], state)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def test_rglru_scan_matches_stepwise():
    r = RGLRUConfig(lru_width=16, d_conv=4)
    d_model = 12
    p = rglru_mod.init_rglru(KEY, r, d_model, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, d_model))
    full = rglru_mod.rglru_apply(p, r, x)
    state = rglru_mod.rglru_init_state(r, d_model, 2, jnp.float32)
    outs = []
    for t in range(10):
        o, state = rglru_mod.rglru_decode(p, r, x[:, t:t + 1], state)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               atol=1e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# Attention: prefill/decode consistency, MLA absorbed decode
# ---------------------------------------------------------------------------

def test_gqa_prefill_decode_consistency():
    a = AttentionConfig(kind="gqa", n_heads=4, n_kv_heads=2, head_dim=16)
    d_model = 32
    p = attn_mod.init_gqa(KEY, a, d_model, jnp.float32)
    T = 12
    x = jax.random.normal(jax.random.PRNGKey(1), (2, T, d_model))
    positions = jnp.broadcast_to(jnp.arange(T), (2, T))
    full = attn_mod.gqa_apply(p, a, x, window=None, positions=positions,
                              chunk=4)
    cache = attn_mod.gqa_init_cache(a, 2, T, jnp.float32)
    outs = []
    for t in range(T):
        o, cache = attn_mod.gqa_decode(p, a, x[:, t:t + 1], cache,
                                       jnp.full((2,), t))
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               atol=1e-4, rtol=1e-3)


def test_gqa_ring_buffer_equals_sliding_window():
    """A ring buffer of W slots == sliding-window attention of width W."""
    a = AttentionConfig(kind="gqa", n_heads=2, n_kv_heads=2, head_dim=8,
                        sliding_window=4, layer_pattern=("local",))
    d_model = 16
    p = attn_mod.init_gqa(KEY, a, d_model, jnp.float32)
    T, W = 12, 4
    x = jax.random.normal(jax.random.PRNGKey(1), (1, T, d_model))
    positions = jnp.broadcast_to(jnp.arange(T), (1, T))
    full = attn_mod.gqa_apply(p, a, x, window=W, positions=positions,
                              chunk=4)
    cache = attn_mod.gqa_init_cache(a, 1, W, jnp.float32)   # W slots only
    outs = []
    for t in range(T):
        o, cache = attn_mod.gqa_decode(p, a, x[:, t:t + 1], cache,
                                       jnp.full((1,), t))
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               atol=1e-4, rtol=1e-3)


def test_mla_absorbed_decode_matches_prefill():
    a = AttentionConfig(kind="mla", n_heads=4, n_kv_heads=4, head_dim=32,
                        q_lora_rank=16, kv_lora_rank=8, rope_head_dim=8,
                        nope_head_dim=16, v_head_dim=16)
    d_model = 32
    p = attn_mod.init_mla(KEY, a, d_model, jnp.float32)
    T = 10
    x = jax.random.normal(jax.random.PRNGKey(1), (2, T, d_model))
    positions = jnp.broadcast_to(jnp.arange(T), (2, T))
    full = attn_mod.mla_apply(p, a, x, positions=positions, chunk=5)
    cache = attn_mod.mla_init_cache(a, 2, T, jnp.float32)
    outs = []
    for t in range(T):
        o, cache = attn_mod.mla_decode(p, a, x[:, t:t + 1], cache,
                                       jnp.full((2,), t))
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def test_moe_output_finite_and_aux_positive():
    m = MoEConfig(n_experts=4, n_shared=1, top_k=2, d_ff_expert=16,
                  capacity_factor=2.0)
    p = moe_mod.init_moe(KEY, m, 8, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 8))
    y, aux = moe_mod.moe_apply(p, m, x)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) > 0.0


def test_moe_capacity_drops_tokens_gracefully():
    m = MoEConfig(n_experts=2, n_shared=0, top_k=1, d_ff_expert=8,
                  capacity_factor=0.1)       # absurdly low capacity
    p = moe_mod.init_moe(KEY, m, 8, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 8))
    y, aux = moe_mod.moe_apply(p, m, x)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_moe_grad_flows_to_router():
    m = MoEConfig(n_experts=4, n_shared=0, top_k=2, d_ff_expert=8)
    p = moe_mod.init_moe(KEY, m, 8, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 8))

    def loss(p):
        y, aux = moe_mod.moe_apply(p, m, x)
        return jnp.sum(y ** 2) + aux
    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]["w"]).sum()) > 0.0
