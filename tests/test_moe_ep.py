"""shard_map expert-parallel MoE == dense MoE (subprocess: own device count)."""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_moe_ep_matches_dense():
    script = os.path.join(os.path.dirname(__file__), "moe_ep_script.py")
    out = subprocess.run([sys.executable, script], capture_output=True,
                         text=True, timeout=600)
    assert "EP_MOE_OK" in out.stdout, out.stdout + "\n" + out.stderr
