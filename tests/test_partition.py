"""Property-based tests (hypothesis) for the label-skew partitioner — the
system invariants every experiment depends on.

Deterministic (no-hypothesis) partitioner tests live in
``test_partition_basic.py`` so minimal installs still cover them."""
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis; the "
                           "deterministic ones run in "
                           "test_partition_basic.py")
from hypothesis import given, settings, strategies as st

from repro.core.partition import (label_distribution,
                                  partition_label_skew, skew_index)


@st.composite
def labels_and_nodes(draw):
    n_classes = draw(st.integers(2, 10))
    n_nodes = draw(st.integers(2, min(5, n_classes)))
    n = draw(st.integers(n_classes * n_nodes * 4, 600))
    y = draw(st.lists(st.integers(0, n_classes - 1), min_size=n, max_size=n))
    y = np.asarray(y, np.int64)
    # ensure every class is present so partitions are non-degenerate
    y[:n_classes] = np.arange(n_classes)
    return y, n_nodes


@st.composite
def balanced_labels_and_nodes(draw):
    n_classes = draw(st.integers(2, 10))
    n_nodes = draw(st.integers(2, min(5, n_classes)))
    per = draw(st.integers(n_nodes * 4, 60))
    y = np.repeat(np.arange(n_classes), per)
    rng = np.random.default_rng(draw(st.integers(0, 100)))
    rng.shuffle(y)
    return y.astype(np.int64), n_nodes


@given(labels_and_nodes(), st.floats(0.0, 1.0), st.integers(0, 5))
@settings(max_examples=40, deadline=None)
def test_partition_is_exact_cover(args, skew, seed):
    y, n_nodes = args
    parts = partition_label_skew(y, n_nodes, skew, seed=seed)
    all_idx = np.concatenate(parts)
    assert len(all_idx) == len(y)
    assert len(np.unique(all_idx)) == len(y)          # disjoint + complete


@given(labels_and_nodes(), st.integers(0, 5))
@settings(max_examples=25, deadline=None)
def test_full_skew_gives_disjoint_label_sets(args, seed):
    y, n_nodes = args
    parts = partition_label_skew(y, n_nodes, 1.0, seed=seed)
    label_sets = [set(np.unique(y[p])) for p in parts]
    for i in range(n_nodes):
        for j in range(i + 1, n_nodes):
            assert not (label_sets[i] & label_sets[j])


@given(balanced_labels_and_nodes(), st.integers(0, 3))
@settings(max_examples=25, deadline=None)
def test_skew_index_monotone_in_skew(args, seed):
    y, n_nodes = args
    vals = [skew_index(y, partition_label_skew(y, n_nodes, s, seed=seed))
            for s in (0.0, 0.5, 1.0)]
    # tolerance scales with sampling noise (TV of an n-sample empirical
    # distribution fluctuates ~ 1/sqrt(samples-per-node))
    tol = 0.1 + 2.0 / np.sqrt(len(y) / n_nodes)
    assert vals[0] <= vals[1] + tol
    assert vals[1] <= vals[2] + tol
    assert vals[2] >= 0.45        # full label skew is very skewed


@given(labels_and_nodes())
@settings(max_examples=20, deadline=None)
def test_iid_partition_label_distributions_close(args):
    y, n_nodes = args
    parts = partition_label_skew(y, n_nodes, 0.0, seed=0)
    dist = label_distribution(y, parts)
    glob = np.bincount(y, minlength=dist.shape[1]) / len(y)
    assert np.abs(dist - glob).max() < 0.35


