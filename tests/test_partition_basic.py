"""Deterministic partitioner tests — no hypothesis dependency, so they
run on minimal installs (the property-based variants live in
``test_partition.py`` behind ``pytest.importorskip``)."""
import numpy as np

from repro.core.partition import (label_distribution, partition_80_20,
                                  partition_by_region, partition_label_skew,
                                  skew_index)


def test_partition_80_20():
    y = np.repeat(np.arange(10), 100)
    parts = partition_80_20(y, 10, major=0.8, seed=0)
    assert sum(len(p) for p in parts) == len(y)
    dist = label_distribution(y, parts)
    for k in range(10):
        assert abs(dist[k, k] - 0.8) < 0.05
        assert abs(dist[k, (k - 1) % 10] - 0.2) < 0.05


def test_partition_by_region():
    region = np.asarray([0, 1, 2, 0, 1, 2, 0])
    parts = partition_by_region(region, 3)
    assert [len(p) for p in parts] == [3, 2, 2]


def test_label_skew_exact_cover_and_monotone_skew():
    rng = np.random.default_rng(0)
    y = rng.integers(0, 10, size=1000)
    y[:10] = np.arange(10)
    vals = []
    for s in (0.0, 0.5, 1.0):
        parts = partition_label_skew(y, 5, s, seed=3)
        all_idx = np.concatenate(parts)
        assert len(all_idx) == len(y)
        assert len(np.unique(all_idx)) == len(y)
        vals.append(skew_index(y, parts))
    assert vals[0] < vals[1] < vals[2]
    assert vals[2] > 0.45
