"""SkewScout mechanism tests: tuner behaviour on the Eq.1 objective, and the
travel/adapt loop against synthetic accuracy-loss landscapes."""

import numpy as np
import pytest

from repro.configs.base import CommConfig
from repro.core.skewscout import SkewScout, THETA_LADDERS
from repro.core.tuners import HillClimb, make_tuner


def run_tuner(tuner, objective, steps=30):
    for _ in range(steps):
        tuner.step(objective(tuner.i))
    return tuner.i


def test_hillclimb_finds_minimum_of_unimodal():
    ladder = list(range(10))
    obj = lambda i: (i - 7) ** 2
    t = HillClimb(ladder, start_index=1)
    assert run_tuner(t, obj) == 7


def test_hillclimb_memoizes():
    ladder = list(range(5))
    calls = []
    t = HillClimb(ladder, start_index=2)
    for _ in range(10):
        calls.append(t.i)
        t.step(float((t.i - 0) ** 2))
    assert t.i == 0


def test_stochastic_and_anneal_reach_good_region():
    ladder = list(range(12))
    obj = lambda i: abs(i - 3)
    for kind in ("stochastic", "anneal"):
        t = make_tuner(kind, ladder, start_index=10, seed=1)
        final = run_tuner(t, obj, steps=60)
        assert abs(final - 3) <= 2, (kind, final)


class FakeAlgo:
    """Accuracy loss landscape: higher theta index -> less comm -> more
    divergence -> bigger home/away gap."""
    K = 2

    def __init__(self, scout):
        self.scout = scout

    def node_params(self, state, k):
        return ("p", "s")


def test_skewscout_tightens_under_high_loss_and_relaxes_under_low():
    comm = CommConfig(skewscout=True, travel_every=1, sigma_al=0.05,
                      lambda_al=50.0, lambda_c=1.0)

    for landscape, expect_low in (("steep", True), ("flat", False)):
        idx_holder = {}

        def eval_acc(params, mstate, x, y):
            # home acc 0.9; away acc depends on theta index via closure
            i = idx_holder["scout"].tuner.i
            n = len(THETA_LADDERS["gaia"])
            if landscape == "steep":
                gap = 0.6 * i / (n - 1)          # relaxed theta -> 60% loss
            else:
                gap = 0.0                        # IID-like: no loss anywhere
            return 0.9 - (gap if y == "away" else 0.0)

        scout = SkewScout(comm, "gaia", model_floats=1000,
                          eval_acc_fn=eval_acc, start_index=4)
        idx_holder["scout"] = scout
        algo = FakeAlgo(scout)

        def sample_subset(node):
            return ("x", "away" if node != 0 else "home")

        # pretend home node == node polled first each probe:
        def sample(node):
            return ("x", "home") if sample.call % 2 == 0 else ("x", "away")
        for step in range(40):
            # comm cost grows as theta tightens (lower index = more comm)
            scout.record_step(comm_floats=1000 / (scout.tuner.i + 1))
            def subset(node, _s=scout):
                return ("x", "home")
            # emulate: home eval then away eval per node
            calls = {"n": 0}
            def eval2(params, mstate, x, y, _i=scout.tuner.i):
                calls["n"] += 1
                home = calls["n"] % 2 == 1
                n = len(THETA_LADDERS["gaia"])
                gap = (0.6 * _i / (n - 1)) if landscape == "steep" else 0.0
                return 0.9 if home else 0.9 - gap
            scout.eval_acc = eval2
            scout.maybe_travel(step, algo, None, lambda node: ("x", "y"))
        final = scout.tuner.i
        if expect_low:
            assert final <= 2, (landscape, final)      # tightened comm
        else:
            assert final >= 5, (landscape, final)      # relaxed comm


def test_skewscout_topology_rung_trades_edges():
    """Topology as a theta rung: under a steep accuracy-loss landscape
    (sparser fabric -> more divergence) the controller climbs toward the
    dense end; when skew costs nothing it relaxes toward the sparse end,
    trading edges for bandwidth."""
    from repro.topology import topology_ladder

    ladder = topology_ladder(6)             # full -> ... -> ring
    n = len(ladder)
    comm = CommConfig(skewscout=True, travel_every=1, sigma_al=0.05,
                      lambda_al=50.0, lambda_c=1.0)

    class A:
        K = 2
        def node_params(self, state, k):
            return None, None

    for landscape, expect_dense in (("steep", True), ("flat", False)):
        scout = SkewScout(comm, "dpsgd", model_floats=1000,
                          eval_acc_fn=lambda p, s, x, y: 0.9,
                          start_index=n // 2, ladder=ladder)
        for step in range(30):
            sched = scout.theta             # a TopologySchedule rung
            edges = np.mean([len(sched.at(r).edges)
                             for r in range(sched.period)])
            scout.record_step(comm_floats=100.0 * edges)
            calls = {"n": 0}
            def eval2(params, mstate, x, y, _i=scout.tuner.i):
                calls["n"] += 1
                home = calls["n"] % 2 == 1
                gap = (0.6 * _i / (n - 1)) if landscape == "steep" else 0.0
                return 0.9 if home else 0.9 - gap
            scout.eval_acc = eval2
            scout.maybe_travel(step, A(), None, lambda node: ("x", "y"))
        if expect_dense:
            assert scout.tuner.i == 0, (landscape, scout.tuner.i)
            assert scout.theta.at(0).name == "full"
        else:
            assert scout.tuner.i == n - 1, (landscape, scout.tuner.i)
            assert scout.theta.at(0).name == "ring"


def test_travel_report_fields():
    comm = CommConfig(skewscout=True, travel_every=2)
    scout = SkewScout(comm, "fedavg", model_floats=100,
                      eval_acc_fn=lambda p, s, x, y: 0.8, start_index=3)

    class A:
        K = 2
        def node_params(self, state, k):
            return None, None
    scout.record_step(10.0)
    assert scout.maybe_travel(0, A(), None, lambda n: (None, None)) is None
    scout.record_step(10.0)
    rep = scout.maybe_travel(1, A(), None, lambda n: (None, None))
    assert rep is not None
    assert rep.accuracy_loss == 0.0                 # equal home/away acc
    assert rep.comm_ratio == pytest.approx(0.1)
    assert len(scout.history) == 1
    # no fabric at all: legacy ring route, one probe per node
    assert rep.probe_edges == ((0, 1), (0, 1))
    assert rep.probe_floats == pytest.approx(2 * 100)


# ---------------------------------------------------------------------------
# probe routing + probe booking (schedule-aware model traveling)
# ---------------------------------------------------------------------------

class GossipStub:
    """An algo that exposes a fabric, like DPSGD, without any training."""
    def __init__(self, schedule):
        from repro.topology import as_schedule
        self.schedule = as_schedule(schedule)
        self.K = self.schedule.n_nodes

    def node_params(self, state, k):
        return None, None


def tv_sched(n_nodes=9, n_classes=3):
    from repro.topology import time_varying_d_cliques
    hist = np.zeros((n_nodes, n_classes))
    for k in range(n_nodes):
        hist[k, k % n_classes] = 100
    return time_varying_d_cliques(hist, seed=0)


def make_scout(algo, ledger=None, travel_every=1, warmup=1):
    comm = CommConfig(skewscout=True, travel_every=travel_every)
    return SkewScout(comm, "fedavg", model_floats=1000,
                     eval_acc_fn=lambda p, s, x, y: 0.9, start_index=3,
                     ledger=ledger, warmup_travels=warmup)


def test_probes_follow_the_rounds_active_edges():
    """Bugfix: probes must travel links that exist in the round's graph
    (falling back to union neighbors on isolated nodes), never the
    hardcoded (k+1) % K ring."""
    sched = tv_sched()
    algo = GossipStub(sched)
    scout = make_scout(algo)
    union_edges = set(sched.union().edges)
    for step in range(sched.period):
        scout.record_step(10.0)
        rep = scout.maybe_travel(step, algo, None, lambda n: (None, None))
        active = set(sched.at(step).edges)
        active_nodes = {v for e in sched.at(step).edges for v in e}
        assert len(rep.probe_edges) == algo.K
        for e in rep.probe_edges:
            # active edge when the node has one, union edge otherwise
            assert e in active or e in union_edges, (step, e)
        # nodes with an active edge this round probed along it
        k_on_active = [e for e in rep.probe_edges if e in active]
        assert len(k_on_active) >= len(active_nodes)
    # the ring would have produced (k, k+1) edges most of which are not
    # even on the union fabric
    ring_edges = {(k, (k + 1) % 9) for k in range(9)}
    ring_edges = {(min(a, b), max(a, b)) for a, b in ring_edges}
    assert not ring_edges <= union_edges


def test_probe_rotation_covers_neighbors_across_travels():
    from repro.topology import fully_connected
    algo = GossipStub(fully_connected(4))
    scout = make_scout(algo)
    seen = set()
    for step in range(3):
        scout.record_step(1.0)
        rep = scout.maybe_travel(step, algo, None, lambda n: (None, None))
        seen.update(rep.probe_edges)
    assert len(seen) > 3      # successive travels rotate probe targets


def test_probe_traffic_is_booked_on_the_ledger():
    """Bugfix: each probe's model shipment lands on the edge it crossed
    — total floats, LAN/WAN split, and the per-edge dict all see it, and
    C(θ) windows price it."""
    from repro.topology import CommLedger, LINK_PROFILES
    sched = tv_sched()
    algo = GossipStub(sched)
    ledger = CommLedger(sched, LINK_PROFILES["geo-wan"])
    scout = make_scout(algo, ledger=ledger)
    scout.record_step(0.0)
    rep = scout.maybe_travel(0, algo, None, lambda n: (None, None))
    assert ledger.view().total_floats == pytest.approx(rep.probe_floats)
    assert ledger.view().total_floats == pytest.approx(
        ledger.lan_floats + ledger.wan_floats)
    by_edge = ledger.view().traffic_map()
    for e in set(rep.probe_edges):
        assert by_edge[e] >= 1000
    # the probe's own cost is part of the measured window: with zero
    # training traffic the window is exactly the probe shipment
    assert rep.comm_ratio > 0


def test_travel_overhead_excludes_warmup_probes():
    """Bugfix: measure-only warm-up travels are not overhead charged to
    θ (their traffic is still booked on the ledger)."""
    algo = GossipStub(tv_sched())
    scout = make_scout(algo, warmup=2)
    for step in range(4):
        scout.record_step(1.0)
        scout.maybe_travel(step, algo, None, lambda n: (None, None))
    assert len(scout.history) == 4
    expected = sum(r.probe_floats for r in scout.history[2:])
    assert scout.travel_overhead_floats() == pytest.approx(expected)
    assert scout.travel_overhead_floats() < sum(
        r.probe_floats for r in scout.history)
