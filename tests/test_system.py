"""End-to-end behaviour tests for the paper's system: short real training
runs asserting the paper's qualitative claims hold in this implementation.

These are the fastest versions of the claims that still discriminate —
the full-scale versions live in benchmarks/ (fig1/fig5/fig6/fig8)."""
import numpy as np
import pytest

from repro.configs.base import CommConfig
from repro.configs.cnn_zoo import CNN_ZOO
from repro.core import partition_label_skew, train_decentralized
from repro.core.skewscout import THETA_LADDERS
from repro.data.synthetic import synth_images

STEPS = 250
TRAIN = dict(steps=STEPS, batch=20, lr=0.02, eval_every=STEPS)


@pytest.fixture(scope="module")
def data():
    ds = synth_images(2500, seed=0, noise=0.8, class_sep=0.35)
    val = synth_images(600, seed=99, noise=0.8, class_sep=0.35)
    return ds, val


def _run(data, model, algo, skew, comm=None, **kw):
    ds, val = data
    idx = partition_label_skew(ds.y, 5, skew, seed=1)
    parts = [(ds.x[i], ds.y[i]) for i in idx]
    args = dict(TRAIN)
    args.update(kw)
    return train_decentralized(CNN_ZOO[model], algo, parts,
                               (val.x, val.y), comm=comm or CommConfig(),
                               **args)


@pytest.mark.slow
def test_bsp_iid_baseline_learns(data):
    r = _run(data, "gn-lenet", "bsp", 0.0)
    assert r.val_acc > 0.9, r.val_acc


@pytest.mark.slow
def test_noniid_hurts_fedavg_but_not_iid(data):
    """Paper Fig 1: same theta retains accuracy IID, loses it non-IID."""
    comm = CommConfig(iter_local=20)
    iid = _run(data, "gn-lenet", "fedavg", 0.0, comm)
    non = _run(data, "gn-lenet", "fedavg", 1.0, comm)
    assert iid.val_acc > 0.85, iid.val_acc
    assert non.val_acc < iid.val_acc - 0.05, (iid.val_acc, non.val_acc)


@pytest.mark.slow
def test_gaia_saves_communication_at_iid_quality(data):
    comm = CommConfig(gaia_t0=0.10)
    r = _run(data, "gn-lenet", "gaia", 0.0, comm)
    assert r.val_acc > 0.85
    assert r.comm_savings > 5.0, r.comm_savings


@pytest.mark.slow
def test_skewscout_tightens_theta_under_skew(data):
    """Paper §7: under heavy skew the controller should walk theta toward
    more communication (lower Gaia T0) relative to its start."""
    comm = CommConfig(skewscout=True, travel_every=30, sigma_al=0.05)
    r = _run(data, "gn-lenet", "gaia", 1.0, comm, theta_start_index=5)
    assert r.skewscout_history, "no travel happened"
    start = THETA_LADDERS["gaia"][5]
    final = r.skewscout_history[-1].new_theta
    assert final <= start, (start, final)


@pytest.mark.slow
def test_skewscout_relaxes_theta_when_iid(data):
    comm = CommConfig(skewscout=True, travel_every=30, sigma_al=0.05)
    r = _run(data, "gn-lenet", "gaia", 0.0, comm, theta_start_index=1)
    assert r.skewscout_history
    start = THETA_LADDERS["gaia"][1]
    final = r.skewscout_history[-1].new_theta
    assert final >= start, (start, final)


@pytest.mark.slow
def test_bn_minibatch_divergence_larger_under_skew(data):
    """Paper Fig 4 mechanism, as a direct probe."""
    import jax
    from repro.core.divergence import bn_divergence
    from repro.data.pipeline import DecentralizedLoader
    from repro.models.cnn import init_cnn
    ds, _ = data
    cfg = CNN_ZOO["bn-lenet"]
    params, _ = init_cnn(jax.random.PRNGKey(0), cfg)
    divs = {}
    for skew in (0.0, 1.0):
        idx = partition_label_skew(ds.y, 2, skew, seed=1)
        loader = DecentralizedLoader([(ds.x[i], ds.y[i]) for i in idx],
                                     batch=20, seed=0)
        acc = None
        for _ in range(30):
            xs, _ = loader.next_stacked()
            mu_d, _ = bn_divergence(params, cfg, list(xs), layer=0)
            acc = mu_d if acc is None else acc + mu_d
        divs[skew] = float(np.mean(acc / 30))
    assert divs[1.0] > 1.5 * divs[0.0], divs
